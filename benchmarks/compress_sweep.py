"""Codec x channel sweep: compressed split-learning payloads vs fp32.

Runs the faithful CNN simulator (FedSim) once per (codec, channel) cell
with the SAME codec applied in the literal dataflow (cut activations,
gradients, offloads — so accuracy pays the real quantization price) and in
the wireless byte accounting (so the scheduler prices the bits the
numerics pay), and emits a JSON table: accuracy, scheduled/participation
rates, round time, total bits moved.

The acceptance bar of ISSUE 4, checked in-run on the deterministic static
channel (and at test scale in tests/test_compress.py): int8 activations
STRICTLY increase PARTICIPATION over fp32 at the same fixed deadline and
energy budget, without ever being scheduled less.  At the default settings
the contended fp32 uplink (10 Mbps effective) cannot move the payload
inside the 1 s deadline: under the deadline-capped energy gate (ISSUE 5)
those clients are still scheduled — they can afford the capped charge —
but every transmission is cut off and discarded until the budget drains,
so fp32 burns its whole budget moving bits that never complete, while
int8's ~4x smaller payload finishes inside the deadline and aggregates.
(Before ISSUE 5 the uncapped gate barred fp32 from transmitting at all,
and scheduled_rate doubled as the bar; with the corrected straggler
semantics, scheduling no longer implies useful work.)

``--dry-run`` skips training and drives the ParticipationScheduler alone
(same channel, same byte accounting) — seconds, not minutes; the tier-1
smoke test and CI invoke this mode so the benchmark cannot rot.

    PYTHONPATH=src python benchmarks/compress_sweep.py \
        [--channels static rayleigh] [--deadline 1.0] [--rounds 2] \
        [--dry-run] [--out compress_sweep.json]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.compress import link_codecs
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.configs.sweeps import (sweep_hierarchy, sweep_train,
                                  sweep_wireless)
from repro.core.comm import comm_for_cnn
from repro.core.fedsim import FedSim
from repro.core.hierarchy import es_assignment
from repro.data.synthetic import make_federated_image_data
from repro.wireless import make_scheduler

CODECS = ("fp32", "int8", "int4", "topk", "fp8")


def _wireless(channel: str, *, deadline: float, es_uplink_mbps: float,
              energy_budget: float, seed: int):
    return sweep_wireless(channel, deadline_s=deadline,
                          es_uplink_mbps=es_uplink_mbps,
                          energy_budget_j=energy_budget, seed=seed)


def _codecs_for(codec: str, topk_frac: float):
    return None if codec == "fp32" else link_codecs(codec,
                                                    topk_frac=topk_frac)


def _summarize(codec, channel, network, h, extra):
    parts = [n["participants"] for n in network] or [0]
    # FedSim rows carry the scheduled COUNT, to_json_dict rows the (U,)
    # bool list — np.sum collapses both to the count
    sched = [np.sum(n["scheduled"]) for n in network] or [0]
    times = [n["round_time_s"] for n in network] or [0.0]
    bits = [n.get("bits", n.get("bits_tx", 0.0)) for n in network] or [0.0]
    return {
        "codec": codec, "channel": channel,
        "participation_rate": float(np.mean(parts)) / h.num_clients,
        "scheduled_rate": float(np.mean(sched)) / h.num_clients,
        "mean_round_time_s": float(np.mean(times)),
        "total_bits": float(np.sum(bits)), **extra,
    }


def run_one(fed, codec: str, channel: str, *, deadline: float, rounds: int,
            es_uplink_mbps: float, energy_budget: float, seed: int,
            topk_frac: float) -> dict:
    """One full cell: real training with the codec in the dataflow."""
    h = sweep_hierarchy(rounds)
    t = sweep_train()
    sim = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=2, seed=seed,
                 wireless=_wireless(channel, deadline=deadline,
                                    es_uplink_mbps=es_uplink_mbps,
                                    energy_budget=energy_budget, seed=seed),
                 codecs=_codecs_for(codec, topk_frac))
    res = sim.run(rounds=rounds, log_every=rounds)
    return _summarize(codec, channel, res.network, h, {
        "deadline_s": deadline,
        "final_loss": res.history[-1]["test_loss"],
        "final_acc": res.history[-1]["test_acc"],
        "total_sim_time_s": res.total_sim_time_s,
    })


def dry_run_one(codec: str, channel: str, *, deadline: float, rounds: int,
                es_uplink_mbps: float, energy_budget: float, seed: int,
                topk_frac: float) -> dict:
    """Scheduler-only cell: same channel + byte accounting, no training."""
    h = sweep_hierarchy(rounds)
    comm = comm_for_cnn(CNN_CFG, dataset_size=400,
                        batch_size=sweep_train().batch_size,
                        batches_per_epoch=2,
                        codecs=_codecs_for(codec, topk_frac))
    sched = make_scheduler(
        _wireless(channel, deadline=deadline, es_uplink_mbps=es_uplink_mbps,
                  energy_budget=energy_budget, seed=seed),
        h.num_clients, comm, h.kappa0,
        es_assign=es_assignment(h.num_clients, h.clients_per_es))
    network = [sched.step(r).to_json_dict()
               for r in range(rounds * h.kappa1)]
    return _summarize(codec, channel, network, h,
                      {"deadline_s": deadline, "dry_run": True})


def sweep(fed, channels, *, dry_run: bool = False, **kw) -> list[dict]:
    return [dry_run_one(c, ch, **kw) if dry_run
            else run_one(fed, c, ch, **kw)
            for ch in channels for c in CODECS]


def check_acceptance(table, channels) -> bool:
    """int8 must STRICTLY beat fp32 on PARTICIPATION (and never be
    scheduled less) on the static channel; other channels are reported but
    not enforced (fading can be kind at some seeds).  Scheduling alone is
    no longer the bar: the deadline-capped energy gate schedules fp32
    stragglers too — they just never complete (see module docstring)."""
    ok = True
    for ch in channels:
        rows = {r["codec"]: r for r in table if r["channel"] == ch}
        fp, q = rows["fp32"], rows["int8"]
        better = (q["participation_rate"] > fp["participation_rate"]
                  and q["scheduled_rate"] >= fp["scheduled_rate"])
        flag = "OK " if better else ("FAIL" if ch == "static" else "warn")
        print(f"[{flag}] {ch}: int8 part {q['participation_rate']:.3f} / "
              f"scheduled {q['scheduled_rate']:.3f} vs fp32 "
              f"{fp['participation_rate']:.3f} / {fp['scheduled_rate']:.3f}")
        if ch == "static" and not better:
            ok = False
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--channels", nargs="+", default=["static", "rayleigh"],
                    choices=["static", "rayleigh"])
    ap.add_argument("--deadline", type=float, default=1.0)
    ap.add_argument("--es-uplink-mbps", type=float, default=40.0)
    ap.add_argument("--energy-budget", type=float, default=1.0)
    ap.add_argument("--topk-frac", type=float, default=0.05)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true",
                    help="scheduler-only sweep: no training, seconds")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    fed = None
    if not args.dry_run:
        fed = make_federated_image_data(8, alpha=args.alpha,
                                        train_per_class=40,
                                        test_per_class=20, seed=args.seed)
    table = sweep(fed, args.channels, dry_run=args.dry_run,
                  deadline=args.deadline, rounds=args.rounds,
                  es_uplink_mbps=args.es_uplink_mbps,
                  energy_budget=args.energy_budget, seed=args.seed,
                  topk_frac=args.topk_frac)
    print(json.dumps(table, indent=2))
    ok = check_acceptance(table, args.channels)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
    if not ok:
        raise SystemExit("ACCEPTANCE FAILED: int8 did not strictly "
                         "increase participation over fp32")
    return table


if __name__ == "__main__":
    main()
