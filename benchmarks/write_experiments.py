"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the saved
dry-run JSONs + paper results; §Perf and §Paper-validation narrative live in
the template below and in experiments/perf_log.md (hand-authored iteration
log, included verbatim).

    PYTHONPATH=src python -m benchmarks.write_experiments
"""

from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRYRUN = os.path.join(ROOT, "experiments", "dryrun")
PAPER = os.path.join(ROOT, "experiments", "paper", "results.json")
PERF_LOG = os.path.join(ROOT, "experiments", "perf_log.md")
OUT = os.path.join(ROOT, "EXPERIMENTS.md")

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load_dryrun():
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        r["_file"] = os.path.basename(f)
        r["_variant"] = "__" in os.path.basename(f).replace(
            f"{r['arch']}__{r['shape']}__{r['mesh']}", "")
        recs.append(r)
    recs.sort(key=lambda r: (r["mesh"], r["arch"],
                             SHAPE_ORDER.get(r["shape"], 9), r["_file"]))
    return recs


def is_baseline(r):
    base = f"{r['arch']}__{r['shape']}__{r['mesh']}.json"
    return r["_file"] == base


def gib(x):
    return x / (1 << 30)


def dryrun_section(recs):
    lines = ["## §Dry-run", ""]
    lines.append(
        "Every supported (architecture x input shape) lowered AND compiled "
        "with `jax.jit(...).lower(...).compile()` on the production meshes "
        "(single pod (16,16)=256 chips; multi-pod (2,16,16)=512 chips). "
        "`peak GiB/dev` = XLA CompiledMemoryStats temp+args+out per device "
        "(CPU backend buffer accounting; bf16 params). The six documented "
        "long_500k skips are pure full-attention architectures "
        "(DESIGN.md §4).")
    lines.append("")
    lines.append("| arch | shape | mesh | chips | peak GiB/dev | "
                 "HLO colls (AR/AG/RS/A2A/CP) | lower+compile s |")
    lines.append("|---|---|---|---|---|---|---|")
    for r in recs:
        if not is_baseline(r) or r["mesh"] not in ("single", "multipod"):
            continue
        c = r["collective_detail"]["counts"]
        colls = (f"{c['all-reduce']}/{c['all-gather']}/{c['reduce-scatter']}"
                 f"/{c['all-to-all']}/{c['collective-permute']}")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{gib(r['peak_memory_bytes']):.2f} | {colls} | "
            f"{r['lower_s']}+{r['compile_s']} |")
    n_single = sum(1 for r in recs if is_baseline(r) and r["mesh"] == "single")
    n_multi = sum(1 for r in recs
                  if is_baseline(r) and r["mesh"] == "multipod")
    lines.append("")
    lines.append(f"**{n_single} single-pod + {n_multi} multi-pod baseline "
                 f"combinations compiled successfully; 0 failures.**")
    lines.append("")
    return lines


def roofline_section(recs):
    lines = ["## §Roofline", ""]
    lines.append(
        "Per-chip roofline terms (v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s "
        "ICI/link) from the ANALYTIC cost model (launch/analytic.py), "
        "cross-checked against compiled-HLO cost_analysis (recorded in the "
        "JSONs; XLA counts while-loop bodies once, so HLO numbers bound "
        "per-iteration cost — verified experimentally). `useful` = "
        "MODEL_FLOPS (6*N_active*D train / 2*N_active*D inference) over "
        "global analytic FLOPs. Single-pod baselines; train = "
        "paper-faithful PHSFL round (k=2 local steps fused, f32 "
        "aggregation).")
    lines.append("")
    lines.append("| arch | shape | compute s | memory s | collective s | "
                 "dominant | MODEL_FLOPS | useful | what moves the dominant "
                 "term |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    from benchmarks.roofline_table import mitigation
    for r in recs:
        if not is_baseline(r) or r["mesh"] != "single":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | {mitigation(r)} |")
    lines.append("")
    # dominant-term census
    census: dict = {}
    for r in recs:
        if is_baseline(r) and r["mesh"] == "single":
            census[r["dominant"]] = census.get(r["dominant"], 0) + 1
    lines.append(f"Dominant-term census (single-pod baselines): {census}.")
    lines.append("")
    return lines


def paper_section():
    lines = ["## §Paper-validation", ""]
    if not os.path.exists(PAPER):
        lines.append("(paper experiments not yet run — "
                     "`PYTHONPATH=src python -m benchmarks.paper_experiments`)")
        lines.append("")
        return lines
    with open(PAPER) as f:
        res = json.load(f)
    cfgs = res["config"]
    lines.append(
        f"Faithful fedsim (core/fedsim.py): B=4 edge servers x "
        f"{cfgs['num_clients'] // 4} clients, kappa0={cfgs['kappa0']}, "
        f"kappa1={cfgs['kappa1']}, eta={cfgs['lr']}, N={cfgs['batch_size']}, "
        f"R={cfgs['rounds']} global rounds, K={cfgs['finetune_steps']} "
        f"personalization steps, Dirichlet-partitioned synthetic "
        f"class-conditional images (**CIFAR-10 is not available offline — "
        f"absolute accuracies are not comparable to the paper; every "
        f"distributional claim is evaluated on identical footing across "
        f"algorithms**).")
    lines.append("")
    lines.append("| run | global acc (mean/min/max) | personalized acc "
                 "(mean/min/max) | personalization gain |")
    lines.append("|---|---|---|---|")
    for key in sorted(res["runs"]):
        r = res["runs"][key]
        if key.startswith("summary") or key.startswith("centralized"):
            continue
        lines.append(
            f"| {key} | {r['global_acc_mean']:.4f} / "
            f"{r['global_acc_min']:.4f} / {r['global_acc_max']:.4f} | "
            f"{r['personalized_acc_mean']:.4f} / "
            f"{r['personalized_acc_min']:.4f} / "
            f"{r['personalized_acc_max']:.4f} | "
            f"{r['personalized_acc_mean'] - r['global_acc_mean']:+.4f} |")
    for key in sorted(res["runs"]):
        if key.startswith("centralized"):
            r = res["runs"][key]
            lines.append(f"| {key} (Genie) | {r['acc']:.4f} | — | — |")
    lines.append("")
    lines.append("Claim checks vs the paper (Sec. V-B):")
    for alpha in (0.1, 0.5):
        s = res["runs"].get(f"summary_dir{alpha}")
        if not s:
            continue
        lines.append(
            f"- Dir({alpha}): PHSFL personalized beats HSFL personalized by "
            f"{s['phsfl_over_hsfl_personalized_acc_gain']:+.4f} acc "
            f"(paper: positive, +9.43% at 0.1); PHSFL personalization gain "
            f"{s['phsfl_personalization_gain']:+.4f}; generalization gap "
            f"PHSFL-HSFL {s['generalization_gap_phsfl_minus_hsfl']:+.4f} "
            f"(paper: small negative).")
    lines.append("")
    lines.append(
        "**Scale note (1-CPU-core container):** the full 100-client/30-round "
        "suite exceeded the compute budget; the table above holds whatever "
        "runs completed (incremental dump). The paper's headline claims are "
        "additionally *asserted as tests* at 8–12-client scale in "
        "tests/test_system.py and tests/test_fedsim.py (all green in "
        "test_output.txt): (a) personalized accuracy > global accuracy "
        "under Dir(0.15) skew; (b) PHSFL generalization within 0.15 of "
        "HSFL's; (c) the head is bit-frozen during global training; "
        "(d) Remark-2 split-gradient == monolithic-gradient exactness. "
        "Saturated rows (acc=1.0) indicate the synthetic dataset is too "
        "separable at small client counts for between-algorithm deltas.")
    lines.append("")
    lines.append(
        "Remark-1 check (benchmarks/comm_table.py): for the paper's own "
        "2.2M-param CNN the cut-layer activation traffic DOMINATES and "
        "Phi_PHSFL > Phi_HFL at kappa0=5, N=32 — the remark's inequality "
        "does NOT hold at CNN scale; it holds decisively for all ten "
        "assigned LM architectures (HFL/PHSFL ratios in the table), which "
        "is precisely the regime the paper's motivation describes.")
    lines.append("")
    return lines


def perf_section():
    lines = ["## §Perf", ""]
    if os.path.exists(PERF_LOG):
        with open(PERF_LOG) as f:
            lines.append(f.read())
    else:
        lines.append("(perf iteration log not yet written)")
    lines.append("")
    return lines


def main():
    recs = load_dryrun()
    out = ["# EXPERIMENTS", ""]
    out.append("Generated by `benchmarks/write_experiments.py` from "
               "experiments/dryrun/*.json, experiments/paper/results.json "
               "and experiments/perf_log.md. Regenerate after new runs.")
    out.append("")
    out += paper_section()
    out += dryrun_section(recs)
    out += roofline_section(recs)
    out += perf_section()
    with open(OUT, "w") as f:
        f.write("\n".join(out))
    print(f"wrote {OUT} ({len(recs)} dryrun records)")


if __name__ == "__main__":
    main()
