"""Theorem-1 bound table: how each knob moves the convergence bound.

Sweeps eta, kappa0, kappa1 and the weighting scheme at the paper's topology
(B=4, U_b=25) and prints each additive term of Eq. (21).
"""

from __future__ import annotations

import numpy as np

from repro.core import BoundInputs, bound_terms, lr_limit, uniform_weights


def sweep() -> list[dict]:
    au, ab = uniform_weights(4, 25)
    base = dict(beta=1.0, sigma2=1.0, eps0_2=0.5, eps1_2=0.5, T=1500,
                f0_minus_fT=2.0, alpha_u=au, alpha_b=ab)
    rows = []
    for eta in (0.001, 0.005, 0.01):
        for (k0, k1) in ((5, 3), (10, 3), (5, 6), (1, 1)):
            bi = BoundInputs(eta=eta, kappa0=k0, kappa1=k1, **base)
            t = bound_terms(bi)
            rows.append({"eta": eta, "kappa0": k0, "kappa1": k1,
                         "lr_limit": lr_limit(1.0, k0, k1), **t})
    return rows


def main():
    hdr = ("eta", "kappa0", "kappa1", "eta_ok", "optimality",
           "sgd_variance", "eps0_divergence", "eps1_divergence", "total")
    print(" ".join(f"{h:>16s}" for h in hdr))
    for r in sweep():
        print(" ".join(
            f"{r[h]:16.3e}" if isinstance(r[h], float) else f"{str(r[h]):>16s}"
            for h in hdr))


if __name__ == "__main__":
    main()
