"""Population-scale scheduling wall time: 10**3 -> 10**6 registered clients.

The cohort refactor (``repro.wireless.population``) rewrote the per-round
decision path as two fused jit stages over struct-of-arrays client state,
with per-round cohort sampling and k-means ES placement on top.  This
bench measures what that bought: for each population size it registers N
clients, builds a :class:`CohortScheduler` on a contended Rayleigh
scenario (8 edge servers, k-means placement, energy budgets, deadline),
and times scheduled rounds — the BUILD cost, the first round (jit
compile), and the steady-state mean — while the whole registry's channel,
energy, and participation state advances every round.

The acceptance bar of the population ISSUE, checked in-run at full scale:
a 10**6-client round schedules in single-digit SECONDS on one CPU
(steady-state, compile excluded).

``--dry-run`` shrinks the population list to its sub-10**4 prefix —
seconds, not minutes; the tier-1 smoke test and CI invoke this mode so
the benchmark cannot rot.

    PYTHONPATH=src python benchmarks/cohort_bench.py \
        [--populations 1000 10000 100000 1000000] [--cohort-size 512] \
        [--rounds 5] [--sampling pareto] [--dry-run] [--out BENCH.json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.base import WirelessConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.comm import comm_for_cnn
from repro.wireless.population import Population, make_cohort_scheduler

NUM_ES = 8


def _wireless(channel: str, seed: int) -> WirelessConfig:
    """A deliberately busy scenario: contended shared uplinks, per-client
    fading, finite energy, a binding deadline — every gate and the
    conditional reshare stay live at all N."""
    return WirelessConfig(model=channel, mean_uplink_mbps=25.0,
                          mean_downlink_mbps=100.0, latency_s=0.01,
                          deadline_s=2.0, energy_budget_j=500.0,
                          tx_power_w=0.7, heterogeneity=0.5,
                          es_uplink_mbps=800.0, contention="proportional",
                          seed=seed)


def bench_one(population: int, *, cohort_size: int, rounds: int,
              channel: str, sampling: str, seed: int,
              dry_run: bool = False) -> dict:
    """Register ``population`` clients, schedule ``rounds + 1`` rounds,
    report build / compile / steady-state wall times."""
    comm = comm_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                        batches_per_epoch=1)
    k = min(cohort_size, population)

    t0 = time.perf_counter()
    pop = Population(population, num_es=NUM_ES, seed=seed,
                     assignment="kmeans")
    sched = make_cohort_scheduler(_wireless(channel, seed), population,
                                  comm, 1, population=pop, cohort_size=k,
                                  sampling=sampling)
    build_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    rep = sched.step(0)                      # jit compile + first round
    warmup_s = time.perf_counter() - t0
    parts = [rep.num_participants]
    steady = []
    for r in range(1, rounds + 1):
        t0 = time.perf_counter()
        rep = sched.step(r)
        steady.append(time.perf_counter() - t0)
        parts.append(rep.num_participants)
    row = {"name": f"N={population}", "population": population,
           "cohort_size": k, "rounds": rounds,
           "participation_rate": float(np.mean(parts)) / k,
           "build_s": round(build_s, 4),
           "warmup_s": round(warmup_s, 4),
           "wall_s_per_round": round(float(np.mean(steady)), 4),
           "wall_s_per_round_max": round(float(np.max(steady)), 4)}
    if dry_run:
        row["dry_run"] = True
    return row


def check_acceptance(table) -> bool:
    """The largest measured population schedules a steady-state round in
    single-digit seconds on CPU."""
    biggest = max(table, key=lambda r: r["population"])
    wall = biggest["wall_s_per_round"]
    good = wall < 10.0
    print(f"[{'OK ' if good else 'FAIL'}] N={biggest['population']} "
          f"steady-state round {wall:.3f}s < 10s")
    return good


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--populations", type=int, nargs="+",
                    default=[1000, 10000, 100000, 1000000],
                    help="registered-client counts to sweep")
    ap.add_argument("--cohort-size", type=int, default=512,
                    help="clients sampled (and scheduled at gate 1) per "
                         "round; capped at the population size")
    ap.add_argument("--rounds", type=int, default=5,
                    help="steady-state rounds timed per population (one "
                         "extra warmup round pays the jit compile)")
    ap.add_argument("--channels", default="rayleigh", dest="channel",
                    choices=["static", "rayleigh"],
                    help="per-client channel model")
    ap.add_argument("--sampling", default="pareto",
                    choices=list(Population.SAMPLING),
                    help="cohort sampling rule")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true",
                    help="sub-10**4 populations only: seconds, no files")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    pops = sorted(set(args.populations))
    if args.dry_run:
        pops = [p for p in pops if p < 10_000] or [min(pops)]
    table = [bench_one(p, cohort_size=args.cohort_size, rounds=args.rounds,
                       channel=args.channel, sampling=args.sampling,
                       seed=args.seed, dry_run=args.dry_run) for p in pops]
    print(json.dumps(table, indent=2))
    ok = check_acceptance(table)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
    if not ok:
        raise SystemExit("ACCEPTANCE FAILED: population-scale round over "
                         "the single-digit-seconds bar")
    return table


if __name__ == "__main__":
    main()
