"""Aggregate experiments/dryrun/*.json into the §Roofline / §Dry-run tables.

Usage: PYTHONPATH=src python -m benchmarks.roofline_table [--markdown]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

MITIGATION = {
    ("compute",): "shard more / flash-kernel block-skip to cut masked-"
                  "rectangle waste",
    ("memory",): "fuse / widen arithmetic intensity; decode: batch more "
                 "sequences per chip",
    ("collective",): "lower aggregation frequency (raise kappa0) or switch "
                     "to shared-server mode (client-block-only all-reduce)",
}


def load(pattern: str = "*") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"{pattern}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def mitigation(rec: dict) -> str:
    dom = rec["dominant"]
    if dom == "collective" and rec["shape"].startswith("train"):
        return MITIGATION[("collective",)]
    if dom == "collective":
        return "keep params resident (TP-only serving layout) to kill the " \
               "FSDP all-gather"
    return MITIGATION[(dom,)]


def fmt_row(r: dict) -> str:
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('train_mode') or '-'} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['model_flops']:.3e} | {r['useful_flops_ratio']:.2f} | "
            f"{mitigation(r)} |")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--pattern", default="*")
    args = ap.parse_args(argv)
    recs = load(args.pattern)
    if args.markdown:
        print("| arch | shape | mesh | mode | compute s | memory s | "
              "collective s | dominant | MODEL_FLOPS | useful ratio | "
              "what moves the dominant term |")
        print("|" + "---|" * 11)
        for r in recs:
            print(fmt_row(r))
    else:
        print(f"{'arch':24s} {'shape':12s} {'mesh':8s} {'dom':10s} "
              f"{'compute_s':>11s} {'memory_s':>11s} {'coll_s':>11s} "
              f"{'useful':>7s}")
        for r in recs:
            print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
                  f"{r['dominant']:10s} {r['compute_s']:11.3e} "
                  f"{r['memory_s']:11.3e} {r['collective_s']:11.3e} "
                  f"{r['useful_flops_ratio']:7.2f}")
    print(f"\n{len(recs)} records")


if __name__ == "__main__":
    main()
