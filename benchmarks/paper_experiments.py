"""Paper reproduction driver (Figs. 1-4 + Sec. V-B numbers).

Runs PHSFL and the HSFL baseline on Dirichlet-partitioned synthetic
federated image data (CIFAR-10 is not available offline; see EXPERIMENTS.md
§Paper-validation for the comparability caveat), plus the centralized Genie
baseline, at Dir(0.1) and Dir(0.5).  Reports:

  - Fig. 1 analogue: per-client test-accuracy dispersion of the global model
    (mean / max / min);
  - Figs. 3-4 analogue: global vs personalized accuracy per algorithm and
    skew level;
  - Sec. V-B analogue: PHSFL-vs-HSFL personalized improvement.

The paper's full scale is U=100, B=4, kappa0=5, kappa1=3, R=100, eta=0.01,
N=32.  Defaults below use the same topology with fewer rounds/minibatches
(CPU budget); pass --full for the paper's schedule.

Usage: PYTHONPATH=src python -m benchmarks.paper_experiments [--rounds R]
Writes experiments/paper/results.json.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.configs.base import HierarchyConfig, TrainConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.fedsim import FedSim, centralized_sgd
from repro.data.synthetic import make_federated_image_data

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "paper")


def run_suite(*, rounds: int, batches_per_epoch: int, num_clients: int,
              num_es: int, kappa0: int, kappa1: int, lr: float,
              batch_size: int, finetune_steps: int, seed: int,
              alphas=(0.1, 0.5), train_per_class: int = 500,
              test_per_class: int = 100, dump_path: str | None = None) -> dict:
    results: dict = {"config": {
        "rounds": rounds, "batches_per_epoch": batches_per_epoch,
        "num_clients": num_clients, "num_es": num_es, "kappa0": kappa0,
        "kappa1": kappa1, "lr": lr, "batch_size": batch_size,
        "finetune_steps": finetune_steps, "seed": seed,
        "dataset": "synthetic class-conditional (no CIFAR-10 offline)",
    }, "runs": {}}

    for alpha in alphas:
        data = make_federated_image_data(
            num_clients, alpha=alpha, train_per_class=train_per_class,
            test_per_class=test_per_class, seed=seed)
        h = HierarchyConfig(num_edge_servers=num_es,
                            clients_per_es=num_clients // num_es,
                            kappa0=kappa0, kappa1=kappa1,
                            global_rounds=rounds)
        for algo, freeze in (("phsfl", True), ("hsfl", False)):
            t0 = time.time()
            t = TrainConfig(learning_rate=lr, batch_size=batch_size,
                            freeze_head=freeze,
                            finetune_steps=finetune_steps, finetune_lr=lr)
            sim = FedSim(CNN_CFG, data, h, t,
                         batches_per_epoch=batches_per_epoch, seed=seed)
            res = sim.run(rounds=rounds, log_every=max(rounds // 4, 1))
            heads, per = sim.personalize(res.global_params)
            g = res.per_client_global
            rec = {
                "alpha": alpha, "algo": algo,
                "history": res.history,
                # Fig. 1 analogue: dispersion of the global model
                "global_acc_mean": float(g["acc"].mean()),
                "global_acc_max": float(g["acc"].max()),
                "global_acc_min": float(g["acc"].min()),
                "global_loss_mean": float(g["loss"].mean()),
                # Figs. 3-4 analogue
                "personalized_acc_mean": float(per["acc"].mean()),
                "personalized_acc_max": float(per["acc"].max()),
                "personalized_acc_min": float(per["acc"].min()),
                "personalized_loss_mean": float(per["loss"].mean()),
                "wall_s": round(time.time() - t0, 1),
            }
            results["runs"][f"{algo}_dir{alpha}"] = rec
            if dump_path:  # incremental dump so partial results survive
                with open(dump_path, "w") as f:
                    json.dump(results, f, indent=1)
            print(f"[paper] {algo} Dir({alpha}): global "
                  f"{rec['global_acc_mean']:.4f} "
                  f"(min {rec['global_acc_min']:.4f} / max "
                  f"{rec['global_acc_max']:.4f})  personalized "
                  f"{rec['personalized_acc_mean']:.4f}  "
                  f"[{rec['wall_s']}s]", flush=True)

        # centralized Genie (once per alpha's dataset)
        t = TrainConfig(learning_rate=lr, batch_size=batch_size)
        _, genie = centralized_sgd(CNN_CFG, data, t,
                                   epochs=max(rounds // 10, 2), seed=seed)
        results["runs"][f"centralized_dir{alpha}"] = genie
        print(f"[paper] centralized Dir({alpha}): acc {genie['acc']:.4f}",
              flush=True)

    # derived headline numbers (Sec. V-B analogues)
    for alpha in alphas:
        p = results["runs"][f"phsfl_dir{alpha}"]
        hh = results["runs"][f"hsfl_dir{alpha}"]
        results["runs"][f"summary_dir{alpha}"] = {
            "phsfl_over_hsfl_personalized_acc_gain":
                p["personalized_acc_mean"] - hh["personalized_acc_mean"],
            "phsfl_personalization_gain":
                p["personalized_acc_mean"] - p["global_acc_mean"],
            "hsfl_personalization_gain":
                hh["personalized_acc_mean"] - hh["global_acc_mean"],
            "generalization_gap_phsfl_minus_hsfl":
                p["global_acc_mean"] - hh["global_acc_mean"],
        }
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--batches-per-epoch", type=int, default=2)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--full", action="store_true",
                    help="paper schedule: R=100, 5 minibatches/epoch")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(OUT, "results.json"))
    args = ap.parse_args(argv)

    rounds = 100 if args.full else args.rounds
    bpe = 5 if args.full else args.batches_per_epoch
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    res = run_suite(rounds=rounds, batches_per_epoch=bpe,
                    num_clients=args.clients, num_es=4, kappa0=5, kappa1=3,
                    lr=0.01 if args.full else 0.02, batch_size=32,
                    finetune_steps=10, seed=args.seed, dump_path=args.out)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)
    print(f"[paper] wrote {args.out}")


if __name__ == "__main__":
    main()
