"""Recompute the analytic roofline fields of every experiments/dryrun JSON
with the CURRENT launch/analytic.py cost model (the HLO fields from the
actual compile are preserved untouched).

Needed because the analytic model evolved during the sweeps (attention
baseline switched from optimistic causal-half to the masked-rectangle cost
that matches the pure-JAX implementation); this keeps the whole table
consistent without re-lowering 70+ combos.

    PYTHONPATH=src python -m benchmarks.recompute_analytic
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.configs.shapes import SHAPES
from repro.launch.analytic import cost_for
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, model_flops_for

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

MESH_SHAPES = {
    "single": {"data": 16, "model": 16},
    "multipod": {"pod": 2, "data": 16, "model": 16},
    "alt32x8": {"data": 32, "model": 8},
}


def variant_kwargs(fname: str, rec: dict) -> dict:
    kw: dict = {}
    if "__shared_server" in fname:
        kw["mode"] = "shared_server"
    if "__tp" in fname and "__fsdp" not in fname:
        kw["param_mode"] = "tp"
    if "__aggbfloat16" in fname:
        kw["agg_dtype_bytes"] = 2
    tc = {}
    if "__noremat" in fname:
        tc["remat"] = False
    if "__remat_dots" in fname:
        tc["remat_policy"] = "dots"
    if "__k4" in fname:
        tc["local_steps_in_step"] = 4
    if tc:
        kw["tcfg"] = TrainConfig(**tc)
    return kw


def main():
    n = 0
    for f in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(f) as fh:
            rec = json.load(fh)
        fname = os.path.basename(f)
        cfg = get_arch(rec["arch"])
        shape = SHAPES[rec["shape"]]
        mesh_shape = MESH_SHAPES[rec["mesh"]]
        kw = variant_kwargs(fname, rec)
        ac = cost_for(cfg, shape, mesh_shape, **kw)
        rec["flops_per_chip"] = ac.flops
        rec["hbm_bytes_per_chip"] = ac.hbm_bytes
        rec["collective_bytes_per_chip"] = ac.coll_bytes
        rec["analytic_detail"] = ac.detail
        rec["compute_s"] = ac.flops / PEAK_FLOPS
        rec["memory_s"] = ac.hbm_bytes / HBM_BW
        rec["collective_s"] = ac.coll_bytes / ICI_BW
        terms = {"compute": rec["compute_s"], "memory": rec["memory_s"],
                 "collective": rec["collective_s"]}
        rec["dominant"] = max(terms, key=terms.get)
        rec["model_flops"] = model_flops_for(cfg, shape, shape.kind)
        total = ac.flops * rec["chips"]
        rec["useful_flops_ratio"] = rec["model_flops"] / total if total else 0
        with open(f, "w") as fh:
            json.dump(rec, fh, indent=1)
        n += 1
    print(f"recomputed {n} records")


if __name__ == "__main__":
    main()
