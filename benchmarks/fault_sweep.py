"""Fault-injection sweep: erasure_prob x recovery policy, graceful or not.

The fault subsystem (``repro.wireless.faults``) claims graceful
degradation: erased payloads retransmit (HARQ) as honestly-priced timeline
segments, HARQ-exhausted updates flow into the staleness bank instead of
vanishing, and every retransmitted bit/joule is visible in the accounting.
This sweep puts a number on each claim.  The grid is erasure_prob in
{0, 0.15, 0.3} x three recovery policies — the ONLY config deltas per row:

- ``no-retry``:   max_retries=0, staleness_lambda=0 — a lost payload is a
                  lost round (hard drop, the strawman);
- ``harq``:       max_retries=3 — retransmit up to 3 times, still hard-drop
                  what exhausts its retries or misses the deadline;
- ``harq+stale``: max_retries=3, staleness_lambda=0.5 — retries PLUS the
                  bank: what still fails delivers late and discounted.

Each cell reports live participation, EFFECTIVE participation (live +
stale deliveries), mean round time, total air bits, and the retransmit
overhead (``retx_bits``, ``retx_j``) the HARQ policies pay for their
robustness; full runs add final loss/accuracy.  The in-run acceptance bar
(the fault-injection ISSUE), checked on the deterministic static channel:

1. at erasure_prob=0.3 under the finite deadline, ``harq+stale`` EFFECTIVE
   participation strictly exceeds ``no-retry`` participation — retries +
   late delivery rescue what hard drop loses;
2. every cell's retransmit overhead is reported (zero-erasure cells pay
   exactly zero).

``--dry-run`` drives the ParticipationScheduler alone (no training) with
rows taken straight from ``RoundReport.to_json_dict()`` — seconds, not
minutes; tier-1 CI smokes this mode.

    PYTHONPATH=src python benchmarks/fault_sweep.py \
        [--deadline 4.0] [--crash-hazard 0.0] [--rounds 2] [--dry-run] \
        [--out BENCH_faults.json]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.base import FaultConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.configs.sweeps import sweep_hierarchy, sweep_train, sweep_wireless
from repro.core.comm import comm_table_for_cnn
from repro.core.fedsim import FedSim
from repro.core.hierarchy import es_assignment
from repro.data.synthetic import make_federated_image_data
from repro.wireless import make_scheduler

# (policy name, max_retries, staleness_lambda): the only per-policy deltas
POLICIES = (("no-retry", 0, 0.0), ("harq", 3, 0.0), ("harq+stale", 3, 0.5))
ERASURES = (0.0, 0.15, 0.3)


def _wireless(retries: int, lam: float, erasure: float, *, channel: str,
              deadline: float, crash_hazard: float, seed: int):
    """One cell's scenario: the shared sweep channel + a finite deadline +
    random thinning (the stale bank delivers only on idle rounds) + the
    cell's fault knobs."""
    return sweep_wireless(
        channel, deadline_s=deadline, selection="random",
        participation_prob=0.8, staleness_lambda=lam,
        faults=FaultConfig(erasure_prob=erasure, max_retries=retries,
                           crash_hazard=crash_hazard),
        seed=seed)


def _stale_count(row) -> int:
    """Deliveries in one network row: FedSim rows carry the count, raw
    ``to_json_dict`` rows the per-client staleness list."""
    v = row.get("stale_delivered") or 0
    if isinstance(v, list):
        return int(sum(1 for s in v if s > 0))
    return int(v)


def _summarize(policy, erasure, network, h, extra):
    parts = [n["participants"] for n in network] or [0]
    times = [n["round_time_s"] for n in network] or [0.0]
    bits = [n.get("bits", n.get("bits_tx", 0.0)) for n in network] or [0.0]
    deliv = [_stale_count(n) for n in network] or [0]
    eff = [p + d for p, d in zip(parts, deliv)]
    return {
        "policy": policy,
        "erasure_prob": erasure,
        "participation_rate": float(np.mean(parts)) / h.num_clients,
        "stale_delivered_per_round": float(np.mean(deliv)),
        "effective_participation_rate": float(np.mean(eff)) / h.num_clients,
        "mean_round_time_s": float(np.mean(times)),
        "total_bits": float(np.sum(bits)),
        "retx_bits": float(np.sum([n.get("retx_bits", 0.0)
                                   for n in network])),
        "retx_j": float(np.sum([n.get("retx_j", 0.0) for n in network])),
        "failed": int(np.sum([np.sum(n.get("failed") or 0)
                              for n in network])),
        "crashed": int(np.sum([np.sum(n.get("crashed") or 0)
                               for n in network])),
        **extra,
    }


def run_one(fed, policy: str, retries: int, lam: float, erasure: float, *,
            rounds: int, seed: int, **kw) -> dict:
    """One full cell: real training under the fault schedule — erasure
    failures bank and fold late, dead downlinks keep local models."""
    h = sweep_hierarchy(rounds)
    t = sweep_train()
    sim = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=2, seed=seed,
                 wireless=_wireless(retries, lam, erasure, seed=seed, **kw))
    res = sim.run(rounds=rounds, log_every=rounds)
    return _summarize(policy, erasure, res.network, h, {
        "final_loss": res.history[-1]["test_loss"],
        "final_acc": res.history[-1]["test_acc"],
        "total_sim_time_s": res.total_sim_time_s,
    })


def dry_run_one(policy: str, retries: int, lam: float, erasure: float, *,
                rounds: int, seed: int, **kw) -> dict:
    """Scheduler-only cell; network rows come straight from
    ``RoundReport.to_json_dict()`` (the same serialization BENCH files
    use, round-trip-tested in tests/test_faults.py)."""
    h = sweep_hierarchy(rounds)
    wireless = _wireless(retries, lam, erasure, seed=seed, **kw)
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400,
                               batch_size=sweep_train().batch_size,
                               batches_per_epoch=2)
    sched = make_scheduler(
        wireless, h.num_clients, kappa0=h.kappa0, comm_table=table,
        es_assign=es_assignment(h.num_clients, h.clients_per_es))
    # the acceptance bar is statistical (bank deliveries land ROUNDS after
    # the failure they rescue), so the cheap scheduler-only sweep drives a
    # floor of edge rounds no matter how small --rounds is
    steps = max(rounds * h.kappa1, 12)
    network = [sched.step(r).to_json_dict() for r in range(steps)]
    return _summarize(policy, erasure, network, h, {"dry_run": True})


def sweep(fed, *, dry_run: bool = False, **kw) -> list[dict]:
    return [dry_run_one(pol, retries, lam, er, **kw) if dry_run
            else run_one(fed, pol, retries, lam, er, **kw)
            for pol, retries, lam in POLICIES for er in ERASURES]


def check_acceptance(table) -> bool:
    """(1) harq+stale effective participation strictly beats no-retry hard
    drop at erasure 0.3; (2) retransmit overhead is reported per cell and
    is exactly zero without erasures."""
    rows = {(r["policy"], r["erasure_prob"]): r for r in table}
    ok = True
    hard = rows[("no-retry", 0.3)]["participation_rate"]
    soft = rows[("harq+stale", 0.3)]["effective_participation_rate"]
    good = soft > hard
    ok &= good
    print(f"[{'OK ' if good else 'FAIL'}] p=0.3 effective participation "
          f"harq+stale {soft:.3f} > no-retry {hard:.3f}")
    for key, r in rows.items():
        has = "retx_bits" in r and "retx_j" in r
        clean = r["erasure_prob"] > 0 or (r["retx_bits"] == 0.0
                                          and r["retx_j"] == 0.0)
        good = has and clean
        ok &= good
        print(f"[{'OK ' if good else 'FAIL'}] {key[0]} p={key[1]:.2f} "
              f"retx overhead {r['retx_bits']:.0f} bits / "
              f"{r['retx_j']:.3f} J")
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--channels", default="static", dest="channel",
                    choices=["static", "rayleigh"],
                    help="channel model shared by all cells")
    ap.add_argument("--deadline", type=float, default=4.0,
                    help="edge-round deadline; finite so HARQ retries can "
                         "straggle and the stale bank has work to do")
    ap.add_argument("--crash-hazard", type=float, default=0.0,
                    help="per-round client crash probability added to "
                         "every cell (0 = erasures only)")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true",
                    help="scheduler-only sweep: no training, seconds")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    fed = None
    if not args.dry_run:
        fed = make_federated_image_data(8, alpha=args.alpha,
                                        train_per_class=40,
                                        test_per_class=20, seed=args.seed)
    table = sweep(fed, dry_run=args.dry_run, channel=args.channel,
                  rounds=args.rounds, seed=args.seed,
                  deadline=args.deadline, crash_hazard=args.crash_hazard)
    print(json.dumps(table, indent=2))
    ok = check_acceptance(table)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
    if not ok:
        raise SystemExit("ACCEPTANCE FAILED: HARQ+staleness did not beat "
                         "hard drop, or retransmit overhead is missing")
    return table


if __name__ == "__main__":
    main()
