"""Compute-heterogeneity x cut-policy sweep: the device model in action.

The wireless simulator priced only *bits* until the device model
(``repro.wireless.device``) landed: a deeper cut ships fewer activation
bits but keeps more layers — more FLOPs — on the client.  This sweep runs
the faithful CNN simulator (FedSim) once per (policy, compute
heterogeneity sigma) cell at a FINITE per-client compute rate and emits a
JSON table: mean chosen cut, participation, round time, compute seconds /
joules, total bits.

The acceptance bar of ISSUE 5, checked in-run on the deterministic static
channel (and at test scale in tests/test_device.py): as compute
heterogeneity rises, the ``deadline`` policy steers the slow-device
clients to SHALLOWER cuts — the mean chosen cut is non-increasing in
sigma and strictly shallower at the highest sigma than with homogeneous
devices.  A bits-only controller (``compute_gflops=inf``) cannot see this
at all: every sigma column would pick the same cut.

``--dry-run`` skips training and drives the ParticipationScheduler alone
(same channel, same byte+FLOP accounting) — seconds, not minutes; the
tier-1 smoke test and CI invoke this mode so the benchmark cannot rot.

    PYTHONPATH=src python benchmarks/device_sweep.py \
        [--compute-gflops 10] [--sigmas 0.0 1.0 2.0] [--deadline 4.0] \
        [--rounds 2] [--dry-run] [--out device_sweep.json]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.configs.sweeps import sweep_hierarchy, sweep_train, sweep_wireless
from repro.core.comm import comm_table_for_cnn
from repro.core.fedsim import FedSim
from repro.core.hierarchy import es_assignment
from repro.data.synthetic import make_federated_image_data
from repro.models.cnn import CUT_CANDIDATES
from repro.wireless import make_scheduler

POLICIES = ("fixed:conv1", "fixed:fc1", "greedy", "deadline")


def _wireless(policy: str, sigma: float, *, channel: str, deadline: float,
              es_uplink_mbps: float, compute_gflops: float,
              compute_power_w: float, seed: int):
    fixed_cut = None
    if policy.startswith("fixed:"):
        fixed_cut = policy.split(":", 1)[1]
        cut_policy, candidates = "fixed", (fixed_cut,)
    else:
        cut_policy, candidates = policy, CUT_CANDIDATES
    return fixed_cut, sweep_wireless(
        channel, deadline_s=deadline, es_uplink_mbps=es_uplink_mbps,
        cut_policy=cut_policy, cut_candidates=candidates,
        compute_gflops=compute_gflops, compute_heterogeneity=sigma,
        compute_power_w=compute_power_w, seed=seed)


def _summarize(policy, sigma, network, h, extra):
    parts = [n["participants"] for n in network] or [0]
    times = [n["round_time_s"] for n in network] or [0.0]
    bits = [n.get("bits", n.get("bits_tx", 0.0)) for n in network] or [0.0]
    cuts = [n["mean_cut"] for n in network
            if n.get("mean_cut") is not None]
    # FedSim rows pre-reduce to compute_s_max / summed compute_j floats;
    # to_json_dict rows carry the raw (U,) lists
    comp = [np.max(n["compute_s"]) if n.get("compute_s") is not None
            else n.get("compute_s_max", 0.0) for n in network] or [0.0]
    cj = [np.sum(n["compute_j"]) if isinstance(n.get("compute_j"), list)
          else n.get("compute_j") or 0.0 for n in network] or [0.0]
    return {
        "policy": policy, "compute_heterogeneity": sigma,
        "participation_rate": float(np.mean(parts)) / h.num_clients,
        "mean_cut": float(np.mean(cuts)) if cuts else 0.0,
        "mean_round_time_s": float(np.mean(times)),
        "max_compute_s": float(np.max(comp)),
        "total_compute_j": float(np.sum(cj)),
        "total_bits": float(np.sum(bits)), **extra,
    }


def _absolute_cut(row, fixed_cut):
    """A fixed policy's controller sees a single-candidate table, so its
    reported mean_cut is position 0 regardless of WHICH cut was pinned;
    rewrite it as the cut's position in the shared CUT_CANDIDATES axis so
    the column is comparable across policies."""
    if fixed_cut is not None:
        row["mean_cut"] = float(CUT_CANDIDATES.index(fixed_cut))
    return row


def run_one(fed, policy: str, sigma: float, *, rounds: int, seed: int,
            **kw) -> dict:
    """One full cell: real training, device-aware wireless accounting."""
    h = sweep_hierarchy(rounds)
    t = sweep_train()
    fixed_cut, wireless = _wireless(policy, sigma, seed=seed, **kw)
    sim = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=2, seed=seed,
                 wireless=wireless, cut=fixed_cut)
    res = sim.run(rounds=rounds, log_every=rounds)
    return _absolute_cut(_summarize(policy, sigma, res.network, h, {
        "final_loss": res.history[-1]["test_loss"],
        "final_acc": res.history[-1]["test_acc"],
        "total_sim_time_s": res.total_sim_time_s,
    }), fixed_cut)


def dry_run_one(policy: str, sigma: float, *, rounds: int, seed: int,
                **kw) -> dict:
    """Scheduler-only cell: same channel + byte/FLOP accounting, no
    training."""
    h = sweep_hierarchy(rounds)
    fixed_cut, wireless = _wireless(policy, sigma, seed=seed, **kw)
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400,
                               batch_size=sweep_train().batch_size,
                               batches_per_epoch=2,
                               cuts=wireless.cut_candidates)
    sched = make_scheduler(
        wireless, h.num_clients, kappa0=h.kappa0, comm_table=table,
        es_assign=es_assignment(h.num_clients, h.clients_per_es),
        fixed_cut=fixed_cut if fixed_cut in table else 0)
    network = [sched.step(r).to_json_dict()
               for r in range(rounds * h.kappa1)]
    return _absolute_cut(_summarize(policy, sigma, network, h,
                                    {"dry_run": True}), fixed_cut)


def sweep(fed, sigmas, *, dry_run: bool = False, **kw) -> list[dict]:
    return [dry_run_one(p, s, **kw) if dry_run else run_one(fed, p, s, **kw)
            for p in POLICIES for s in sigmas]


def check_acceptance(table, sigmas) -> bool:
    """The deadline policy must steer toward SHALLOWER cuts as compute
    heterogeneity rises: mean_cut non-increasing in sigma and strictly
    lower at the top sigma than at sigma=0 (only checkable with a finite
    compute rate — infinite compute makes every column identical)."""
    rows = {r["compute_heterogeneity"]: r for r in table
            if r["policy"] == "deadline"}
    cuts = [rows[s]["mean_cut"] for s in sigmas]
    if len(cuts) < 2:
        print(f"[warn] single sigma {list(sigmas)}: nothing to compare, "
              f"acceptance not evaluated (mean_cut {cuts[0]:.2f})")
        return True
    mono = all(a >= b - 1e-12 for a, b in zip(cuts, cuts[1:]))
    strict = cuts[-1] < cuts[0]
    ok = mono and strict
    print(f"[{'OK ' if ok else 'FAIL'}] deadline mean_cut over sigma "
          f"{list(sigmas)}: {[f'{c:.2f}' for c in cuts]} "
          f"(non-increasing={mono}, strictly shallower at top={strict})")
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--channel", default="static",
                    choices=["static", "rayleigh"])
    ap.add_argument("--sigmas", type=float, nargs="+", default=[0.0, 1.0, 2.0],
                    help="compute-heterogeneity sigmas (sorted ascending "
                         "before the sweep)")
    ap.add_argument("--compute-gflops", type=float, default=10.0)
    ap.add_argument("--compute-power-w", type=float, default=0.2)
    ap.add_argument("--deadline", type=float, default=4.0)
    ap.add_argument("--es-uplink-mbps", type=float, default=40.0)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true",
                    help="scheduler-only sweep: no training, seconds")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    # the acceptance bar reads the deadline row left-to-right as
    # "heterogeneity rises", so the sigma axis must be ascending
    args.sigmas = sorted(args.sigmas)
    fed = None
    if not args.dry_run:
        fed = make_federated_image_data(8, alpha=args.alpha,
                                        train_per_class=40,
                                        test_per_class=20, seed=args.seed)
    table = sweep(fed, args.sigmas, dry_run=args.dry_run,
                  channel=args.channel, rounds=args.rounds, seed=args.seed,
                  deadline=args.deadline,
                  es_uplink_mbps=args.es_uplink_mbps,
                  compute_gflops=args.compute_gflops,
                  compute_power_w=args.compute_power_w)
    print(json.dumps(table, indent=2))
    ok = check_acceptance(table, args.sigmas)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
    if not ok:
        raise SystemExit("ACCEPTANCE FAILED: deadline policy did not pick "
                         "shallower cuts as compute heterogeneity rose")
    return table


if __name__ == "__main__":
    main()
