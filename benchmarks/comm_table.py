"""Remark-1 communication-overhead table (paper Eq. 17).

One row per model: Phi_local, Phi_off, Phi_PHSFL vs Phi_HFL per edge round,
and the savings ratio.  Covers the paper's CNN and all 10 assigned LM
architectures (cut after n_client_layers blocks, seq 4096 activations).
"""

from __future__ import annotations

from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.configs.registry import ARCHS, get_arch
from repro.core import comm_for_cnn, comm_for_lm

KAPPA0 = 5


def rows():
    out = []
    cm = comm_for_cnn(CNN_CFG, dataset_size=500)
    out.append(("phsfl-cnn", cm, KAPPA0))
    for name in sorted(ARCHS):
        cfg = get_arch(name)
        cm = comm_for_lm(cfg, seq_len=4096, dataset_size=100_000)
        out.append((name, cm, KAPPA0))
    return out


def table() -> list[dict]:
    recs = []
    for name, cm, k0 in rows():
        phsfl = cm.phi_phsfl_bits(k0)
        hfl = cm.phi_hfl_bits()
        recs.append({
            "model": name,
            "Z_total": cm.total_params,
            "Z_client": cm.client_params,
            "Zc_per_sample": cm.cut_size,
            "phi_local_Mbit": cm.phi_local_bits() / 1e6,
            "phi_off_Mbit": cm.phi_off_bits() / 1e6,
            "phi_phsfl_Mbit": phsfl / 1e6,
            "phi_hfl_Mbit": hfl / 1e6,
            "hfl_over_phsfl": hfl / phsfl,
            "phsfl_wins": bool(hfl > phsfl),
        })
    return recs


def main():
    print(f"{'model':24s} {'Z_total':>14s} {'Z_client':>12s} "
          f"{'PHSFL Mbit':>12s} {'HFL Mbit':>14s} {'HFL/PHSFL':>10s} win")
    for r in table():
        print(f"{r['model']:24s} {r['Z_total']:14,d} {r['Z_client']:12,d} "
              f"{r['phi_phsfl_Mbit']:12.1f} {r['phi_hfl_Mbit']:14.1f} "
              f"{r['hfl_over_phsfl']:10.2f} {r['phsfl_wins']}")


if __name__ == "__main__":
    main()
