"""Benchmark entrypoint: one suite per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run            # everything quick
    PYTHONPATH=src python -m benchmarks.run --suite comm

Prints ``name,us_per_call,derived`` CSV rows per bench; analysis suites
print their tables.  The long paper-reproduction run and the dry-run sweeps
are separate entrypoints (benchmarks.paper_experiments, repro.launch.dryrun)
— this runner reports their saved results if present.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def suite_kernels():
    from benchmarks.kernel_bench import bench_rows
    for r in bench_rows():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


def suite_train():
    from benchmarks.train_bench import bench_rows
    for r in bench_rows():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")


def suite_comm():
    print("# Remark-1 communication table (Eq. 17)")
    from benchmarks.comm_table import main as comm_main
    comm_main()


def suite_theory():
    print("# Theorem-1 bound terms (Eq. 21)")
    from benchmarks.theory_table import main as theory_main
    theory_main()


def suite_roofline():
    print("# Roofline table (from experiments/dryrun)")
    from benchmarks.roofline_table import main as roof_main
    roof_main([])


def suite_paper():
    """Report saved paper-reproduction results (run separately if absent)."""
    path = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "paper", "results.json")
    if not os.path.exists(path):
        print("paper_experiments,0.0,not_run (PYTHONPATH=src python -m "
              "benchmarks.paper_experiments)")
        return
    with open(path) as f:
        res = json.load(f)
    for key, rec in sorted(res["runs"].items()):
        if key.startswith("summary"):
            print(f"paper_{key},0.0,{json.dumps(rec)}")
        elif key.startswith("centralized"):
            print(f"paper_{key},0.0,acc={rec['acc']:.4f}")
        else:
            print(f"paper_{key},0.0,global={rec['global_acc_mean']:.4f};"
                  f"personalized={rec['personalized_acc_mean']:.4f};"
                  f"min={rec['global_acc_min']:.4f};"
                  f"max={rec['global_acc_max']:.4f}")


SUITES = {
    "kernels": suite_kernels,
    "train": suite_train,
    "comm": suite_comm,
    "theory": suite_theory,
    "roofline": suite_roofline,
    "paper": suite_paper,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", choices=sorted(SUITES) + ["all"], default="all")
    args = ap.parse_args(argv)
    names = sorted(SUITES) if args.suite == "all" else [args.suite]
    for n in names:
        print(f"\n=== suite: {n} ===", flush=True)
        t0 = time.time()
        SUITES[n]()
        print(f"=== {n} done in {time.time() - t0:.1f}s ===", flush=True)


if __name__ == "__main__":
    main()
