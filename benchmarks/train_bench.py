"""CPU-scale training-step microbenches: one PHSFL round + one shared-server
step on reduced architectures (real execution, single device).  Prints
name,us_per_call,derived CSV rows."""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import HierarchyConfig, TrainConfig
from repro.configs.registry import get_arch
from repro.core import build_optimizer
from repro.data.synthetic import synthetic_token_batch
from repro.models import build_model
from repro.optim import apply_updates


def _time(fn, *args, iters=3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_rows() -> list[tuple[str, float, str]]:
    rows = []
    for arch in ("mistral-large-123b", "olmoe-1b-7b", "xlstm-350m",
                 "recurrentgemma-2b"):
        cfg = get_arch(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tcfg = TrainConfig(learning_rate=0.01, freeze_head=True)
        opt, _ = build_optimizer(model, tcfg)
        state = opt.init(params)
        nb = synthetic_token_batch(0, 4, 128, cfg.vocab_size)
        batch = {k: jnp.asarray(v) for k, v in nb.items()}

        @jax.jit
        def step(params, state, batch):
            loss, g = jax.value_and_grad(
                lambda p: model.loss(p, batch))(params)
            upd, state2 = opt.update(g, state, params)
            return apply_updates(params, upd), state2, loss

        us = _time(step, params, state, batch)
        loss = float(step(params, state, batch)[2])
        tokens = batch["tokens"].size
        rows.append((f"train_step_{arch}", us,
                     f"tok_per_s={tokens / (us / 1e6):.0f};loss={loss:.3f}"))
    return rows


def main():
    for name, us, derived in bench_rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
