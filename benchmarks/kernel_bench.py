"""Kernel microbenches (CPU interpret mode — correctness-level timing only;
the BlockSpec/VMEM reasoning that matters for TPU is in each kernel's
docstring and the §Perf log).  Prints name,us_per_call,derived CSV rows."""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_rows() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []

    # flash attention vs dense reference (small shape; interpret mode)
    from repro.kernels.flash_attention.kernel import flash_attention_hmajor
    from repro.kernels.flash_attention.ref import attention_ref
    b, h, kvh, s, d = 1, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, kvh, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, kvh, s, d)).astype(np.float32))
    t_ref = _time(lambda: attention_ref(q, k, v, causal=True))
    t_ker = _time(lambda: flash_attention_hmajor(q, k, v, causal=True,
                                                 block_q=128, block_k=128))
    err = float(jnp.abs(
        flash_attention_hmajor(q, k, v, causal=True, block_q=128, block_k=128)
        - attention_ref(q, k, v, causal=True)).max())
    rows.append(("flash_attention_interp", t_ker, f"ref_us={t_ref:.0f};max_err={err:.1e}"))

    # rglru kernel vs sequential scan ref
    from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
    from repro.kernels.rglru_scan.ref import rglru_scan_ref
    la = -jnp.abs(jnp.asarray(rng.normal(size=(2, 512, 256)).astype(np.float32))) * 0.1
    bb = jnp.asarray(rng.normal(size=(2, 512, 256)).astype(np.float32))
    h0 = jnp.zeros((2, 256), jnp.float32)
    t_ref = _time(lambda: rglru_scan_ref(la, bb, h0))
    t_ker = _time(lambda: rglru_scan_pallas(la, bb, h0, block_t=128,
                                            block_w=256))
    err = float(jnp.abs(rglru_scan_pallas(la, bb, h0, block_t=128, block_w=256)
                        - rglru_scan_ref(la, bb, h0)).max())
    rows.append(("rglru_scan_interp", t_ker, f"ref_us={t_ref:.0f};max_err={err:.1e}"))

    # mlstm chunk kernel vs chunkwise ref
    from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_pallas
    from repro.kernels.mlstm_chunk.ref import mlstm_ref
    b, h, s, dh = 1, 4, 512, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32)) / 8
    v = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    li = jnp.asarray(rng.normal(size=(b, h, s)).astype(np.float32))
    lf = jnp.log(jax.nn.sigmoid(jnp.asarray(
        rng.normal(size=(b, h, s)).astype(np.float32))))
    t_ref = _time(lambda: mlstm_ref(q, k, v, li, lf))
    t_ker = _time(lambda: mlstm_chunk_pallas(q, k, v, li, lf, chunk=128))
    err = float(jnp.abs(mlstm_chunk_pallas(q, k, v, li, lf, chunk=128)
                        - mlstm_ref(q, k, v, li, lf)).max())
    rows.append(("mlstm_chunk_interp", t_ker, f"ref_us={t_ref:.0f};max_err={err:.1e}"))
    return rows


def main():
    for name, us, derived in bench_rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
