"""Pipelined streaming x async-aggregation sweep: serial vs overlapped.

The timeline refactor (``repro.wireless.timeline``) made two scheduler
upgrades possible: **pipelined streaming** (``WirelessConfig.pipeline``)
overlaps each minibatch's uplink payload with the next minibatch's compute,
and **staleness-weighted async aggregation** (``staleness_lambda``) banks a
deadline-cut straggler's remainder and folds it into a later edge round
with weight ``alpha_u * lambda**staleness``.  This sweep runs the four
(serial | pipelined) x (sync | async) cells under ONE tight deadline, one
channel, one energy budget — the only knobs that differ between cells are
``pipeline`` and ``staleness_lambda`` — and emits a JSON table: mean round
time, live participation, stale deliveries, effective participation
(live + delivered), bits moved, final loss/accuracy (full run).

The acceptance bar of the pipelined-training ISSUE, checked in-run on the
deterministic static channel (and at test scale in tests/test_pipeline.py):

1. pipelining never hurts — the pipelined cells' mean round time is <= the
   matching serial cells' (the per-client timeline saves exactly
   ``(n-1)*min(c, u) >= 0``);
2. under the tight deadline, ``pipelined+async`` EFFECTIVE participation
   is strictly greater than ``serial+sync`` at the same energy budget —
   pipelining rescues clients whose serial compute+tx overshoots the
   deadline, and async delivery salvages the stragglers even pipelining
   cannot save.

``--dry-run`` skips training and drives the ParticipationScheduler alone
(same channel, same byte+FLOP accounting) — seconds, not minutes; the
tier-1 smoke test and CI invoke this mode so the benchmark cannot rot.

    PYTHONPATH=src python benchmarks/pipeline_sweep.py \
        [--deadline 3.0] [--compute-gflops 0.5] [--staleness-lambda 0.5] \
        [--rounds 2] [--dry-run] [--out pipeline_sweep.json]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.configs.sweeps import sweep_hierarchy, sweep_train, sweep_wireless
from repro.core.comm import comm_table_for_cnn
from repro.core.fedsim import FedSim
from repro.core.hierarchy import es_assignment
from repro.data.synthetic import make_federated_image_data
from repro.wireless import make_scheduler

# the four cells: the ONLY config deltas are pipeline / staleness_lambda
MODES = (("serial+sync", False, 0.0), ("pipelined+sync", True, 0.0),
         ("serial+async", False, None), ("pipelined+async", True, None))


def _wireless(pipeline: bool, lam: float, *, channel: str, deadline: float,
              compute_gflops: float, seed: int):
    """One cell's scenario: shared sweep channel + tight deadline + random
    thinning (a banked straggler delivers only on rounds its radio is IDLE,
    so some unscheduled rounds must exist even on a static channel)."""
    return sweep_wireless(
        channel, heterogeneity=0.5, deadline_s=deadline,
        compute_gflops=compute_gflops, compute_power_w=0.2,
        selection="random", participation_prob=0.8,
        pipeline=pipeline, staleness_lambda=lam, seed=seed)


def _stale_count(row) -> int:
    """Deliveries in one network row: FedSim rows carry the count,
    ``RoundReport.to_json_dict`` rows the per-client staleness list."""
    v = row.get("stale_delivered") or 0
    if isinstance(v, list):
        return int(sum(1 for s in v if s > 0))
    return int(v)


def _summarize(mode, network, h, extra):
    parts = [n["participants"] for n in network] or [0]
    times = [n["round_time_s"] for n in network] or [0.0]
    bits = [n.get("bits", n.get("bits_tx", 0.0)) for n in network] or [0.0]
    deliv = [_stale_count(n) for n in network] or [0]
    eff = [p + d for p, d in zip(parts, deliv)]
    return {
        "mode": mode,
        "participation_rate": float(np.mean(parts)) / h.num_clients,
        "stale_delivered_per_round": float(np.mean(deliv)),
        "effective_participation_rate": float(np.mean(eff)) / h.num_clients,
        "mean_round_time_s": float(np.mean(times)),
        "total_bits": float(np.sum(bits)), **extra,
    }


def run_one(fed, mode: str, pipeline: bool, lam: float, *, rounds: int,
            seed: int, **kw) -> dict:
    """One full cell: real training, timeline-priced wireless accounting,
    staleness folds applied in the aggregation (FedSim)."""
    h = sweep_hierarchy(rounds)
    t = sweep_train()
    sim = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=2, seed=seed,
                 wireless=_wireless(pipeline, lam, seed=seed, **kw))
    res = sim.run(rounds=rounds, log_every=rounds)
    return _summarize(mode, res.network, h, {
        "final_loss": res.history[-1]["test_loss"],
        "final_acc": res.history[-1]["test_acc"],
        "total_sim_time_s": res.total_sim_time_s,
    })


def dry_run_one(mode: str, pipeline: bool, lam: float, *, rounds: int,
                seed: int, **kw) -> dict:
    """Scheduler-only cell: same channel + timeline accounting, no
    training (the aggregation-side fold needs FedSim and is exercised in
    tests/test_pipeline.py)."""
    h = sweep_hierarchy(rounds)
    wireless = _wireless(pipeline, lam, seed=seed, **kw)
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400,
                               batch_size=sweep_train().batch_size,
                               batches_per_epoch=2)
    sched = make_scheduler(
        wireless, h.num_clients, kappa0=h.kappa0, comm_table=table,
        es_assign=es_assignment(h.num_clients, h.clients_per_es))
    network = [sched.step(r).to_json_dict()
               for r in range(rounds * h.kappa1)]
    return _summarize(mode, network, h, {"dry_run": True})


def sweep(fed, lam: float, *, dry_run: bool = False, **kw) -> list[dict]:
    cells = [(m, p, lam if la is None else la) for m, p, la in MODES]
    return [dry_run_one(m, p, la, **kw) if dry_run
            else run_one(fed, m, p, la, **kw) for m, p, la in cells]


def check_acceptance(table) -> bool:
    """(1) pipelining never slows a cell down; (2) pipelined+async beats
    serial+sync on EFFECTIVE participation, strictly, at equal energy."""
    rows = {r["mode"]: r for r in table}
    ok = True
    for serial, piped in (("serial+sync", "pipelined+sync"),
                          ("serial+async", "pipelined+async")):
        ts, tp = (rows[serial]["mean_round_time_s"],
                  rows[piped]["mean_round_time_s"])
        good = tp <= ts + 1e-9
        ok &= good
        print(f"[{'OK ' if good else 'FAIL'}] round time {piped} {tp:.3f}s "
              f"<= {serial} {ts:.3f}s")
    ps = rows["serial+sync"]["effective_participation_rate"]
    pa = rows["pipelined+async"]["effective_participation_rate"]
    good = pa > ps
    ok &= good
    print(f"[{'OK ' if good else 'FAIL'}] effective participation "
          f"pipelined+async {pa:.3f} > serial+sync {ps:.3f}")
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--channels", default="static", dest="channel",
                    choices=["static", "rayleigh"],
                    help="channel model shared by all four cells")
    ap.add_argument("--deadline", type=float, default=3.0,
                    help="edge-round deadline; tight enough that the serial "
                         "timeline stragglers while the pipelined one fits")
    ap.add_argument("--compute-gflops", type=float, default=0.5,
                    help="per-client compute rate; pipelining gains "
                         "(n-1)*min(c, u), so compute must be non-trivial")
    ap.add_argument("--staleness-lambda", type=float, default=0.5,
                    help="staleness discount of the async cells")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true",
                    help="scheduler-only sweep: no training, seconds")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    fed = None
    if not args.dry_run:
        fed = make_federated_image_data(8, alpha=args.alpha,
                                        train_per_class=40,
                                        test_per_class=20, seed=args.seed)
    table = sweep(fed, args.staleness_lambda, dry_run=args.dry_run,
                  channel=args.channel, rounds=args.rounds, seed=args.seed,
                  deadline=args.deadline,
                  compute_gflops=args.compute_gflops)
    print(json.dumps(table, indent=2))
    ok = check_acceptance(table)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
    if not ok:
        raise SystemExit("ACCEPTANCE FAILED: pipelining slowed a cell down "
                         "or async did not lift effective participation")
    return table


if __name__ == "__main__":
    main()
