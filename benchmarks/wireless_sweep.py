"""Deadline sweep: wireless participation vs accuracy vs round time.

Runs the faithful CNN simulator (FedSim) under a Rayleigh-faded channel at
several edge-round deadlines and emits a JSON table: tighter deadlines drop
more stragglers per round (cheaper, faster rounds) but aggregate fewer
clients (noisier global model) — the wall-clock/accuracy trade-off the
wireless papers optimize.

    PYTHONPATH=src python benchmarks/wireless_sweep.py \
        [--deadlines 0.5 1.0 2.0 inf] [--rounds 3] [--out sweep.json]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.base import HierarchyConfig, TrainConfig, WirelessConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.fedsim import FedSim
from repro.data.synthetic import make_federated_image_data


def run_one(fed, deadline: float, *, rounds: int, seed: int) -> dict:
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=2,
                        kappa1=2, global_rounds=rounds)
    t = TrainConfig(learning_rate=0.05, batch_size=16, freeze_head=True)
    # an infinite deadline still pays the channel's round times — it is the
    # "wait for every straggler" baseline, not the ideal network
    wireless = WirelessConfig(model="rayleigh", mean_uplink_mbps=20.0,
                              mean_downlink_mbps=80.0, latency_s=0.02,
                              deadline_s=deadline, seed=seed)
    sim = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=2, seed=seed,
                 wireless=wireless)
    res = sim.run(rounds=rounds, log_every=rounds)
    parts = [n["participants"] for n in res.network] or [h.num_clients]
    times = [n["round_time_s"] for n in res.network] or [0.0]
    return {
        "deadline_s": deadline,
        "final_loss": res.history[-1]["test_loss"],
        "final_acc": res.history[-1]["test_acc"],
        "mean_participants": float(np.mean(parts)),
        "mean_round_time_s": float(np.mean(times)),
        "total_sim_time_s": res.total_sim_time_s,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--deadlines", type=float, nargs="+",
                    default=[0.5, 1.0, 2.0, float("inf")])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)
    assert args.clients == 8, "grid is fixed at 2 ES x 4 clients"

    fed = make_federated_image_data(args.clients, alpha=args.alpha,
                                    train_per_class=40, test_per_class=20,
                                    seed=args.seed)
    table = [run_one(fed, d, rounds=args.rounds, seed=args.seed)
             for d in args.deadlines]
    print(json.dumps(table, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
    return table


if __name__ == "__main__":
    main()
