"""Cut-policy x channel sweep: adaptive cut selection vs fixed cuts.

Runs the faithful CNN simulator (FedSim) under per-ES shared uplinks and an
edge-round deadline, once per (cut policy, channel model) cell, and emits a
JSON table.  The fixed policies pin every client to one candidate cut
(conv1 / conv2 / fc1 — the Remark-2 invariant choices that only move bits);
``greedy`` picks each client's fastest affordable cut per round and
``deadline`` the deepest cut that still makes the deadline at the contended
rate (ASFL-style).  The table shows the adaptive policies matching or
beating the participation rate of the worst fixed cut at the same deadline
— the acceptance bar of ISSUE 2 — while fixed cuts pay whichever bits their
frozen split costs.

``--dry-run`` skips training and drives the ParticipationScheduler alone
(same channel, same per-cut byte/FLOP table) — seconds, not minutes; the
tier-1 smoke test and CI invoke this mode so the benchmark cannot rot.

    PYTHONPATH=src python benchmarks/cut_sweep.py \
        [--channels static rayleigh] [--deadline 4.0] [--rounds 2] \
        [--dry-run] [--out cut_sweep.json]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.configs.sweeps import sweep_hierarchy, sweep_train, sweep_wireless
from repro.core.comm import comm_table_for_cnn
from repro.core.fedsim import FedSim
from repro.core.hierarchy import es_assignment
from repro.data.synthetic import make_federated_image_data
from repro.models.cnn import CUT_CANDIDATES
from repro.wireless import make_scheduler


def run_one(fed, policy: str, channel: str, *, deadline: float, rounds: int,
            es_uplink_mbps: float, seed: int) -> dict:
    """One sweep cell.  ``policy`` is "greedy", "deadline", or "fixed:<cut>"."""
    h = sweep_hierarchy(rounds)
    t = sweep_train()
    fixed_cut = None
    if policy.startswith("fixed:"):
        fixed_cut = policy.split(":", 1)[1]
        cut_policy, candidates = "fixed", (fixed_cut,)
    else:
        cut_policy, candidates = policy, CUT_CANDIDATES
    wireless = sweep_wireless(channel, deadline_s=deadline,
                              es_uplink_mbps=es_uplink_mbps,
                              cut_policy=cut_policy,
                              cut_candidates=candidates, seed=seed)
    sim = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=2, seed=seed,
                 wireless=wireless, cut=fixed_cut)
    res = sim.run(rounds=rounds, log_every=rounds)
    parts = [n["participants"] for n in res.network] or [h.num_clients]
    times = [n["round_time_s"] for n in res.network] or [0.0]
    cuts = [n["mean_cut"] for n in res.network if "mean_cut" in n]
    return {
        "policy": policy,
        "channel": channel,
        "deadline_s": deadline,
        "final_loss": res.history[-1]["test_loss"],
        "final_acc": res.history[-1]["test_acc"],
        "participation_rate": float(np.mean(parts)) / h.num_clients,
        "mean_round_time_s": float(np.mean(times)),
        "mean_cut": float(np.mean(cuts)) if cuts else 0.0,
        "total_sim_time_s": res.total_sim_time_s,
    }


def dry_run_one(policy: str, channel: str, *, deadline: float, rounds: int,
                es_uplink_mbps: float, seed: int) -> dict:
    """Scheduler-only cell: same channel + per-cut byte table, no training."""
    h = sweep_hierarchy(rounds)
    fixed_cut = None
    if policy.startswith("fixed:"):
        fixed_cut = policy.split(":", 1)[1]
        cut_policy, candidates = "fixed", (fixed_cut,)
    else:
        cut_policy, candidates = policy, CUT_CANDIDATES
    wireless = sweep_wireless(channel, deadline_s=deadline,
                              es_uplink_mbps=es_uplink_mbps,
                              cut_policy=cut_policy,
                              cut_candidates=candidates, seed=seed)
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400,
                               batch_size=sweep_train().batch_size,
                               batches_per_epoch=2, cuts=candidates)
    sched = make_scheduler(
        wireless, h.num_clients, kappa0=h.kappa0, comm_table=table,
        es_assign=es_assignment(h.num_clients, h.clients_per_es))
    network = [sched.step(r).to_json_dict()
               for r in range(rounds * h.kappa1)]
    parts = [n["participants"] for n in network] or [0]
    times = [n["round_time_s"] for n in network] or [0.0]
    cuts = [n["mean_cut"] for n in network
            if n.get("mean_cut") is not None]
    return {
        "policy": policy,
        "channel": channel,
        "deadline_s": deadline,
        "participation_rate": float(np.mean(parts)) / h.num_clients,
        "mean_round_time_s": float(np.mean(times)),
        "mean_cut": float(np.mean(cuts)) if cuts else 0.0,
        "dry_run": True,
    }


def sweep(fed, channels, *, dry_run: bool = False, deadline: float,
          rounds: int, es_uplink_mbps: float, seed: int) -> list[dict]:
    policies = [f"fixed:{c}" for c in CUT_CANDIDATES] + ["greedy", "deadline"]
    kw = dict(deadline=deadline, rounds=rounds,
              es_uplink_mbps=es_uplink_mbps, seed=seed)
    return [dry_run_one(p, ch, **kw) if dry_run else run_one(fed, p, ch, **kw)
            for ch in channels for p in policies]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--channels", nargs="+", default=["static", "rayleigh"],
                    choices=["static", "rayleigh"])
    ap.add_argument("--deadline", type=float, default=4.0)
    ap.add_argument("--es-uplink-mbps", type=float, default=40.0)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true",
                    help="scheduler-only sweep: no training, seconds")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    fed = None
    if not args.dry_run:
        fed = make_federated_image_data(8, alpha=args.alpha,
                                        train_per_class=40,
                                        test_per_class=20, seed=args.seed)
    table = sweep(fed, args.channels, dry_run=args.dry_run,
                  deadline=args.deadline, rounds=args.rounds,
                  es_uplink_mbps=args.es_uplink_mbps, seed=args.seed)
    print(json.dumps(table, indent=2))
    # the ISSUE-2 acceptance bar, checked per channel
    for ch in args.channels:
        rows = [r for r in table if r["channel"] == ch]
        worst_fixed = min(r["participation_rate"] for r in rows
                          if r["policy"].startswith("fixed:"))
        for pol in ("greedy", "deadline"):
            got = next(r["participation_rate"] for r in rows
                       if r["policy"] == pol)
            flag = "OK " if got >= worst_fixed else "FAIL"
            print(f"[{flag}] {ch}/{pol}: participation {got:.3f} >= "
                  f"worst fixed {worst_fixed:.3f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(table, f, indent=2)
    return table


if __name__ == "__main__":
    main()
