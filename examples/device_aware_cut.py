"""Compute-aware cut selection: slow devices get shallower cuts.

    PYTHONPATH=src python examples/device_aware_cut.py [--compute-gflops 10]

What happens:
  1. prints each candidate cut's TWO prices — the Remark-1 bits it moves
     and the client-block FLOPs it keeps on the device (the half of the
     trade-off the simulator could not see before the device model);
  2. drives the deadline-aware cut controller over a static channel where
     every client has the SAME 20 Mbps link but a lognormal spread of
     compute speeds (``compute_heterogeneity``): the compute-starved
     clients are steered to a shallower cut than their fast-channel peers,
     because the deep cut's client-side FLOPs — not its bits — would blow
     the deadline for them;
  3. re-runs the same scenario with ``compute_gflops=inf`` (the bits-only
     controller): every client picks the same deep cut, demonstrating the
     blind spot the device model closes.

The energy ledger also shows compute joules now: each scheduled client is
charged ``compute_power_w * compute_s`` on top of its transmit energy.
"""

import argparse

import numpy as np

from repro.configs.base import WirelessConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.comm import comm_table_for_cnn
from repro.core.hierarchy import es_assignment
from repro.models.cnn import CUT_CANDIDATES
from repro.wireless import client_round_bits, client_round_flops, \
    make_scheduler

KAPPA0 = 2


def run(gflops: float, sigma: float, args, table):
    cfg = WirelessConfig(model="static", mean_uplink_mbps=20.0,
                         mean_downlink_mbps=80.0, latency_s=0.02,
                         deadline_s=args.deadline,
                         cut_policy="deadline", cut_candidates=CUT_CANDIDATES,
                         compute_gflops=gflops, compute_heterogeneity=sigma,
                         compute_power_w=0.2, energy_budget_j=50.0,
                         seed=args.seed)
    sched = make_scheduler(cfg, 8, kappa0=KAPPA0, comm_table=table,
                           es_assign=es_assignment(8, 4))
    rep = sched.step(0)
    return sched, rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--compute-gflops", type=float, default=10.0)
    ap.add_argument("--compute-heterogeneity", type=float, default=1.0)
    ap.add_argument("--deadline", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    table = comm_table_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                               batches_per_epoch=2)
    print("== candidate cuts: bits moved vs FLOPs kept on the client ==")
    for name, cm in table.items():
        bits = client_round_bits(cm, KAPPA0)
        flops = client_round_flops(cm, KAPPA0)
        print(f"  {name:5s}: uplink {bits.uplink / 1e6:6.1f} Mb/round   "
              f"client compute {flops / 1e9:5.2f} GFLOP/round")

    print(f"\n== deadline policy, same 20 Mbps channel for all 8 clients, "
          f"compute ~lognormal(sigma={args.compute_heterogeneity}) around "
          f"{args.compute_gflops} GFLOP/s ==")
    sched, rep = run(args.compute_gflops, args.compute_heterogeneity, args,
                     table)
    order = np.argsort(sched.device.flops_per_s)
    for u in order:
        cut = CUT_CANDIDATES[rep.cuts[u]]
        status = ("made deadline" if rep.mask[u] else
                  ("straggled" if rep.scheduled[u] else "not scheduled"))
        print(f"  client {u}: {sched.device.flops_per_s[u] / 1e9:6.1f} "
              f"GFLOP/s -> cut {cut:5s}  compute {rep.compute_s[u]:5.2f}s  "
              f"tx+compute energy "
              f"{sched.cfg.energy_budget_j - rep.energy_left_j[u]:4.2f}J  "
              f"({status})")
    slow, fast = order[0], order[-1]
    assert rep.cuts[slow] <= rep.cuts[fast], "slowest device went deeper?!"
    if rep.cuts[slow] < rep.cuts[fast]:
        print(f"  -> compute-starved client {slow} sits at "
              f"{CUT_CANDIDATES[rep.cuts[slow]]} while its fast peer {fast} "
              f"holds {CUT_CANDIDATES[rep.cuts[fast]]}")
    else:
        print(f"  -> every device keeps up at this compute rate (all at "
              f"{CUT_CANDIDATES[rep.cuts[fast]]}); lower --compute-gflops "
              f"or raise --compute-heterogeneity to see the steering")

    print("\n== same scenario, bits-only controller (compute_gflops=inf) ==")
    _, rep0 = run(float("inf"), args.compute_heterogeneity, args, table)
    picked = sorted({CUT_CANDIDATES[c] for c in rep0.cuts})
    print(f"  every client picks {picked} — the compute spread is invisible "
          f"when FLOPs are priced at zero")


if __name__ == "__main__":
    main()
