"""Quantized smashed data: a deep cut infeasible at fp32 becomes feasible.

    PYTHONPATH=src python examples/compressed_phsfl.py [--deadline 1.0]

What happens:
  1. prints the Remark-1 byte table of every (cut, codec) cell — the
     compression subsystem (repro.compress) makes the bits the cut
     controller optimizes over configurable, so the cut x codec grid is
     just more candidate cells with fewer bits;
  2. runs the SAME federation three times over a static channel with a
     shared ES uplink and a round deadline, at the paper's kappa0 = 5
     local epochs (where the per-minibatch activation stream dominates):
     the deep cut (fc1) at fp32 — its 2.17M-param offload alone is ~72 Mb,
     hopeless; the paper cut (conv1) at int8 — activations still stream
     ~52 Mb/round, a straggler at any deadline the deep cut can make; and
     the deep cut at int8 — tiny activations AND an affordable 17 Mb
     offload, the only cell of the grid that participates at all;
  3. prints per-run scheduled/participating clients, bits moved, and final
     accuracy — the joint (cut, codec) choice turns a dead network into a
     training one.

Unlike the cut (Remark 2), a lossy codec DOES touch learning dynamics —
the int8 runs pay a small stochastic-rounding tax in exchange for
participating at all.  tests/test_compress.py pins the identity codec to
the uncompressed trajectory bit-for-bit.
"""

import argparse

import numpy as np

from repro.compress import link_codecs
from repro.configs.base import HierarchyConfig, TrainConfig, WirelessConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.comm import comm_table_for_cnn
from repro.core.fedsim import FedSim
from repro.data.synthetic import make_federated_image_data
from repro.models.cnn import CUT_CANDIDATES
from repro.wireless import client_round_bits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--deadline", type=float, default=2.5)
    ap.add_argument("--es-uplink-mbps", type=float, default=40.0)
    ap.add_argument("--energy-budget", type=float, default=4.0)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=5,
                        kappa1=2, global_rounds=args.rounds)
    t = TrainConfig(learning_rate=0.05, batch_size=16, freeze_head=True)

    print("== cut x codec byte table (Remark 1 with configurable bits) ==")
    named = {"fp32": None, "int8": link_codecs("int8")}
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400,
                               batch_size=t.batch_size, batches_per_epoch=5,
                               codecs=named)
    for (cut, codec), cm in table.items():
        bits = client_round_bits(cm, h.kappa0)
        print(f"  {cut:5s} x {codec:4s}: Z_0 {cm.client_params:>9,} params   "
              f"uplink {bits.uplink / 1e6:6.1f} Mb/round")

    fed = make_federated_image_data(8, alpha=0.3, train_per_class=40,
                                    test_per_class=20, seed=args.seed)
    wireless = WirelessConfig(model="static", mean_uplink_mbps=20.0,
                              mean_downlink_mbps=80.0, latency_s=0.02,
                              deadline_s=args.deadline,
                              es_uplink_mbps=args.es_uplink_mbps,
                              energy_budget_j=args.energy_budget,
                              seed=args.seed)

    runs = [("fp32, deep cut (fc1)", None, CUT_CANDIDATES[-1]),
            ("int8, paper cut (conv1)", link_codecs("int8"),
             CUT_CANDIDATES[0]),
            ("int8, deep cut (fc1)", link_codecs("int8"),
             CUT_CANDIDATES[-1])]
    for label, codecs, cut in runs:
        sim = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=5,
                     seed=args.seed, wireless=wireless, cut=cut,
                     codecs=codecs)
        res = sim.run(rounds=args.rounds, log_every=args.rounds)
        sched = np.mean([n["scheduled"] for n in res.network])
        parts = np.mean([n["participants"] for n in res.network])
        bits = np.sum([n["bits"] for n in res.network])
        print(f"== {label} ==")
        print(f"  scheduled {sched:.1f}/8   participating {parts:.1f}/8   "
              f"bits {bits / 1e6:.1f} Mb   "
              f"final acc {res.history[-1]['test_acc']:.3f}")


if __name__ == "__main__":
    main()
