"""The paper's pipeline end-to-end (scaled down): hierarchical split
federated training with a frozen classifier -> per-client head fine-tuning
-> per-client evaluation, vs the HSFL baseline.

    PYTHONPATH=src python examples/personalized_federation.py
"""

from repro.configs.base import HierarchyConfig, TrainConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.fedsim import FedSim
from repro.data.synthetic import make_federated_image_data


def main():
    # 2 edge servers x 8 clients, Dir(0.2) non-IID synthetic images
    data = make_federated_image_data(16, alpha=0.2, train_per_class=100,
                                     test_per_class=40, seed=0)
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=8, kappa0=3,
                        kappa1=2, global_rounds=8)
    print(f"{'algo':8s} {'global acc':>12s} {'personalized':>13s} {'gain':>7s}")
    for algo, freeze in (("phsfl", True), ("hsfl", False)):
        t = TrainConfig(learning_rate=0.05, batch_size=32, freeze_head=freeze,
                        finetune_steps=10, finetune_lr=0.05)
        sim = FedSim(CNN_CFG, data, h, t, batches_per_epoch=2, seed=0)
        res = sim.run(rounds=8, log_every=8)
        heads, per = sim.personalize(res.global_params)
        g = res.per_client_global["acc"].mean()
        p = per["acc"].mean()
        print(f"{algo:8s} {g:12.4f} {p:13.4f} {p - g:7.4f}")


if __name__ == "__main__":
    main()
