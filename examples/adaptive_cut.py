"""Adaptive cut-layer selection under a shared, fading ES uplink.

    PYTHONPATH=src python examples/adaptive_cut.py [--deadline 4.0]

What happens:
  1. prints the Remark-1 byte accounting of every candidate cut of the
     paper's CNN — the cut trades the per-minibatch activation tensor
     (N * Z_c, shrinking as the cut deepens) against the client-block
     offload (Z_0, growing with depth);
  2. runs the SAME federation three times over a Rayleigh-faded channel
     where the 4 clients of each ES share one uplink pipe: pinned to the
     shallow cut, pinned to the deep cut, and with the deadline-aware
     controller that re-picks each client's cut every round from the
     contended rate (repro.wireless.cutter);
  3. prints per-run participation, mean chosen cut, and simulated
     wall-clock — the adaptive controller keeps clients in rounds a frozen
     cut would price out.

By the paper's Remark 2 all three runs would train IDENTICALLY on an ideal
network (see test_cutter.py for the bit-exact check) — the cut only decides
who pays which bits, which is exactly why it is free to chase the channel.
"""

import argparse

import numpy as np

from repro.configs.base import HierarchyConfig, TrainConfig, WirelessConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.comm import comm_table_for_cnn
from repro.core.fedsim import FedSim
from repro.data.synthetic import make_federated_image_data
from repro.models.cnn import CUT_CANDIDATES
from repro.wireless import client_round_bits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--deadline", type=float, default=4.0)
    ap.add_argument("--es-uplink-mbps", type=float, default=40.0)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=2,
                        kappa1=2, global_rounds=args.rounds)
    t = TrainConfig(learning_rate=0.05, batch_size=16, freeze_head=True)

    print("== candidate cuts (Remark 1: who pays which bits) ==")
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400,
                               batch_size=t.batch_size, batches_per_epoch=2)
    for name, cm in table.items():
        bits = client_round_bits(cm, h.kappa0)
        print(f"  {name:5s}: Z_0 {cm.client_params:>9,} params   "
              f"Z_c {cm.cut_size:>6,} /sample   "
              f"uplink {bits.uplink / 1e6:6.1f} Mb/round")

    fed = make_federated_image_data(8, alpha=0.3, train_per_class=40,
                                    test_per_class=20, seed=args.seed)

    def wireless(policy, candidates):
        return WirelessConfig(model="rayleigh", mean_uplink_mbps=20.0,
                              mean_downlink_mbps=80.0, latency_s=0.02,
                              deadline_s=args.deadline,
                              es_uplink_mbps=args.es_uplink_mbps,
                              cut_policy=policy, cut_candidates=candidates,
                              seed=args.seed)

    runs = [("fixed shallow (conv1)", "fixed", (CUT_CANDIDATES[0],),
             CUT_CANDIDATES[0]),
            ("fixed deep (fc1)", "fixed", (CUT_CANDIDATES[-1],),
             CUT_CANDIDATES[-1]),
            ("deadline-aware", "deadline", CUT_CANDIDATES, None)]
    for label, policy, candidates, train_cut in runs:
        sim = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=2, seed=args.seed,
                     wireless=wireless(policy, candidates), cut=train_cut)
        res = sim.run(rounds=args.rounds, log_every=args.rounds)
        parts = np.mean([n["participants"] for n in res.network])
        cuts = np.mean([n.get("mean_cut", 0.0) for n in res.network])
        print(f"== {label} ==")
        print(f"  participation {parts:.1f}/8 per round   mean cut index "
              f"{cuts:.2f}   sim clock {res.total_sim_time_s:.1f}s   "
              f"final acc {res.history[-1]['test_acc']:.3f}")


if __name__ == "__main__":
    main()
