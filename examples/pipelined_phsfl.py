"""Pipelined split training: stream each minibatch's activations mid-compute.

    PYTHONPATH=src python examples/pipelined_phsfl.py [--deadline 3.0]

What happens:
  1. builds the SAME wireless round twice — once with the serial Eq.-17
     timeline (compute everything, then transmit everything) and once with
     ``WirelessConfig.pipeline=True`` (each of the kappa0 x
     batches_per_epoch minibatch activation payloads transmits as soon as
     its minibatch's compute finishes and the radio is free) — and prints
     one client's explicit event timeline for both
     (``RoundTimeline.segments``): in the pipelined one the uplink
     segments interleave with the compute chunks instead of waiting for
     the last one;
  2. compares the per-client completion times: pipelining saves exactly
     ``(n-1) * min(c, u)`` (per-chunk compute c, per-payload airtime u) —
     never negative, and the round moves from ``compute + tx`` toward
     ``max(compute, tx)`` plus one fill bubble;
  3. applies a tight deadline: clients whose serial timeline overshoots it
     are straggler-dropped, while their pipelined timeline fits — the
     deadline gate, the energy charge, and the moved-bits ledger all read
     the overlapped schedule.

Async staleness banking (``staleness_lambda``) composes with this — see
benchmarks/pipeline_sweep.py for the four-cell comparison.
"""

import argparse

import numpy as np

from repro.configs.base import WirelessConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.comm import comm_for_cnn
from repro.core.hierarchy import es_assignment
from repro.wireless import client_round_bits, make_scheduler

KAPPA0 = 2
U = 8


def scenario(pipeline: bool, args) -> WirelessConfig:
    return WirelessConfig(model="static", mean_uplink_mbps=20.0,
                          mean_downlink_mbps=80.0, latency_s=0.02,
                          heterogeneity=0.5, deadline_s=args.deadline,
                          compute_gflops=args.compute_gflops,
                          compute_power_w=0.2, pipeline=pipeline,
                          seed=args.seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--deadline", type=float, default=3.0)
    ap.add_argument("--compute-gflops", type=float, default=0.5)
    ap.add_argument("--client", type=int, default=0,
                    help="whose timeline to print")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    comm = comm_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                        batches_per_epoch=2)
    bits = client_round_bits(comm, KAPPA0)
    print(f"per round: {bits.chunks} minibatch payloads x "
          f"{bits.up_stream:,} bits + {bits.up_tail:,} offload bits up, "
          f"{np.asarray(bits.downlink):,} bits down\n")

    reps = {}
    for pipeline in (False, True):
        cfg = scenario(pipeline, args)
        sched = make_scheduler(cfg, U, comm, KAPPA0,
                               es_assign=es_assignment(U, U // 2))
        link = sched.channel.sample(0)
        tl = sched._timeline(link, bits, sched._compute_s(None))
        name = "pipelined" if pipeline else "serial"
        print(f"--- {name} timeline of client {args.client} "
              f"(activity clock, seconds) ---")
        for seg in tl.segments(args.client):
            span = f"[{seg['start']:7.3f}, {seg['end']:7.3f})"
            extra = f"  {seg['bits']:,.0f} bits" if "bits" in seg else ""
            print(f"  {seg['kind']:8s} {span}{extra}")
        reps[name] = sched.step(0)
        print(f"  -> completion {np.round(tl.times_s, 3)}\n")

    serial, piped = reps["serial"], reps["pipelined"]
    saved = serial.times_s - piped.times_s
    print(f"pipelining saves per client (s): {np.round(saved, 3)}")
    assert (saved >= -1e-9).all(), "pipelining must never be slower"
    print(f"deadline {args.deadline}s participation: "
          f"serial {serial.num_participants}/{U}, "
          f"pipelined {piped.num_participants}/{U}")


if __name__ == "__main__":
    main()
