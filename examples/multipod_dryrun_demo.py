"""Lower + compile ONE (arch x shape) on the 512-chip multi-pod production
mesh and print its memory/cost/roofline analysis.

    PYTHONPATH=src python examples/multipod_dryrun_demo.py \
        [--arch gemma3-12b] [--shape train_4k]

(This re-execs repro.launch.dryrun so the 512-device XLA flag is set before
jax initializes.)
"""

import argparse
import os
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="multipod", choices=["single", "multipod"])
    args = ap.parse_args()
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
         "--shape", args.shape, "--mesh", args.mesh], env=env))
