"""PHSFL over a wireless network: straggler dropout vs the ideal network.

    PYTHONPATH=src python examples/wireless_phsfl.py [--deadline 1.0]

What happens:
  1. runs the paper-faithful CNN simulator on an IDEAL network (every
     client aggregates every edge round — the pre-wireless behavior);
  2. re-runs the SAME federation over a Rayleigh-faded channel with an
     edge-round deadline: per round, each client's uplink/downlink airtime
     for its cut-layer traffic (Remark 1 accounting) decides whether it
     makes the deadline, and Eq. 14-16 weights renormalize over the
     participants;
  3. prints per-round participation, simulated wall-clock, and the final
     accuracy gap the deadline costs.

Also demonstrates the LM-scale path:

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m \
        --rounds 3 --clients 4 --channel rayleigh --deadline 0.5
"""

import argparse

from repro.configs.base import HierarchyConfig, TrainConfig, WirelessConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.fedsim import FedSim
from repro.data.synthetic import make_federated_image_data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--deadline", type=float, default=1.0)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    fed = make_federated_image_data(8, alpha=0.3, train_per_class=40,
                                    test_per_class=20, seed=args.seed)
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=2,
                        kappa1=2, global_rounds=args.rounds)
    t = TrainConfig(learning_rate=0.05, batch_size=16, freeze_head=True)

    print("== ideal network ==")
    ideal = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=2, seed=args.seed)
    res_i = ideal.run(rounds=args.rounds, log_every=1)
    for row in res_i.history:
        print(f"  round {row['round']}: acc {row['test_acc']:.3f}")

    print(f"== rayleigh channel, deadline {args.deadline}s ==")
    w = WirelessConfig(model="rayleigh", mean_uplink_mbps=20.0,
                       mean_downlink_mbps=80.0, latency_s=0.02,
                       deadline_s=args.deadline, seed=args.seed)
    sim = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=2, seed=args.seed,
                 wireless=w)
    res_w = sim.run(rounds=args.rounds, log_every=1)
    for row in res_w.history:
        print(f"  round {row['round']}: acc {row['test_acc']:.3f}  "
              f"participants {row['mean_participants']:.1f}/8  "
              f"sim clock {row['sim_time_s']:.1f}s")
    gap = res_i.history[-1]["test_acc"] - res_w.history[-1]["test_acc"]
    print(f"accuracy cost of the {args.deadline}s deadline: {gap:+.3f} "
          f"(at {res_w.total_sim_time_s:.1f}s simulated wall-clock)")


if __name__ == "__main__":
    main()
