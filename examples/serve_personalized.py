"""Serve a reduced model with batched requests and per-request personalized
heads (the PHSFL head bank).

    PYTHONPATH=src python examples/serve_personalized.py [--arch xlstm-350m]
"""

import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--batch", "4", "--steps", "12",
                "--clients", "3"])
