"""Fault injection + recovery: erasures, HARQ, outages, crashes, resume.

    PYTHONPATH=src python examples/faulty_phsfl.py [--erasure 0.3]

What happens (scheduler only — seconds, no training):
  1. prints one client's explicit event timeline on a round with payload
     erasures: the erased uplink is RETRANSMITTED as extra real segments
     (each after a ``backoff_s`` radio gap), so its airtime, energy, and
     moved bits flow through the same deadline gate and ledger as any
     first transmission; the report splits total air bits from the
     retransmit overhead (``retx_bits``/``retx_j``);
  2. compares three recovery policies over many rounds at the same
     erasure rate: hard drop (``max_retries=0``), HARQ, and
     HARQ + staleness banking (retry-exhausted updates deliver late and
     discounted instead of vanishing) — effective participation recovers
     step by step;
  3. marks an edge server DOWN for a round (``es_outage_trace``): its
     clients re-associate to the nearest live ES (``RoundReport.es_map``)
     or sit out under ``failover="skip"``;
  4. crashes clients mid-round (``crash_hazard``): the timeline truncates
     at the crash instant — partial compute/airtime are charged, nothing
     is delivered, nothing is banked;
  5. snapshots the scheduler mid-chaos (``state_dict``) and replays the
     remaining rounds in a FRESH scheduler — bit-identical, fault stream
     included (the checkpoint/resume contract ``launch/train.py --resume``
     and ``FedSim.save/restore`` are built on).
"""

import argparse

import numpy as np

from repro.configs.base import FaultConfig, WirelessConfig
from repro.core.comm import comm_for_cnn
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.hierarchy import es_assignment
from repro.wireless import client_round_bits, make_scheduler

KAPPA0 = 2
U = 8


def scenario(args, **faults) -> WirelessConfig:
    return WirelessConfig(model="static", mean_uplink_mbps=20.0,
                          mean_downlink_mbps=80.0, latency_s=0.02,
                          heterogeneity=0.5, deadline_s=args.deadline,
                          selection="random", participation_prob=0.8,
                          staleness_lambda=faults.pop("lam", 0.0),
                          faults=FaultConfig(**faults), seed=args.seed)


def _sched(comm, cfg):
    return make_scheduler(cfg, U, comm, KAPPA0,
                          es_assign=es_assignment(U, U // 2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--erasure", type=float, default=0.3)
    ap.add_argument("--deadline", type=float, default=4.0)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--client", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    comm = comm_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                        batches_per_epoch=2)
    bits = client_round_bits(comm, KAPPA0)

    # 1. the HARQ timeline, segment by segment
    print(f"--- HARQ timeline, erasure={args.erasure}, backoff=0.05s "
          f"(client {args.client}) ---")
    s = _sched(comm, scenario(args, erasure_prob=args.erasure,
                              max_retries=3, backoff_s=0.05))
    for r in range(4):                      # find a round with a retx
        link = s.channel.sample(r)
        plan = s.injector.round_plan()
        if plan.up_attempts[args.client].max() > 1:
            break
    s._plan = plan
    tl = s._timeline(link, bits, s._compute_s(None))
    for seg in tl.segments(args.client):
        span = f"[{seg['start']:7.3f}, {seg['end']:7.3f})"
        extra = f"  {seg['bits']:,.0f} bits" if "bits" in seg else ""
        print(f"  {seg['kind']:8s} {span}{extra}")
    print(f"  attempts per payload: {plan.up_attempts[args.client]}, "
          f"air {tl.air_up_bits[args.client]:,.0f} bits vs goodput "
          f"{tl.goodput_up_bits[args.client]:,.0f}\n")

    # 2. recovery policies at the same erasure rate
    print(f"--- recovery over {args.rounds} rounds at "
          f"erasure={args.erasure} ---")
    cells = {"hard drop  ": scenario(args, erasure_prob=args.erasure,
                                     max_retries=0),
             "harq       ": scenario(args, erasure_prob=args.erasure,
                                     max_retries=3),
             "harq+stale ": scenario(args, erasure_prob=args.erasure,
                                     max_retries=3, lam=0.5)}
    for name, cfg in cells.items():
        sc = _sched(comm, cfg)
        live = deliv = retx = 0.0
        for r in range(args.rounds):
            rep = sc.step(r)
            live += rep.num_participants
            if rep.stale_delivered is not None:
                deliv += int((rep.stale_delivered > 0).sum())
            retx += rep.retx_bits
        print(f"  {name} live {live / (args.rounds * U):5.1%}  "
              f"effective {(live + deliv) / (args.rounds * U):5.1%}  "
              f"retx {retx / 1e6:8.1f} Mbit")

    # 3. an ES outage round: reassoc vs skip
    print("\n--- ES 1 down for one round ---")
    for policy in ("reassoc", "skip"):
        sc = _sched(comm, scenario(args, es_outage_trace=((0, 1),),
                                   failover=policy))
        rep = sc.step(0)
        home = f"es_map {rep.es_map}" if rep.es_map is not None else \
            f"ES-1 clients sat out ({int(rep.scheduled[4:].sum())} sched)"
        print(f"  {policy:8s}: participants {rep.num_participants}/{U}, "
              f"{home}")

    # 4. crashes
    sc = _sched(comm, scenario(args, crash_hazard=0.4))
    crashed = sched = 0
    for r in range(6):
        rep = sc.step(r)
        crashed += int(rep.crashed.sum())
        sched += int(rep.scheduled.sum())
    print(f"\n--- crash_hazard=0.4 over 6 rounds: {crashed}/{sched} "
          f"scheduled client-rounds died mid-round (partial compute and "
          f"airtime charged, nothing delivered or banked) ---")

    # 5. checkpoint/resume mid-chaos, bit-identical
    chaos = dict(erasure_prob=args.erasure, max_retries=2,
                 crash_hazard=0.2, lam=0.5)
    ref = _sched(comm, scenario(args, **chaos))
    want = [ref.step(r) for r in range(8)]
    sc = _sched(comm, scenario(args, **chaos))
    for r in range(4):
        sc.step(r)
    snap = sc.state_dict()
    fresh = _sched(comm, scenario(args, **chaos))
    fresh.load_state_dict(snap)
    same = all(np.array_equal(fresh.step(r).mask, want[r].mask)
               for r in range(4, 8))
    print(f"\nresume from a round-4 snapshot replays rounds 4..7 "
          f"bit-identically: {same}")
    assert same


if __name__ == "__main__":
    main()
