"""Quickstart: train a reduced assigned architecture with PHSFL on CPU.

    PYTHONPATH=src python examples/quickstart.py [--arch gemma3-12b]

What happens:
  1. builds the architecture at a reduced (smoke) scale;
  2. runs R PHSFL rounds — per-client local SGD with the classifier FROZEN,
     then weighted hierarchical aggregation;
  3. fine-tunes a personalized head per client (Eq. 18) and prints the
     per-client personalization gain.
"""

import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--rounds", type=int, default=5)
    args = ap.parse_args()
    train_main(["--arch", args.arch, "--rounds", str(args.rounds),
                "--clients", "4", "--seq", "128"])
