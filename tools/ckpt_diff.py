"""Bitwise diff of two flat-npz checkpoints (or checkpoint directories).

The resume contract (``FedSim.save``/``restore``, ``launch/train.py
--resume``) is BIT-identity: a killed-and-resumed run must produce byte-
for-byte the same checkpoints as an uninterrupted one.  ``make
resume-smoke`` drives two such runs and calls this tool on the results —
exit 0 iff every array agrees exactly (shape, dtype, and raw bytes, so
NaN payloads and signed zeros count too), 1 with a per-key report
otherwise.

    python -m tools.ckpt_diff runA/state runB/state        # latest steps
    python -m tools.ckpt_diff a.npz b.npz
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np


def _resolve(path: str) -> str:
    """A .npz file as-is; a directory resolves to its latest ckpt_*.npz."""
    if os.path.isdir(path):
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "src"))
        from repro.checkpoint import latest_step
        step = latest_step(path)
        if step is None:
            raise SystemExit(f"ckpt_diff: no ckpt_*.npz in {path}")
        return os.path.join(path, f"ckpt_{step:08d}.npz")
    return path


def diff(path_a: str, path_b: str) -> list[str]:
    """Human-readable mismatch lines; empty iff bit-identical."""
    out = []
    with np.load(path_a) as a, np.load(path_b) as b:
        keys_a, keys_b = set(a.files), set(b.files)
        for k in sorted(keys_a - keys_b):
            out.append(f"only in {path_a}: {k}")
        for k in sorted(keys_b - keys_a):
            out.append(f"only in {path_b}: {k}")
        for k in sorted(keys_a & keys_b):
            va, vb = a[k], b[k]
            if va.shape != vb.shape:
                out.append(f"{k}: shape {va.shape} != {vb.shape}")
            elif va.dtype != vb.dtype:
                out.append(f"{k}: dtype {va.dtype} != {vb.dtype}")
            elif va.tobytes() != vb.tobytes():
                n = int(np.sum(np.frombuffer(va.tobytes(), np.uint8)
                               != np.frombuffer(vb.tobytes(), np.uint8)))
                out.append(f"{k}: {n} differing byte(s) of "
                           f"{va.nbytes}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.ckpt_diff",
        description="bitwise-compare two flat-npz checkpoints")
    ap.add_argument("a", help="checkpoint file or directory (latest step)")
    ap.add_argument("b", help="checkpoint file or directory (latest step)")
    args = ap.parse_args(argv)
    pa, pb = _resolve(args.a), _resolve(args.b)
    mismatches = diff(pa, pb)
    for line in mismatches:
        print(line)
    if mismatches:
        print(f"ckpt_diff: {pa} != {pb} ({len(mismatches)} mismatch(es))")
        return 1
    print(f"ckpt_diff: {pa} == {pb} (bit-identical)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
