"""Schema check for a telemetry output directory (the trace-smoke gate).

Usage::

    python tools/check_trace.py <dir>

Validates the three files a ``--trace-dir`` run emits:

- ``trace.json``   — Trace Event JSON Array Format: parses (with the
  optional trailing ``]`` restored if the run died mid-stream), every
  event carries ph/pid/name, ``ts``/``dur`` are non-negative and finite,
  the M-metadata names the three fixed tracks, and at least one round
  marker and one client-track X event exist;
- ``metrics.jsonl`` — one ``{"step": ..., "metrics": {...}}`` record per
  line, every instrument self-describing (``kind`` in counter/gauge/
  histogram with the matching state fields);
- ``manifest.json`` — run provenance: config_hash/seeds/python/platform.

Exit status 0 iff everything holds; prints one line per problem.
"""

from __future__ import annotations

import json
import math
import sys
from pathlib import Path

_KIND_FIELDS = {"counter": {"value"}, "gauge": {"value"},
                "histogram": {"count", "sum", "bounds", "bucket_counts"}}


def check_trace(path: Path, problems: list) -> None:
    text = path.read_text()
    try:
        evs = json.loads(text)
    except json.JSONDecodeError:
        try:
            evs = json.loads(text.rstrip().rstrip(",") + "]")
        except json.JSONDecodeError as e:
            problems.append(f"{path}: unparseable even with ']' fixup: {e}")
            return
    if not isinstance(evs, list) or not evs:
        problems.append(f"{path}: expected a non-empty event array")
        return
    for i, ev in enumerate(evs):
        for k in ("ph", "pid", "name"):
            if k not in ev:
                problems.append(f"{path}: event {i} missing {k!r}: {ev}")
                return
        for k in ("ts", "dur"):
            v = ev.get(k)
            if v is not None and (not isinstance(v, (int, float))
                                  or not math.isfinite(v) or v < 0):
                problems.append(f"{path}: event {i} bad {k}={v!r}")
    tracks = {ev["args"]["name"] for ev in evs if ev["ph"] == "M"
              and ev.get("name") == "process_name"}
    for want in ("round markers", "clients", "edge servers"):
        if want not in tracks:
            problems.append(f"{path}: no process_name metadata for {want!r}")
    if not any(ev["ph"] == "i" and ev["pid"] == 0
               and ev["name"].startswith("round ") for ev in evs):
        problems.append(f"{path}: no round marker instants")
    if not any(ev["ph"] == "X" and ev["pid"] == 1 for ev in evs):
        problems.append(f"{path}: no client-track X events")


def check_metrics(path: Path, problems: list) -> None:
    lines = path.read_text().splitlines()
    if not lines:
        problems.append(f"{path}: empty")
        return
    for n, line in enumerate(lines, start=1):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            problems.append(f"{path}:{n}: bad JSON: {e}")
            continue
        if "step" not in rec or "metrics" not in rec:
            problems.append(f"{path}:{n}: record missing step/metrics")
            continue
        for name, inst in rec["metrics"].items():
            kind = inst.get("kind")
            want = _KIND_FIELDS.get(kind)
            if want is None:
                problems.append(f"{path}:{n}: {name}: unknown kind {kind!r}")
            elif not want <= set(inst):
                problems.append(f"{path}:{n}: {name}: {kind} missing "
                                f"{sorted(want - set(inst))}")


def check_manifest(path: Path, problems: list) -> None:
    man = json.loads(path.read_text())
    for k in ("config_hash", "seeds", "python", "platform"):
        if k not in man:
            problems.append(f"{path}: missing key {k!r}")


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print(__doc__.strip().splitlines()[0])
        print(f"usage: {Path(sys.argv[0]).name} <telemetry-dir>")
        return 2
    root = Path(argv[0])
    problems: list = []
    checks = {"trace.json": check_trace, "metrics.jsonl": check_metrics,
              "manifest.json": check_manifest}
    for name, fn in checks.items():
        p = root / name
        if not p.exists():
            problems.append(f"{p}: missing")
        else:
            fn(p, problems)
    for msg in problems:
        print(msg)
    if not problems:
        print(f"ok: {', '.join(checks)} in {root} all well-formed")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
