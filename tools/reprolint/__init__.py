"""reprolint: JAX/Pallas-aware static analysis for this repo's contracts.

Three layers (see README.md for the rule catalog):

1. AST checkers      (tools.reprolint.astchecks)       — PRNG discipline,
   host-numpy-in-jit, static-arg hashability, mutable defaults, float64;
2. Pallas contracts  (tools.reprolint.pallas_contracts) — kernel/ref/ops
   triplets, interpret fallbacks, lane widths, tiling asserts, VMEM budget;
3. Shape audit       (tools.reprolint.shape_audit)      — CommModel Z_0/Z_c
   bit accounting vs jax.eval_shape, per registry config × cut candidate.

Run as ``python -m tools.reprolint src tests benchmarks examples``.
"""

from tools.reprolint.engine import (Finding, Report, Rule, RULES,
                                    Suppressions, python_files)

__all__ = ["Finding", "Report", "Rule", "RULES", "Suppressions",
           "python_files"]
