"""reprolint core: findings, rule registry, suppressions, file walking.

A *rule* is a named static contract (see README.md for the catalog); a
*checker* is a callable producing :class:`Finding`s.  The engine owns the
pieces every checker shares: the finding record, the per-line / per-file
suppression mechanism (``# reprolint: disable=<rule>[,<rule>...]`` and
``# reprolint: disable-file=<rule>``), source-file discovery, and the
plain-text / JSON reports.
"""

from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass, field
from pathlib import Path


@dataclass(frozen=True)
class Rule:
    """One static contract the linter enforces."""
    id: str
    summary: str
    layer: str            # "ast" | "pallas" | "shapes"


# The rule catalog (kept in sync with README.md; tests assert the sync).
RULES: dict[str, Rule] = {r.id: r for r in (
    # --- layer 1: AST checkers -------------------------------------------
    Rule("prng-reuse", "a PRNG key is consumed by two samplers without an "
         "intervening split/fold_in/reassignment", "ast"),
    Rule("lossy-codec-no-key", "a codec encode/apply (or quantize_dequantize)"
         " call passes key=None on a potentially lossy path", "ast"),
    Rule("host-np-in-jit", "host-side numpy call inside a jit-decorated "
         "function or a Pallas kernel body", "ast"),
    Rule("nonfrozen-static", "a non-frozen dataclass flows into jit "
         "static_argnames (unhashable static arg)", "ast"),
    Rule("mutable-default", "mutable default argument (list/dict/set) in a "
         "function signature", "ast"),
    Rule("float64-literal", "explicit float64 dtype in accelerator code "
         "(jax default is x64-disabled; this silently truncates)", "ast"),
    Rule("fault-free-default", "a FaultConfig hazard field defaults to a "
         "non-zero value (a default-on fault would break the fault-free "
         "bit-identity goldens)", "ast"),
    Rule("telemetry-off-default", "a 'telemetry' parameter is required or "
         "defaults to an enabled value (observability must be opt-in: "
         "telemetry=None keeps instrumented code bit-inert)", "ast"),
    Rule("client-loop-in-wireless", "a python-level loop over the client "
         "axis in the vectorized wireless modules (population/"
         "scheduler_core must stay O(1) python per round at 10**6 "
         "clients)", "ast"),
    # --- layer 2: Pallas kernel contracts --------------------------------
    Rule("pallas-triplet", "a kernels/<name>/ package is missing one of "
         "kernel.py / ref.py / ops.py", "pallas"),
    Rule("pallas-interpret", "a pallas_call has no interpret= fallback "
         "parameter (kernel cannot run off-TPU)", "pallas"),
    Rule("pallas-lane", "a resolvable trailing BlockSpec tile dim is not a "
         "multiple of the 128-wide TPU lane", "pallas"),
    Rule("pallas-divisibility", "a pallas_call wrapper has no divisibility "
         "assert guarding its tile grid", "pallas"),
    Rule("pallas-vmem", "estimated per-program VMEM footprint (blocks + "
         "scratch at default tile sizes) exceeds the budget", "pallas"),
    Rule("kernel-ref-signature", "kernel entry and ref oracle public "
         "signatures do not match", "pallas"),
    # --- layer 3: shape / accounting audit -------------------------------
    Rule("comm-cut-size", "CommModel.cut_size disagrees with the abstract "
         "(eval_shape) cut activation size", "shapes"),
    Rule("comm-client-params", "CommModel Z_0/Z totals disagree with the "
         "abstract parameter tree under the split spec", "shapes"),
    Rule("comm-bits", "CommModel bit accounting violates a payload identity "
         "for the configured codec", "shapes"),
)}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str             # repo-relative where possible
    line: int             # 1-based; 0 for file/config-level findings
    message: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"


_DISABLE_LINE = re.compile(r"#\s*reprolint:\s*disable=([\w,-]+)")
_DISABLE_FILE = re.compile(r"#\s*reprolint:\s*disable-file=([\w,-]+)")


@dataclass
class Suppressions:
    """Which (line, rule) pairs a source file opted out of."""
    file_rules: set = field(default_factory=set)
    line_rules: dict = field(default_factory=dict)   # line -> set of rules

    @classmethod
    def scan(cls, source: str) -> "Suppressions":
        sup = cls()
        for i, text in enumerate(source.splitlines(), start=1):
            m = _DISABLE_FILE.search(text)
            if m:
                sup.file_rules.update(m.group(1).split(","))
            m = _DISABLE_LINE.search(text)
            if m:
                sup.line_rules.setdefault(i, set()).update(
                    m.group(1).split(","))
        return sup

    def covers(self, finding: Finding) -> bool:
        if finding.rule in self.file_rules or "all" in self.file_rules:
            return True
        rules = self.line_rules.get(finding.line, ())
        return finding.rule in rules or "all" in rules


def python_files(paths: list[str], root: Path | None = None) -> list[Path]:
    """Expand files/directories into a sorted list of .py files."""
    root = root or Path.cwd()
    out: set[Path] = set()
    for p in paths:
        pp = Path(p)
        if not pp.is_absolute():
            pp = root / pp
        if pp.is_file() and pp.suffix == ".py":
            out.add(pp)
        elif pp.is_dir():
            out.update(f for f in pp.rglob("*.py"))
    return sorted(out)


def relpath(path: Path, root: Path) -> str:
    try:
        return str(path.relative_to(root))
    except ValueError:
        return str(path)


@dataclass
class Report:
    findings: list = field(default_factory=list)     # surviving findings
    suppressed: list = field(default_factory=list)   # suppressed findings
    files_checked: int = 0

    def extend(self, findings: list[Finding], sup: Suppressions | None):
        for f in findings:
            if sup is not None and sup.covers(f):
                self.suppressed.append(f)
            else:
                self.findings.append(f)

    @property
    def ok(self) -> bool:
        return not self.findings

    def as_dict(self) -> dict:
        counts: dict[str, int] = {}
        for f in self.findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return {
            "tool": "reprolint",
            "files_checked": self.files_checked,
            "counts": counts,
            "findings": [f.as_dict() for f in self.findings],
            "suppressed": [f.as_dict() for f in self.suppressed],
            "ok": self.ok,
        }

    def to_json(self) -> str:
        return json.dumps(self.as_dict(), indent=2)
