"""Layer 2: Pallas kernel contract checker.

The repo's kernels live as ``kernels/<name>/{kernel,ref,ops}.py`` triplets:
the Pallas TPU kernel, a pure-jnp oracle it must stay bit-comparable with,
and the shape-generic jitted wrapper.  The runtime tests compare numerics;
this checker verifies the *structural* contracts without executing anything
on a TPU:

- ``pallas-triplet``       — all three files exist;
- ``pallas-interpret``     — every ``pallas_call`` threads an ``interpret``
  parameter (the CPU fallback this container, CI, and the tests rely on);
- ``pallas-lane``          — every resolvable trailing BlockSpec tile dim
  is 1 (scalar operand) or a multiple of the 128-wide TPU lane;
- ``pallas-divisibility``  — the wrapper guarding a tiled grid asserts the
  padded dims divide by the tile (``x % block == 0`` style);
- ``pallas-vmem``          — the per-program VMEM footprint estimated from
  the default tile sizes (BlockSpec tiles + scratch, f32) fits the budget;
- ``kernel-ref-signature`` — some public oracle in ref.py is call-compatible
  with the kernel entry (required positionals form a prefix of the kernel's
  parameters and every oracle parameter exists on the kernel).

Resolution is static: tile dims are resolved through literal ints, module
constants, and keyword-only defaults; unresolvable dims (e.g. a head dim
taken from the input shape) are skipped for the lane check and assumed
``DEFAULT_UNRESOLVED_DIM`` wide for the VMEM estimate.
"""

from __future__ import annotations

import ast
from pathlib import Path

from tools.reprolint.engine import Finding

LANE = 128
DEFAULT_UNRESOLVED_DIM = 128          # assumed width of e.g. a head dim
BYTES_PER_ELEMENT = 4                 # kernels compute in f32
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


def _attr_chain(node: ast.AST) -> list[str]:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def _module_constants(tree: ast.Module) -> dict[str, int]:
    consts: dict[str, int] = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, int)):
            consts[node.targets[0].id] = node.value.value
    return consts


def _param_defaults(fn: ast.FunctionDef, consts: dict[str, int]) -> dict[str, int]:
    """Resolvable integer defaults of a function's parameters."""
    out: dict[str, int] = {}
    args = fn.args
    pos = args.posonlyargs + args.args
    for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        v = _resolve(d, consts, {})
        if v is not None:
            out[a.arg] = v
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if d is None:
            continue
        v = _resolve(d, consts, {})
        if v is not None:
            out[a.arg] = v
    return out


def _resolve(node: ast.AST, consts: dict[str, int],
             defaults: dict[str, int]) -> int | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        if node.id in consts:
            return consts[node.id]
        return defaults.get(node.id)
    return None


def _params_of(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]


def _required_positionals(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    pos = a.posonlyargs + a.args
    n_required = len(pos) - len(a.defaults)
    return [p.arg for p in pos[:n_required]]


def _has_mod_assert(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Assert):
            for sub in ast.walk(node.test):
                if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod):
                    return True
    return False


def _block_shapes(call: ast.Call):
    """(lineno, [dim nodes]) for every BlockSpec tuple in a pallas_call."""
    for node in ast.walk(call):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not (chain and chain[-1] == "BlockSpec"):
            continue
        if node.args and isinstance(node.args[0], ast.Tuple):
            yield node.lineno, node.args[0].elts


def _scratch_shapes(call: ast.Call):
    """[dim nodes] per VMEM scratch declaration in a pallas_call."""
    for kw in call.keywords:
        if kw.arg != "scratch_shapes":
            continue
        for node in ast.walk(kw.value):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if chain and chain[-1] in ("VMEM", "MemoryRef"):
                if node.args and isinstance(node.args[0], ast.Tuple):
                    yield node.args[0].elts


def check_kernel_module(path: Path, rel: str, *,
                        vmem_budget: int = DEFAULT_VMEM_BUDGET) -> list[Finding]:
    """Contracts on one kernel.py: interpret, lane, divisibility, VMEM."""
    source = path.read_text()
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("pallas-interpret", rel, e.lineno or 0,
                        f"kernel module does not parse: {e.msg}")]
    consts = _module_constants(tree)
    out: list[Finding] = []

    for fn in [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)]:
        calls = [c for c in ast.walk(fn) if isinstance(c, ast.Call)
                 and (ch := _attr_chain(c.func)) and ch[-1] == "pallas_call"]
        if not calls:
            continue
        defaults = _param_defaults(fn, consts)
        if not _has_mod_assert(fn):
            out.append(Finding(
                "pallas-divisibility", rel, fn.lineno,
                f"{fn.name!r} wraps a pallas_call but never asserts that "
                f"the tiled dims divide by the tile (x % block == 0); an "
                f"indivisible input would silently read out of bounds"))
        for call in calls:
            if not any(kw.arg == "interpret" for kw in call.keywords):
                out.append(Finding(
                    "pallas-interpret", rel, call.lineno,
                    f"pallas_call in {fn.name!r} has no interpret= "
                    f"parameter: the kernel cannot fall back to CPU "
                    f"(tests, CI, and this container need interpret=True)"))
            vmem_bytes = 0
            for lineno, dims in _block_shapes(call):
                resolved = [_resolve(d, consts, defaults) for d in dims]
                trailing = resolved[-1] if resolved else None
                if trailing is not None and trailing != 1 \
                        and trailing % LANE != 0:
                    out.append(Finding(
                        "pallas-lane", rel, lineno,
                        f"trailing BlockSpec tile dim {trailing} in "
                        f"{fn.name!r} is neither 1 (scalar) nor a multiple "
                        f"of the {LANE}-wide TPU lane"))
                n = 1
                for r in resolved:
                    n *= r if r is not None else DEFAULT_UNRESOLVED_DIM
                vmem_bytes += n * BYTES_PER_ELEMENT
            for dims in _scratch_shapes(call):
                n = 1
                for d in dims:
                    r = _resolve(d, consts, defaults)
                    n *= r if r is not None else DEFAULT_UNRESOLVED_DIM
                vmem_bytes += n * BYTES_PER_ELEMENT
            if vmem_bytes > vmem_budget:
                out.append(Finding(
                    "pallas-vmem", rel, call.lineno,
                    f"estimated VMEM footprint of {fn.name!r} at default "
                    f"tiles is {vmem_bytes / 2**20:.1f} MiB > budget "
                    f"{vmem_budget / 2**20:.1f} MiB (blocks + scratch, "
                    f"f32, unresolved dims assumed "
                    f"{DEFAULT_UNRESOLVED_DIM})"))
    return out


def check_kernel_ref_signatures(kernel_path: Path, ref_path: Path,
                                rel: str) -> list[Finding]:
    """Some oracle in ref.py must be call-compatible with the kernel entry."""
    ktree = ast.parse(kernel_path.read_text())
    rtree = ast.parse(ref_path.read_text())
    entries = []
    for fn in [n for n in ast.walk(ktree) if isinstance(n, ast.FunctionDef)]:
        if any((ch := _attr_chain(c.func)) and ch[-1] == "pallas_call"
               for c in ast.walk(fn) if isinstance(c, ast.Call)):
            entries.append(fn)
    refs = [n for n in rtree.body if isinstance(n, ast.FunctionDef)
            and not n.name.startswith("_")]
    if not entries or not refs:
        return [Finding("kernel-ref-signature", rel, 0,
                        "could not pair a pallas_call entry in kernel.py "
                        "with a public oracle in ref.py")]
    out = []
    for entry in entries:
        kparams = _params_of(entry)
        ok = False
        for ref in refs:
            req = _required_positionals(ref)
            if (req and req == kparams[:len(req)]
                    and set(_params_of(ref)) <= set(kparams)):
                ok = True
                break
        if not ok:
            out.append(Finding(
                "kernel-ref-signature", rel, entry.lineno,
                f"no public oracle in ref.py is call-compatible with "
                f"kernel entry {entry.name}({', '.join(kparams)}): the "
                f"oracle's required positionals must prefix the kernel's "
                f"parameters so the bit-comparability tests can drive "
                f"both with one argument list"))
    return out


def check_kernels_root(root: Path, repo_root: Path, *,
                       vmem_budget: int = DEFAULT_VMEM_BUDGET) -> list[dict]:
    """All pallas-layer checks for one kernels/ directory.

    Returns ``[{path, findings}]`` so the caller can apply each file's own
    suppressions."""
    results = []
    for pkg in sorted(p for p in root.iterdir() if p.is_dir()):
        files = {n: pkg / f"{n}.py" for n in ("kernel", "ref", "ops")}
        missing = [n for n, p in files.items() if not p.exists()]
        rel_pkg = str(pkg.relative_to(repo_root)) if pkg.is_relative_to(
            repo_root) else str(pkg)
        if missing:
            if len(missing) == 3:
                continue                     # not a kernel package at all
            results.append({"path": None, "findings": [Finding(
                "pallas-triplet", rel_pkg, 0,
                f"kernel package is missing {', '.join(sorted(missing))}: "
                f"every kernel ships as a kernel/ref/ops triplet so the "
                f"oracle and wrapper cannot drift away")]})
            continue
        krel = str(files["kernel"].relative_to(repo_root)) \
            if files["kernel"].is_relative_to(repo_root) else str(files["kernel"])
        fnd = check_kernel_module(files["kernel"], krel,
                                  vmem_budget=vmem_budget)
        fnd += check_kernel_ref_signatures(files["kernel"], files["ref"], krel)
        results.append({"path": files["kernel"], "findings": fnd})
    return results
