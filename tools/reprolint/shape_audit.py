"""Layer 3: shape / accounting auditor.

The wireless scheduler prices every decision off ``CommModel`` tables —
``Z_c`` (cut activation elements), ``Z_0``/``Z`` (client / total params)
and the Remark-1 bit formulas.  Those numbers are *derived twice* in this
repo: once as closed-form formulas (``cnn.cut_activation_size``,
``seq_len * d_model``, ``count_parts``) and once implicitly by the actual
model code.  This auditor cross-checks the two derivations abstractly with
``jax.eval_shape`` — no concrete parameter is ever materialized — for every
registry config × cut candidate:

- ``comm-cut-size``      — ``CommModel.cut_size`` vs the traced cut-layer
  activation shape (CNN: ``client_forward`` under eval_shape; LM: the embed
  table's trailing dim × seq_len);
- ``comm-client-params`` — ``Z_0``/``Z`` vs an independent recount of the
  abstract parameter tree (CNN: top-level client keys; LM: part_masks);
- ``comm-bits``          — the payload/bit identities: per-codec
  ``payload_bits`` re-derived from first principles by codec type, plus the
  Phi_local / Phi_off / Phi_PHSFL (Eq. 17) composition identities.

Findings carry a config-level pseudo-path (``<registry:NAME@cut=C>``), so
they cannot be line-suppressed — an accounting mismatch has no single
offending line and must be fixed, not silenced.
"""

from __future__ import annotations

import math

from tools.reprolint.engine import Finding

CNN_CODEC_NAMES = (None, "fp32", "int8", "int4", "topk", "fp8")


def _loc(kind: str, name: str, cut) -> str:
    return f"<{kind}:{name}@cut={cut}>"


def _expected_payload_bits(codec, n: int, omega: int) -> int:
    """Re-derive what one n-element tensor should cost on the wire, from
    the codec's *declared fields* rather than its payload_bits method."""
    from repro.compress.codecs import (Fp8Codec, IdentityCodec, TopKCodec,
                                       UniformQuantCodec)
    if codec is None:
        return n * (omega + 1)
    if isinstance(codec, UniformQuantCodec):
        return n * codec.bits + codec.scale_bits
    if isinstance(codec, TopKCodec):
        k = max(1, int(n * codec.frac))
        return k * (codec.value_bits + math.ceil(math.log2(max(n, 2))))
    if isinstance(codec, Fp8Codec):
        return n * 8 + codec.scale_bits
    if isinstance(codec, IdentityCodec):
        return n * (omega + 1) if codec.bits_per_element is None \
            else n * codec.bits_per_element
    raise TypeError(f"unknown codec type {type(codec).__name__}")


def _check_bits(comm, codecs, loc: str) -> list[Finding]:
    """The Remark-1 / Eq.-17 bit identities for one comm model."""
    out = []
    n_act = comm.batch_size * comm.cut_size
    act = codecs.activations if codecs is not None else None
    grad = codecs.gradients if codecs is not None else None
    off = codecs.offload if codecs is not None else None
    checks = [
        ("phi_activation_up_bits", comm.phi_activation_up_bits(),
         _expected_payload_bits(act, n_act, comm.omega)),
        ("phi_grad_down_bits", comm.phi_grad_down_bits(),
         _expected_payload_bits(grad, n_act, comm.omega)),
        ("phi_off_bits", comm.phi_off_bits(),
         _expected_payload_bits(off, comm.client_params, comm.omega)),
        ("phi_activation_bits", comm.phi_activation_bits(),
         n_act * (comm.omega + 1)),
        ("phi_indices_bits", comm.phi_indices_bits(),
         comm.batch_size
         * (math.ceil(math.log2(max(comm.dataset_size, 2))) + 1)),
        ("phi_local_bits", comm.phi_local_bits(),
         comm.batches_per_epoch * (comm.phi_activation_up_bits()
                                   + comm.phi_grad_down_bits()
                                   + comm.phi_indices_bits())),
        ("phi_phsfl_bits(3)", comm.phi_phsfl_bits(3),
         3 * comm.phi_local_bits() + 2 * comm.phi_off_bits()),
        ("phi_hfl_bits", comm.phi_hfl_bits(),
         2 * comm.total_params * (comm.omega + 1)),
    ]
    for name, got, want in checks:
        if got != want:
            out.append(Finding(
                "comm-bits", loc, 0,
                f"{name} = {got} but the payload identity re-derived from "
                f"the codec fields gives {want}"))
    return out


def _mask_count(params, mask) -> int:
    import jax
    import numpy as np
    total = 0
    for leaf, m in zip(jax.tree.leaves(params), jax.tree.leaves(mask)):
        if m:
            total += int(np.prod(leaf.shape))
    return total


def audit_cnn(dataset_size: int = 1000) -> list[Finding]:
    """Every CNN cut candidate × codec preset, abstractly."""
    import jax
    import numpy as np

    from repro.compress import link_codecs
    from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
    from repro.core.comm import comm_for_cnn
    from repro.models import cnn

    out: list[Finding] = []
    params = jax.eval_shape(lambda k: cnn.init(k, CNN_CFG),
                            jax.random.PRNGKey(0))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    for cut in cnn.CUT_CANDIDATES:
        # trace the client block itself: the formula cut_activation_size
        # must agree with what client_forward actually produces
        x = jax.ShapeDtypeStruct(
            (1, CNN_CFG.image_size, CNN_CFG.image_size, CNN_CFG.channels),
            jax.numpy.float32)
        o_fp = jax.eval_shape(
            lambda p, xx, c=cut: cnn.client_forward(p, xx, c), params, x)
        z_c_traced = int(np.prod(o_fp.shape))
        client_keys = cnn.client_keys_for(cut)
        z0_recount = sum(int(np.prod(l.shape))
                         for k in client_keys
                         for l in jax.tree.leaves(params[k]))
        for codec_name in CNN_CODEC_NAMES:
            codecs = link_codecs(codec_name) if codec_name else None
            loc = _loc("cnn", f"{CNN_CFG.name}/{codec_name or 'raw'}", cut)
            comm = comm_for_cnn(CNN_CFG, dataset_size, cut=cut, codecs=codecs)
            if comm.cut_size != z_c_traced:
                out.append(Finding(
                    "comm-cut-size", loc, 0,
                    f"CommModel.cut_size={comm.cut_size} but eval_shape of "
                    f"client_forward at cut={cut!r} gives {z_c_traced} "
                    f"elements per sample"))
            if comm.client_params != z0_recount:
                out.append(Finding(
                    "comm-client-params", loc, 0,
                    f"Z_0={comm.client_params} but the abstract param tree "
                    f"holds {z0_recount} elements under client keys "
                    f"{client_keys}"))
            if comm.total_params != total:
                out.append(Finding(
                    "comm-client-params", loc, 0,
                    f"Z={comm.total_params} but the abstract param tree "
                    f"holds {total} elements in total"))
            out.extend(_check_bits(comm, codecs, loc))
    return out


def lm_cut_candidates(cfg) -> tuple:
    """The depth candidates the cut controller would price for this arch:
    the shallowest split (1 block) and the config's own default.  Encoder-
    decoder archs have a frontend-based split — only the default cut."""
    if cfg.encdec is not None:
        return (None,)
    return tuple(sorted({1, int(cfg.n_client_layers)}))


def audit_lm(cfg, seq_len: int = 64, dataset_size: int = 1000) -> list[Finding]:
    """One LM registry config, every cut candidate, abstractly."""
    import dataclasses

    import jax
    import numpy as np

    from repro.core.comm import comm_for_lm
    from repro.core.split import part_masks, split_spec_for
    from repro.models import build_model

    out: list[Finding] = []
    for cut in lm_cut_candidates(cfg):
        loc = _loc("lm", cfg.name, cut if cut is not None else "default")
        comm = comm_for_lm(cfg, seq_len, dataset_size, cut=cut)
        used = cfg if cut is None or cut == cfg.n_client_layers \
            else dataclasses.replace(cfg, n_client_layers=int(cut))
        model = build_model(used)
        params = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
        # Z_c: the cut tensor is the residual stream, so its trailing dim
        # must equal the embed table's trailing dim in the abstract tree
        from repro.utils.tree import map_with_path
        embed_dims: list[int] = []

        def note_embed(path, leaf):
            if path.startswith("embed") and len(leaf.shape) >= 2:
                embed_dims.append(int(leaf.shape[-1]))
            return leaf

        map_with_path(note_embed, params)
        if embed_dims and comm.cut_size != seq_len * embed_dims[0]:
            out.append(Finding(
                "comm-cut-size", loc, 0,
                f"CommModel.cut_size={comm.cut_size} but the abstract embed "
                f"table is {embed_dims[0]}-wide, so the residual-stream cut "
                f"tensor holds {seq_len * embed_dims[0]} elements per "
                f"sample at seq_len={seq_len}"))
        if not embed_dims:
            out.append(Finding(
                "comm-cut-size", loc, 0,
                "no embed/* leaf in the abstract param tree: the auditor "
                "cannot tie cut_size to the model's residual width"))
        # Z_0 / Z: recount through the mask path (count_parts is what
        # comm_for_lm itself used; part_masks + explicit leaf walk is the
        # independent route to the same partition)
        masks = part_masks(params, split_spec_for(used))
        z0 = _mask_count(params, masks["client"])
        z = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        if comm.client_params != z0:
            out.append(Finding(
                "comm-client-params", loc, 0,
                f"Z_0={comm.client_params} but the client part_mask over "
                f"the abstract tree selects {z0} elements"))
        if comm.total_params != z:
            out.append(Finding(
                "comm-client-params", loc, 0,
                f"Z={comm.total_params} but the abstract tree holds {z} "
                f"elements in total"))
        out.extend(_check_bits(comm, None, loc))
    return out


def audit_all(seq_len: int = 64, dataset_size: int = 1000,
              archs: dict | None = None) -> tuple[list[Finding], int]:
    """CNN + every registry LM config.  Returns (findings, configs_checked)."""
    from repro.configs.registry import ARCHS

    findings = audit_cnn(dataset_size)
    checked = 1
    for cfg in (archs or ARCHS).values():
        findings.extend(audit_lm(cfg, seq_len, dataset_size))
        checked += 1
    return findings, checked
