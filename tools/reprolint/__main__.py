"""reprolint CLI — the tier-0 gate.

    python -m tools.reprolint src tests benchmarks examples
    python -m tools.reprolint --json report.json src
    python -m tools.reprolint --list-rules

Exit status 0 iff no non-suppressed finding survives.  Layer 2 runs on any
``kernels/`` package found under the given paths; layer 3 (the eval_shape
accounting audit) runs whenever the repo's ``src/repro`` is in scope and
can be disabled with ``--no-shape-audit`` (it imports jax and traces every
registry config, which the pure-AST layers never need).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.reprolint import astchecks, engine
from tools.reprolint import pallas_contracts


def _find_kernels_roots(paths: list[str], root: Path) -> list[Path]:
    roots: set[Path] = set()
    for p in paths:
        pp = Path(p)
        if not pp.is_absolute():
            pp = root / pp
        if not pp.is_dir():
            continue
        if pp.name == "kernels":
            roots.add(pp)
        roots.update(d for d in pp.rglob("kernels") if d.is_dir())
    return sorted(roots)


def _covers_repro_src(paths: list[str], root: Path) -> bool:
    for p in paths:
        pp = Path(p)
        if not pp.is_absolute():
            pp = root / pp
        if (pp / "repro").is_dir() or pp.name == "repro":
            return True
    return False


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="static analysis for the repo's JAX/Pallas/accounting "
                    "contracts (tier-0 gate)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to check "
                         "(default: src tests benchmarks examples)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write the JSON report to FILE ('-' for stdout)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--vmem-budget-mib", type=float, default=16.0,
                    help="per-program VMEM budget for pallas-vmem (MiB)")
    ap.add_argument("--no-shape-audit", action="store_true",
                    help="skip the eval_shape accounting audit (layer 3)")
    ap.add_argument("--seq-len", type=int, default=64,
                    help="abstract sequence length for the LM shape audit")
    args = ap.parse_args(argv)

    if args.list_rules:
        width = max(len(r) for r in engine.RULES)
        for rule in engine.RULES.values():
            print(f"{rule.id:<{width}}  [{rule.layer}]  {rule.summary}")
        return 0

    root = Path.cwd()
    paths = args.paths or ["src", "tests", "benchmarks", "examples"]
    report = engine.Report()

    # layer 1: AST checks on every python file in scope
    for f in engine.python_files(paths, root):
        source = f.read_text()
        rel = engine.relpath(f, root)
        report.files_checked += 1
        report.extend(astchecks.check_source(source, rel),
                      engine.Suppressions.scan(source))

    # layer 2: pallas kernel contracts on every kernels/ package in scope
    budget = int(args.vmem_budget_mib * 1024 * 1024)
    for kroot in _find_kernels_roots(paths, root):
        for entry in pallas_contracts.check_kernels_root(
                kroot, root, vmem_budget=budget):
            sup = None
            if entry["path"] is not None:
                sup = engine.Suppressions.scan(entry["path"].read_text())
            report.extend(entry["findings"], sup)

    # layer 3: eval_shape accounting audit (needs the repro package)
    if not args.no_shape_audit and _covers_repro_src(paths, root):
        src = root / "src"
        if src.is_dir() and str(src) not in sys.path:
            sys.path.insert(0, str(src))
        from tools.reprolint import shape_audit
        findings, checked = shape_audit.audit_all(seq_len=args.seq_len)
        report.extend(findings, None)
        print(f"shape audit: {checked} configs x cut candidates checked",
              file=sys.stderr)

    if args.json:
        if args.json == "-":
            print(report.to_json())
        else:
            Path(args.json).write_text(report.to_json() + "\n")

    for f in report.findings:
        print(f.render())
    n, s = len(report.findings), len(report.suppressed)
    print(f"reprolint: {report.files_checked} files, {n} finding(s), "
          f"{s} suppressed", file=sys.stderr)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
