"""Layer 1: AST checkers for JAX-specific hazards.

These are heuristic, purely syntactic checks — no imports are executed.
Each checker errs toward precision (few false positives) because the lint
gate fails CI on any non-suppressed finding; anything genuinely intentional
carries a ``# reprolint: disable=<rule>`` with a justification comment.

Rules (see engine.RULES / README.md):

- ``prng-reuse``        — one PRNG key variable consumed by two sampler
  calls without an intervening ``split``/``fold_in``/reassignment.  Loop
  bodies are simulated twice, so a sampler drawing from a loop-invariant
  key is caught (it would replay identical noise every iteration — the
  order-dependent-flake class of bug).
- ``lossy-codec-no-key`` — a codec-style ``.apply``/``.encode`` (or
  ``quantize_dequantize``) call whose key argument is the literal ``None``:
  the stochastic path would silently fall back to fixed rounding noise.
- ``host-np-in-jit``    — a host ``np.*`` call inside a jit-decorated
  function or a Pallas kernel body (concrete numpy ops break under tracing
  or silently constant-fold the trace-time value).
- ``nonfrozen-static``  — a non-frozen dataclass annotation on a parameter
  named in ``static_argnames`` (unhashable static args fail inside jit,
  far from the definition).
- ``mutable-default``   — list/dict/set default arguments.
- ``float64-literal``   — explicit float64 dtypes in accelerator code;
  jax runs x64-disabled, so these silently truncate to float32.
- ``fault-free-default`` — a class named ``FaultConfig`` whose hazard
  fields (``erasure_prob``, ``crash_hazard``, ``backoff_s``,
  ``es_outage_trace``) default to anything but zero/empty.  The whole
  fault subsystem's bit-identity story rests on ``FaultConfig()`` meaning
  "no faults"; a default-on hazard would silently fork every golden.
- ``telemetry-off-default`` — a ``telemetry`` parameter that is required
  or defaults to an enabled value.  Observability (``repro.telemetry``)
  must be strictly opt-in: the all-defaults call of every instrumented
  entry point has to be bit-inert, or the goldens run instrumented.
- ``client-loop-in-wireless`` — a python ``for`` loop (or comprehension)
  over the CLIENT axis inside the vectorized wireless modules
  (``wireless/population.py``, ``wireless/scheduler_core.py``).  Those
  modules exist to keep per-round python work O(1) in the number of
  registered clients; an innocent ``for u in range(self.U)`` there is a
  10**6-iteration regression.  Loops over other axes (edge servers,
  k-means iterations, chunk tails) are fine — only loops whose range/
  iterable names a client-axis quantity are flagged.
"""

from __future__ import annotations

import ast

from tools.reprolint.engine import Finding

# jax.random.* calls that DERIVE keys rather than consuming them
_DERIVERS = {"split", "fold_in", "PRNGKey", "key", "key_data",
             "wrap_key_data", "clone", "fold_in_str"}
# bare sampler names treated as consumers even without a jax.random. prefix
_SAMPLERS = {"uniform", "normal", "bernoulli", "truncated_normal",
             "categorical", "gumbel", "exponential", "choice", "randint",
             "permutation", "poisson", "laplace", "beta", "gamma",
             "dirichlet", "rademacher", "bits", "ball", "orthogonal",
             "t", "cauchy", "logistic", "maxwell", "multivariate_normal"}


def _attr_chain(node: ast.AST) -> list[str]:
    """['jax', 'random', 'uniform'] for jax.random.uniform; [] if not a
    plain name/attribute chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def check_source(source: str, path: str) -> list[Finding]:
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("host-np-in-jit", path, e.lineno or 0,
                        f"file does not parse: {e.msg}")]
    out: list[Finding] = []
    out += _check_prng_reuse(tree, path)
    out += _check_codec_key(tree, path)
    out += _check_np_in_jit(tree, path)
    out += _check_nonfrozen_static(tree, path)
    out += _check_mutable_default(tree, path)
    out += _check_float64(tree, path)
    out += _check_fault_free_default(tree, path)
    out += _check_telemetry_off_default(tree, path)
    out += _check_client_loop(tree, path)
    return out


# ---------------------------------------------------------------------------
# prng-reuse
# ---------------------------------------------------------------------------
def _is_sampler_call(call: ast.Call) -> bool:
    chain = _attr_chain(call.func)
    if not chain:
        return False
    name = chain[-1]
    if name in _DERIVERS:
        return False
    if len(chain) >= 2 and chain[-2] == "random":
        return True          # jax.random.<anything non-deriving>
    return name in _SAMPLERS


def _is_deriver_call(call: ast.Call) -> bool:
    chain = _attr_chain(call.func)
    return bool(chain) and chain[-1] in _DERIVERS


def _key_args(call: ast.Call):
    """Bare-name arguments of a sampler call (candidate key variables).

    Only the first positional argument (or an explicit ``key=``) is the key
    slot in every jax.random sampler signature; later args are shapes,
    bounds, and dtypes."""
    names = []
    if call.args and isinstance(call.args[0], ast.Name):
        names.append(call.args[0].id)
    for kw in call.keywords:
        if kw.arg == "key" and isinstance(kw.value, ast.Name):
            names.append(kw.value.id)
    return names


class _KeyState:
    """Names consumed so far -> line of first consumption."""

    def __init__(self):
        self.consumed: dict[str, int] = {}

    def copy(self) -> "_KeyState":
        s = _KeyState()
        s.consumed = dict(self.consumed)
        return s

    def merge(self, other: "_KeyState"):
        for k, v in other.consumed.items():
            self.consumed.setdefault(k, v)


def _walk_stmts(stmts, state: _KeyState, path: str, out, seen):
    for st in stmts:
        _walk_stmt(st, state, path, out, seen)


def _expr_calls(node: ast.AST):
    """Calls in an expression, outermost-first, skipping nested defs."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            yield sub


def _consume_in_expr(node: ast.AST, state: _KeyState, path: str, out, seen):
    for call in _expr_calls(node):
        if _is_deriver_call(call):
            # split(key)/fold_in(key, …) re-derive: the base key may be
            # reused afterwards (the canonical chain pattern)
            for name in _key_args(call):
                state.consumed.pop(name, None)
            continue
        if not _is_sampler_call(call):
            continue
        for name in _key_args(call):
            if name in state.consumed:
                tag = (path, call.lineno, name)
                if tag not in seen:
                    seen.add(tag)
                    out.append(Finding(
                        "prng-reuse", path, call.lineno,
                        f"key {name!r} already consumed at line "
                        f"{state.consumed[name]}; split/fold_in before "
                        f"drawing again"))
            else:
                state.consumed[name] = call.lineno


def _assigned_names(target: ast.AST):
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _assigned_names(elt)


def _walk_stmt(st: ast.stmt, state: _KeyState, path: str, out, seen):
    if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
        inner = _KeyState()
        _walk_stmts(st.body, inner, path, out, seen)
        return
    if isinstance(st, ast.ClassDef):
        _walk_stmts(st.body, _KeyState(), path, out, seen)
        return
    if isinstance(st, (ast.If,)):
        _consume_in_expr(st.test, state, path, out, seen)
        a, b = state.copy(), state.copy()
        _walk_stmts(st.body, a, path, out, seen)
        _walk_stmts(st.orelse, b, path, out, seen)
        state.merge(a)
        state.merge(b)
        return
    if isinstance(st, (ast.For, ast.AsyncFor, ast.While)):
        if isinstance(st, ast.While):
            _consume_in_expr(st.test, state, path, out, seen)
        else:
            _consume_in_expr(st.iter, state, path, out, seen)
            for name in _assigned_names(st.target):
                state.consumed.pop(name, None)
        # simulate two iterations: a key consumed on pass 1 and not
        # re-derived before pass 2 replays identical noise every iteration
        _walk_stmts(st.body, state, path, out, seen)
        _walk_stmts(st.body, state, path, out, seen)
        _walk_stmts(st.orelse, state, path, out, seen)
        return
    if isinstance(st, (ast.With, ast.AsyncWith)):
        _walk_stmts(st.body, state, path, out, seen)
        return
    if isinstance(st, ast.Try):
        _walk_stmts(st.body, state, path, out, seen)
        for h in st.handlers:
            _walk_stmts(h.body, state.copy(), path, out, seen)
        _walk_stmts(st.orelse, state, path, out, seen)
        _walk_stmts(st.finalbody, state, path, out, seen)
        return
    # plain statement: evaluate RHS first, then clear reassigned names
    for node in ast.iter_child_nodes(st):
        _consume_in_expr(node, state, path, out, seen)
    if isinstance(st, ast.Assign):
        for t in st.targets:
            for name in _assigned_names(t):
                state.consumed.pop(name, None)
    elif isinstance(st, (ast.AnnAssign, ast.AugAssign)):
        for name in _assigned_names(st.target):
            state.consumed.pop(name, None)


def _check_prng_reuse(tree: ast.Module, path: str) -> list[Finding]:
    out: list[Finding] = []
    _walk_stmts(tree.body, _KeyState(), path, out, set())
    return out


# ---------------------------------------------------------------------------
# lossy-codec-no-key
# ---------------------------------------------------------------------------
def _check_codec_key(tree: ast.Module, path: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not chain:
            continue
        name = chain[-1]
        key_arg = None
        if name in ("apply", "encode") and len(chain) >= 2:
            # codec API: first positional argument is the key
            if node.args:
                key_arg = node.args[0]
        elif name == "quantize_dequantize":
            if len(node.args) >= 2:
                key_arg = node.args[1]
        for kw in node.keywords:
            if kw.arg == "key":
                key_arg = kw.value
        if (key_arg is not None and isinstance(key_arg, ast.Constant)
                and key_arg.value is None):
            out.append(Finding(
                "lossy-codec-no-key", path, node.lineno,
                f"{'.'.join(chain)}(...) passes key=None: a lossy codec "
                f"would silently reuse fixed rounding noise; thread a real "
                f"key (or guard the lossless case explicitly)"))
    return out


# ---------------------------------------------------------------------------
# host-np-in-jit
# ---------------------------------------------------------------------------
def _is_jit_decorator(dec: ast.AST) -> bool:
    chain = _attr_chain(dec)
    if chain and chain[-1] == "jit":
        return True
    if isinstance(dec, ast.Call):
        chain = _attr_chain(dec.func)
        if chain and chain[-1] == "jit":
            return True
        if chain and chain[-1] == "partial" and dec.args:
            inner = _attr_chain(dec.args[0])
            return bool(inner) and inner[-1] == "jit"
    return False


def _pallas_kernel_names(tree: ast.Module) -> set[str]:
    """Function names passed (possibly via functools.partial) as the first
    argument to a pallas_call, plus names bound to such partials."""
    partial_of: dict[str, str] = {}
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and isinstance(node.value, ast.Call)
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            chain = _attr_chain(node.value.func)
            if chain and chain[-1] == "partial" and node.value.args:
                inner = _attr_chain(node.value.args[0])
                if inner:
                    partial_of[node.targets[0].id] = inner[-1]
    names: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not (chain and chain[-1] == "pallas_call" and node.args):
            continue
        first = node.args[0]
        if isinstance(first, ast.Name):
            names.add(partial_of.get(first.id, first.id))
        elif isinstance(first, ast.Call):           # partial(kernel, ...)
            fchain = _attr_chain(first.func)
            if fchain and fchain[-1] == "partial" and first.args:
                inner = _attr_chain(first.args[0])
                if inner:
                    names.add(inner[-1])
    return names


def _check_np_in_jit(tree: ast.Module, path: str) -> list[Finding]:
    kernels = _pallas_kernel_names(tree)
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        jitted = any(_is_jit_decorator(d) for d in node.decorator_list)
        is_kernel = node.name in kernels
        if not (jitted or is_kernel):
            continue
        where = "Pallas kernel body" if is_kernel else "jit-decorated function"
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            chain = _attr_chain(sub.func)
            if len(chain) >= 2 and chain[0] in ("np", "numpy"):
                out.append(Finding(
                    "host-np-in-jit", path, sub.lineno,
                    f"host numpy call {'.'.join(chain)}() inside "
                    f"{where} {node.name!r}: this constant-folds at trace "
                    f"time (or fails on tracers); use jnp/lax, or hoist it "
                    f"out of the traced region"))
    return out


# ---------------------------------------------------------------------------
# nonfrozen-static
# ---------------------------------------------------------------------------
def _dataclass_frozen(dec: ast.AST) -> bool | None:
    """True/False if ``dec`` is a dataclass decorator; None otherwise."""
    chain = _attr_chain(dec)
    if chain and chain[-1] == "dataclass":
        return False
    if isinstance(dec, ast.Call):
        chain = _attr_chain(dec.func)
        if chain and chain[-1] == "dataclass":
            for kw in dec.keywords:
                if (kw.arg == "frozen" and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True):
                    return True
            return False
    return None


def _static_argnames_of(dec: ast.AST):
    """The static_argnames tuple of a jit decorator, if resolvable."""
    if not isinstance(dec, ast.Call):
        return None
    chain = _attr_chain(dec.func)
    is_jit = chain and chain[-1] == "jit"
    is_partial_jit = (chain and chain[-1] == "partial" and dec.args
                      and (c := _attr_chain(dec.args[0])) and c[-1] == "jit")
    if not (is_jit or is_partial_jit):
        return None
    for kw in dec.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                return tuple(e.value for e in v.elts
                             if isinstance(e, ast.Constant))
    return None


def _check_nonfrozen_static(tree: ast.Module, path: str) -> list[Finding]:
    nonfrozen: dict[str, int] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            for d in node.decorator_list:
                fr = _dataclass_frozen(d)
                if fr is False:
                    nonfrozen[node.name] = node.lineno
    if not nonfrozen:
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for d in node.decorator_list:
            statics = _static_argnames_of(d)
            if not statics:
                continue
            args = node.args.posonlyargs + node.args.args + node.args.kwonlyargs
            for a in args:
                if a.arg not in statics or a.annotation is None:
                    continue
                ann = _attr_chain(a.annotation)
                if ann and ann[-1] in nonfrozen:
                    out.append(Finding(
                        "nonfrozen-static", path, node.lineno,
                        f"static arg {a.arg!r} of {node.name!r} is a "
                        f"non-frozen dataclass {ann[-1]!r} (defined line "
                        f"{nonfrozen[ann[-1]]}): static_argnames require "
                        f"hashable values — mark it frozen=True"))
    return out


# ---------------------------------------------------------------------------
# mutable-default
# ---------------------------------------------------------------------------
def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        return bool(chain) and chain[-1] in ("list", "dict", "set")
    return False


def _check_mutable_default(tree: ast.Module, path: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            continue
        name = getattr(node, "name", "<lambda>")
        for default in (list(node.args.defaults)
                        + [d for d in node.args.kw_defaults if d]):
            if _is_mutable_literal(default):
                out.append(Finding(
                    "mutable-default", path, default.lineno,
                    f"mutable default argument in {name!r}: shared across "
                    f"calls — default to None and construct inside"))
    return out


# ---------------------------------------------------------------------------
# float64-literal
# ---------------------------------------------------------------------------
def _check_float64(tree: ast.Module, path: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "float64":
            chain = _attr_chain(node)
            if chain and chain[0] in ("jnp", "jax"):
                out.append(Finding(
                    "float64-literal", path, node.lineno,
                    f"{'.'.join(chain)}: jax runs with x64 disabled, so "
                    f"this silently becomes float32; use float32 (or np "
                    f"for genuine host-side double precision)"))
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if (kw.arg == "dtype" and isinstance(kw.value, ast.Constant)
                        and kw.value.value == "float64"):
                    out.append(Finding(
                        "float64-literal", path, kw.value.lineno,
                        'dtype="float64" in accelerator code: jax runs '
                        'x64-disabled, so this silently becomes float32'))
    return out


# ---------------------------------------------------------------------------
# fault-free-default
# ---------------------------------------------------------------------------
# hazard field -> predicate its default AST node must satisfy to encode
# "this hazard is off"
def _is_zero(node: ast.AST) -> bool:
    return (isinstance(node, ast.Constant)
            and isinstance(node.value, (int, float))
            and not isinstance(node.value, bool) and node.value == 0)


def _is_empty_tuple(node: ast.AST) -> bool:
    if isinstance(node, ast.Tuple) and not node.elts:
        return True
    # field(default=()) / field(default_factory=tuple)
    if isinstance(node, ast.Call) and _attr_chain(node.func)[-1:] == ["field"]:
        for kw in node.keywords:
            if kw.arg == "default":
                return _is_empty_tuple(kw.value)
            if kw.arg == "default_factory":
                return (isinstance(kw.value, ast.Name)
                        and kw.value.id == "tuple")
    return False


_FAULT_HAZARDS = {"erasure_prob": (_is_zero, "0.0"),
                  "crash_hazard": (_is_zero, "0.0"),
                  "backoff_s": (_is_zero, "0.0"),
                  "es_outage_trace": (_is_empty_tuple, "()")}


def _check_fault_free_default(tree: ast.Module, path: str) -> list[Finding]:
    """Any class literally named FaultConfig must default its hazard knobs
    to zero/empty — ``FaultConfig()`` MUST mean "no faults" (the fault-free
    golden regressions depend on it)."""
    out = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.ClassDef) and node.name == "FaultConfig"):
            continue
        for stmt in node.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)
                    and stmt.target.id in _FAULT_HAZARDS):
                continue
            pred, want = _FAULT_HAZARDS[stmt.target.id]
            if stmt.value is None:
                out.append(Finding(
                    "fault-free-default", path, stmt.lineno,
                    f"FaultConfig.{stmt.target.id} has no default: "
                    f"FaultConfig() must construct with zero faults "
                    f"(default it to {want})"))
            elif not pred(stmt.value):
                out.append(Finding(
                    "fault-free-default", path, stmt.lineno,
                    f"FaultConfig.{stmt.target.id} defaults to a live "
                    f"hazard: the all-defaults config must encode zero "
                    f"faults (expected {want}) or every fault-free golden "
                    f"regression silently forks"))
    return out


# ---------------------------------------------------------------------------
# telemetry-off-default
# ---------------------------------------------------------------------------
# ---------------------------------------------------------------------------
# client-loop-in-wireless
# ---------------------------------------------------------------------------
# the modules whose contract is O(1) python per round in the client count
_VECTORIZED_WIRELESS = {"population.py", "scheduler_core.py"}
# quantities that name the client axis when they appear in a range() bound
_CLIENT_AXIS = {"U", "N", "num_clients", "n_clients", "population_size",
                "cohort_size"}
# iterables that ARE per-client collections
_CLIENT_ITERS = {"clients", "cohort", "cohort_ids", "client_ids"}


def _terminal_names(node: ast.AST):
    """Every bare name and attribute terminal in an expression (``self.U``
    yields 'U'; ``len(pool)`` yields 'len' and 'pool')."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub.id
        elif isinstance(sub, ast.Attribute):
            yield sub.attr


def _client_loop_iter(it: ast.AST) -> bool:
    """Does this ``for``/comprehension iterable walk the client axis?"""
    if isinstance(it, ast.Call):
        chain = _attr_chain(it.func)
        if chain and chain[-1] in ("range", "enumerate", "zip"):
            return any(n in _CLIENT_AXIS or n in _CLIENT_ITERS
                       for a in it.args for n in _terminal_names(a))
        return False
    return any(n in _CLIENT_ITERS for n in _terminal_names(it))


def _check_client_loop(tree: ast.Module, path: str) -> list[Finding]:
    """No python-level per-client loops in the vectorized wireless modules.

    ``population.py`` / ``scheduler_core.py`` promise O(1) python work per
    round no matter how many clients are registered — that is the whole
    point of the struct-of-arrays refactor.  A ``for u in range(self.U)``
    (or a comprehension over a cohort) quietly reintroduces the
    10**6-iteration python loop the fused jax stages replaced.  Loops over
    non-client axes (edge servers, Lloyd iterations, chunk tails) pass."""
    parts = path.replace("\\", "/").split("/")
    if parts[-1] not in _VECTORIZED_WIRELESS or (
            len(parts) > 1 and "wireless" not in parts):
        return []
    out = []
    for node in ast.walk(tree):
        iters = []
        if isinstance(node, (ast.For, ast.AsyncFor)):
            iters = [(node.iter, node.lineno)]
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            iters = [(g.iter, node.lineno) for g in node.generators]
        for it, line in iters:
            if _client_loop_iter(it):
                out.append(Finding(
                    "client-loop-in-wireless", path, line,
                    "python-level loop over the client axis in a "
                    "vectorized wireless module: per-round python work "
                    "must stay O(1) in the registered-client count "
                    "(use numpy/jax vector ops)"))
    return out


def _is_off_default(node: ast.AST) -> bool:
    """None, or the canonical OFF handle Telemetry.disabled()."""
    if isinstance(node, ast.Constant) and node.value is None:
        return True
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        return chain[-2:] == ["Telemetry", "disabled"]
    return False


def _check_telemetry_off_default(tree: ast.Module, path: str) -> list[Finding]:
    """Every ``telemetry`` parameter must default to the OFF state.

    Observability is strictly opt-in: a function that REQUIRES a telemetry
    handle, or defaults it to an enabled instance, makes instrumentation a
    load-bearing input — and the bit-identity goldens run with it absent.
    ``telemetry=None`` (or ``Telemetry.disabled()``) is the contract."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        a = node.args
        pos = a.posonlyargs + a.args
        pos_defaults = ([None] * (len(pos) - len(a.defaults))
                        + list(a.defaults))
        for arg, default in (list(zip(pos, pos_defaults))
                             + list(zip(a.kwonlyargs, a.kw_defaults))):
            if arg.arg != "telemetry":
                continue
            if default is None:
                out.append(Finding(
                    "telemetry-off-default", path, node.lineno,
                    f"{node.name}() requires 'telemetry': observability "
                    f"must be opt-in — default it to None"))
            elif not _is_off_default(default):
                out.append(Finding(
                    "telemetry-off-default", path, node.lineno,
                    f"{node.name}() defaults 'telemetry' to an enabled "
                    f"value: the all-defaults call must be bit-inert "
                    f"(default to None or Telemetry.disabled())"))
    return out
