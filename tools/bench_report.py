"""Benchmark trajectory report: every BENCH_*.json as one table.

The sweep drivers under ``benchmarks/`` each leave a ``BENCH_<name>.json``
in the repo root — a JSON list of row dicts whose schemas drifted as the
sweeps grew (``mode`` vs ``policy`` labels, ``total_bits`` vs ``bits`` vs
``bits_tx``, scalar counts vs per-client lists).  This tool loads them
all, validates and NORMALIZES every row to one schema, and renders the
combined trajectory as markdown (stdout or ``--markdown``) and/or CSV
(``--csv``) — the "did this PR move the numbers" view across every sweep
at once.

Malformed records (a non-list file, a non-dict row, a non-numeric metric)
are an ERROR, not a skip: a benchmark file that stopped parsing is a
regression this report exists to catch.

    python -m tools.bench_report                 # markdown to stdout
    python -m tools.bench_report --csv report.csv --markdown report.md
    make report
"""

from __future__ import annotations

import argparse
import csv
import glob
import json
import os
import sys

# the unified row schema, in column order
COLUMNS = ["source", "label", "participation_rate",
           "effective_participation_rate", "mean_round_time_s",
           "wall_s_per_round", "total_bits", "retx_bits", "failed",
           "crashed", "stale_delivered", "final_loss", "final_acc",
           "total_sim_time_s"]

# metric keys that must be numeric when present (post-normalization)
_NUMERIC = COLUMNS[2:]


class MalformedRecord(ValueError):
    """A BENCH_*.json record that does not normalize to the schema."""


def _count(v):
    """Unify scalar counts with per-client lists/masks (sum of truthiness)."""
    if isinstance(v, (list, tuple)):
        return int(sum(1 for x in v if x))
    return v


def _num(v, key, where):
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        raise MalformedRecord(f"{where}: {key!r} is {type(v).__name__} "
                              f"{v!r}, expected a number")
    return float(v)


def _label(row) -> str:
    """The row's sweep point: mode, policy (+erasure), or any name-ish key."""
    if "mode" in row:
        return str(row["mode"])
    if "policy" in row:
        lab = str(row["policy"])
        if "erasure_prob" in row:
            lab += f" @ p={row['erasure_prob']}"
        return lab
    for k in ("name", "label", "arch", "codec", "cut"):
        if k in row:
            return str(row[k])
    return "?"


def normalize_row(row: dict, source: str, idx: int) -> dict:
    """One drifted sweep row -> the unified schema (raises MalformedRecord).

    Unifications: ``total_bits``/``bits``/``bits_tx`` -> ``total_bits``;
    per-client list counts (``failed``/``crashed``/``stale_delivered``) ->
    scalar counts; absent metrics -> None (rendered blank).
    """
    where = f"{source}[{idx}]"
    if not isinstance(row, dict):
        raise MalformedRecord(f"{where}: row is {type(row).__name__}, "
                              f"expected an object")
    out = {"source": source, "label": _label(row)}
    bits = row.get("total_bits", row.get("bits", row.get("bits_tx")))
    unified = {"total_bits": bits,
               "failed": _count(row.get("failed")) if "failed" in row
               else None,
               "crashed": _count(row.get("crashed")) if "crashed" in row
               else None,
               "stale_delivered": _count(row.get("stale_delivered"))
               if "stale_delivered" in row
               else row.get("stale_delivered_per_round")}
    for key in _NUMERIC:
        v = unified.get(key, row.get(key)) if key in unified \
            else row.get(key)
        out[key] = None if v is None else _num(v, key, where)
    return out


def load_bench(path: str) -> list[dict]:
    """One BENCH_*.json -> normalized rows (raises MalformedRecord)."""
    source = os.path.basename(path)
    if source.startswith("BENCH_"):
        source = source[len("BENCH_"):]
    source = source.rsplit(".", 1)[0]
    try:
        with open(path) as fh:
            records = json.load(fh)
    except json.JSONDecodeError as e:
        raise MalformedRecord(f"{path}: not valid JSON ({e})") from e
    if not isinstance(records, list):
        raise MalformedRecord(f"{path}: top level is "
                              f"{type(records).__name__}, expected a list")
    return [normalize_row(r, source, i) for i, r in enumerate(records)]


def load_all(root: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        rows.extend(load_bench(path))
    return rows


def _fmt(v) -> str:
    if v is None:
        return ""
    if isinstance(v, float):
        if v == int(v) and abs(v) < 1e15:
            return str(int(v))
        return f"{v:.6g}"
    return str(v)


def to_markdown(rows: list[dict]) -> str:
    head = "| " + " | ".join(COLUMNS) + " |"
    sep = "|" + "|".join("---" for _ in COLUMNS) + "|"
    body = ["| " + " | ".join(_fmt(r[c]) for c in COLUMNS) + " |"
            for r in rows]
    return "\n".join([head, sep] + body)


def write_csv(rows: list[dict], fh) -> None:
    w = csv.DictWriter(fh, fieldnames=COLUMNS)
    w.writeheader()
    for r in rows:
        w.writerow({c: "" if r[c] is None else r[c] for c in COLUMNS})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=".",
                    help="directory holding the BENCH_*.json files")
    ap.add_argument("--csv", default=None, help="also write CSV here")
    ap.add_argument("--markdown", default=None,
                    help="write markdown here instead of stdout")
    args = ap.parse_args(argv)
    try:
        rows = load_all(args.dir)
    except MalformedRecord as e:
        print(f"bench_report: {e}", file=sys.stderr)
        return 1
    if not rows:
        print(f"bench_report: no BENCH_*.json under {args.dir}",
              file=sys.stderr)
        return 1
    md = to_markdown(rows)
    if args.markdown:
        with open(args.markdown, "w") as fh:
            fh.write(md + "\n")
    else:
        print(md)
    if args.csv:
        with open(args.csv, "w", newline="") as fh:
            write_csv(rows, fh)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
