"""Dry-run machinery on a small fake mesh: build_step lowers and compiles for
all three step kinds (subprocess so XLA device-count flags apply)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           "--xla_disable_hlo_passes=all-reduce-promotion")
import json
import jax
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import build_step

results = {}
mesh = make_debug_mesh(multi_pod=True)   # (2,2,2)
shapes = [ShapeConfig("t", 128, 16, "train"),
          ShapeConfig("p", 128, 8, "prefill"),
          ShapeConfig("d", 128, 8, "decode")]
for arch in ["gemma3-12b", "olmoe-1b-7b", "recurrentgemma-2b"]:
    cfg = get_arch(arch).reduced()
    for sh in shapes:
        with mesh:
            b = build_step(cfg, sh, mesh)
            compiled = jax.jit(b.fn).lower(*b.args).compile()
            txt = compiled.as_text()
        results[f"{arch}/{sh.kind}"] = {
            "ok": True,
            "has_collective": ("all-reduce" in txt or "all-gather" in txt
                               or "collective-permute" in txt),
        }
print(json.dumps(results))
"""


@pytest.mark.slow
def test_debug_mesh_lowering_all_step_kinds():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-4000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(rec) == 9
    for k, v in rec.items():
        assert v["ok"], k
    # train steps must contain aggregation collectives
    assert rec["gemma3-12b/train"]["has_collective"]
