"""PHSFL split semantics: pytree partition, masks, and the Remark-2
equivalence of split-learning gradients to monolithic backprop."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.configs.registry import ARCHS, get_arch
from repro.core import (GLOBAL_TRAIN, HSFL_TRAIN, PERSONALIZE, count_parts,
                        monolithic_grad, part_masks, split_grad,
                        split_spec_for, trainable_mask)
from repro.models import build_model, cnn


def test_cnn_split_parts_cover_everything():
    params = cnn.init(jax.random.PRNGKey(0), CNN_CFG)
    spec = split_spec_for(CNN_CFG)
    masks = part_masks(params, spec)
    flat = [jax.tree.leaves(masks[p]) for p in ("client", "body", "head")]
    for triple in zip(*flat):
        assert sum(triple) == 1, "every leaf in exactly one part"
    counts = count_parts(params, spec)
    assert counts["client"] > 0 and counts["body"] > 0 and counts["head"] > 0
    # the head is the small classifier; the body is the bulk (paper Sec. II)
    assert counts["body"] > counts["head"]
    assert counts["body"] > counts["client"]


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_lm_split_parts(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    spec = split_spec_for(cfg)
    masks = part_masks(shapes, spec)
    for triple in zip(*(jax.tree.leaves(masks[p])
                        for p in ("client", "body", "head"))):
        assert sum(triple) == 1
    counts = count_parts(shapes, spec)
    # head must be exactly the lm_head
    assert counts["head"] > 0
    # client side includes the embedding (+ lead blocks for decoder LMs)
    assert counts["client"] > 0


def test_trainable_mask_phases():
    params = cnn.init(jax.random.PRNGKey(0), CNN_CFG)
    spec = split_spec_for(CNN_CFG)
    m_global = trainable_mask(params, spec, GLOBAL_TRAIN)
    m_hsfl = trainable_mask(params, spec, HSFL_TRAIN)
    m_pers = trainable_mask(params, spec, PERSONALIZE)
    # PHSFL: head frozen; HSFL: everything trains; personalize: only head
    assert not any(jax.tree.leaves(
        {k: m_global[k] for k in cnn.HEAD_KEYS}))
    assert all(jax.tree.leaves(m_hsfl))
    pers_leaves = jax.tree_util.tree_flatten_with_path(m_pers)[0]
    for path, v in pers_leaves:
        is_head = any("fc2" in str(p) for p in path)
        assert v == is_head


def test_split_grad_equals_monolithic():
    """Remark 2: the cut-layer dataflow does not change the gradients."""
    rng = np.random.default_rng(0)
    params = cnn.init(jax.random.PRNGKey(1), CNN_CFG)
    x = jnp.asarray(rng.normal(size=(16, 32, 32, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=16).astype(np.int32))
    l1, g1 = split_grad(params, x, y)
    l2, g2 = monolithic_grad(params, x, y)
    assert jnp.allclose(l1, l2, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_cut_layer_position_does_not_change_loss():
    """Remark 2 at the LM scale: n_client_layers only re-partitions the
    pytree; the forward function is identical."""
    import dataclasses
    cfg1 = get_arch("mistral-large-123b").reduced(num_layers=4)
    cfg2 = dataclasses.replace(cfg1, n_client_layers=2)
    m1, m2 = build_model(cfg1), build_model(cfg2)
    p1 = m1.init(jax.random.PRNGKey(0))
    p2 = m2.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(64, dtype=jnp.int32).reshape(1, 64) % cfg1.vocab_size}
    batch["labels"] = batch["tokens"]
    l1 = m1.loss(p1, batch)
    l2 = m2.loss(p2, batch)
    assert jnp.allclose(l1, l2, rtol=1e-5), (l1, l2)
