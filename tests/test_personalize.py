"""Personalization (Eq. 18): head-only fine-tuning from cached hiddens."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.core import merge_head, personalize_head_bank, personalized_eval
from repro.data.synthetic import synthetic_token_batch
from repro.models import build_model


def _client_batches(cfg, C, B, S):
    nbs = [synthetic_token_batch(i, B, S, cfg.vocab_size) for i in range(C)]
    return {k: jnp.stack([jnp.asarray(nb[k]) for nb in nbs])
            for k in nbs[0]}


def test_head_bank_personalization_reduces_loss():
    cfg = get_arch("olmoe-1b-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(finetune_lr=0.5, finetune_steps=8)
    C = 3
    batches = _client_batches(cfg, C, 2, 32)
    heads, losses = personalize_head_bank(model, params, batches, tcfg)
    assert heads.shape[0] == C
    # loss decreases over fine-tuning steps for every client
    assert bool((losses[:, -1] < losses[:, 0]).all()), losses
    # evaluation API works and is per-client
    ev = personalized_eval(model, params, heads, batches)
    assert ev.shape == (C,)
    assert bool(jnp.isfinite(ev).all())


def test_merge_head_only_touches_head():
    cfg = get_arch("xlstm-350m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    new_head = {"lm_head": {"w": params["lm_head"]["w"] + 1.0}}
    merged = merge_head(params, new_head, cfg)
    assert bool(jnp.allclose(merged["lm_head"]["w"],
                             params["lm_head"]["w"] + 1.0))
    # everything else identical
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(merged)[0]):
        if "lm_head" in str(pa):
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_personalized_heads_differ_across_clients():
    cfg = get_arch("gemma3-12b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(finetune_lr=0.2, finetune_steps=4)
    batches = _client_batches(cfg, 2, 2, 32)
    heads, _ = personalize_head_bank(model, params, batches, tcfg)
    assert not bool(jnp.allclose(heads[0], heads[1]))
