"""Attention path consistency: dense vs chunked vs banded vs decode."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.data.synthetic import synthetic_token_batch
from repro.models import build_model
from repro.models.attention import (banded_attention, chunked_attention,
                                    dense_attention)
from repro.models.layers import apply_mrope, apply_rope


def _qkv(rng, b, s, h, kvh, d):
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    return q, k, v


@pytest.mark.parametrize("window", [0, 64])
def test_chunked_matches_dense(window, rng):
    q, k, v = _qkv(rng, 2, 256, 4, 2, 32)
    ref = dense_attention(q, k, v, causal=True, window=window, softcap=0.0)
    out = chunked_attention(q, k, v, causal=True, window=window, softcap=0.0,
                            q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_banded_matches_dense(rng):
    q, k, v = _qkv(rng, 1, 256, 4, 4, 32)
    ref = dense_attention(q, k, v, causal=True, window=64, softcap=0.0)
    out = banded_attention(q, k, v, window=64, softcap=0.0, q_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_rope_relative_shift_invariance(rng):
    """RoPE: shifting q and k positions together preserves attention logits."""
    d = 32
    q = jnp.asarray(rng.normal(size=(1, 8, 2, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 8, 2, d)).astype(np.float32))
    pos = jnp.arange(8)[None, :]
    q1, k1 = apply_rope(q, pos, 1e4), apply_rope(k, pos, 1e4)
    q2, k2 = apply_rope(q, pos + 13, 1e4), apply_rope(k, pos + 13, 1e4)
    l1 = jnp.einsum("bqhd,bkhd->bhqk", q1, k1)
    l2 = jnp.einsum("bqhd,bkhd->bhqk", q2, k2)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-3, atol=1e-3)


def test_mrope_reduces_to_rope_for_text(rng):
    """With identical (t,h,w) position streams, M-RoPE == RoPE."""
    d = 32
    x = jnp.asarray(rng.normal(size=(1, 8, 2, d)).astype(np.float32))
    pos = jnp.arange(8)[None, :]
    pos3 = jnp.tile(pos[..., None], (1, 1, 3))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, 1e4, (8, 4, 4))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("arch", ["mistral-large-123b", "gemma3-12b",
                                  "deepseek-v2-236b", "recurrentgemma-2b",
                                  "xlstm-350m", "seamless-m4t-medium"])
def test_prefill_decode_equivalence(arch, rng):
    """Teacher-forced logits at position t == decode logits after feeding
    tokens 0..t-1 through the cache path."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 12
    nb = synthetic_token_batch(0, 1, S, cfg.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in nb.items()}
    if cfg.encdec is not None:
        batch["source_embeds"] = 0.02 * jnp.ones(
            (1, cfg.encdec.max_source_len, cfg.d_model), jnp.float32)
    hidden, _ = model.apply(params, batch)
    full_logits = model.logits(params, hidden)        # (1,S,V)

    cache = model.init_cache(1, S, dtype=jnp.float32)
    if cfg.encdec is not None:
        from repro.models import encdec as ed
        memory = ed.encode(params, cfg, batch["source_embeds"])
        cache["cross"] = ed.precompute_cross(params, cfg, memory,
                                             dtype=jnp.float32)
    logits_steps = []
    for t in range(S):
        tok = batch["tokens"][:, t:t + 1]
        lg, cache = model.decode_step(params, tok, cache,
                                      jnp.asarray(t, jnp.int32))
        logits_steps.append(lg[:, 0])
    dec = jnp.stack(logits_steps, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=5e-3, atol=5e-3)
