"""Faithful paper-semantics simulation: short end-to-end runs."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import HierarchyConfig, TrainConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.fedsim import FedSim, centralized_sgd
from repro.data.synthetic import make_federated_image_data
from repro.models import cnn


@pytest.fixture(scope="module")
def fed_data():
    return make_federated_image_data(8, alpha=0.3, train_per_class=40,
                                     test_per_class=20, seed=0)


def _mk(fed_data, freeze):
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=2,
                        kappa1=2, global_rounds=3)
    t = TrainConfig(learning_rate=0.05, batch_size=16, freeze_head=freeze,
                    finetune_steps=5, finetune_lr=0.05)
    return FedSim(CNN_CFG, fed_data, h, t, batches_per_epoch=2, seed=0)


@pytest.mark.slow
def test_phsfl_trains_and_freezes_head(fed_data):
    sim = _mk(fed_data, freeze=True)
    res = sim.run(rounds=3, log_every=1)
    assert res.history[-1]["test_acc"] > 0.4          # learns something
    assert res.history[-1]["train_loss"] < res.history[0]["train_loss"]
    p0 = cnn.init(jax.random.PRNGKey(0), CNN_CFG)
    # Eq. (12): the classifier never moves during global training.  (The
    # weighted aggregation of bit-identical head replicas reintroduces
    # float32 epsilon — sum(alpha_u)=1 only up to ulp — so allclose, not
    # array_equal; the optimizer mask itself is exact, see test_optim.)
    np.testing.assert_allclose(np.asarray(res.global_params["fc2"]["w"]),
                               np.asarray(p0["fc2"]["w"]), rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res.global_params["fc2"]["b"]),
                               np.asarray(p0["fc2"]["b"]), rtol=0, atol=1e-5)


@pytest.mark.slow
def test_hsfl_baseline_head_moves(fed_data):
    sim = _mk(fed_data, freeze=False)
    res = sim.run(rounds=2, log_every=1)
    p0 = cnn.init(jax.random.PRNGKey(0), CNN_CFG)
    assert not np.allclose(np.asarray(res.global_params["fc2"]["w"]),
                           np.asarray(p0["fc2"]["w"]))


@pytest.mark.slow
def test_personalization_improves_per_client_accuracy(fed_data):
    sim = _mk(fed_data, freeze=True)
    res = sim.run(rounds=3, log_every=3)
    heads, per = sim.personalize(res.global_params)
    # personalized models beat the shared global model on local test sets
    assert per["acc"].mean() >= res.per_client_global["acc"].mean() - 1e-6
    # heads differ per client
    w = np.asarray(heads["w"])
    assert not np.allclose(w[0], w[1])


@pytest.mark.slow
def test_centralized_genie_upper_bound(fed_data):
    """Recalibrated (ISSUE 2): the fixture pools only 400 train images, so
    SGD at lr=0.05/batch=32 needs ~120 steps to fit the synthetic task —
    3 epochs (36 steps) stalled at acc 0.21, 10 epochs reaches ~1.0.  The
    assert keeps a wide margin below that so the test checks "the genie
    learns the task", not a brittle point estimate."""
    t = TrainConfig(learning_rate=0.05, batch_size=32)
    _, metrics = centralized_sgd(CNN_CFG, fed_data, t, epochs=10, seed=0)
    assert metrics["acc"] > 0.5


def test_kappa_1_1_single_client_equals_centralized_steps(fed_data):
    """With B=1, U=1, kappa0=kappa1=1, one fedsim round == plain SGD steps
    (aggregation is the identity)."""
    data = make_federated_image_data(1, alpha=100.0, train_per_class=40,
                                     test_per_class=10, seed=1)
    h = HierarchyConfig(num_edge_servers=1, clients_per_es=1, kappa0=1,
                        kappa1=1, global_rounds=1)
    t = TrainConfig(learning_rate=0.05, batch_size=16, freeze_head=True)
    sim = FedSim(CNN_CFG, data, h, t, batches_per_epoch=1, seed=3)
    # manual reference with identical sampling
    import copy
    rng_state = copy.deepcopy(sim.rng)
    res = sim.run(rounds=1, log_every=1)
    x, y = data.client_train(0)
    idx = rng_state.choice(len(x), size=16, replace=len(x) < 16)
    from repro.core.fedsim import split_grad
    p = cnn.init(sim.key, CNN_CFG)
    loss, g = split_grad(p, jnp.asarray(x[idx]), jnp.asarray(y[idx]))
    ref = {k: jax.tree.map(lambda a, b: a - 0.05 * b, p[k], g[k])
           for k in p}
    ref["fc2"] = p["fc2"]                      # frozen head
    for a, b in zip(jax.tree.leaves(res.global_params),
                    jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)
