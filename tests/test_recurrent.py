"""Recurrent blocks: parallel/chunkwise training forms must match the O(1)
recurrent decode forms step by step."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.models.rglru import rglru_scan_assoc
from repro.models.xlstm import mlstm_chunkwise, mlstm_step


def test_mlstm_chunkwise_matches_recurrent(rng):
    b, s, h, dh = 2, 64, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32)) / np.sqrt(dh)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    li = jnp.asarray(rng.normal(size=(b, s, h)).astype(np.float32))
    lf = jnp.log(jax.nn.sigmoid(
        jnp.asarray(rng.normal(size=(b, s, h)).astype(np.float32))))

    out_chunk, _ = mlstm_chunkwise(q, k, v, li, lf, chunk=16)

    carry = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)),
             jnp.full((b, h), -1e30))
    outs = []
    for t in range(s):
        o, carry = mlstm_step(q[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1],
                              li[:, t:t + 1], lf[:, t:t + 1], carry)
        outs.append(o[:, 0])
    out_rec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_rec),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunks", [(16,), (32,), (64,)])
def test_mlstm_chunk_size_invariance(chunks, rng):
    """The chunk size is an implementation detail, not semantics."""
    b, s, h, dh = 1, 64, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    li = jnp.asarray(rng.normal(size=(b, s, h)).astype(np.float32))
    lf = jnp.log(jax.nn.sigmoid(
        jnp.asarray(rng.normal(size=(b, s, h)).astype(np.float32))))
    ref, _ = mlstm_chunkwise(q, k, v, li, lf, chunk=s)
    out, _ = mlstm_chunkwise(q, k, v, li, lf, chunk=chunks[0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_rglru_assoc_scan_matches_sequential(rng):
    b, s, w = 2, 48, 16
    log_a = -jnp.abs(jnp.asarray(rng.normal(size=(b, s, w)).astype(np.float32))) * 0.2
    bb = jnp.asarray(rng.normal(size=(b, s, w)).astype(np.float32))
    h = rglru_scan_assoc(log_a, bb)
    href = np.zeros((b, w), np.float32)
    la, bn = np.asarray(log_a), np.asarray(bb)
    outs = []
    for t in range(s):
        href = np.exp(la[:, t]) * href + bn[:, t]
        outs.append(href.copy())
    ref = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h), ref, rtol=1e-4, atol=1e-5)


def test_rglru_with_initial_state(rng):
    b, s, w = 1, 8, 4
    log_a = -jnp.abs(jnp.asarray(rng.normal(size=(b, s, w)).astype(np.float32)))
    bb = jnp.asarray(rng.normal(size=(b, s, w)).astype(np.float32))
    h0 = jnp.asarray(rng.normal(size=(b, w)).astype(np.float32))
    h = rglru_scan_assoc(log_a, bb, h0=h0)
    # sequential with h0
    href = np.asarray(h0).copy()
    la, bn = np.asarray(log_a), np.asarray(bb)
    for t in range(s):
        href = np.exp(la[:, t]) * href + bn[:, t]
    np.testing.assert_allclose(np.asarray(h[:, -1]), href, rtol=1e-4,
                               atol=1e-5)


def test_xlstm_decode_state_bounded():
    """xLSTM/RG-LRU decode caches are O(1) in sequence length — the
    long_500k enabling property."""
    import jax

    from repro.models import build_model
    cfg = get_arch("xlstm-350m").reduced()
    model = build_model(cfg)
    c1 = jax.eval_shape(lambda: model.init_cache(1, 1000, dtype=jnp.float32))
    c2 = jax.eval_shape(lambda: model.init_cache(1, 100000, dtype=jnp.float32))
    from repro.utils.tree import tree_bytes
    assert tree_bytes(c1) == tree_bytes(c2)
