"""Population-scale cohort simulation (repro.wireless.population).

The load-bearing property: ``CohortScheduler`` — the jit/vmap-rewritten
per-round decision path — is BIT-IDENTICAL to the numpy
``ParticipationScheduler`` oracle, field by field of every RoundReport
and over every piece of carried mutable state, across all channel
models, contention rules, pipeline on/off, selection/cut policies,
staleness, and fault-injected rounds (ES outages vectorize; erasure/
crash rounds delegate to the inherited oracle path on shared state).

Plus the population layer itself: sampling rules, k-means vs round-robin
ES assignment, the FedSim cohort mode, and checkpoint resume.
"""

import dataclasses

import numpy as np
import pytest

from repro.configs.base import (FaultConfig, HierarchyConfig, TrainConfig,
                                WirelessConfig)
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.comm import comm_for_cnn, comm_table_for_cnn
from repro.core.hierarchy import es_assignment
from repro.wireless import make_scheduler
from repro.wireless.population import (CohortScheduler, Population,
                                       cohort_report, kmeans_assign,
                                       make_cohort_scheduler)
from repro.wireless.scheduler import RoundReport

U = 8
ES2 = np.arange(U) // 4
BASE = dict(mean_uplink_mbps=8.0, mean_downlink_mbps=30.0, latency_s=0.01,
            deadline_s=1.5, energy_budget_j=20.0, tx_power_w=0.7,
            heterogeneity=0.5, seed=3)
TRACE = tuple(tuple(5.0 + 3 * ((i * 7 + j * 3) % 5) for j in range(U))
              for i in range(4))
TRACE_DOWN = tuple(tuple(20.0 + 5 * ((i * 3 + j) % 4) for j in range(U))
                   for i in range(4))
OUTAGE = tuple((0, 1) if i % 3 == 1 else (0, 0) for i in range(6))

# every decision-path configuration the oracle supports; the vectorized
# scheduler must reproduce each one bit-for-bit at U=8 over 6 rounds
CONFIGS = {
    "static": dict(model="static", **BASE),
    "rayleigh": dict(model="rayleigh", **BASE),
    "trace": dict(model="trace", trace=TRACE, **BASE),
    "trace_down": dict(model="trace", trace=TRACE, trace_down=TRACE_DOWN,
                       **BASE),
    "contend_eq": dict(model="rayleigh", es_uplink_mbps=12.0, **BASE),
    "contend_prop": dict(model="rayleigh", es_uplink_mbps=12.0,
                         contention="proportional", **BASE),
    "contend_noreshare": dict(model="rayleigh", es_uplink_mbps=12.0,
                              contention="proportional",
                              reshare_uplink=False, **BASE),
    "pipeline": dict(model="rayleigh", pipeline=True, **BASE),
    "pipeline_contend": dict(model="rayleigh", pipeline=True,
                             es_uplink_mbps=12.0,
                             contention="proportional", **BASE),
    "greedy_cut": dict(model="rayleigh", cut_policy="greedy",
                       compute_gflops=2.0, compute_heterogeneity=0.4,
                       compute_power_w=0.3, **BASE),
    "deadline_cut": dict(model="rayleigh", cut_policy="deadline",
                         es_uplink_mbps=12.0, contention="proportional",
                         compute_gflops=2.0, compute_power_w=0.3, **BASE),
    "topk": dict(model="rayleigh", selection="topk", topk=3,
                 es_uplink_mbps=10.0, contention="proportional", **BASE),
    "random": dict(model="rayleigh", selection="random",
                   participation_prob=0.6, **BASE),
    "stale": dict(model="rayleigh", staleness_lambda=0.5, **BASE),
    "ideal": dict(model="ideal"),
    # fault injection: ES outages run the vectorized path; erasure/crash
    # rounds draw a FaultPlan and delegate to the oracle on shared state
    "outage_reassoc": dict(model="rayleigh", es_uplink_mbps=12.0,
                           contention="proportional",
                           faults=FaultConfig(es_outage_trace=OUTAGE),
                           **BASE),
    "outage_skip": dict(model="rayleigh", es_uplink_mbps=12.0,
                        faults=FaultConfig(es_outage_trace=OUTAGE,
                                           failover="skip"), **BASE),
    "harq": dict(model="rayleigh",
                 faults=FaultConfig(erasure_prob=0.3, max_retries=2,
                                    backoff_s=0.02), **BASE),
    "crash": dict(model="rayleigh", faults=FaultConfig(crash_hazard=0.3),
                  **BASE),
    "harq_outage_stale": dict(model="rayleigh", staleness_lambda=0.5,
                              es_uplink_mbps=12.0,
                              faults=FaultConfig(erasure_prob=0.25,
                                                 max_retries=2,
                                                 backoff_s=0.02,
                                                 es_outage_trace=OUTAGE),
                              **BASE),
}
# which configs use the cut-candidate table and which the two-ES layout
TABLE = {"greedy_cut", "deadline_cut"}
TWO_ES = {"contend_eq", "contend_prop", "contend_noreshare",
          "pipeline_contend", "deadline_cut", "topk", "outage_reassoc",
          "outage_skip", "harq_outage_stale"}


def _pair(name):
    wcfg = WirelessConfig(**CONFIGS[name])
    es = ES2 if name in TWO_ES else None
    kw = dict(dataset_size=400, batch_size=16)
    if name in TABLE:
        t = comm_table_for_cnn(CNN_CFG, **kw)
        mk = lambda **e: make_scheduler(wcfg, U, kappa0=2, comm_table=t,
                                        es_assign=es, **e)
    else:
        c = comm_for_cnn(CNN_CFG, **kw)
        mk = lambda **e: make_scheduler(wcfg, U, c, 2, es_assign=es, **e)
    return mk(), mk(cls=CohortScheduler)


def _assert_reports_equal(ra, rb, tag=""):
    for f in dataclasses.fields(RoundReport):
        va, vb = getattr(ra, f.name), getattr(rb, f.name)
        if isinstance(va, np.ndarray) or isinstance(vb, np.ndarray):
            assert (va is None) == (vb is None), (tag, f.name)
            assert np.array_equal(np.asarray(va), np.asarray(vb)), \
                (tag, f.name, va, vb)
        else:
            assert va == vb, (tag, f.name, va, vb)


# ------------------------------------------------ bit-identity property ----
@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_vectorized_matches_oracle(name):
    oracle, vec = _pair(name)
    assert type(oracle).__name__ == "ParticipationScheduler"
    for r in range(6):
        _assert_reports_equal(oracle.step(r), vec.step(r), f"{name} r{r}")
    # the carried mutable state advanced in lockstep too
    for attr in ("energy_left", "_stale_pending", "_stale_age"):
        assert np.array_equal(getattr(oracle, attr), getattr(vec, attr)), \
            (name, attr)


@pytest.mark.parametrize("name", ["contend_prop", "topk"])
def test_vectorized_matches_oracle_under_cohort_mask(name):
    """An externally pinned cohort mask thins gate 1 identically."""
    oracle, vec = _pair(name)
    mrng = np.random.default_rng(77)
    for r in range(6):
        mask = mrng.random(U) < 0.6
        oracle.cohort_mask = mask
        vec.cohort_mask = mask
        _assert_reports_equal(oracle.step(r), vec.step(r), f"{name} r{r}")


def test_vectorized_checkpoint_resume():
    """state_dict/load_state_dict into a fresh CohortScheduler continues
    the oracle's trajectory bit-identically mid-run."""
    oracle, vec = _pair("contend_prop")
    for r in range(3):
        oracle.step(r)
        vec.step(r)
    _, vec2 = _pair("contend_prop")
    vec2.load_state_dict(vec.state_dict())
    for r in range(3, 6):
        _assert_reports_equal(oracle.step(r), vec2.step(r), f"resume r{r}")


# ------------------------------------------------------- the population ----
def test_es_assignment_round_robin_pinned():
    # the canonical layout every layer shares (regression pin: FedSim,
    # train.py, and Population.round_robin all used to hand-roll this)
    assert np.array_equal(es_assignment(8, 4), np.array([0] * 4 + [1] * 4))
    pop = Population(10, num_es=3, seed=0, assignment="round_robin")
    assert np.array_equal(pop.es_assign,
                          np.array([0, 0, 0, 0, 1, 1, 1, 1, 2, 2]))


def test_kmeans_assignment_clusters_by_location():
    rng = np.random.default_rng(0)
    centers = np.array([[0.1, 0.1], [0.9, 0.9], [0.1, 0.9]])
    coords = np.concatenate([c + 0.03 * rng.standard_normal((50, 2))
                             for c in centers])
    labels, found = kmeans_assign(coords, 3, np.random.default_rng(1))
    # each ground-truth blob lands in exactly one cluster
    for blob in range(3):
        assert len(set(labels[50 * blob:50 * (blob + 1)])) == 1
    assert len(set(labels)) == 3
    pop = Population(150, num_es=3, seed=0, assignment="kmeans")
    assert sorted(np.bincount(pop.es_assign, minlength=3)) != [0, 0, 150]


def test_population_sampling_methods():
    pop = Population(100, num_es=2, seed=0)
    a = pop.sample_cohort(10, "uniform")
    assert len(a) == len(set(a.tolist())) == 10
    assert (pop.part_count.sum() == 10) and (pop.part_count.max() == 1)
    # pareto-style cap: the least-sampled clients go first, so 10 rounds
    # of 10 visit every client exactly once before anyone repeats
    pop2 = Population(100, num_es=2, seed=0)
    for _ in range(10):
        pop2.sample_cohort(10, "pareto")
    assert pop2.part_count.max() == pop2.part_count.min() == 1
    # rate bias: clients with much better channels are sampled more often
    pop3 = Population(100, num_es=2, seed=0)
    pop3.rate_scale = np.where(np.arange(100) < 50, 10.0, 0.1)
    for _ in range(20):
        pop3.sample_cohort(10, "rate")
    fast = pop3.part_count[:50].sum()
    assert fast > 0.8 * pop3.part_count.sum()


def test_population_es_balanced_cohort():
    pop = Population(64, num_es=4, seed=1)
    ids = pop.sample_cohort(8, "uniform", es_balanced=True)
    # two per ES, concatenated in ES order -> slot i's home ES is i // 2
    assert np.array_equal(pop.es_assign[ids], np.arange(8) // 2)
    with pytest.raises(ValueError):
        pop.sample_cohort(6, "uniform", es_balanced=True)  # 6 % 4 != 0
    with pytest.raises(ValueError):
        pop.sample_cohort(12, "bogus")


def test_cohort_scheduler_population_mode():
    """End to end on a 64-client registry: only cohort members schedule,
    the whole registry's energy state advances, and state_dict resume is
    bit-identical."""
    wc = WirelessConfig(model="rayleigh", es_uplink_mbps=12.0,
                        contention="proportional", **BASE)
    comm = comm_for_cnn(CNN_CFG, dataset_size=400, batch_size=16)

    def build(pop):
        return make_cohort_scheduler(wc, 64, comm, 2, population=pop,
                                     cohort_size=8, sampling="pareto",
                                     es_balanced=True)

    pop = Population(64, num_es=2, seed=3, assignment="kmeans",
                     data_sigma=0.5)
    s = build(pop)
    for r in range(4):
        rep = s.step(r)
        assert set(np.flatnonzero(rep.scheduled)) <= set(s.last_cohort)
        view = cohort_report(rep, s.last_cohort)
        assert view.mask.shape == (8,)
        assert np.array_equal(view.scheduled,
                              rep.scheduled[s.last_cohort])
        assert view.round_time_s == rep.round_time_s
    assert pop.part_count.sum() == 32 and pop.part_count.max() <= 1
    st = s.state_dict()
    pop2 = Population(64, num_es=2, seed=3, assignment="kmeans",
                      data_sigma=0.5)
    s2 = build(pop2)
    s2.load_state_dict(st)
    for r in range(4, 7):
        _assert_reports_equal(s.step(r), s2.step(r), f"pop resume r{r}")
        assert np.array_equal(s.last_cohort, s2.last_cohort)


def test_cohort_scheduler_rejects_bad_population():
    wc = WirelessConfig(model="rayleigh", **BASE)
    comm = comm_for_cnn(CNN_CFG, dataset_size=400, batch_size=16)
    with pytest.raises(ValueError):        # N != U
        make_cohort_scheduler(wc, 8, comm, 2,
                              population=Population(64), cohort_size=8)
    with pytest.raises(ValueError):        # missing cohort_size
        make_cohort_scheduler(wc, 64, comm, 2, population=Population(64))


# ------------------------------------------------------- FedSim cohorts ----
def test_fedsim_population_smoke():
    from repro.core.fedsim import FedSim
    from repro.data.synthetic import make_federated_image_data
    fed = make_federated_image_data(4, alpha=0.5, train_per_class=20,
                                    test_per_class=10, seed=0)
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=2, kappa0=1,
                        kappa1=2, global_rounds=2)
    t = TrainConfig(learning_rate=0.05, batch_size=8, freeze_head=True)
    w = WirelessConfig(model="rayleigh", es_uplink_mbps=12.0,
                       contention="proportional", deadline_s=2.0, **{
                           k: v for k, v in BASE.items()
                           if k != "deadline_s"})

    def build(pop):
        return FedSim(CNN_CFG, fed, h, t, batches_per_epoch=1, seed=0,
                      wireless=w, population=pop, sampling="rate")

    pop = Population(64, num_es=2, seed=3, assignment="kmeans",
                     data_sigma=0.5)
    sim = build(pop)
    res = sim.run(rounds=2, log_every=1)
    assert len(res.network) == 4           # kappa1 * global_rounds
    assert pop.part_count.sum() == 16      # 4 edge rounds x cohort of 4
    assert (pop.head_slot >= 0).sum() > 0  # participants got the model
    # per-slot report rows came from the cohort view, not the registry
    assert all(r["scheduled"] <= 4 for r in res.network)
    # checkpoint resume into a FRESH sim + population: bit-identical
    st = sim.state_dict()
    sim2 = build(Population(64, num_es=2, seed=3, assignment="kmeans",
                            data_sigma=0.5))
    sim2.load_state_dict(st)
    r1, r2 = sim.run(rounds=3, log_every=3), sim2.run(rounds=3, log_every=3)
    import jax
    for a, b in zip(jax.tree.leaves(r1.global_params),
                    jax.tree.leaves(r2.global_params)):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert r1.history == r2.history


def test_fedsim_population_rejects_staleness_and_ideal():
    from repro.core.fedsim import FedSim
    from repro.data.synthetic import make_federated_image_data
    fed = make_federated_image_data(4, alpha=0.5, train_per_class=10,
                                    test_per_class=5, seed=0)
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=2, kappa0=1,
                        kappa1=1, global_rounds=1)
    t = TrainConfig(learning_rate=0.05, batch_size=8)
    pop = Population(64, num_es=2, seed=0)
    with pytest.raises(ValueError):
        FedSim(CNN_CFG, fed, h, t, population=pop)       # no wireless
    with pytest.raises(ValueError):
        FedSim(CNN_CFG, fed, h, t, population=pop,
               wireless=WirelessConfig(model="rayleigh",
                                       staleness_lambda=0.5, **BASE))
    with pytest.raises(ValueError):                      # B mismatch
        FedSim(CNN_CFG, fed, h, t,
               population=Population(64, num_es=4, seed=0),
               wireless=WirelessConfig(model="rayleigh", **BASE))
