"""Data pipeline: Dirichlet partitioner (property-based) + synthetic sets."""

import numpy as np
import pytest

from repro.data import dirichlet_partition, make_federated_image_data
from repro.data.loader import ClientLoader, batch_iterator
from repro.data.synthetic import make_image_dataset, synthetic_token_batch

# seeded stand-in for hypothesis: 20 (num_clients, alpha, seed) draws
_DRAW = np.random.default_rng(1234)
_PARTITION_CASES = [
    (int(_DRAW.integers(2, 21)), float(_DRAW.uniform(0.05, 10.0)),
     int(_DRAW.integers(0, 10 ** 6)))
    for _ in range(20)
]


@pytest.mark.parametrize("num_clients,alpha,seed", _PARTITION_CASES)
def test_dirichlet_partition_conserves_samples(num_clients, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=500)
    parts = dirichlet_partition(labels, num_clients, alpha, seed=seed)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(labels)
    assert len(np.unique(all_idx)) == len(labels)   # each exactly once
    assert all(len(p) >= 2 for p in parts)


@pytest.mark.parametrize("seed", list(range(8)))
def test_dirichlet_topup_extreme_skew(seed):
    """Regression: at alpha=0.05 with many clients the retry loop exhausts
    and the top-up fallback runs; it must never pick a starved client as its
    own donor (which used to loop forever / move samples nowhere) and must
    still conserve samples while satisfying min_per_client."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=300)
    parts = dirichlet_partition(labels, 40, alpha=0.05, seed=seed)
    assert sum(len(p) for p in parts) == len(labels)
    all_idx = np.concatenate(parts)
    assert len(np.unique(all_idx)) == len(labels)
    assert all(len(p) >= 2 for p in parts)


def test_dirichlet_topup_infeasible_raises():
    """Regression: with fewer samples than num_clients * min_per_client the
    old fallback silently drained already-topped-up clients and returned a
    partition full of empty clients; now it raises."""
    labels = np.random.default_rng(0).integers(0, 10, size=30)
    with pytest.raises(ValueError):
        dirichlet_partition(labels, 40, alpha=0.05, seed=0)


def test_dirichlet_skew_increases_with_small_alpha():
    labels = np.random.default_rng(0).integers(0, 10, size=5000)

    def class_entropy(parts):
        ents = []
        for p in parts:
            c = np.bincount(labels[p], minlength=10) + 1e-9
            q = c / c.sum()
            ents.append(-(q * np.log(q)).sum())
        return np.mean(ents)

    e_skewed = class_entropy(dirichlet_partition(labels, 20, 0.1, seed=1))
    e_iid = class_entropy(dirichlet_partition(labels, 20, 100.0, seed=1))
    assert e_skewed < e_iid - 0.3


def test_synthetic_image_dataset_learnable_structure():
    ds = make_image_dataset(train_per_class=50, test_per_class=10, seed=0)
    assert ds.x_train.shape == (500, 32, 32, 3)
    assert ds.x_test.shape == (100, 32, 32, 3)
    assert set(np.unique(ds.y_train)) == set(range(10))
    # classes are separated in pixel space by a linear probe direction:
    mus = np.stack([ds.x_train[ds.y_train == c].mean(0).ravel()
                    for c in range(10)])
    d = np.linalg.norm(mus[0] - mus[1])
    within = np.std([np.linalg.norm(
        ds.x_train[ds.y_train == 0][i].ravel() - mus[0]) for i in range(10)])
    assert d > 0.1 * within


def test_federated_data_weights():
    fed = make_federated_image_data(8, alpha=0.3, train_per_class=40,
                                    test_per_class=20, seed=0)
    w = fed.client_weights()
    assert abs(w.sum() - 1) < 1e-9
    assert (w > 0).all()


def test_client_loader_and_batch_iterator():
    x = np.arange(20)[:, None].astype(np.float32)
    y = np.arange(20).astype(np.int32)
    dl = ClientLoader(x, y, batch_size=8, seed=0)
    bx, by, idx = dl.next_batch()
    assert bx.shape == (8, 1) and (x[idx] == bx).all()
    batches = list(batch_iterator(x, y, 8, epochs=2))
    assert len(batches) == 4       # floor(20/8)=2 per epoch


def test_synthetic_tokens():
    b = synthetic_token_batch(0, 4, 32, vocab=100)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < 100
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
