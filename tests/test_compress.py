"""Compression subsystem (ISSUE 4): codecs, Pallas quantizer, wiring.

Four layers of lock-down:

1. the codecs themselves — payload accounting, bounded/unbiased quantization
   error, encode/decode vs the fused apply path, and the Pallas kernel vs
   its pure-jnp oracle (exact, under jit and interpret mode);
2. the comm layer — identity codecs reproduce the (omega+1)-bit accounting
   exactly, the cut x codec table prices every cell, and compressed cells
   strictly undercut fp32;
3. the dataflow — split_grad/FedSim with identity codecs are BIT-identical
   to the codec-free simulator (the subsystem's regression anchor), int8
   actually perturbs training (proof the codec sits in the real dataflow)
   while still learning;
4. the wireless side — the joint (cut, codec) controller grid, the codec
   carried per client in RoundReport, proportional-fair contention,
   capacity re-sharing after withdrawals, and the compress-sweep acceptance
   bar: int8 strictly increases scheduled participation over fp32 at a
   fixed deadline.
"""

import importlib.util
import math
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compress import (CODEC_NAMES, Fp8Codec, IdentityCodec, LinkCodecs,
                            TopKCodec, get_codec, link_codecs)
from repro.configs.base import HierarchyConfig, TrainConfig, WirelessConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.comm import comm_for_cnn, comm_table_for_cnn
from repro.core.fedsim import FedSim, split_grad
from repro.data.synthetic import make_federated_image_data
from repro.kernels.quantize.ops import quantize_dequantize, tensor_scale
from repro.models import cnn
from repro.wireless import (ChannelModel, client_round_bits,
                            make_cut_controller, make_scheduler)


def _sweep_module():
    spec = importlib.util.spec_from_file_location(
        "compress_sweep", pathlib.Path(__file__).parent.parent /
        "benchmarks" / "compress_sweep.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------------- codecs ------
def test_codec_factory_and_payloads():
    n = 10_000
    fp32 = get_codec("fp32")
    assert isinstance(fp32, IdentityCodec)
    # the default identity codec defers its width to the comm model's omega
    # (so one codec is exact for CNN omega=32 AND LM omega=16); standalone
    # payload math needs an explicit width
    assert fp32.bits_per_element is None
    with pytest.raises(ValueError, match="omega"):
        fp32.payload_bits(n)
    assert get_codec("fp32", omega=32).payload_bits(n) == n * 33
    assert get_codec("fp32", omega=16).payload_bits(n) == n * 17
    assert get_codec("int8").payload_bits(n) == n * 8 + 32
    assert get_codec("int4").payload_bits(n) == n * 4 + 32
    assert get_codec("int8", bits=6).payload_bits(n) == n * 6 + 32
    assert get_codec("fp8").payload_bits(n) == n * 8 + 32
    k = max(1, int(n * 0.05))
    assert get_codec("topk").payload_bits(n) == k * (32 + 14)  # log2(1e4)->14
    with pytest.raises(ValueError):
        get_codec("huffman")
    # int8 lanes cap the quantizer width: wider would silently wrap
    with pytest.raises(ValueError, match="2..8"):
        get_codec("int8", bits=12)
    # frozen + hashable: usable as static jit data and CommModel fields
    assert get_codec("int8") == get_codec("int8")
    assert hash(get_codec("int4")) == hash(get_codec("int4"))


@pytest.mark.parametrize("shape", [(7,), (16, 16, 16, 64), (3, 5, 11)])
@pytest.mark.parametrize("bits", [8, 4])
def test_pallas_quantizer_matches_ref(shape, bits, rng):
    """Acceptance: the Pallas int8/int4 quantizer matches ref.py exactly —
    eager vs eager AND jit vs jit, since both run the same float ops.
    The comparison must be like-for-like: an OUTER jit fuses the
    surrounding scale/uniform arithmetic differently (1-ulp FMA-style
    drift for ~half of int4 inputs), so jitted-pallas vs EAGER-ref is not
    a kernel property and used to flake with the session rng's state."""
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32)) * 3.0
    key = jax.random.PRNGKey(0)
    got = quantize_dequantize(x, key, bits=bits)            # pallas interpret
    ref = quantize_dequantize(x, key, bits=bits, use_ref=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    jitted = jax.jit(lambda x_, k_: quantize_dequantize(x_, k_, bits=bits))
    jitted_ref = jax.jit(lambda x_, k_: quantize_dequantize(x_, k_,
                                                            bits=bits,
                                                            use_ref=True))
    np.testing.assert_array_equal(np.asarray(jitted(x, key)),
                                  np.asarray(jitted_ref(x, key)))


def test_quantizer_error_bounded_and_unbiased(rng):
    x = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    scale = float(tensor_scale(x, 127)[0, 0])
    out = quantize_dequantize(x, jax.random.PRNGKey(1), bits=8)
    # stochastic rounding moves a value at most one grid step
    assert float(jnp.abs(out - x).max()) <= scale + 1e-7
    # ...and is unbiased: averaging over keys recovers x
    outs = [quantize_dequantize(x, jax.random.PRNGKey(k), bits=8)
            for k in range(64)]
    mean_err = float(jnp.abs(jnp.stack(outs).mean(0) - x).mean())
    assert mean_err < scale / 4


def test_quantizer_deterministic_mode_and_zero_input():
    x = jnp.zeros((8, 128), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(quantize_dequantize(x, jax.random.PRNGKey(0), bits=8)), 0.0)
    y = jnp.asarray([[0.2, -1.0, 0.6]], jnp.float32)
    a = quantize_dequantize(y, jax.random.PRNGKey(0), bits=8, stochastic=False)
    b = quantize_dequantize(y, jax.random.PRNGKey(9), bits=8, stochastic=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantizer_ste_gradient(rng):
    x = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    g = jax.grad(lambda z: quantize_dequantize(
        z, jax.random.PRNGKey(0), bits=8).sum())(x)
    np.testing.assert_array_equal(np.asarray(g), 1.0)


@pytest.mark.parametrize("name", ["int8", "int4"])
def test_uniform_codec_encode_decode_matches_apply(name, rng):
    c = get_codec(name)
    x = jnp.asarray(rng.normal(size=(16, 128)).astype(np.float32))
    key = jax.random.PRNGKey(3)
    q, scale = c.encode(key, x)
    assert q.dtype == jnp.int8
    assert int(jnp.abs(q).max()) <= c.qmax
    np.testing.assert_allclose(np.asarray(c.decode((q, scale))),
                               np.asarray(c.apply(key, x)), rtol=0, atol=0)


def test_topk_codec_keeps_largest_and_counts_index_bits(rng):
    c = TopKCodec(frac=0.1)
    x = jnp.asarray(rng.normal(size=(10, 50)).astype(np.float32))
    xh = np.asarray(c.apply(jax.random.PRNGKey(0), x))
    k = c.k_for(500)
    assert k == 50
    nz = xh != 0
    assert nz.sum() == k
    # the kept entries are exact and are the k largest magnitudes
    np.testing.assert_array_equal(xh[nz], np.asarray(x)[nz])
    thresh = np.sort(np.abs(np.asarray(x)).ravel())[-k]
    assert (np.abs(np.asarray(x)[~nz]) <= thresh).all()
    assert c.payload_bits(500) == k * (32 + math.ceil(math.log2(500)))


def test_fp8_codec_roundtrip(rng):
    c = Fp8Codec()
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32)) * 100.0
    xh = np.asarray(c.apply(jax.random.PRNGKey(0), x))
    # e4m3 keeps ~3 mantissa bits: 2^-3 relative error after scaling
    np.testing.assert_allclose(xh, np.asarray(x),
                               atol=float(np.abs(x).max()) * 2 ** -3)


# ------------------------------------------------------- comm accounting ---
def test_identity_codecs_reproduce_legacy_accounting():
    plain = comm_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                         batches_per_epoch=2)
    ident = comm_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                         batches_per_epoch=2, codecs=link_codecs("fp32"))
    assert ident.phi_local_bits() == plain.phi_local_bits()
    assert ident.phi_off_bits() == plain.phi_off_bits()
    assert ident.phi_phsfl_bits(5) == plain.phi_phsfl_bits(5)
    for k0 in (1, 5):
        assert client_round_bits(ident, k0) == client_round_bits(plain, k0)
    # per-direction payloads fall back to the full-precision reference
    assert plain.phi_activation_up_bits() == plain.phi_activation_bits()
    assert plain.phi_grad_down_bits() == plain.phi_activation_bits()
    # the deferred-width identity codec is exact at ANY omega — the LM path
    # prices floats at (16+1) bits, not the CNN's 33
    from repro.configs.registry import get_arch
    lm_cfg = get_arch("xlstm-350m").reduced()
    from repro.core.comm import comm_for_lm
    lm_plain = comm_for_lm(lm_cfg, seq_len=64, dataset_size=100)
    lm_ident = comm_for_lm(lm_cfg, seq_len=64, dataset_size=100,
                           codecs=link_codecs("fp32"))
    assert lm_ident.phi_local_bits() == lm_plain.phi_local_bits()
    assert lm_ident.phi_off_bits() == lm_plain.phi_off_bits()


def test_cut_codec_table_prices_every_cell():
    named = {"fp32": None, "int8": link_codecs("int8")}
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                               batches_per_epoch=2, codecs=named)
    assert set(table) == {(c, n) for c in cnn.CUT_CANDIDATES for n in named}
    for c in cnn.CUT_CANDIDATES:
        fp, q = table[(c, "fp32")], table[(c, "int8")]
        assert q.phi_local_bits() < fp.phi_local_bits()
        assert q.phi_off_bits() < fp.phi_off_bits()
        b_fp, b_q = client_round_bits(fp, 2), client_round_bits(q, 2)
        assert b_q.uplink < b_fp.uplink and b_q.downlink < b_fp.downlink
    # asymmetric codecs: only the uplink payload shrinks
    up_only = LinkCodecs(activations=get_codec("int8"))
    cm = comm_for_cnn(CNN_CFG, dataset_size=400, codecs=up_only)
    plain = comm_for_cnn(CNN_CFG, dataset_size=400)
    assert cm.phi_activation_up_bits() < plain.phi_activation_up_bits()
    assert cm.phi_grad_down_bits() == plain.phi_grad_down_bits()
    assert cm.phi_off_bits() == plain.phi_off_bits()


# ------------------------------------------------------------ dataflow -----
def test_split_grad_identity_codecs_bit_identical(rng):
    params = cnn.init(jax.random.PRNGKey(1), CNN_CFG)
    x = jnp.asarray(rng.normal(size=(16, 32, 32, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=16).astype(np.int32))
    f = jax.jit(split_grad, static_argnames=("cut", "codecs"))
    ref_loss, ref_g = f(params, x, y, cut="conv1")
    loss, g = f(params, x, y, cut="conv1", codecs=link_codecs("fp32"),
                key=jax.random.PRNGKey(7))
    assert np.asarray(loss) == np.asarray(ref_loss)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(ref_g)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # lossless codecs never consume the key, so omitting it is fine...
    loss2, _ = f(params, x, y, cut="conv1", codecs=link_codecs("fp32"))
    assert np.asarray(loss2) == np.asarray(ref_loss)
    # ...but stochastic codecs without a key would silently reuse the same
    # rounding noise every call — that misuse must raise
    with pytest.raises(ValueError, match="key"):
        split_grad(params, x, y, cut="conv1", codecs=link_codecs("int8"))


def test_split_grad_int8_perturbs_but_tracks(rng):
    params = cnn.init(jax.random.PRNGKey(1), CNN_CFG)
    x = jnp.asarray(rng.normal(size=(8, 32, 32, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=8).astype(np.int32))
    ref_loss, ref_g = split_grad(params, x, y, cut="conv1")
    loss, g = split_grad(params, x, y, cut="conv1",
                         codecs=link_codecs("int8"),
                         key=jax.random.PRNGKey(7))
    assert float(loss) != float(ref_loss)            # the codec is in play
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=0.1)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(ref_g)):
        assert np.isfinite(np.asarray(a)).all()
        assert np.asarray(a).shape == np.asarray(b).shape


@pytest.fixture(scope="module")
def small_fed():
    return make_federated_image_data(8, alpha=0.4, train_per_class=20,
                                     test_per_class=10, seed=0)


def _fedsim(fed, codecs=None, wireless=None, **kw):
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=1,
                        kappa1=2, global_rounds=2)
    t = TrainConfig(learning_rate=0.05, batch_size=8, freeze_head=True)
    return FedSim(CNN_CFG, fed, h, t, batches_per_epoch=1, seed=0,
                  codecs=codecs, wireless=wireless, **kw)


def test_fedsim_identity_codec_trajectory_bit_identical(small_fed):
    """ISSUE 4 primary acceptance test: the identity codec reproduces the
    codec-free trajectory bit-for-bit — per-round losses, test metrics, and
    final parameters — even though it runs the codec-aware step path
    (per-minibatch keys, offload hook and all)."""
    base = _fedsim(small_fed).run(rounds=2, log_every=1)
    ident = _fedsim(small_fed, codecs=link_codecs("fp32")).run(
        rounds=2, log_every=1)
    assert base.history == ident.history
    for a, b in zip(jax.tree.leaves(base.global_params),
                    jax.tree.leaves(ident.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fedsim_int8_trains_but_differs(small_fed):
    base = _fedsim(small_fed).run(rounds=2, log_every=1)
    q = _fedsim(small_fed, codecs=link_codecs("int8")).run(
        rounds=2, log_every=1)
    assert q.history[-1]["train_loss"] != base.history[-1]["train_loss"]
    assert np.isfinite(q.history[-1]["test_loss"])
    # quantized training still learns: well above the 10-class chance floor
    assert q.history[-1]["test_acc"] > 0.2


# --------------------------------------------- joint (cut, codec) grid -----
def _grid_controller(policy, deadline=float("inf")):
    named = {"fp32": None, "int8": link_codecs("int8")}
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                               batches_per_epoch=2, codecs=named)
    return make_cut_controller(table, 2, policy=policy, deadline_s=deadline)


def test_controller_grid_maps_cells_to_cut_and_codec():
    ctl = _grid_controller("greedy")
    assert ctl.num_cuts == 6 and ctl.has_codec_grid
    assert ctl.cut_names == cnn.CUT_CANDIDATES
    assert ctl.codec_names == ("fp32", "int8")
    specs = ctl.specs
    assert {(s.name, s.codec) for s in specs} == \
        {(c, n) for c in cnn.CUT_CANDIDATES for n in ("fp32", "int8")}
    np.testing.assert_array_equal(np.sort(ctl.cut_pos), [0, 0, 1, 1, 2, 2])
    np.testing.assert_array_equal(np.sort(ctl.codec_pos), [0, 0, 0, 1, 1, 1])
    # a single-codec table has no codec grid
    plain = make_cut_controller(
        comm_table_for_cnn(CNN_CFG, dataset_size=400), 2, policy="greedy")
    assert not plain.has_codec_grid
    assert plain.codec_names == ("fp32",)


def test_grid_deadline_policy_buys_compression_when_rate_drops():
    """At a generous rate the deepest cut wins regardless of codec; at a
    starved rate only compressed cells fit the deadline, so the controller
    pays quantization to stay deep — the joint decision the cut-only
    controller could not express."""
    ctl = _grid_controller("deadline", deadline=4.0)
    rich = ctl.decide(np.array([200e6]), np.array([800e6]), 0.0,
                      np.array([np.inf]))
    assert ctl.cut_pos[rich[0]] == 2                  # deepest cut
    poor = ctl.decide(np.array([4e6]), np.array([16e6]), 0.0,
                      np.array([np.inf]))
    spec = ctl.specs[poor[0]]
    assert spec.codec == "int8"                       # fp32 can't make it
    # greedy on the same grid picks the global fastest cell, which at a
    # finite rate is always a compressed one (fewer bits, same latency)
    g = _grid_controller("greedy")
    cut = g.decide(np.array([10e6]), np.array([40e6]), 0.0, np.array([np.inf]))
    assert g.specs[cut[0]].codec == "int8"


def test_fixed_cell_selection_on_grid():
    named = {"fp32": None, "int8": link_codecs("int8")}
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400, codecs=named)
    ctl = make_cut_controller(table, 2, policy="fixed",
                              fixed_cut=("conv2", "int8"))
    spec = ctl.specs[ctl.fixed_cut]
    assert (spec.name, spec.codec) == ("conv2", "int8")
    # a bare cut name picks that cut's first-listed codec
    ctl2 = make_cut_controller(table, 2, policy="fixed", fixed_cut="conv2")
    spec2 = ctl2.specs[ctl2.fixed_cut]
    assert (spec2.name, spec2.codec) == ("conv2", "fp32")
    with pytest.raises(ValueError):
        make_cut_controller(table, 2, policy="fixed",
                            fixed_cut=("conv2", "zip"))


@pytest.mark.parametrize("seed", range(3))
def test_scheduler_reports_codec_per_client(seed):
    cfg = WirelessConfig(model="rayleigh", mean_uplink_mbps=15.0,
                         mean_downlink_mbps=60.0, latency_s=0.01,
                         heterogeneity=0.7, deadline_s=2.0,
                         es_uplink_mbps=30.0, cut_policy="deadline",
                         cut_candidates=cnn.CUT_CANDIDATES, seed=seed)
    named = {"fp32": None, "int8": link_codecs("int8")}
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                               batches_per_epoch=2, codecs=named)
    s = make_scheduler(cfg, 8, kappa0=2, comm_table=table,
                       es_assign=np.arange(8) // 4)
    saw_q = False
    for r in range(4):
        rep = s.step(r)
        assert rep.cuts is not None and rep.codecs is not None
        assert ((rep.cuts >= 0) & (rep.cuts < 3)).all()
        assert ((rep.codecs >= 0) & (rep.codecs < 2)).all()
        assert rep.bits_tx >= 0.0
        saw_q |= bool((rep.codecs == 1).any())
    assert saw_q, "the grid never chose a compressed cell"


# ----------------------------------------------------- contention rules ----
def test_proportional_fair_weights_shares_by_private_rate():
    cfg = WirelessConfig(model="static", mean_uplink_mbps=10.0,
                         heterogeneity=1.0, es_uplink_mbps=20.0,
                         contention="proportional", seed=3)
    ch = ChannelModel(cfg, num_clients=8)
    link = ch.sample(0)
    es = np.arange(8) // 4
    active = np.ones(8, bool)
    eff = ch.contended_uplink(link, active, es)
    cap = 20e6
    for b in range(2):
        grp = es == b
        r = link.uplink_bps[grp]
        expect = np.minimum(r, cap * r / r.sum())
        np.testing.assert_allclose(eff[grp], expect)
        assert eff[grp].sum() <= cap * (1 + 1e-9)
    # rates differ across clients (the whole point vs equal split)
    assert len(np.unique(eff)) > 2
    # inactive clients keep their private rate
    active[0] = False
    eff = ch.contended_uplink(link, active, es)
    assert eff[0] == link.uplink_bps[0]


def test_equal_contention_unchanged_and_validation():
    cfg = WirelessConfig(model="static", mean_uplink_mbps=10.0,
                         es_uplink_mbps=20.0, contention="equal")
    ch = ChannelModel(cfg, num_clients=4)
    eff = ch.contended_uplink(ch.sample(0), np.ones(4, bool),
                              np.zeros(4, int))
    np.testing.assert_allclose(eff, 5e6)
    with pytest.raises(ValueError, match="contention"):
        ChannelModel(WirelessConfig(model="static", contention="maxmin"), 4)


@pytest.mark.parametrize("seed", range(5))
def test_reshare_never_decreases_survivor_rates(seed):
    """ISSUE 4 satellite: after unaffordable clients withdraw, the second
    contention pass hands their capacity to the survivors — so for the
    identical first round, every surviving client's effective uplink under
    reshare_uplink=True is >= the conservative single pass, and whenever a
    withdrawal actually happened somebody's rate strictly rises."""
    def mk(reshare):
        cfg = WirelessConfig(model="static", mean_uplink_mbps=30.0,
                             mean_downlink_mbps=120.0, latency_s=0.0,
                             heterogeneity=1.2, es_uplink_mbps=40.0,
                             contention="proportional",
                             energy_budget_j=1.0, tx_power_w=0.5,
                             reshare_uplink=reshare, seed=seed)
        comm = comm_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                            batches_per_epoch=2)
        return make_scheduler(cfg, 8, comm, 2, es_assign=np.arange(8) // 4)

    rep_on, rep_off = mk(True).step(0), mk(False).step(0)
    np.testing.assert_array_equal(rep_on.scheduled, rep_off.scheduled)
    surv = rep_on.scheduled
    assert (rep_on.uplink_bps[surv] >= rep_off.uplink_bps[surv] - 1e-9).all()
    assert (rep_on.times_s[surv] <= rep_off.times_s[surv] + 1e-12).all()
    assert rep_on.num_participants >= rep_off.num_participants


def test_reshare_strictly_raises_survivor_rate():
    """Deterministic reshare scenario (trace channel): the fast client can
    afford its FIRST-pass proportional share, the slow one cannot and
    withdraws; the second pass hands the whole 30 Mbps pipe to the
    survivor, whose rate strictly rises above the single-pass share."""
    def mk(reshare):
        cfg = WirelessConfig(model="trace", mean_uplink_mbps=100.0,
                             mean_downlink_mbps=100.0, latency_s=0.0,
                             trace=((100.0, 18.0),), es_uplink_mbps=30.0,
                             contention="proportional", energy_budget_j=1.0,
                             tx_power_w=0.5, reshare_uplink=reshare, seed=0)
        comm = comm_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                            batches_per_epoch=2)   # 34.66 Mb uplink
        return make_scheduler(cfg, 2, comm, 2, es_assign=np.zeros(2, int))

    rep_on, rep_off = mk(True).step(0), mk(False).step(0)
    # both passes agree on WHO survives: the 18 Mbps client's contended
    # share (30 * 18/118 = 4.6 Mbps) prices it out, the fast one stays
    for rep in (rep_on, rep_off):
        np.testing.assert_array_equal(rep.scheduled, [True, False])
    # single pass: the survivor keeps its first-pass share 30*100/118
    np.testing.assert_allclose(rep_off.uplink_bps[0], 30e6 * 100 / 118)
    # reshare: the survivor absorbs the freed capacity -> the full pipe
    np.testing.assert_allclose(rep_on.uplink_bps[0], 30e6)
    assert rep_on.uplink_bps[0] > rep_off.uplink_bps[0]
    assert rep_on.times_s[0] < rep_off.times_s[0]


# ------------------------------------------------------ sweep acceptance ---
def test_compress_sweep_dry_run_int8_beats_fp32():
    """The benchmark's acceptance bar at tier-1 speed (scheduler only, no
    training): int8 activations STRICTLY increase participation over fp32
    at the same fixed deadline and energy budget — fp32 clients are still
    scheduled under the deadline-capped energy gate (ISSUE 5) but every
    transmission is cut off at the deadline, so they burn budget moving
    bits that never complete."""
    sweep = _sweep_module()
    table = sweep.sweep(None, ["static"], dry_run=True, deadline=1.0,
                        rounds=2, es_uplink_mbps=40.0, energy_budget=1.0,
                        seed=0, topk_frac=0.05)
    rows = {r["codec"]: r for r in table}
    assert set(rows) == set(CODEC_NAMES)
    assert rows["int8"]["scheduled_rate"] >= rows["fp32"]["scheduled_rate"]
    assert (rows["int8"]["participation_rate"]
            > rows["fp32"]["participation_rate"])
    # the honest moved-bits accounting makes the waste visible: fp32 moved
    # bits (its stragglers transmitted until the cutoff) yet nobody ever
    # completed an aggregation
    assert rows["fp32"]["participation_rate"] == 0.0
    assert rows["fp32"]["total_bits"] > 0.0
    assert rows["int8"]["participation_rate"] > 0.0
    assert sweep.check_acceptance(table, ["static"])


def test_compress_sweep_fedsim_int8_participates_fp32_priced_out(small_fed):
    """The same bar through the REAL simulator at test scale: with the
    benchmark's channel, fp32 clients transmit (the deadline-capped charge
    is affordable) but every transmission is cut off before completing, so
    no fp32 client ever participates — while int8 clients are scheduled,
    make the deadline, and train."""
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=2,
                        kappa1=2, global_rounds=1)
    t = TrainConfig(learning_rate=0.05, batch_size=16, freeze_head=True)
    w = WirelessConfig(model="static", mean_uplink_mbps=20.0,
                       mean_downlink_mbps=80.0, latency_s=0.02,
                       deadline_s=1.0, es_uplink_mbps=40.0,
                       energy_budget_j=1.0, seed=0)

    def run(codecs):
        sim = FedSim(CNN_CFG, small_fed, h, t, batches_per_epoch=2, seed=0,
                     wireless=w, codecs=codecs)
        res = sim.run(rounds=1, log_every=1)
        return res.network

    net_fp = run(None)
    net_q = run(link_codecs("int8"))
    sched_fp = sum(n["scheduled"] for n in net_fp)
    sched_q = sum(n["scheduled"] for n in net_q)
    parts_fp = sum(n["participants"] for n in net_fp)
    parts_q = sum(n["participants"] for n in net_q)
    assert sched_q >= sched_fp
    assert parts_fp == 0                  # fp32: all cut off at the deadline
    assert parts_q > 0                    # int8: completes and aggregates
    # fp32 DID transmit (the capped charge was affordable) and its moved
    # bits were all wasted on discarded transmissions
    assert sched_fp > 0
    assert sum(n["bits"] for n in net_fp) > 0
