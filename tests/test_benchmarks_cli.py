"""Argparse surfaces of the benchmark sweep CLIs.

The lint gate covers ``benchmarks/`` statically; these tests keep the entry
points themselves executable: bad flags exit nonzero with a usage message,
and ``--dry-run`` is genuinely side-effect-free (no files written, seconds
not minutes, scheduler-only).
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SWEEPS = ["benchmarks/cut_sweep.py", "benchmarks/compress_sweep.py",
          "benchmarks/device_sweep.py", "benchmarks/pipeline_sweep.py",
          "benchmarks/fault_sweep.py", "benchmarks/cohort_bench.py"]


def _run(script: str, *args: str, cwd=None):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    return subprocess.run(
        [sys.executable, str(REPO / script), *args],
        capture_output=True, text=True, env=env, cwd=cwd or REPO,
        timeout=600)


class TestBadFlags:
    @pytest.mark.parametrize("script", SWEEPS)
    def test_unknown_flag_exits_nonzero(self, script):
        r = _run(script, "--definitely-not-a-flag")
        assert r.returncode == 2
        assert "usage" in r.stderr.lower()

    @pytest.mark.parametrize("script", SWEEPS)
    def test_bad_value_exits_nonzero(self, script):
        r = _run(script, "--rounds", "not-an-int")
        assert r.returncode == 2
        assert "invalid" in r.stderr.lower()

    def test_bad_channel_choice_rejected(self):
        r = _run("benchmarks/cut_sweep.py", "--channels", "plasma")
        assert r.returncode == 2
        assert "invalid choice" in r.stderr.lower()


class TestDryRun:
    @pytest.mark.parametrize("script", SWEEPS)
    def test_dry_run_is_side_effect_free(self, script, tmp_path):
        # run from an empty cwd: a side-effecting run would drop files here
        r = _run(script, "--dry-run", "--channels", "static", "--rounds", "1",
                 cwd=tmp_path) if "device" not in script else \
            _run(script, "--dry-run", "--sigmas", "0.0", "--rounds", "1",
                 cwd=tmp_path)
        assert r.returncode == 0, r.stderr[-2000:]
        assert list(tmp_path.iterdir()) == []
        # the table is the first pretty-printed JSON array on stdout (the
        # acceptance summary lines may follow it)
        start = r.stdout.index("[")
        rows = json.loads(r.stdout[start:r.stdout.index("\n]", start) + 2])
        assert rows and all(row.get("dry_run") for row in rows)
        assert all(0.0 <= row["participation_rate"] <= 1.0 for row in rows)

    def test_dry_run_out_writes_only_the_asked_file(self, tmp_path):
        out = tmp_path / "table.json"
        r = _run("benchmarks/cut_sweep.py", "--dry-run", "--channels",
                 "static", "--rounds", "1", "--out", str(out), cwd=tmp_path)
        assert r.returncode == 0, r.stderr[-2000:]
        assert [p.name for p in tmp_path.iterdir()] == ["table.json"]
        assert json.loads(out.read_text())
