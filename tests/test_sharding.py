"""Partitioning rules: logical axes -> PartitionSpec with divisibility-aware
fallback, on an abstract production-shaped mesh (no devices needed)."""

import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.registry import ARCHS, get_arch
from repro.models import build_model
from repro.sharding.rules import params_specs, spec_for


def _mesh(multi=False):
    sizes = (2, 16, 16) if multi else (16, 16)
    names = ("pod", "data", "model") if multi else ("data", "model")
    try:
        return AbstractMesh(sizes, names)            # jax >= 0.5 signature
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))  # 0.4.x: (name, size)


def test_spec_for_basic_rules():
    mesh = _mesh()
    # mlp dim sharded over model
    assert spec_for((12288, 33792), ("embed", "mlp"), mesh) == P(None, "model")
    # fsdp mode also shards embed over data
    assert spec_for((12288, 33792), ("embed", "mlp"), mesh, mode="fsdp_tp") \
        == P("data", "model")
    # vocab over model
    assert spec_for((256000, 12288), ("vocab", "embed"), mesh) == P("model", None)


def test_spec_for_divisibility_fallback():
    mesh = _mesh()
    # 10 heads do not divide 16-way -> replicated
    assert spec_for((2560, 10, 256), ("embed", "heads", "head_dim"), mesh) \
        == P(None, None, None)
    # 96 heads divide -> sharded
    assert spec_for((12288, 96, 128), ("embed", "heads", "head_dim"), mesh) \
        == P(None, "model", None)
    # embed 1024 doesn't divide 32-way on multipod fsdp -> replicated
    m2 = _mesh(multi=True)
    assert spec_for((1000, 512), ("embed", "mlp"), m2, mode="fsdp_tp") \
        == P(None, "model")


def test_no_axis_used_twice():
    mesh = _mesh()
    s = spec_for((512, 512), ("mlp", "mlp"), mesh)
    used = [a for a in s if a is not None]
    assert len(used) <= 1


def test_params_specs_cover_all_archs_production_mesh():
    """Every param leaf of every FULL arch gets a valid spec on (16,16) and
    (2,16,16) — dims mentioned in specs must divide the mesh axes."""
    for multi in (False, True):
        mesh = _mesh(multi)
        for name in ARCHS:
            cfg = get_arch(name)
            model = build_model(cfg)
            shapes = jax.eval_shape(lambda k: model.init(k),
                                    jax.random.PRNGKey(0))
            specs = params_specs(shapes, model.axes(), mesh, mode="fsdp_tp")
            flat_s = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            flat_p = jax.tree.leaves(shapes)
            assert len(flat_s) == len(flat_p)
            for s, p in zip(flat_s, flat_p):
                for dim, entry in zip(p.shape, tuple(s)):
                    if entry is None:
                        continue
                    axes = entry if isinstance(entry, tuple) else (entry,)
                    size = 1
                    for a in axes:
                        size *= mesh.shape[a]
                    assert dim % size == 0, (name, p.shape, s)
