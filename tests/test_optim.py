"""Optimizers and the frozen-head mask (Eq. 12 as an optimizer transform)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim import (adamw, apply_updates, clip_by_global_norm,
                         global_norm, make_optimizer, masked, momentum, sgd)


def _quad_problem():
    params = {"w": jnp.asarray([1.0, -2.0, 3.0]), "frozen": jnp.ones((2,))}
    grads = {"w": 2 * params["w"], "frozen": jnp.asarray([5.0, -5.0])}
    return params, grads


def test_sgd_step():
    params, grads = _quad_problem()
    opt = sgd(0.1)
    st = opt.init(params)
    upd, st = opt.update(grads, st, params)
    new = apply_updates(params, upd)
    np.testing.assert_allclose(new["w"], params["w"] - 0.2 * params["w"],
                               rtol=1e-6)
    assert int(st["count"]) == 1


def test_masked_freezes_leaves():
    params, grads = _quad_problem()
    mask = {"w": True, "frozen": False}
    for name in ("sgd", "momentum", "adamw"):
        opt = masked(make_optimizer(name, 0.1), mask)
        st = opt.init(params)
        p = params
        for _ in range(3):
            upd, st = opt.update(grads, st, p)
            p = apply_updates(p, upd)
        np.testing.assert_array_equal(p["frozen"], params["frozen"])
        assert not np.allclose(p["w"], params["w"])


def test_sgd_descends_quadratic():
    opt = sgd(0.1)
    p = {"w": jnp.asarray([4.0, -3.0])}
    st = opt.init(p)
    for _ in range(50):
        g = {"w": 2 * p["w"]}
        upd, st = opt.update(g, st, p)
        p = apply_updates(p, upd)
    assert float(jnp.abs(p["w"]).max()) < 1e-3


def test_adamw_weight_decay():
    opt = adamw(0.01, weight_decay=0.1)
    p = {"w": jnp.asarray([10.0])}
    st = opt.init(p)
    upd, st = opt.update({"w": jnp.asarray([0.0])}, st, p)
    new = apply_updates(p, upd)
    assert float(new["w"][0]) < 10.0      # decay pulls toward zero


def test_momentum_accumulates():
    opt = momentum(0.1, beta=0.9)
    p = {"w": jnp.asarray([1.0])}
    st = opt.init(p)
    g = {"w": jnp.asarray([1.0])}
    upd1, st = opt.update(g, st, p)
    upd2, st = opt.update(g, st, p)
    assert abs(float(upd2["w"][0])) > abs(float(upd1["w"][0]))


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    g_small = {"a": jnp.full((4,), 0.01)}
    same = clip_by_global_norm(g_small, 1.0)
    np.testing.assert_allclose(same["a"], g_small["a"], rtol=1e-6)
