"""Roofline machinery: HLO collective parser + analytic cost model sanity."""

import pytest

from repro.configs.registry import get_arch
from repro.configs.shapes import SHAPES
from repro.launch.analytic import (cost_for, decode_cost,
                                   forward_flops_per_token, prefill_cost,
                                   train_cost)
from repro.launch.roofline import (Roofline, collective_bytes,
                                   model_flops_for)

HLO = """
ENTRY %main {
  %p0 = bf16[8,128]{1,0} parameter(0)
  %ar = bf16[8,128]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = f32[16,128]{1,0} all-gather(%p0), dimensions={0}
  %rs.1 = f32[4,128]{1,0} reduce-scatter(%ag), dimensions={0}, to_apply=%add
  %cp = bf16[8,128]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
  %dot = f32[8,8]{1,0} dot(%p0, %p0)
}
"""


def test_collective_parser():
    out = collective_bytes(HLO)
    assert out["all-reduce"] == 8 * 128 * 2
    assert out["all-gather"] == 16 * 128 * 4
    assert out["reduce-scatter"] == 4 * 128 * 4
    assert out["collective-permute"] == 8 * 128 * 2
    assert out["all-to-all"] == 0
    assert out["total"] == sum(out[k] for k in
                               ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_forward_flops_scaling():
    cfg12 = get_arch("gemma3-12b")
    cfg27 = get_arch("gemma3-27b")
    f12 = forward_flops_per_token(cfg12, 4096)
    f27 = forward_flops_per_token(cfg27, 4096)
    assert f27 > f12 > 0
    # roughly 2N flops/token
    assert 1.5e10 < f12 < 6e10


def test_moe_flops_are_active_not_total():
    cfg = get_arch("olmoe-1b-7b")
    f = forward_flops_per_token(cfg, 4096)
    # olmoe active ~1.3B params -> ~2*N_active + attention; far below 64-expert dense
    dense_equiv = 2 * 7e9
    assert f < dense_equiv


def test_train_cost_structure():
    cfg = get_arch("mistral-large-123b")
    shape = SHAPES["train_4k"]
    mesh = {"data": 16, "model": 16}
    c = train_cost(cfg, shape, mesh)
    assert c.flops > 0 and c.hbm_bytes > 0 and c.coll_bytes > 0
    # multi-pod adds the CS-level aggregation bytes
    c2 = train_cost(cfg, shape, {"pod": 2, "data": 16, "model": 16})
    assert c2.detail["coll_pod"] > 0
    assert c.detail["coll_pod"] == 0


def test_shared_server_cuts_edge_aggregation():
    cfg = get_arch("command-r-plus-104b")
    shape = SHAPES["train_4k"]
    mesh = {"data": 16, "model": 16}
    faithful = train_cost(cfg, shape, mesh, mode="paper_faithful")
    shared = train_cost(cfg, shape, mesh, mode="shared_server")
    # the paper-Remark-1 effect at datacenter scale: the kappa0-boundary
    # full-model all-reduce disappears (body syncs via per-step grad
    # all-reduce, client block is tiny)
    assert shared.detail["coll_edge"] < faithful.detail["coll_edge"] * 1.2


def test_decode_memory_bound():
    cfg = get_arch("command-r-plus-104b")
    c = decode_cost(cfg, SHAPES["decode_32k"], {"data": 16, "model": 16})
    r = Roofline(arch="x", shape="decode_32k", mesh="single", chips=256,
                 flops=c.flops, hbm_bytes=c.hbm_bytes, coll_bytes=c.coll_bytes)
    assert r.memory_s > r.compute_s       # decode is memory/collective bound


def test_model_flops_kinds():
    cfg = get_arch("gemma3-12b")
    tr = model_flops_for(cfg, SHAPES["train_4k"], "train")
    pf = model_flops_for(cfg, SHAPES["prefill_32k"], "prefill")
    dc = model_flops_for(cfg, SHAPES["decode_32k"], "decode")
    assert tr > pf > dc > 0


@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k"])
def test_cost_for_all_archs(shape_name):
    from repro.configs.registry import ARCHS
    for name in ARCHS:
        c = cost_for(get_arch(name), SHAPES[shape_name],
                     {"data": 16, "model": 16})
        assert c.flops > 0 and c.hbm_bytes > 0, name
