"""utils/prng.py and the repo's documented PRNG stream conventions.

The wireless subsystem carves three host-side streams out of one seed —
channel = seed, scheduler = seed + 1, device = seed + 2 — and the jax side
derives per-purpose keys via fold_in.  These tests pin the disjointness
those conventions rely on.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.utils.prng import fold_in_str, key_iter


class TestKeyIter:
    def test_yields_distinct_keys(self):
        it = key_iter(0)
        keys = [jax.random.key_data(next(it)) for _ in range(8)]
        seen = {tuple(np.asarray(k).tolist()) for k in keys}
        assert len(seen) == 8

    def test_deterministic_across_instances(self):
        a = [np.asarray(jax.random.key_data(k))
             for k, _ in zip(key_iter(7), range(4))]
        b = [np.asarray(jax.random.key_data(k))
             for k, _ in zip(key_iter(7), range(4))]
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_seeds_give_disjoint_streams(self):
        a = [tuple(np.asarray(jax.random.key_data(k)).tolist())
             for k, _ in zip(key_iter(0), range(16))]
        b = [tuple(np.asarray(jax.random.key_data(k)).tolist())
             for k, _ in zip(key_iter(1), range(16))]
        assert not set(a) & set(b)


class TestFoldInStr:
    def test_stable_and_name_sensitive(self):
        key = jax.random.PRNGKey(0)
        k1 = fold_in_str(key, "codec")
        k2 = fold_in_str(key, "codec")
        k3 = fold_in_str(key, "channel")
        np.testing.assert_array_equal(jax.random.key_data(k1),
                                      jax.random.key_data(k2))
        assert not np.array_equal(jax.random.key_data(k1),
                                  jax.random.key_data(k3))

    def test_draws_differ_between_names(self):
        key = jax.random.PRNGKey(3)
        a = jax.random.normal(fold_in_str(key, "a"), (64,))
        b = jax.random.normal(fold_in_str(key, "b"), (64,))
        assert not np.allclose(a, b)


class TestHostStreamConvention:
    """channel=seed, scheduler=seed+1, device=seed+2 (wireless docstrings)."""

    @pytest.mark.parametrize("seed", [0, 1, 123])
    def test_adjacent_seeds_are_decorrelated(self, seed):
        draws = [np.random.default_rng(seed + off).uniform(size=4096)
                 for off in range(3)]
        for i in range(3):
            for j in range(i + 1, 3):
                r = np.corrcoef(draws[i], draws[j])[0, 1]
                assert abs(r) < 0.05, (i, j, r)

    def test_streams_do_not_collide(self):
        streams = [np.random.default_rng(off).integers(0, 2**63, size=256)
                   for off in range(3)]
        sets = [set(s.tolist()) for s in streams]
        assert not (sets[0] & sets[1] or sets[0] & sets[2]
                    or sets[1] & sets[2])

    def test_wireless_uses_the_convention(self):
        # the convention is load-bearing: the channel (seed) and device
        # (seed+2) draw per-client lognormal heterogeneity scales from the
        # SAME base seed and must not be the same realization
        from repro.configs.base import WirelessConfig
        from repro.wireless import ChannelModel, DeviceModel

        cfg = WirelessConfig(model="static", seed=0, heterogeneity=1.0,
                             compute_heterogeneity=1.0, compute_gflops=10.0)
        n = 256
        ch = ChannelModel(cfg, n)
        dev = DeviceModel(cfg, n)
        assert ch._scale.shape == dev._scale.shape == (n,)
        assert not np.allclose(ch._scale, dev._scale)
        r = np.corrcoef(ch._scale, dev._scale)[0, 1]
        assert abs(r) < 0.2   # identical streams would give exactly 1.0
