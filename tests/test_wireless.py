"""Wireless channel, participation scheduler, and masked-aggregation
integration: the ideal-network trajectory must be reproduced bit-for-bit
under a full participation mask, and partial masks must renormalize."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import (HierarchyConfig, TrainConfig, WirelessConfig)
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.fedsim import FedSim
from repro.data.synthetic import make_federated_image_data
from repro.wireless import (ChannelModel, ParticipationScheduler, RoundBits,
                            client_round_bits, make_scheduler)
from repro.wireless.channel import LinkState


BITS = RoundBits(uplink=10_000_000, downlink=10_000_000)


def _chan(**kw):
    return ChannelModel(WirelessConfig(**kw), num_clients=8)


# ----------------------------------------------------------- channel -------
def test_ideal_channel_is_free():
    ch = _chan(model="ideal")
    link = ch.sample(0)
    t = ch.round_time_s(link, BITS)
    assert (t == 0).all()
    assert (ch.round_energy_j(link, BITS) == 0).all()


def test_static_channel_deterministic_and_correct():
    ch = _chan(model="static", mean_uplink_mbps=10.0, mean_downlink_mbps=40.0,
               latency_s=0.01)
    l0, l1 = ch.sample(0), ch.sample(7)
    np.testing.assert_array_equal(l0.uplink_bps, l1.uplink_bps)
    t = ch.round_time_s(l0, BITS)
    # 2*10ms latency + 10Mb/10Mbps + 10Mb/40Mbps = 0.02 + 1.0 + 0.25
    np.testing.assert_allclose(t, 1.27, rtol=1e-6)
    e = ch.round_energy_j(l0, BITS)
    np.testing.assert_allclose(e, 0.5 * 1.0, rtol=1e-6)   # P_tx * airtime


def test_rayleigh_fades_per_round_not_per_client_scale():
    ch = _chan(model="rayleigh", seed=3)
    t0 = ch.round_time_s(ch.sample(0), BITS)
    t1 = ch.round_time_s(ch.sample(1), BITS)
    assert not np.allclose(t0, t1)          # fading varies round to round
    assert (t0 > 0).all() and np.isfinite(t0).all()


def test_heterogeneity_gives_persistent_fast_and_slow_clients():
    ch = _chan(model="static", heterogeneity=1.0, seed=0)
    t = ch.round_time_s(ch.sample(0), BITS)
    assert t.min() < t.max() / 2            # clearly heterogeneous
    t2 = ch.round_time_s(ch.sample(5), BITS)
    np.testing.assert_array_equal(t, t2)    # but fixed over rounds


def test_trace_channel_replays_rows():
    tr = ((5.0,) * 8, (50.0,) * 8)
    ch = _chan(model="trace", trace=tr, latency_s=0.0)
    t0 = ch.round_time_s(ch.sample(0), BITS)
    t1 = ch.round_time_s(ch.sample(1), BITS)
    t2 = ch.round_time_s(ch.sample(2), BITS)   # cycles back to row 0
    assert (t0 > t1).all()
    np.testing.assert_array_equal(t0, t2)


def test_client_round_bits_accounting():
    from repro.core.comm import comm_for_cnn
    comm = comm_for_cnn(CNN_CFG, dataset_size=100)
    bits = client_round_bits(comm, kappa0=3)
    nb = comm.batches_per_epoch
    assert bits.uplink == (3 * nb * (comm.phi_activation_bits()
                                     + comm.phi_indices_bits())
                           + comm.phi_off_bits())
    assert bits.downlink == 3 * nb * comm.phi_activation_bits() \
        + comm.phi_off_bits()
    # uplink ships the minibatch indices too, so it is strictly bigger
    assert bits.uplink > bits.downlink


# --------------------------------------------------------- scheduler -------
def _sched(**kw):
    cfg = WirelessConfig(model="static", mean_uplink_mbps=10.0,
                         mean_downlink_mbps=40.0, latency_s=0.0,
                         heterogeneity=1.0, **kw)
    return ParticipationScheduler(cfg, ChannelModel(cfg, 8), BITS)


def test_deadline_drops_stragglers():
    s = _sched(deadline_s=1.0)
    rep = s.step(0)
    assert 0 < rep.num_participants < 8     # heterogeneity: some miss 1.0s
    times = s.channel.round_time_s(s.channel.sample(0), BITS)
    np.testing.assert_array_equal(rep.mask, (times <= 1.0).astype(np.float64))
    assert rep.round_time_s == 1.0          # ES waited out the deadline


def test_topk_keeps_fastest():
    s = _sched(selection="topk", topk=3)
    rep = s.step(0)
    assert rep.num_participants == 3
    picked = np.flatnonzero(rep.mask)
    assert set(picked) == set(np.argsort(rep.times_s)[:3])


def test_unscheduled_clients_cost_no_waiting():
    """Regression: clients dropped by the SCHEDULER (top-k) — not by the
    deadline — must not inflate the simulated round time to the deadline;
    the ES only waits for clients it scheduled."""
    s = _sched(selection="topk", topk=3, deadline_s=10.0)
    rep = s.step(0)
    assert rep.num_participants == 3
    assert rep.round_time_s == rep.times_s[rep.mask > 0].max()
    assert rep.round_time_s < 10.0


def test_random_selection_thins():
    s = _sched(selection="random", participation_prob=0.5)
    counts = [s.step(r).num_participants for r in range(40)]
    assert 0.2 < np.mean(counts) / 8 < 0.8


def test_energy_budget_gates_participation():
    # static homogeneous channel: every participating round costs the same,
    # so once the budget is below one round's cost the dropout is permanent
    cfg = WirelessConfig(model="static", mean_uplink_mbps=10.0,
                         mean_downlink_mbps=40.0, latency_s=0.0,
                         energy_budget_j=1.2, tx_power_w=0.5)
    s = ParticipationScheduler(cfg, ChannelModel(cfg, 4), BITS)
    # one round costs 0.5 W * 1 s = 0.5 J -> budget 1.2 J allows 2 rounds
    parts = [s.step(r).num_participants for r in range(4)]
    assert parts == [4, 4, 0, 0]
    assert (s.energy_left >= 0).all()


# ------------------------------------- fedsim + mask integration -----------
@pytest.fixture(scope="module")
def small_fed():
    return make_federated_image_data(4, alpha=0.5, train_per_class=20,
                                     test_per_class=10, seed=0)


def _fedsim(fed, wireless=None, seed=0):
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=2, kappa0=1,
                        kappa1=2, global_rounds=2)
    t = TrainConfig(learning_rate=0.05, batch_size=8, freeze_head=True)
    return FedSim(CNN_CFG, fed, h, t, batches_per_epoch=1, seed=seed,
                  wireless=wireless)


def test_full_participation_bit_identical_to_ideal(small_fed):
    """Acceptance regression: a wireless scenario whose mask is all-ones on
    every edge round reproduces the pre-wireless trajectory bit-for-bit."""
    res_ideal = _fedsim(small_fed).run(rounds=2, log_every=1)
    # static channel, no deadline, no energy cap => everyone participates
    w = WirelessConfig(model="static", deadline_s=float("inf"))
    sim = _fedsim(small_fed, wireless=w)
    assert sim.scheduler is not None
    res_w = sim.run(rounds=2, log_every=1)
    assert all(n["participants"] == 4 for n in res_w.network)
    for a, b in zip(jax.tree.leaves(res_ideal.global_params),
                    jax.tree.leaves(res_w.global_params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for ra, rb in zip(res_ideal.history, res_w.history):
        assert ra["train_loss"] == rb["train_loss"]
        assert ra["test_loss"] == rb["test_loss"]


def test_zero_participation_freezes_models(small_fed):
    """Impossible deadline: nobody ever participates, so every edge round
    keeps the previous edge model and training goes nowhere."""
    w = WirelessConfig(model="static", mean_uplink_mbps=0.001,
                       deadline_s=0.01)
    sim = _fedsim(small_fed, wireless=w)
    res = sim.run(rounds=1, log_every=1)
    assert all(n["participants"] == 0 for n in res.network)
    import jax.random
    from repro.models import cnn
    p0 = cnn.init(jax.random.PRNGKey(0), CNN_CFG)
    for a, b in zip(jax.tree.leaves(res.global_params), jax.tree.leaves(p0)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_partial_participation_trains_and_logs(small_fed):
    w = WirelessConfig(model="rayleigh", mean_uplink_mbps=2.0,
                       mean_downlink_mbps=8.0, deadline_s=3.0, seed=1)
    sim = _fedsim(small_fed, wireless=w)
    res = sim.run(rounds=2, log_every=1)
    parts = [n["participants"] for n in res.network]
    assert len(parts) == 4                  # kappa1=2 edge rounds x 2 rounds
    assert min(parts) < 4                   # someone dropped at least once
    assert res.total_sim_time_s > 0
    assert "mean_participants" in res.history[-1]
    assert np.isfinite(res.history[-1]["test_loss"])
    # training still moved: someone participated, so params left the init
    import jax.random
    from repro.models import cnn
    p0 = cnn.init(jax.random.PRNGKey(0), CNN_CFG)
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(res.global_params),
                                jax.tree.leaves(p0)))
    assert moved
