"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
the real single CPU device; only tests that need a fake mesh spawn it via
the subprocess helper or use jax's single device."""

import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
