"""Adaptive cut-layer selection + per-ES uplink contention (ISSUE 2).

Three layers of lock-down:

1. the parameterized split itself — every candidate cut composes to the
   same forward, and split-learning gradients are BIT-identical across
   cuts (Remark 2 at the op level: the VJP composition replays the same
   chain rule wherever the cut falls);
2. the controller + scheduler — policy behavior, the contended per-ES
   uplink, the energy accounting, and the seeded invariants
   (mask ⊆ scheduled, monotone energy, capacity cap, free ideal channel);
3. the system — FedSim's full training trajectory is bit-identical across
   all candidate cuts under an ideal channel while the Remark-1 bits and
   the simulated round times differ (the tentpole's primary acceptance
   test), and the adaptive policies keep at least the participation of the
   worst fixed cut in the cut-sweep benchmark.
"""

import importlib.util
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import HierarchyConfig, TrainConfig, WirelessConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.comm import comm_for_cnn, comm_table_for_cnn
from repro.core.fedsim import FedSim, split_grad
from repro.data.synthetic import make_federated_image_data
from repro.models import cnn
from repro.wireless import (ChannelModel, CutController, ParticipationScheduler,
                            RoundBits, client_round_bits, cut_specs,
                            make_cut_controller, make_scheduler)


# ------------------------------------------------- parameterized split -----
@pytest.mark.parametrize("cut", cnn.CUT_CANDIDATES)
def test_client_server_compose_to_apply(cut):
    rng = np.random.default_rng(0)
    params = cnn.init(jax.random.PRNGKey(1), CNN_CFG)
    x = jnp.asarray(rng.normal(size=(4, 32, 32, 3)).astype(np.float32))
    o_fp = cnn.client_forward(params, x, cut)
    logits = cnn.server_forward(params, o_fp, cut)
    np.testing.assert_array_equal(np.asarray(logits),
                                  np.asarray(cnn.apply(params, x)))
    # the o_fp shape is exactly what the comm accounting charges for
    assert int(np.prod(o_fp.shape)) == cnn.cut_activation_size(CNN_CFG,
                                                               x.shape[0], cut)


def test_client_keys_nest_with_depth():
    keys = [cnn.client_keys_for(c) for c in cnn.CUT_CANDIDATES]
    for shallow, deep in zip(keys, keys[1:]):
        assert set(shallow) < set(deep)
    assert cnn.client_keys_for(cnn.DEFAULT_CUT) == cnn.CLIENT_KEYS
    with pytest.raises(ValueError):
        cnn.client_keys_for("fc2")


def test_split_grad_bit_identical_across_cuts():
    """Remark 2 at the gradient level: the cut-layer dataflow returns the
    SAME bits for every cut, not merely close ones."""
    rng = np.random.default_rng(0)
    params = cnn.init(jax.random.PRNGKey(1), CNN_CFG)
    x = jnp.asarray(rng.normal(size=(16, 32, 32, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=16).astype(np.int32))
    f = jax.jit(split_grad, static_argnames="cut")
    ref_loss, ref_g = f(params, x, y, cut=cnn.CUT_CANDIDATES[0])
    for cut in cnn.CUT_CANDIDATES[1:]:
        loss, g = f(params, x, y, cut=cut)
        assert np.asarray(loss) == np.asarray(ref_loss)
        for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(ref_g)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- byte accounting ---
def test_cut_table_trades_activations_for_offload():
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400)
    z0 = [cm.client_params for cm in table.values()]
    zc = [cm.cut_size for cm in table.values()]
    assert z0 == sorted(z0) and z0[0] < z0[-1]      # deeper cut: bigger w_0
    assert zc == sorted(zc, reverse=True) and zc[0] > zc[-1]  # smaller o_fp
    phi = [cm.phi_phsfl_bits(kappa0=2) for cm in table.values()]
    assert len(set(phi)) == len(phi)                # every cut pays its own
    # single-cut comm_for_cnn agrees with the table entry
    one = comm_for_cnn(CNN_CFG, dataset_size=400, cut="conv2")
    assert one == table["conv2"]


def test_client_round_bits_cut_indexed():
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400)
    specs = cut_specs(table, kappa0=2)
    assert tuple(s.name for s in specs) == cnn.CUT_CANDIDATES
    for s, cm in zip(specs, table.values()):
        assert s.bits == client_round_bits(cm, 2)
        assert (s.z0, s.z_c) == (cm.client_params, cm.cut_size)


# ------------------------------------------------------------ controller ---
def _controller(policy, deadline=float("inf"), kappa0=2):
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                               batches_per_epoch=2)
    return make_cut_controller(table, kappa0, policy=policy,
                               deadline_s=deadline, tx_power_w=0.5)


def test_fixed_policy_and_named_fixed_cut():
    ctl = _controller("fixed")
    up = np.full(4, 1e6)
    cuts = ctl.decide(up, 4 * up, 0.0, np.full(4, np.inf))
    np.testing.assert_array_equal(cuts, np.zeros(4, int))
    table = comm_table_for_cnn(CNN_CFG, dataset_size=400)
    ctl2 = make_cut_controller(table, 2, policy="fixed", fixed_cut="fc1")
    assert ctl2.fixed_cut == 2
    with pytest.raises(ValueError):
        make_cut_controller(table, 2, policy="fixed", fixed_cut="nope")
    with pytest.raises(ValueError):
        make_cut_controller(table, 2, policy="warp")


def test_greedy_picks_min_time_cut():
    ctl = _controller("greedy")
    up = np.full(3, 10e6)                    # 10 Mbps
    cuts = ctl.decide(up, 4 * up, 0.0, np.full(3, np.inf))
    # unconstrained greedy = global argmin of estimated time
    times, _ = ctl._estimates(up, 4 * up, np.zeros(3))
    np.testing.assert_array_equal(cuts, times.argmin(axis=0))


def test_greedy_respects_energy_budget():
    ctl = _controller("greedy")
    up = np.full(2, 10e6)
    _, energy = ctl._estimates(up, 4 * up, np.zeros(2))
    best = energy.argmin(axis=0)[0]
    # a budget below every cut's cost falls back to the cheapest-energy cut
    cuts = ctl.decide(up, 4 * up, 0.0, np.full(2, energy.min() * 0.5))
    np.testing.assert_array_equal(cuts, [best, best])
    # a budget that only affords the cheapest cut picks it too
    cuts = ctl.decide(up, 4 * up, 0.0, np.full(2, energy.min() * 1.01))
    np.testing.assert_array_equal(cuts, [best, best])


def test_deadline_policy_walks_deeper_as_rate_drops():
    """At a generous rate every cut makes the deadline -> deepest wins; as
    the rate drops only cheaper cuts fit; when nothing fits -> fastest."""
    ctl = _controller("deadline", deadline=4.0)
    times, _ = ctl._estimates(np.array([100e6, 100e6]), np.array([400e6, 400e6]),
                              np.zeros(2))
    assert (times <= 4.0).all()
    cuts = ctl.decide(np.array([100e6]), np.array([400e6]), 0.0,
                      np.array([np.inf]))
    assert cuts[0] == ctl.num_cuts - 1              # deepest affordable
    # 7 Mbps: fc1's 72 Mb uplink blows the deadline, conv2's 19.8 Mb fits
    cuts = ctl.decide(np.array([7e6]), np.array([28e6]), 0.0,
                      np.array([np.inf]))
    assert ctl.specs[cuts[0]].name == "conv2"
    # 0.1 Mbps: nothing makes the deadline -> fastest (still conv2: fewest bits)
    cuts = ctl.decide(np.array([0.1e6]), np.array([0.4e6]), 0.0,
                      np.array([np.inf]))
    assert ctl.specs[cuts[0]].name == "conv2"


# ------------------------------------------------------------ contention ---
def test_contended_uplink_splits_es_capacity():
    cfg = WirelessConfig(model="static", mean_uplink_mbps=10.0,
                         es_uplink_mbps=20.0)
    ch = ChannelModel(cfg, num_clients=8)
    link = ch.sample(0)
    es = np.arange(8) // 4
    active = np.ones(8, bool)
    eff = ch.contended_uplink(link, active, es)
    # 4 actives/ES share 20 Mbps -> 5 Mbps each (below the 10 Mbps private)
    np.testing.assert_allclose(eff, 5e6)
    # only one active in ES 0: its share is the full pipe, capped by private
    active = np.zeros(8, bool)
    active[0] = True
    eff = ch.contended_uplink(link, active, es)
    assert eff[0] == 10e6                       # min(private, 20 Mbps)
    np.testing.assert_allclose(eff[1:], 10e6)   # inactives keep private rate


def test_contention_bypassed_for_ideal_and_infinite_capacity():
    for kw in (dict(model="ideal", es_uplink_mbps=20.0),
               dict(model="static", es_uplink_mbps=float("inf"))):
        ch = ChannelModel(WirelessConfig(**kw), num_clients=4)
        link = ch.sample(0)
        eff = ch.contended_uplink(link, np.ones(4, bool), np.zeros(4, int))
        assert eff is link.uplink_bps


# ---------------------------------------------------- scheduler + energy ---
BITS = RoundBits(uplink=10_000_000, downlink=10_000_000)


def test_scheduler_requires_exactly_one_traffic_source():
    cfg = WirelessConfig(model="static")
    ch = ChannelModel(cfg, 4)
    with pytest.raises(ValueError):
        ParticipationScheduler(cfg, ch)
    with pytest.raises(ValueError):
        ParticipationScheduler(cfg, ch, BITS, cutter=_controller("fixed"))


def test_straggler_pays_for_burned_airtime():
    """Regression (ISSUE 2 satellite): a scheduled client that misses the
    deadline transmitted until the deadline cut it off — it must pay
    P_tx * min(uplink airtime, deadline), not zero."""
    cfg = WirelessConfig(model="static", mean_uplink_mbps=10.0,
                         mean_downlink_mbps=40.0, latency_s=0.0,
                         heterogeneity=1.5, deadline_s=1.0,
                         energy_budget_j=100.0, tx_power_w=0.5, seed=0)
    s = ParticipationScheduler(cfg, ChannelModel(cfg, 8), BITS)
    rep = s.step(0)
    dead = (rep.scheduled) & (rep.mask == 0)
    assert dead.any(), "setup must produce scheduled stragglers"
    t_up = BITS.uplink / rep.uplink_bps
    expect = 100.0 - 0.5 * np.minimum(t_up, 1.0)
    # every scheduled client paid for its airtime, stragglers included
    np.testing.assert_allclose(rep.energy_left_j[rep.scheduled],
                               expect[rep.scheduled])
    assert (rep.energy_left_j[dead] < 100.0).all()
    # unscheduled clients pay nothing
    unsched = ~rep.scheduled
    if unsched.any():
        np.testing.assert_array_equal(rep.energy_left_j[unsched], 100.0)


def test_contention_prices_out_unaffordable_clients_before_tx():
    """A client that could afford the PRIVATE rate but not the contended one
    withdraws without transmitting: no energy spent, no ES waiting."""
    # private: 10 Mbps -> 0.5 J per round; contended 4-way on 10 Mbps
    # -> 2.5 Mbps -> 2.0 J per round
    cfg = WirelessConfig(model="static", mean_uplink_mbps=10.0,
                         mean_downlink_mbps=40.0, latency_s=0.0,
                         es_uplink_mbps=10.0, energy_budget_j=1.0,
                         tx_power_w=0.5, seed=0)
    s = ParticipationScheduler(cfg, ChannelModel(cfg, 4), BITS)
    rep = s.step(0)
    assert not rep.scheduled.any()
    assert rep.num_participants == 0
    assert rep.round_time_s == 0.0
    np.testing.assert_array_equal(rep.energy_left_j, 1.0)


# ------------------------------------------------- seeded invariants -------
@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("scenario", ["rayleigh-deadline", "rayleigh-topk",
                                      "static-random", "rayleigh-cutter"])
def test_scheduler_invariants(seed, scenario):
    model, selection = ("static", "random") \
        if scenario == "static-random" else ("rayleigh",
                                             scenario.split("-")[1])
    cfg = WirelessConfig(model=model, mean_uplink_mbps=15.0,
                         mean_downlink_mbps=60.0, latency_s=0.01,
                         heterogeneity=0.7, deadline_s=3.0,
                         selection=selection if selection != "cutter"
                         else "deadline",
                         topk=5 if selection == "topk" else 0,
                         participation_prob=0.6,
                         es_uplink_mbps=30.0, energy_budget_j=20.0,
                         tx_power_w=0.5,
                         cut_policy="deadline" if selection == "cutter"
                         else "fixed",
                         cut_candidates=cnn.CUT_CANDIDATES
                         if selection == "cutter" else (),
                         seed=seed)
    es_assign = np.arange(8) // 4
    if selection == "cutter":
        table = comm_table_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                                   batches_per_epoch=2)
        s = make_scheduler(cfg, 8, kappa0=2, comm_table=table,
                           es_assign=es_assign)
    else:
        comm = comm_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                            batches_per_epoch=2)
        s = make_scheduler(cfg, 8, comm, 2, es_assign=es_assign)
    prev_energy = s.energy_left.copy()
    cap_bps = cfg.es_uplink_mbps * 1e6
    for r in range(6):
        rep = s.step(r)
        # participants are always a subset of the scheduled clients
        assert ((rep.mask > 0) <= rep.scheduled).all()
        # budgets never recharge
        assert (rep.energy_left_j <= prev_energy + 1e-12).all()
        prev_energy = rep.energy_left_j
        # the shared ES uplink is never oversubscribed by transmitters
        for b in range(2):
            tx = rep.scheduled & (es_assign == b)
            assert rep.uplink_bps[tx].sum() <= cap_bps * (1 + 1e-9)
        if rep.cuts is not None:
            assert ((rep.cuts >= 0) & (rep.cuts < 3)).all()


@pytest.mark.parametrize("seed", range(3))
def test_ideal_channel_full_participation_zero_time(seed):
    cfg = WirelessConfig(model="ideal", es_uplink_mbps=5.0,
                         deadline_s=0.5, energy_budget_j=1.0, seed=seed)
    s = make_scheduler(cfg, 6, comm_for_cnn(CNN_CFG, dataset_size=400), 2)
    for r in range(4):
        rep = s.step(r)
        np.testing.assert_array_equal(rep.mask, np.ones(6))
        assert rep.round_time_s == 0.0
        np.testing.assert_array_equal(rep.energy_left_j, 1.0)


# ------------------------------------------ system-level Remark 2 ----------
@pytest.fixture(scope="module")
def small_fed():
    return make_federated_image_data(8, alpha=0.4, train_per_class=20,
                                     test_per_class=10, seed=0)


def _run_fedsim(fed, cut, wireless=None):
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=1,
                        kappa1=2, global_rounds=2)
    t = TrainConfig(learning_rate=0.05, batch_size=8, freeze_head=True)
    sim = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=1, seed=0,
                 wireless=wireless, cut=cut)
    return sim.run(rounds=2, log_every=1)


def test_remark2_trajectory_invariant_but_bits_and_time_differ(small_fed):
    """ISSUE 2 primary acceptance test.  For every candidate cut the FULL
    FedSim trajectory — per-round train losses, test metrics, and the final
    parameters — is bit-identical under an ideal channel (Remark 2: the cut
    does not change learning dynamics), while the Remark-1 byte accounting
    and the simulated wireless round time at that cut both change (Remark 1:
    it changes who pays which bits)."""
    runs = {c: _run_fedsim(small_fed, c) for c in cnn.CUT_CANDIDATES}
    ref = runs[cnn.CUT_CANDIDATES[0]]
    for c in cnn.CUT_CANDIDATES[1:]:
        for ra, rb in zip(ref.history, runs[c].history):
            assert ra["train_loss"] == rb["train_loss"], c
            assert ra["test_loss"] == rb["test_loss"], c
            assert ra["test_acc"] == rb["test_acc"], c
        for a, b in zip(jax.tree.leaves(ref.global_params),
                        jax.tree.leaves(runs[c].global_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # ...while the bits and the simulated round time are cut-dependent
    table = comm_table_for_cnn(CNN_CFG, dataset_size=200, batch_size=8,
                               batches_per_epoch=1)
    phi = {c: cm.phi_phsfl_bits(kappa0=1) for c, cm in table.items()}
    assert len(set(phi.values())) == len(phi)
    times = {}
    for c in cnn.CUT_CANDIDATES:
        w = WirelessConfig(model="static", mean_uplink_mbps=10.0,
                           mean_downlink_mbps=40.0, latency_s=0.0,
                           cut_policy="fixed", cut_candidates=(c,))
        res = _run_fedsim(small_fed, c, wireless=w)
        times[c] = res.total_sim_time_s
    assert len(set(times.values())) == len(times)
    assert all(t > 0 for t in times.values())


def test_fixed_policy_rejects_mismatched_training_cut(small_fed):
    """A fixed cut policy must price the cut the simulation actually
    trains/declares — a silent fallback would report bits/times/energies
    for a different split than the one in the logs."""
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=1,
                        kappa1=1, global_rounds=1)
    t = TrainConfig(learning_rate=0.05, batch_size=8, freeze_head=True)
    w = WirelessConfig(model="static", cut_policy="fixed",
                       cut_candidates=("conv1", "conv2"))
    with pytest.raises(ValueError, match="cut_candidates"):
        FedSim(CNN_CFG, small_fed, h, t, batches_per_epoch=1, seed=0,
               wireless=w, cut="fc1")


def test_cut_sweep_adaptive_beats_worst_fixed(small_fed):
    """The benchmark's acceptance bar at test scale: greedy and deadline
    policies keep at least the participation rate of the WORST fixed cut
    at the same deadline.  One fading channel here (the static case is
    pinned down by the unit-level policy/contention tests above); the full
    policy x channel table is benchmarks/cut_sweep.py."""
    spec = importlib.util.spec_from_file_location(
        "cut_sweep", pathlib.Path(__file__).parent.parent / "benchmarks" /
        "cut_sweep.py")
    cut_sweep = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cut_sweep)
    table = cut_sweep.sweep(small_fed, ["rayleigh"], deadline=4.0,
                            rounds=1, es_uplink_mbps=40.0, seed=0)
    worst_fixed = min(r["participation_rate"] for r in table
                      if r["policy"].startswith("fixed:"))
    for pol in ("greedy", "deadline"):
        got = next(r["participation_rate"] for r in table
                   if r["policy"] == pol)
        assert got >= worst_fixed, (pol, got, worst_fixed)
