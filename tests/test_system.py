"""End-to-end behaviour tests for the PHSFL system.

The headline claims of the paper, verified on the faithful simulator with
synthetic federated data (CIFAR-10 itself is not available offline —
distributional claims, not absolute accuracies):

  1. PHSFL's globally-trained model is competitive with HSFL's
     (generalization gap small) despite the frozen random head;
  2. after K head-only fine-tuning steps, PHSFL's personalized models beat
     its global model per client (personalization gain);
  3. the whole pipeline — hierarchical split training -> personalization ->
     per-client serving — runs end to end.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import HierarchyConfig, TrainConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.fedsim import FedSim
from repro.data.synthetic import make_federated_image_data


@pytest.mark.slow
def test_phsfl_end_to_end_personalization_gain():
    """Recalibrated (ISSUE 2): at 6 global rounds the synthetic task
    SATURATES — the frozen-head global model already scores ~0.98 on every
    client's own distribution, so head fine-tuning has no headroom and the
    old assert failed for the wrong reason (measured: global 0.976 vs
    personalized 0.841).  The paper's claim lives in the under-trained
    regime where features are useful but the head is not yet aligned with
    each client's skewed label profile; 3 rounds puts the global model
    there (measured: global 0.626 -> personalized 0.834)."""
    data = make_federated_image_data(12, alpha=0.15, train_per_class=60,
                                     test_per_class=30, seed=0)
    h = HierarchyConfig(num_edge_servers=3, clients_per_es=4, kappa0=2,
                        kappa1=2, global_rounds=3)
    t = TrainConfig(learning_rate=0.05, batch_size=16, freeze_head=True,
                    finetune_steps=10, finetune_lr=0.05)
    sim = FedSim(CNN_CFG, data, h, t, batches_per_epoch=2, seed=0)
    res = sim.run(rounds=3, log_every=3)
    heads, per = sim.personalize(res.global_params)

    global_acc = res.per_client_global["acc"].mean()
    pers_acc = per["acc"].mean()
    # claim 2: personalization helps under skewed data
    assert pers_acc > global_acc, (pers_acc, global_acc)
    # training actually learned features
    assert global_acc > 0.3


@pytest.mark.slow
def test_phsfl_vs_hsfl_generalization_gap_is_small():
    data = make_federated_image_data(8, alpha=0.5, train_per_class=50,
                                     test_per_class=25, seed=1)
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=2,
                        kappa1=2, global_rounds=4)
    accs = {}
    for name, freeze in (("phsfl", True), ("hsfl", False)):
        t = TrainConfig(learning_rate=0.05, batch_size=16, freeze_head=freeze)
        sim = FedSim(CNN_CFG, data, h, t, batches_per_epoch=2, seed=0)
        res = sim.run(rounds=4, log_every=4)
        accs[name] = res.per_client_global["acc"].mean()
    # claim 1: frozen-head global model in the same ballpark as HSFL
    assert accs["phsfl"] > accs["hsfl"] - 0.15, accs
