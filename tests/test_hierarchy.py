"""Hierarchical aggregation math (Eqs. 4-7, 14-16) — property-based."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import edge_aggregate, global_aggregate, sgd_step_index
from repro.configs.base import HierarchyConfig


def _tree(rng, scale=1.0):
    return {"a": jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32) * scale),
            "b": {"c": jnp.asarray(rng.normal(size=(5,)).astype(np.float32) * scale)}}


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_aggregate_of_identical_trees_is_identity(n, seed):
    rng = np.random.default_rng(seed)
    t = _tree(rng)
    w = rng.dirichlet(np.ones(n))
    agg = edge_aggregate([t] * n, w)
    for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(t)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 5), st.integers(0, 2 ** 31 - 1))
def test_aggregate_is_convex(n, seed):
    """Every coordinate of the aggregate lies in [min, max] of the inputs."""
    rng = np.random.default_rng(seed)
    trees = [_tree(rng) for _ in range(n)]
    w = rng.dirichlet(np.ones(n))
    agg = edge_aggregate(trees, w)
    for leaves in zip(jax.tree.leaves(agg), *(jax.tree.leaves(t) for t in trees)):
        a, rest = np.asarray(leaves[0]), np.stack([np.asarray(x) for x in leaves[1:]])
        assert (a <= rest.max(0) + 1e-5).all()
        assert (a >= rest.min(0) - 1e-5).all()


def test_two_level_equals_flat_weighted_mean():
    """Eq. (7): CS aggregation of ES aggregates == flat weighted sum with
    weights alpha_b * alpha_u."""
    rng = np.random.default_rng(1)
    B, U = 3, 4
    trees = [[_tree(rng) for _ in range(U)] for _ in range(B)]
    au = [rng.dirichlet(np.ones(U)) for _ in range(B)]
    ab = rng.dirichlet(np.ones(B))
    es = [edge_aggregate(trees[b], au[b]) for b in range(B)]
    two_level = global_aggregate(es, ab)
    flat_trees = [trees[b][u] for b in range(B) for u in range(U)]
    flat_w = np.array([ab[b] * au[b][u] for b in range(B) for u in range(U)])
    from repro.utils.tree import tree_weighted_sum
    flat = tree_weighted_sum(flat_trees, list(flat_w))
    for a, b in zip(jax.tree.leaves(two_level), jax.tree.leaves(flat)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_weight_simplex_enforced():
    rng = np.random.default_rng(2)
    trees = [_tree(rng), _tree(rng)]
    import pytest
    with pytest.raises(AssertionError):
        edge_aggregate(trees, [0.7, 0.7])


@given(st.integers(0, 20), st.integers(0, 5), st.integers(0, 4))
@settings(max_examples=30, deadline=None)
def test_sgd_step_index(t2, t1, t0):
    """Eq. (1) bookkeeping is strictly monotone in (t2, t1, t0) lex order."""
    h = HierarchyConfig(kappa0=5, kappa1=3)
    t = sgd_step_index(t2, min(t1, h.kappa1 - 1), min(t0, h.kappa0 - 1), h)
    t_next = sgd_step_index(t2, min(t1, h.kappa1 - 1), min(t0, h.kappa0 - 1), h)
    assert t == t_next
    assert sgd_step_index(t2 + 1, 0, 0, h) > t
