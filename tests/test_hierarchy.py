"""Hierarchical aggregation math (Eqs. 4-7, 14-16) — property-based."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (edge_aggregate, global_aggregate,
                        masked_edge_aggregate, masked_global_aggregate,
                        sgd_step_index)
from repro.configs.base import HierarchyConfig


def _tree(rng, scale=1.0):
    return {"a": jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32) * scale),
            "b": {"c": jnp.asarray(rng.normal(size=(5,)).astype(np.float32) * scale)}}


# seeded stand-in for hypothesis: (n, seed) draws
_DRAW = np.random.default_rng(99)
_N_SEED_CASES = [(int(_DRAW.integers(2, 7)), int(_DRAW.integers(0, 2 ** 31 - 1)))
                 for _ in range(25)]


@pytest.mark.parametrize("n,seed", _N_SEED_CASES)
def test_aggregate_of_identical_trees_is_identity(n, seed):
    rng = np.random.default_rng(seed)
    t = _tree(rng)
    w = rng.dirichlet(np.ones(n))
    agg = edge_aggregate([t] * n, w)
    for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(t)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,seed", _N_SEED_CASES[:20])
def test_aggregate_is_convex(n, seed):
    """Every coordinate of the aggregate lies in [min, max] of the inputs."""
    n = min(n, 5)
    rng = np.random.default_rng(seed)
    trees = [_tree(rng) for _ in range(n)]
    w = rng.dirichlet(np.ones(n))
    agg = edge_aggregate(trees, w)
    for leaves in zip(jax.tree.leaves(agg), *(jax.tree.leaves(t) for t in trees)):
        a, rest = np.asarray(leaves[0]), np.stack([np.asarray(x) for x in leaves[1:]])
        assert (a <= rest.max(0) + 1e-5).all()
        assert (a >= rest.min(0) - 1e-5).all()


def test_two_level_equals_flat_weighted_mean():
    """Eq. (7): CS aggregation of ES aggregates == flat weighted sum with
    weights alpha_b * alpha_u."""
    rng = np.random.default_rng(1)
    B, U = 3, 4
    trees = [[_tree(rng) for _ in range(U)] for _ in range(B)]
    au = [rng.dirichlet(np.ones(U)) for _ in range(B)]
    ab = rng.dirichlet(np.ones(B))
    es = [edge_aggregate(trees[b], au[b]) for b in range(B)]
    two_level = global_aggregate(es, ab)
    flat_trees = [trees[b][u] for b in range(B) for u in range(U)]
    flat_w = np.array([ab[b] * au[b][u] for b in range(B) for u in range(U)])
    from repro.utils.tree import tree_weighted_sum
    flat = tree_weighted_sum(flat_trees, list(flat_w))
    for a, b in zip(jax.tree.leaves(two_level), jax.tree.leaves(flat)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_weight_simplex_enforced():
    rng = np.random.default_rng(2)
    trees = [_tree(rng), _tree(rng)]
    import pytest
    with pytest.raises(AssertionError):
        edge_aggregate(trees, [0.7, 0.7])


@pytest.mark.parametrize(
    "t2,t1,t0",
    [(t2, t1, t0) for t2 in (0, 1, 7, 20) for t1 in (0, 2, 5) for t0 in (0, 3, 4)])
def test_sgd_step_index(t2, t1, t0):
    """Eq. (1) bookkeeping is strictly monotone in (t2, t1, t0) lex order."""
    h = HierarchyConfig(kappa0=5, kappa1=3)
    t = sgd_step_index(t2, min(t1, h.kappa1 - 1), min(t0, h.kappa0 - 1), h)
    t_next = sgd_step_index(t2, min(t1, h.kappa1 - 1), min(t0, h.kappa0 - 1), h)
    assert t == t_next
    assert sgd_step_index(t2 + 1, 0, 0, h) > t


# ------------------------------------------------- participation masks -----
_MASK_CASES = [(int(_DRAW.integers(3, 7)), int(_DRAW.integers(0, 2 ** 31 - 1)))
               for _ in range(15)]


@pytest.mark.parametrize("n,seed", _MASK_CASES)
def test_full_mask_equals_unmasked_bitwise(n, seed):
    """With every client participating, the masked path must be bit-for-bit
    identical to the pre-existing unmasked aggregation (regression guard for
    the ideal-network trajectory)."""
    rng = np.random.default_rng(seed)
    trees = [_tree(rng) for _ in range(n)]
    w = rng.dirichlet(np.ones(n))
    ref = edge_aggregate(trees, w)
    got = masked_edge_aggregate(trees, w, np.ones(n))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    gref = global_aggregate(trees, w)
    gGot = masked_global_aggregate(trees, w, np.ones(n))
    for a, b in zip(jax.tree.leaves(gGot), jax.tree.leaves(gref)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("n,seed", _MASK_CASES)
def test_partial_mask_renormalizes_over_participants(n, seed):
    """Dropping clients renormalizes the Eq. 14-16 weights to sum to 1 over
    the participants: the masked aggregate equals the unmasked aggregate of
    the surviving subset."""
    rng = np.random.default_rng(seed)
    trees = [_tree(rng) for _ in range(n)]
    w = rng.dirichlet(np.ones(n))
    mask = np.zeros(n)
    keep = rng.choice(n, size=max(1, n // 2), replace=False)
    mask[keep] = 1.0
    got = masked_edge_aggregate(trees, w, mask)
    sub_w = w[keep] / w[keep].sum()
    assert abs(sub_w.sum() - 1.0) < 1e-9
    ref = edge_aggregate([trees[i] for i in keep], sub_w)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_empty_mask_returns_fallback():
    rng = np.random.default_rng(7)
    trees = [_tree(rng) for _ in range(3)]
    prev = _tree(rng)
    w = np.ones(3) / 3
    got = masked_edge_aggregate(trees, w, np.zeros(3), fallback=prev)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(prev)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        masked_edge_aggregate(trees, w, np.zeros(3))
