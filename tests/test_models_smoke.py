"""Per-architecture smoke tests (deliverable f): reduced same-family variant
(<=2 layers, d_model<=512, <=4 experts) — forward + one train step on CPU,
asserting output shapes and finiteness."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_arch
from repro.core.phsfl import build_optimizer
from repro.configs.base import TrainConfig
from repro.data.synthetic import synthetic_token_batch
from repro.models import build_model
from repro.optim import apply_updates
from repro.utils import tree_allfinite

BATCH, SEQ = 2, 64


def _batch_for(cfg, seed=0):
    nb = synthetic_token_batch(seed, BATCH, SEQ, cfg.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in nb.items()}
    if cfg.vlm is not None:
        batch["patch_embeds"] = jnp.ones(
            (BATCH, cfg.vlm.num_patch_tokens, cfg.d_model), jnp.float32)
        batch["positions3"] = jnp.tile(
            jnp.arange(SEQ, dtype=jnp.int32)[None, :, None], (BATCH, 1, 3))
    if cfg.encdec is not None:
        batch["source_embeds"] = 0.02 * jnp.ones(
            (BATCH, cfg.encdec.max_source_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    hidden, aux = model.apply(params, batch)
    assert hidden.shape == (BATCH, SEQ, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all())
    logits = model.logits(params, hidden)
    assert logits.shape == (BATCH, SEQ, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch):
    """One PHSFL-masked SGD step: loss finite, body moves, head frozen."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    tcfg = TrainConfig(learning_rate=0.1, freeze_head=True)
    opt, mask = build_optimizer(model, tcfg)
    state = opt.init(params)
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    assert bool(jnp.isfinite(loss)), arch
    assert bool(tree_allfinite(grads)), arch
    upd, state = opt.update(grads, state, params)
    new = apply_updates(params, upd)
    # head bit-identical (Eq. 12); at least one body leaf moved
    head_key = "lm_head"
    assert bool(jnp.array_equal(params[head_key]["w"], new[head_key]["w"]))
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new)))
    assert moved, arch
