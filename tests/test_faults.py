"""Fault injection + recovery: erasures/HARQ, ES outages, crashes, resume.

The subsystem's contracts, pinned here:

- ``FaultConfig()`` defaults encode ZERO faults (the reprolint
  fault-free-default gate): ``active`` is False, the scheduler builds no
  injector, and an outage-only injector (``needs_plan`` False) leaves the
  per-round reports bit-identical to a fault-free scheduler;
- the HARQ attempt expansion, hand-computed segment by segment: a
  retransmission waits ``backoff_s``, airs the full payload again, and
  air bits / goodput / first-attempt airtime split accordingly;
- a crash truncates the timeline at the crash instant: partial compute
  and airtime are charged, the undelivered payload is NOT goodput, the
  client never banks (its local state died with it), and energy budgets
  stay non-negative under sustained chaos;
- ES outage failover: ``reassoc`` re-homes a dead ES's clients to the
  nearest live ES (visible in ``RoundReport.es_map``), ``skip`` sits them
  out; stale background pushes pause while the effective ES is down;
- determinism + resume: same seed => identical multi-round trajectories;
  ``state_dict``/``load_state_dict`` replay rounds k.. bit-identically
  (including the fault stream); FedSim kill-at-k + restore reproduces the
  uninterrupted run's final parameters bit-for-bit;
- ``RoundReport.to_json_dict``/``from_json_dict`` round-trips every field
  through actual JSON text (the BENCH file format).
"""

import json

import numpy as np
import pytest

import jax

from repro.configs.base import (FaultConfig, HierarchyConfig, TrainConfig,
                                WirelessConfig)
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.fedsim import FedSim
from repro.data.synthetic import make_federated_image_data
from repro.wireless import (ChannelModel, ParticipationScheduler, RoundBits,
                            build_timeline)
from repro.wireless.channel import LinkState
from repro.wireless.faults import (FAULT_SEED_OFFSET, FaultInjector,
                                   FaultPlan, expected_attempts)
from repro.wireless.scheduler import RoundReport

BITS = RoundBits(uplink=10_000_000, downlink=10_000_000)


def _link(up, down=1e6, latency=0.01, U=1):
    return LinkState(np.full(U, float(up)), np.full(U, float(down)),
                     np.full(U, float(latency)))


def _plan(attempts, ok, down_attempts=1, down_ok=True, crash=np.inf,
          backoff=0.0, U=1):
    return FaultPlan(up_attempts=np.full((U, 1), attempts, int),
                     up_ok=np.full((U, 1), ok, bool),
                     down_attempts=np.full(U, down_attempts, int),
                     down_ok=np.full(U, down_ok, bool),
                     crash_frac=np.full(U, crash, float),
                     backoff_s=backoff)


def _sched(U=8, faults=None, **kw):
    kw.setdefault("model", "static")
    kw.setdefault("mean_uplink_mbps", 10.0)
    kw.setdefault("mean_downlink_mbps", 40.0)
    kw.setdefault("latency_s", 0.0)
    kw.setdefault("heterogeneity", 1.0)
    if faults is not None:
        kw["faults"] = faults
    cfg = WirelessConfig(**kw)
    return ParticipationScheduler(cfg, ChannelModel(cfg, U), BITS,
                                  es_assign=np.arange(U) // (U // 2))


# ------------------------------------------------ fault-free defaults ------
def test_fault_free_default():
    """The reprolint ``fault-free-default`` gate: all-defaults FaultConfig
    encodes zero faults, so constructing it can never change behavior."""
    f = FaultConfig()
    assert f.erasure_prob == 0.0
    assert f.crash_hazard == 0.0
    assert f.es_outage_trace == ()
    assert f.backoff_s == 0.0
    assert f.active is False
    assert _sched().injector is None          # no injector ever built
    assert WirelessConfig(model="static").faults == f


def test_outage_only_injector_is_inert_without_outages():
    """An all-zeros outage trace turns the injector ON but ``needs_plan``
    OFF: no fault RNG is consumed per round and every report matches the
    fault-free scheduler exactly."""
    quiet = _sched(faults=FaultConfig(es_outage_trace=((0, 0),)))
    clean = _sched()
    assert quiet.injector is not None
    assert not quiet.injector.needs_plan
    for r in range(5):
        a, b = quiet.step(r), clean.step(r)
        np.testing.assert_array_equal(a.mask, b.mask)
        np.testing.assert_array_equal(a.times_s, b.times_s)
        assert a.bits_tx == b.bits_tx
        assert a.round_time_s == b.round_time_s
        assert a.retx_bits == 0.0 and a.retx_j == 0.0
        assert a.es_down is None and a.es_map is None
    np.testing.assert_array_equal(quiet.energy_left, clean.energy_left)


def test_expected_attempts_truncated_geometric():
    assert expected_attempts(0.0, 5) == 1.0
    assert expected_attempts(0.7, 0) == 1.0         # no retries = 1 attempt
    np.testing.assert_allclose(expected_attempts(0.5, 1), 1.5)
    np.testing.assert_allclose(expected_attempts(0.3, 3),
                               (1 - 0.3 ** 4) / 0.7)
    assert expected_attempts(1.0, 3) == 4.0         # every attempt airs


def test_injector_validates_config():
    for bad in (dict(erasure_prob=1.5), dict(crash_hazard=-0.1),
                dict(max_retries=-1), dict(backoff_s=-1.0),
                dict(failover="nope")):
        with pytest.raises(ValueError):
            FaultInjector(FaultConfig(**bad), 4, 1, 2, 0)


def test_plan_draws_are_deterministic_and_schedule_independent():
    """Same seed => same plans; the stream position after round r depends
    on r alone (fixed draw shapes), never on scheduling outcomes."""
    cfg = FaultConfig(erasure_prob=0.4, max_retries=2, crash_hazard=0.2)
    a = FaultInjector(cfg, 6, 1, 2, seed=9)
    b = FaultInjector(cfg, 6, 1, 2, seed=9)
    for _ in range(4):
        pa, pb = a.round_plan(), b.round_plan()
        np.testing.assert_array_equal(pa.up_attempts, pb.up_attempts)
        np.testing.assert_array_equal(pa.up_ok, pb.up_ok)
        np.testing.assert_array_equal(pa.down_attempts, pb.down_attempts)
        np.testing.assert_array_equal(pa.down_ok, pb.down_ok)
        np.testing.assert_array_equal(pa.crash_frac, pb.crash_frac)
    assert (FaultInjector(cfg, 6, 1, 2, seed=9)._rng.bit_generator.state
            != a._rng.bit_generator.state)          # streams advanced


# ----------------------------------------------------- HARQ timeline -------
def test_harq_retransmission_hand_computed():
    """1 client, serial: compute 1 s, payload 2 s at the link rate, 0.5 s
    backoff, 2 attempts.  Attempt 1 spans [1, 3), the retransmission waits
    the backoff and spans [3.5, 5.5), the downlink (1 s) follows, so the
    round closes at 2*latency + 6.5.  Air bits double, goodput does not."""
    bits = RoundBits(uplink=2_000_000, downlink=1_000_000)
    plan = _plan(attempts=2, ok=True, backoff=0.5)
    tl = build_timeline(_link(1e6), bits, np.array([1.0]), np.inf, 1,
                        plan=plan)
    np.testing.assert_allclose(tl.tx_start[0], [1.0, 3.5])
    np.testing.assert_allclose(tl.tx_end[0], [3.0, 5.5])
    np.testing.assert_allclose(tl.down_end[0], 6.5)
    np.testing.assert_allclose(tl.times_s[0], 0.02 + 6.5)
    np.testing.assert_allclose(tl.air_up_bits[0], 4_000_000)    # both tries
    np.testing.assert_allclose(tl.goodput_up_bits[0], 2_000_000)  # one copy
    np.testing.assert_allclose(tl.tx_charged_s[0], 4.0)
    np.testing.assert_allclose(tl.first_tx_s[0], 2.0)   # retx airtime = 2.0
    assert tl.up_ok_all[0] and tl.down_ok[0] and not tl.crashed[0]


def test_exhausted_retries_deliver_nothing():
    """up_ok=False after every attempt: the airtime is spent and charged,
    but the payload is never goodput and the client is not up_ok."""
    bits = RoundBits(uplink=2_000_000, downlink=1_000_000)
    tl = build_timeline(_link(1e6), bits, np.array([1.0]), np.inf, 1,
                        plan=_plan(attempts=3, ok=False))
    assert not tl.up_ok_all[0]
    np.testing.assert_allclose(tl.air_up_bits[0], 6_000_000)
    np.testing.assert_allclose(tl.goodput_up_bits[0], 0.0)
    np.testing.assert_allclose(tl.tx_charged_s[0], 6.0)


def test_erasure_prob_one_fails_every_scheduled_client():
    s = _sched(faults=FaultConfig(erasure_prob=1.0, max_retries=2))
    rep = s.step(0)
    assert rep.num_participants == 0
    np.testing.assert_array_equal(rep.failed, rep.scheduled)
    assert rep.scheduled.any()
    assert rep.retx_bits > 0.0                  # the retries really aired


def test_failed_payloads_flow_into_the_stale_bank():
    """HARQ exhaustion does not hard-drop under staleness: the undelivered
    update banks (goodput 0 => full remainder) and arrives late on an idle
    round, discounted — participation recovers."""
    s = _sched(faults=FaultConfig(erasure_prob=1.0, max_retries=0),
               selection="random", participation_prob=0.6,
               staleness_lambda=0.5, deadline_s=30.0)
    rep = s.step(0)
    assert (rep.stale_banked == rep.failed).all()       # exactly the failed
    delivered = 0
    for r in range(1, 12):
        delivered += int((s.step(r).stale_delivered > 0).sum())
    assert delivered > 0


# ---------------------------------------------------------- crashes --------
def test_crash_truncates_and_charges_partially():
    """Crash at half the activity span (inf deadline): compute 1 s, uplink
    [1, 3), downlink [3, 4) => span 4, cap 2.  One second of airtime and
    the full compute are charged; the payload misses the cap entirely."""
    bits = RoundBits(uplink=2_000_000, downlink=1_000_000)
    tl = build_timeline(_link(1e6), bits, np.array([1.0]), np.inf, 1,
                        plan=_plan(attempts=1, ok=True, crash=0.5))
    assert tl.crashed[0]
    np.testing.assert_allclose(tl.cap_s[0], 2.0)
    np.testing.assert_allclose(tl.compute_charged_s[0], 1.0)
    np.testing.assert_allclose(tl.tx_charged_s[0], 1.0)     # of [1, 3)
    np.testing.assert_allclose(tl.goodput_up_bits[0], 0.0)
    assert not tl.up_ok_all[0] and not tl.up_done[0]


def test_crashed_clients_never_bank_and_budgets_stay_nonneg():
    s = _sched(faults=FaultConfig(crash_hazard=0.5, erasure_prob=0.2,
                                  max_retries=1),
               staleness_lambda=0.5, deadline_s=5.0, energy_budget_j=3.0)
    saw_crash = False
    for r in range(15):
        rep = s.step(r)
        assert (rep.energy_left_j >= -1e-9).all()
        if rep.crashed.any():
            saw_crash = True
            assert not (rep.stale_banked & rep.crashed).any()
            assert not (rep.mask.astype(bool) & rep.crashed).any()
    assert saw_crash


def test_es_does_not_wait_past_the_crash_silence():
    """A lone crashed client's round clock is the crash cap (+ RTT), not
    the time its transfer would have taken."""
    s = _sched(U=2, faults=FaultConfig(crash_hazard=1.0), selection="topk",
               topk=2)
    rep = s.step(0)
    assert rep.crashed.all()
    tl_cap = rep.times_s[rep.scheduled].max()
    assert rep.round_time_s <= tl_cap
    assert rep.num_participants == 0


# ------------------------------------------------- ES outage/failover ------
def test_outage_reassoc_rehomes_clients():
    """Trace alternates {no outage, ES1 down}.  On outage rounds every
    client of ES1 re-associates to ES0 (visible in es_map) and ES0's pool
    doubles; stale pushes toward the dead ES pause."""
    s = _sched(faults=FaultConfig(es_outage_trace=((0, 0), (0, 1))))
    a = s.step(0)
    assert a.es_down is None and a.es_map is None
    b = s.step(1)
    np.testing.assert_array_equal(b.es_down, [False, True])
    np.testing.assert_array_equal(b.es_map, np.zeros(8, int))
    assert b.scheduled.any()


def test_outage_skip_sits_clients_out():
    s = _sched(faults=FaultConfig(es_outage_trace=((0, 1),),
                                  failover="skip"))
    for r in range(3):
        rep = s.step(r)
        np.testing.assert_array_equal(rep.es_down, [False, True])
        assert rep.es_map is None
        assert not rep.scheduled[4:].any()      # ES1's clients sat out
        assert not rep.mask[4:].astype(bool).any()


def test_all_es_down_is_a_wasted_round():
    s = _sched(faults=FaultConfig(es_outage_trace=((1, 1),)))
    rep = s.step(0)
    assert rep.num_participants == 0
    assert not rep.scheduled.any()
    assert rep.round_time_s == 0.0


# ----------------------------------------- determinism + JSON + resume -----
CHAOS = dict(faults=FaultConfig(erasure_prob=0.25, max_retries=2,
                                backoff_s=0.05, crash_hazard=0.15,
                                es_outage_trace=((0, 0), (0, 1), (0, 0))),
             selection="random", participation_prob=0.7,
             staleness_lambda=0.5, deadline_s=8.0)

_CMP = ("mask", "times_s", "round_time_s", "energy_left_j", "scheduled",
        "bits_tx", "stale_banked", "stale_delivered", "stale_dropped",
        "crashed", "failed", "down_failed", "es_down", "es_map",
        "retx_bits", "retx_j")


def _assert_reports_equal(a: RoundReport, b: RoundReport):
    for name in _CMP:
        va, vb = getattr(a, name), getattr(b, name)
        if va is None or vb is None:
            assert va is None and vb is None, name
        else:
            np.testing.assert_array_equal(va, vb, err_msg=name)


def test_chaos_trajectory_is_deterministic():
    s1, s2 = _sched(**CHAOS), _sched(**CHAOS)
    for r in range(10):
        _assert_reports_equal(s1.step(r), s2.step(r))


def test_scheduler_state_dict_resumes_bit_identically():
    """Run 10 rounds straight vs snapshot-at-4 + resume in a FRESH
    scheduler: rounds 4..9 replay bit-for-bit, fault stream included."""
    ref = _sched(**CHAOS)
    want = [ref.step(r) for r in range(10)]
    s = _sched(**CHAOS)
    for r in range(4):
        s.step(r)
    snap = s.state_dict()
    assert "fault_rng" in snap
    fresh = _sched(**CHAOS)
    fresh.load_state_dict(snap)
    for r in range(4, 10):
        _assert_reports_equal(fresh.step(r), want[r])


def test_resume_without_fault_stream_raises():
    plain = _sched()
    with pytest.raises(ValueError):
        _sched(**CHAOS).load_state_dict(plain.state_dict())


def test_round_report_json_round_trip():
    """Every field survives to_json_dict -> json text -> from_json_dict,
    with arrays restored at their native dtypes (chaos round: the fault
    fields are populated; plain round: they round-trip as None)."""
    chaos = _sched(**CHAOS)
    for rep in [chaos.step(1), chaos.step(2), _sched().step(0)]:
        d = json.loads(json.dumps(rep.to_json_dict()))
        assert d["participants"] == rep.num_participants
        back = RoundReport.from_json_dict(d)
        for f in RoundReport._DTYPES:
            v, w = getattr(rep, f), getattr(back, f)
            if v is None:
                assert w is None, f
            else:
                assert w.dtype == np.asarray(v).dtype, f
                np.testing.assert_array_equal(w, v, err_msg=f)
        for f in ("round_idx", "round_time_s", "bits_tx", "retx_bits",
                  "retx_j"):
            assert getattr(back, f) == getattr(rep, f), f
        np.testing.assert_array_equal(back.times_s, rep.times_s)
        np.testing.assert_array_equal(back.energy_left_j, rep.energy_left_j)


@pytest.fixture(scope="module")
def fed_data():
    return make_federated_image_data(8, alpha=0.3, train_per_class=40,
                                     test_per_class=20, seed=0)


def _chaos_sim(fed_data):
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=1,
                        kappa1=1, global_rounds=3)
    t = TrainConfig(learning_rate=0.05, batch_size=16)
    w = WirelessConfig(model="static", mean_uplink_mbps=10.0,
                       mean_downlink_mbps=40.0, latency_s=0.0,
                       heterogeneity=1.0, selection="random",
                       participation_prob=0.7, staleness_lambda=0.5,
                       deadline_s=8.0,
                       faults=FaultConfig(erasure_prob=0.25, max_retries=1,
                                          crash_hazard=0.2,
                                          es_outage_trace=((0, 0), (0, 1))))
    return FedSim(CNN_CFG, fed_data, h, t, batches_per_epoch=1, seed=0,
                  wireless=w)


def test_fedsim_kill_and_resume_bit_identical(fed_data, tmp_path):
    """The ISSUE's acceptance bar: train 3 rounds under chaos in one go vs
    kill after round 2 + restore in a FRESH sim + finish — the final
    stacked parameters (and the RNG-driven trajectory behind them) agree
    bit-for-bit."""
    ref = _chaos_sim(fed_data)
    res_ref = ref.run(rounds=3, log_every=3)

    sim = _chaos_sim(fed_data)
    sim.run(rounds=2, log_every=2)
    d = str(tmp_path / "state")
    sim.save(d)

    fresh = _chaos_sim(fed_data)
    assert fresh.restore(d) == 2
    res = fresh.run(rounds=3, log_every=3)

    for a, b in zip(jax.tree.leaves(ref._stacked),
                    jax.tree.leaves(fresh._stacked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(res_ref.global_params),
                    jax.tree.leaves(res.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert res.history[-1]["test_loss"] == res_ref.history[-1]["test_loss"]
    assert fresh.restore(str(tmp_path / "nowhere")) is None
