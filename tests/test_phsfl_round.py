"""Datacenter PHSFL round semantics on a fake 8-device mesh.

These tests need XLA_FLAGS set before jax initializes, so they run a child
python process (the same pattern the dry-run uses) and assert on its output.
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           "--xla_disable_hlo_passes=all-reduce-promotion")
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.registry import get_arch
from repro.configs.base import HierarchyConfig, TrainConfig
from repro.models import build_model
from repro.core import (make_phsfl_round, init_stacked_params,
                        build_optimizer, edge_aggregate)
from repro.data.synthetic import synthetic_token_batch
from repro.optim import apply_updates

# NOTE: model axis stays size 1 — XLA's partial-manual (auto TP subgroup)
# partitioner aborts on this jax/XLA version; pod/data manual aggregation is
# what this test verifies.
mesh = jax.make_mesh((2, 4, 1), ("pod", "data", "model"))
cfg = get_arch("mistral-large-123b").reduced()
model = build_model(cfg)
h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=2, kappa1=1)
t = TrainConfig(learning_rate=0.05, freeze_head=True, local_steps_in_step=2,
                remat=False)
C = 8
params = init_stacked_params(model, jax.random.PRNGKey(0), C)
opt, mask = build_optimizer(model, t)
state1 = opt.init(jax.tree.map(lambda x: x[0], params))
opt_state = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (C,) + x.shape),
                         state1)
nb = synthetic_token_batch(0, C * 2 * 2, 32, cfg.vocab_size)
batch = {k: jnp.asarray(v).reshape(C, 2, 2, 32) for k, v in nb.items()}
au = jnp.full((C,), 0.25, jnp.float32)
ab = jnp.full((C,), 0.5, jnp.float32)

with mesh:
    rnd = make_phsfl_round(model, h, t, mesh, global_sync=True)
    p2, s2, metrics = jax.jit(rnd.fn)(params, opt_state, batch, au, ab)

# ---------- host reference: same per-client local SGD + weighted means ----
def host_round(params, batch):
    client_params = []
    for c in range(C):
        p = jax.tree.map(lambda x: x[c], params)
        s = opt.init(p)
        for k_ in range(2):
            mb = {kk: vv[c, k_] for kk, vv in batch.items()}
            loss, g = jax.value_and_grad(lambda q: model.loss(q, mb))(p)
            upd, s = opt.update(g, s, p)
            p = apply_updates(p, upd)
        client_params.append(p)
    es0 = edge_aggregate(client_params[:4], [0.25] * 4)
    es1 = edge_aggregate(client_params[4:], [0.25] * 4)
    from repro.core import global_aggregate
    return global_aggregate([es0, es1], [0.5, 0.5])

ref = host_round(params, batch)
got = jax.tree.map(lambda x: x[0], p2)
errs = [float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref))]

head_same = bool(jnp.array_equal(params["lm_head"]["w"][0],
                                 p2["lm_head"]["w"][0]))
all_clients_equal = all(
    bool(jnp.allclose(x[0], x[i], atol=1e-6))
    for x in jax.tree.leaves(p2) for i in range(1, C))
print(json.dumps({
    "max_err": max(errs),
    "loss": float(metrics["loss"]),
    "head_frozen": head_same,
    "clients_synced": all_clients_equal,
}))
"""


@pytest.mark.slow
def test_phsfl_round_matches_host_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["head_frozen"], rec
    assert rec["clients_synced"], rec
    assert rec["max_err"] < 5e-3, rec      # bf16-free reduced cfg, f32 agg
    assert np_isfinite(rec["loss"])


def np_isfinite(x):
    import math
    return math.isfinite(x)
