"""Device (compute) model + the ISSUE-5 wireless-accounting bugfixes.

Four layers of lock-down:

1. **regression anchor** — with ``compute_gflops=inf`` (and
   ``codec_cycles_per_element=0``) the scheduler reproduces the pre-PR
   ``RoundReport``s BIT-for-bit: ``tests/golden_device_reports.json`` was
   captured from the bits-only scheduler before the device model existed,
   over scenarios the satellite bugfixes cannot touch (``deadline_s=inf``);
2. the FLOP accounting itself — per-cut conv/dense counts, codec
   encode/decode work, monotonicities;
3. the controller — with finite compute the deadline policy picks strictly
   shallower cuts when devices slow down, and the device_sweep benchmark's
   acceptance bar holds at test scale;
4. the satellite bugfixes — straggler bits_tx counts moved bits only, the
   energy gate and the energy charge agree on the deadline-capped quantity,
   an asymmetric trace pair is honored, and FedSim prices index bits at the
   LARGEST client dataset.
"""

import importlib.util
import json
import pathlib

import numpy as np
import pytest

from repro.configs.base import HierarchyConfig, TrainConfig, WirelessConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.comm import CommModel, comm_for_cnn, comm_table_for_cnn
from repro.models import cnn
from repro.utils.flops import conv2d_flops, dense_layer_flops, training_flops
from repro.wireless import (ChannelModel, DeviceModel, RoundBits,
                            client_round_bits, client_round_flops,
                            make_cut_controller, make_scheduler)

GOLDEN = pathlib.Path(__file__).parent / "golden_device_reports.json"

TABLE_KW = dict(dataset_size=400, batch_size=16, batches_per_epoch=2)


def _table():
    return comm_table_for_cnn(CNN_CFG, **TABLE_KW)


# ------------------------------------------------ 1. regression anchor -----
def _golden_scheduler(name):
    es = np.arange(8) // 4
    if name == "static-energy":
        cfg = WirelessConfig(model="static", mean_uplink_mbps=15.0,
                             mean_downlink_mbps=60.0, latency_s=0.01,
                             heterogeneity=1.0, energy_budget_j=2.0,
                             tx_power_w=0.5, seed=0)
        return make_scheduler(cfg, 8, comm_for_cnn(CNN_CFG, **TABLE_KW), 2,
                              es_assign=es)
    if name == "rayleigh-contended-greedy":
        cfg = WirelessConfig(model="rayleigh", mean_uplink_mbps=15.0,
                             mean_downlink_mbps=60.0, latency_s=0.01,
                             heterogeneity=0.7, es_uplink_mbps=30.0,
                             energy_budget_j=20.0, tx_power_w=0.5,
                             cut_policy="greedy",
                             cut_candidates=cnn.CUT_CANDIDATES, seed=3)
        return make_scheduler(cfg, 8, kappa0=2, comm_table=_table(),
                              es_assign=es)
    assert name == "trace-fallback-downlink"
    cfg = WirelessConfig(model="trace",
                         trace=((5.0,) * 8, (25.0,) * 8, (12.0,) * 8),
                         mean_uplink_mbps=10.0, mean_downlink_mbps=40.0,
                         latency_s=0.02, energy_budget_j=30.0,
                         tx_power_w=0.5, seed=1)
    return make_scheduler(cfg, 8, comm_for_cnn(CNN_CFG, **TABLE_KW), 2,
                          es_assign=es)


@pytest.mark.parametrize("scenario", ["static-energy",
                                      "rayleigh-contended-greedy",
                                      "trace-fallback-downlink"])
def test_inf_compute_reproduces_pre_pr_reports_bit_for_bit(scenario):
    """The whole device model must be invisible at its defaults: every
    RoundReport field equals the golden values captured from the bits-only
    scheduler before this PR, bit for bit."""
    golden = json.loads(GOLDEN.read_text())[scenario]
    s = _golden_scheduler(scenario)
    for r, g in enumerate(golden):
        rep = s.step(r)
        assert rep.mask.tolist() == g["mask"]
        assert np.asarray(rep.times_s).tolist() == g["times_s"]
        assert rep.round_time_s == g["round_time_s"]
        assert np.asarray(rep.energy_left_j).tolist() == g["energy_left_j"]
        assert np.asarray(rep.scheduled).astype(int).tolist() == g["scheduled"]
        assert np.broadcast_to(np.asarray(rep.uplink_bps, float),
                               rep.mask.shape).tolist() == g["uplink_bps"]
        assert rep.bits_tx == g["bits_tx"]
        if "cuts" in g:
            assert np.asarray(rep.cuts).tolist() == g["cuts"]
        # ...and the new fields are exactly zero
        assert (rep.compute_s == 0).all()
        assert (rep.compute_j == 0).all()


def test_ideal_channel_inf_compute_still_free():
    cfg = WirelessConfig(model="ideal", deadline_s=0.5, energy_budget_j=1.0)
    s = make_scheduler(cfg, 6, comm_for_cnn(CNN_CFG, **TABLE_KW), 2)
    for r in range(3):
        rep = s.step(r)
        np.testing.assert_array_equal(rep.mask, np.ones(6))
        assert rep.round_time_s == 0.0
        assert (rep.compute_s == 0).all()
        np.testing.assert_array_equal(rep.energy_left_j, 1.0)


# ------------------------------------------------ 2. FLOP accounting -------
def test_cnn_client_block_flops_per_cut():
    """The conv/dense counts, written out longhand: conv FLOPs go by output
    positions, so the deep cuts cost an order of magnitude more compute
    even though their activation tensors shrink."""
    s = CNN_CFG.image_size
    conv1 = conv2d_flops(1, s, s, 3, CNN_CFG.channels, CNN_CFG.conv1_filters)
    conv2 = conv2d_flops(1, s // 2, s // 2, 3, CNN_CFG.conv1_filters,
                         CNN_CFG.conv2_filters)
    fc1 = dense_layer_flops(1, CNN_CFG.flat_dim, CNN_CFG.fc_hidden)
    assert cnn.client_block_flops(CNN_CFG, 1, "conv1") == conv1
    assert cnn.client_block_flops(CNN_CFG, 1, "conv2") == conv1 + conv2
    assert cnn.client_block_flops(CNN_CFG, 1, "fc1") == conv1 + conv2 + fc1
    assert cnn.client_block_flops(CNN_CFG, 4, "conv2") == 4 * (conv1 + conv2)
    with pytest.raises(ValueError):
        cnn.client_block_flops(CNN_CFG, 1, "fc2")
    # deeper cut -> strictly more client compute (the bits say the opposite
    # between conv1 and conv2 — that opposition IS the ASFL trade-off)
    flops = [cnn.client_block_flops(CNN_CFG, 1, c)
             for c in cnn.CUT_CANDIDATES]
    assert flops == sorted(flops) and flops[0] < flops[-1]


def test_comm_models_carry_training_flops():
    table = _table()
    for c, cm in table.items():
        assert cm.client_flops_per_sample == training_flops(
            cnn.client_block_flops(CNN_CFG, 1, c))
    # client_round_flops is kappa0 * batches * batch_size * per-sample
    cm = table["conv2"]
    assert client_round_flops(cm, 3) == 3 * 2 * 16 * cm.client_flops_per_sample
    assert client_round_flops(cm, 4) > client_round_flops(cm, 3)


def test_codec_cycles_charged_only_for_lossy_codecs():
    from repro.compress import get_codec, link_codecs
    base = comm_for_cnn(CNN_CFG, **TABLE_KW)
    f0 = client_round_flops(base, 2, codec_cycles_per_element=10.0)
    assert f0 == client_round_flops(base, 2)     # no codecs: no codec work
    ident = comm_for_cnn(CNN_CFG, **TABLE_KW, codecs=link_codecs("fp32"))
    assert client_round_flops(ident, 2, codec_cycles_per_element=10.0) == f0
    q = comm_for_cnn(CNN_CFG, **TABLE_KW, codecs=link_codecs("int8"))
    fq = client_round_flops(q, 2, codec_cycles_per_element=10.0)
    # encode o_fp up + decode o_bp down each minibatch, 2*Z_0 at the offload
    n_batches = 2 * q.batches_per_epoch
    elems = (2 * n_batches * q.batch_size * q.cut_size
             + 2 * q.client_params)
    assert fq == client_round_flops(q, 2) + 10.0 * elems
    assert fq > f0                                # codec work costs compute
    # a lossy act codec alone charges only the uplink elements
    one = CommModel(batch_size=4, batches_per_epoch=1, cut_size=100,
                    client_params=50, act_codec=get_codec("int8"))
    assert client_round_flops(one, 1, codec_cycles_per_element=2.0) == \
        2.0 * 1 * 4 * 100


def test_device_model_time_and_energy():
    cfg = WirelessConfig(compute_gflops=2.0, compute_power_w=0.5, seed=0)
    dev = DeviceModel(cfg, 4)
    np.testing.assert_allclose(dev.compute_time_s(4e9), 2.0)
    np.testing.assert_allclose(dev.compute_energy_j(dev.compute_time_s(4e9)),
                               1.0)
    # monotone: more FLOPs -> more time -> more energy, per client
    t1, t2 = dev.compute_time_s(1e9), dev.compute_time_s(3e9)
    assert (t2 > t1).all()
    assert (dev.compute_energy_j(t2) > dev.compute_energy_j(t1)).all()
    # infinite compute is exactly free
    inf_dev = DeviceModel(WirelessConfig(), 4)
    assert (inf_dev.compute_time_s(1e18) == 0).all()
    # a zero rate would NaN the deadline math — refuse it loudly
    with pytest.raises(ValueError, match="positive"):
        DeviceModel(WirelessConfig(compute_gflops=0.0), 4)
    # heterogeneity: fixed per-client spread, disjoint from the channel RNG
    het = DeviceModel(WirelessConfig(compute_gflops=10.0,
                                     compute_heterogeneity=1.0, seed=0), 8)
    assert het.flops_per_s.min() < het.flops_per_s.max() / 2
    het2 = DeviceModel(WirelessConfig(compute_gflops=10.0,
                                      compute_heterogeneity=1.0, seed=0), 8)
    np.testing.assert_array_equal(het.flops_per_s, het2.flops_per_s)


def test_compute_energy_monotone_in_flops_through_scheduler():
    """Scheduler level: the same channel with a heavier client workload
    drains strictly more energy from every scheduled client."""
    def run(comm):
        cfg = WirelessConfig(model="static", mean_uplink_mbps=50.0,
                             mean_downlink_mbps=200.0, latency_s=0.0,
                             compute_gflops=5.0, compute_power_w=0.5,
                             energy_budget_j=100.0, seed=0)
        s = make_scheduler(cfg, 4, comm, 2)
        return s.step(0)

    shallow = run(comm_for_cnn(CNN_CFG, cut="conv1", **TABLE_KW))
    deep = run(comm_for_cnn(CNN_CFG, cut="fc1", **TABLE_KW))
    assert (deep.compute_s > shallow.compute_s).all()
    assert (deep.compute_j > shallow.compute_j).all()
    assert (deep.energy_left_j < shallow.energy_left_j).all()
    # and compute time is part of the deadline-facing round time
    assert (deep.times_s > shallow.times_s).all()


# ------------------------------------------------ 3. controller ------------
def test_deadline_policy_shallower_when_compute_slows_10x():
    """The acceptance bar's controller half: at 10 GFLOP/s every client
    holds the deep-feasible cut; 10x slower compute makes that cut's FLOPs
    blow the deadline, so the policy walks strictly shallower."""
    ctl = make_cut_controller(_table(), 2, policy="deadline", deadline_s=4.0)
    up = np.full(4, 10e6)
    kw = dict(compute_gflops=10.0, seed=0)
    fast = DeviceModel(WirelessConfig(**kw), 4)
    slow = DeviceModel(WirelessConfig(**{**kw, "compute_gflops": 1.0}), 4)
    cuts_fast = ctl.decide(up, 4 * up, 0.0, np.full(4, np.inf),
                           fast.sec_per_flop)
    cuts_slow = ctl.decide(up, 4 * up, 0.0, np.full(4, np.inf),
                           slow.sec_per_flop)
    assert (cuts_slow < cuts_fast).all()
    # bits-only (sec_per_flop omitted) matches infinite compute
    inf_dev = DeviceModel(WirelessConfig(seed=0), 4)
    np.testing.assert_array_equal(
        ctl.decide(up, 4 * up, 0.0, np.full(4, np.inf)),
        ctl.decide(up, 4 * up, 0.0, np.full(4, np.inf),
                   inf_dev.sec_per_flop))


def test_controller_estimates_price_compute_energy():
    ctl = make_cut_controller(_table(), 2, policy="greedy",
                              compute_power_w=0.5)
    up = np.full(2, 10e6)
    dev = DeviceModel(WirelessConfig(compute_gflops=2.0, seed=0), 2)
    t0, e0 = ctl._estimates(up, 4 * up, np.zeros(2))
    t1, e1 = ctl._estimates(up, 4 * up, np.zeros(2), dev.sec_per_flop)
    t_comp = ctl.flops[:, None] * dev.sec_per_flop[None, :]
    np.testing.assert_allclose(t1, t0 + t_comp)
    np.testing.assert_allclose(e1, e0 + 0.5 * t_comp)


def test_device_sweep_acceptance_at_test_scale():
    """benchmarks/device_sweep.py's in-run bar, via its dry-run mode: the
    deadline policy's mean cut is non-increasing in compute heterogeneity
    and strictly shallower at the top sigma."""
    spec = importlib.util.spec_from_file_location(
        "device_sweep", pathlib.Path(__file__).parent.parent / "benchmarks" /
        "device_sweep.py")
    device_sweep = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(device_sweep)
    sigmas = (0.0, 1.0, 2.0)
    table = device_sweep.sweep(None, sigmas, dry_run=True, channel="static",
                               rounds=2, seed=0, deadline=4.0,
                               es_uplink_mbps=40.0, compute_gflops=10.0,
                               compute_power_w=0.2)
    assert device_sweep.check_acceptance(table, sigmas)


# ------------------------------------------------ 4. satellite bugfixes ----
BITS = RoundBits(uplink=10_000_000, downlink=10_000_000)


def test_straggler_bits_tx_counts_only_moved_bits():
    """Regression: a deadline-cut straggler moved uplink_bps * tx window
    bits plus the downlink bits it RECEIVED before the cutoff — bits_tx
    must count exactly that, not the full offered up+down traffic.

    Re-pinned for the moved-bits symmetry fix: the pre-timeline ledger
    credited a straggler zero downlink even when the deadline cut it mid-
    broadcast (uplink finished with window to spare).  The downlink segment
    starts when the uplink finishes (latency-free, like the transmit
    window), so its credit is downlink_bps * overlap of [uplink end,
    uplink end + downlink airtime) with the deadline — zero exactly when
    the client never finished its uplink, which is what the old accounting
    assumed for every straggler."""
    cfg = WirelessConfig(model="static", mean_uplink_mbps=10.0,
                         mean_downlink_mbps=40.0, latency_s=0.0,
                         heterogeneity=1.5, deadline_s=1.0,
                         energy_budget_j=100.0, tx_power_w=0.5, seed=0)
    ch = ChannelModel(cfg, 8)
    from repro.wireless import ParticipationScheduler
    s = ParticipationScheduler(cfg, ch, BITS)
    rep = s.step(0)
    dead = rep.scheduled & (rep.mask == 0)
    assert dead.any(), "setup must produce scheduled stragglers"
    link = ch.sample(0)
    t_up = BITS.uplink / rep.uplink_bps
    t_down = BITS.downlink / np.asarray(link.downlink_bps, float)
    expect = 0.0
    saw_partial_down = False
    for u in range(8):
        if rep.mask[u] > 0:
            expect += BITS.uplink + BITS.downlink      # completed: all of it
        elif rep.scheduled[u]:
            expect += rep.uplink_bps[u] * min(t_up[u], 1.0)  # cut off
            down_window = min(max(1.0 - t_up[u], 0.0), t_down[u])
            expect += link.downlink_bps[u] * down_window
            saw_partial_down |= down_window > 0
    assert saw_partial_down, "setup must cut a straggler mid-downlink"
    assert rep.bits_tx == pytest.approx(expect)
    # strictly less than the old all-offered accounting
    offered = float((BITS.uplink + BITS.downlink) * rep.scheduled.sum())
    assert rep.bits_tx < offered


def test_energy_gate_matches_deadline_capped_charge():
    """Regression: a would-be straggler whose budget covers the deadline-
    capped charge (but not the full uncapped airtime) must be scheduled and
    pay exactly the capped charge — the old gate silently barred it while a
    richer client was scheduled and charged the capped amount."""
    # 10 Mb at 5 Mbps = 2 s airtime; deadline 1 s -> capped charge 0.5 J,
    # uncapped 1.0 J.  budget 0.7 J sits exactly in the disputed band.
    cfg = WirelessConfig(model="static", mean_uplink_mbps=5.0,
                         mean_downlink_mbps=20.0, latency_s=0.0,
                         deadline_s=1.0, energy_budget_j=0.7,
                         tx_power_w=0.5, seed=0)
    from repro.wireless import ParticipationScheduler
    s = ParticipationScheduler(cfg, ChannelModel(cfg, 4), BITS)
    rep = s.step(0)
    assert rep.scheduled.all()                    # gate admits the capped 0.5
    assert rep.num_participants == 0              # ...they all straggle
    np.testing.assert_allclose(rep.energy_left_j, 0.7 - 0.5)
    rep2 = s.step(1)                              # 0.2 J < 0.5 J: now barred
    assert not rep2.scheduled.any()
    np.testing.assert_allclose(rep2.energy_left_j, 0.2)


def test_compute_overrun_client_never_scheduled():
    """A client whose compute alone consumes the whole deadline window
    cannot push a single bit — it must not be scheduled (at
    compute_power_w=0 its capped charge is 0, so without the transmit-
    window gate it would be scheduled forever, eating a contention share
    and pinning the round clock at the deadline)."""
    cfg = WirelessConfig(model="static", mean_uplink_mbps=50.0,
                         mean_downlink_mbps=200.0, latency_s=0.0,
                         deadline_s=1.0, compute_gflops=1.0,
                         energy_budget_j=5.0, seed=0)
    # fc1 workload: ~8.7 GFLOP/round at 1 GFLOP/s = ~8.7 s >> 1 s deadline
    s = make_scheduler(cfg, 4, comm_for_cnn(CNN_CFG, cut="fc1", **TABLE_KW),
                       2)
    for r in range(3):
        rep = s.step(r)
        assert not rep.scheduled.any()
        assert rep.num_participants == 0
        assert rep.round_time_s == 0.0            # nobody pins the clock
        np.testing.assert_array_equal(rep.energy_left_j, 5.0)
    # the same devices at a feasible (shallow) cut ARE scheduled
    s2 = make_scheduler(cfg, 4,
                        comm_for_cnn(CNN_CFG, cut="conv1", **TABLE_KW), 2)
    assert s2.step(0).scheduled.all()


@pytest.mark.parametrize("seed", range(4))
def test_energy_never_negative_and_charge_affordable(seed):
    """Seeded invariant: with the gate and the deduction using the same
    deadline-capped quantity, budgets can never go negative and every
    scheduled client could afford what it was actually charged."""
    cfg = WirelessConfig(model="rayleigh", mean_uplink_mbps=8.0,
                         mean_downlink_mbps=32.0, latency_s=0.01,
                         heterogeneity=1.0, deadline_s=2.0,
                         energy_budget_j=1.5, tx_power_w=0.5,
                         es_uplink_mbps=20.0,
                         compute_gflops=5.0, compute_power_w=0.3,
                         compute_heterogeneity=0.5, seed=seed)
    s = make_scheduler(cfg, 8, comm_for_cnn(CNN_CFG, **TABLE_KW), 2,
                       es_assign=np.arange(8) // 4)
    prev = s.energy_left.copy()
    for r in range(12):
        rep = s.step(r)
        assert (rep.energy_left_j >= -1e-12).all()
        charged = prev - rep.energy_left_j
        # every charge was affordable at gate time (gate <=> affordability)
        assert (charged <= prev + 1e-12).all()
        # only scheduled clients were charged
        assert (charged[~rep.scheduled] == 0).all()
        prev = rep.energy_left_j


def test_trace_down_pair_is_honored():
    """An asymmetric measured (uplink, downlink) trace pair must drive the
    two directions independently; without trace_down the downlink falls
    back to the rescaled uplink trace (the documented fallback)."""
    up_tr = ((10.0,) * 4, (2.0,) * 4)
    down_tr = ((1.0,) * 4, (80.0,) * 4)          # anti-correlated on purpose
    cfg = WirelessConfig(model="trace", trace=up_tr, trace_down=down_tr,
                         mean_uplink_mbps=10.0, mean_downlink_mbps=40.0)
    ch = ChannelModel(cfg, 4)
    l0, l1, l2 = ch.sample(0), ch.sample(1), ch.sample(2)
    np.testing.assert_allclose(l0.uplink_bps, 10e6)
    np.testing.assert_allclose(l0.downlink_bps, 1e6)     # NOT 4x the uplink
    np.testing.assert_allclose(l1.uplink_bps, 2e6)
    np.testing.assert_allclose(l1.downlink_bps, 80e6)
    np.testing.assert_allclose(l2.downlink_bps, l0.downlink_bps)  # cycles
    # fallback: same config minus trace_down rescales the uplink trace
    fb = ChannelModel(WirelessConfig(model="trace", trace=up_tr,
                                     mean_uplink_mbps=10.0,
                                     mean_downlink_mbps=40.0), 4)
    f0 = fb.sample(0)
    np.testing.assert_allclose(f0.downlink_bps, 40e6)    # 10 Mbps * 4x ratio
    # a mismatched pair would silently desynchronize (both cycle modulo
    # their own length) — refuse it loudly instead
    with pytest.raises(ValueError, match="round-for-round"):
        ChannelModel(WirelessConfig(model="trace", trace=up_tr,
                                    trace_down=down_tr[:1]), 4)


def test_fedsim_prices_index_bits_at_max_client_size():
    """Eq. 17 is an upper bound: under a Dirichlet(0.05) split the largest
    client's dataset is far above the mean, and the scheduler's byte
    accounting must use the max (the honest bound), not the mean."""
    from repro.core.fedsim import FedSim
    from repro.data.synthetic import make_federated_image_data

    fed = make_federated_image_data(8, alpha=0.05, train_per_class=40,
                                    test_per_class=10, seed=0)
    sizes = [len(i) for i in fed.train_indices]
    assert max(sizes) > int(np.mean(sizes))      # the skew is real
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=1,
                        kappa1=1, global_rounds=1)
    t = TrainConfig(learning_rate=0.05, batch_size=8, freeze_head=True)
    w = WirelessConfig(model="static", deadline_s=float("inf"))
    sim = FedSim(CNN_CFG, fed, h, t, batches_per_epoch=1, seed=0, wireless=w)
    comm_max = comm_for_cnn(CNN_CFG, dataset_size=max(sizes),
                            batch_size=t.batch_size, batches_per_epoch=1)
    comm_mean = comm_for_cnn(CNN_CFG, dataset_size=int(np.mean(sizes)),
                             batch_size=t.batch_size, batches_per_epoch=1)
    want = client_round_bits(comm_max, h.kappa0)
    got = sim.scheduler.bits
    assert (got.uplink, got.downlink) == (want.uplink, want.downlink)
    # and the mean would genuinely undercount at this skew
    under = client_round_bits(comm_mean, h.kappa0)
    assert under.uplink < want.uplink
