"""Telemetry subsystem: trace export, metrics registry, sinks, manifests.

The load-bearing assertions:

- a hand-computed 2-client pipelined fault round (one HARQ retransmission,
  one crash) exports EXACTLY the expected trace events — segment names,
  track ids, microsecond timestamps;
- trace export is a pure function (repeated export is identical) and the
  streamed trace of a REAL fault-injected pipelined scheduler run
  reproduces every RoundTimeline segment number exactly;
- ``Telemetry.disabled()`` (the default everywhere) is bit-inert: the
  golden FedSim history captured at the pre-telemetry HEAD still matches;
- ``MetricLogger`` preserves JSON-native value types (the old
  ``float-or-str`` coercion regression);
- ``tools.bench_report`` unifies the drifted BENCH row schemas and FAILS
  on malformed records.
"""

import io
import json
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import FaultConfig, WirelessConfig
from repro.telemetry import (Counter, Gauge, Histogram, MetricLogger,
                             MetricsRegistry, Telemetry, TraceWriter,
                             collect_manifest, config_hash, json_safe,
                             kernel_probe, round_span_s, set_kernel_sink,
                             timeline_to_trace_events)
from repro.wireless import make_scheduler
from repro.wireless.channel import LinkState, RoundBits
from repro.wireless.faults import FaultPlan
from repro.wireless.timeline import build_timeline

from tools import bench_report


# ------------------------------------------------------------------ metrics
class TestMetrics:
    def test_counter_monotone(self):
        c = Counter("n")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_last_write(self):
        g = Gauge("g")
        g.set(4)
        g.set(-1.5)
        assert g.value == -1.5

    def test_histogram_stats_and_buckets(self):
        h = Histogram("h", buckets=(1.0, 10.0))
        for v in (0.5, 2.0, 3.0, 20.0):
            h.observe(v)
        assert h.count == 4 and h.sum == 25.5
        assert h.min == 0.5 and h.max == 20.0 and h.mean == 25.5 / 4
        assert h.bucket_counts == [1, 2, 1]          # <=1, <=10, overflow

    def test_registry_get_or_create_and_kind_conflict(self):
        reg = MetricsRegistry()
        assert reg.counter("a") is reg.counter("a")
        with pytest.raises(TypeError):
            reg.gauge("a")

    def test_flush_jsonl_roundtrip(self):
        reg = MetricsRegistry()
        reg.counter("bits").inc(7)
        reg.gauge("acc").set(0.5)
        buf = io.StringIO()
        rec = reg.flush_jsonl(buf, step=3)
        parsed = json.loads(buf.getvalue())
        assert parsed == json.loads(json.dumps(rec))
        assert parsed["step"] == 3
        assert parsed["metrics"]["bits"]["value"] == 7
        assert parsed["metrics"]["acc"]["kind"] == "gauge"

    def test_summary_table_lists_all(self):
        reg = MetricsRegistry()
        reg.counter("z.last").inc()
        reg.histogram("a.first").observe(1.0)
        table = reg.summary_table()
        assert table.index("a.first") < table.index("z.last")


# ---------------------------------------------------------------- sinks
class TestMetricLogger:
    def test_json_native_types_preserved(self):
        # regression: the old logger coerced non-floats through str(),
        # stringifying ints, bools, and lists in the JSONL output
        out = io.StringIO()
        log = MetricLogger("t", stream=out)
        rec = log.log(step=2, n=3, ok=True, xs=[1, 2], name="adam",
                      arr=np.arange(2), scalar=np.float32(0.5))
        line = out.getvalue()
        parsed = json.loads(line.split("] ", 1)[1])
        assert parsed["n"] == 3 and isinstance(parsed["n"], int)
        assert parsed["ok"] is True
        assert parsed["xs"] == [1, 2]
        assert parsed["name"] == "adam"
        assert parsed["arr"] == [0, 1]
        assert parsed["scalar"] == 0.5
        assert rec["step"] == 2

    def test_json_safe_fallback(self):
        assert json_safe(object).startswith("<class")
        assert json_safe({"k": (1, np.int64(2))}) == {"k": [1, 2]}

    def test_telemetry_mirror(self):
        tel = Telemetry()                     # enabled, no out_dir: memory
        log = MetricLogger("t", stream=io.StringIO(), telemetry=tel)
        log.log(step=1, loss=2.5, name="x")
        snap = tel.metrics.snapshot()
        assert snap["log.t.loss"]["value"] == 2.5
        assert "log.t.name" not in snap      # non-numeric: not mirrored

    def test_shim_import(self):
        from repro.utils.logging import MetricLogger as Shim
        assert Shim is MetricLogger


# ---------------------------------------------------- hand-computed trace
def _two_client_fault_round():
    """2 clients, pipelined (2 chunks + tail), client 0 retransmits payload
    1 once, client 1 crashes at t=3.5 — every number below is hand-derived.

    Rates: up 100 bps, down 200 bps.  comp_s=2.0 (c=1.0/chunk), payloads
    100 bits (u=1.0 s), tail 50 bits (0.5 s), downlink 100 bits (0.5 s),
    backoff 0.25 s, deadline 10 s.
    """
    U = 2
    link = LinkState(uplink_bps=np.full(U, 100.0),
                     downlink_bps=np.full(U, 200.0),
                     latency_s=np.zeros(U))
    bits = RoundBits(uplink=250.0, downlink=100.0, up_stream=100.0,
                     up_tail=50.0, chunks=2)
    plan = FaultPlan(
        up_attempts=np.array([[1, 2, 1], [1, 1, 1]]),
        up_ok=np.ones((2, 3), bool),
        down_attempts=np.array([1, 1]),
        down_ok=np.array([True, True]),
        crash_frac=np.array([np.inf, 0.35]),     # client 1 dies at 3.5 s
        backoff_s=0.25)
    return build_timeline(link, bits, np.full(U, 2.0), 10.0, U, plan=plan,
                          pipeline=True)


class TestTraceExport:
    def test_hand_computed_round(self):
        tl = _two_client_fault_round()
        evs = timeline_to_trace_events(tl, round_idx=7, t0_s=100.0)

        def seg(u, name):
            match = [e for e in evs if e["tid"] == u and e["name"] == name]
            assert len(match) == 1, (u, name, [e["name"] for e in evs])
            return match[0]

        us = 1e6
        # client 0: 2 compute chunks, 3 payloads + 1 retransmission
        assert seg(0, "compute[0]")["ts"] == 100.0 * us
        assert seg(0, "compute[1]")["ts"] == 101.0 * us
        assert seg(0, "compute[1]")["dur"] == 1.0 * us
        p0 = seg(0, "uplink[p0]")
        assert p0["ts"] == 101.0 * us and p0["dur"] == 1.0 * us
        assert p0["args"]["bits"] == 100.0 and p0["args"]["retx"] is False
        assert seg(0, "uplink[p1]")["ts"] == 102.0 * us
        retx = seg(0, "uplink[p1.a1]")      # backoff 0.25 after p1 ends at 3
        assert retx["ts"] == 103.25 * us and retx["dur"] == 1.0 * us
        assert retx["args"] == {"round": 7, "bits": 100.0, "payload": 1,
                                "attempt": 1, "retx": True}
        tail = seg(0, "uplink[p2]")
        assert tail["ts"] == 104.25 * us and tail["dur"] == 0.5 * us
        d0 = seg(0, "downlink")
        assert d0["ts"] == 104.75 * us and d0["dur"] == 0.5 * us
        assert d0["ph"] == "X" and d0["pid"] == 1

        # client 1: no retransmissions (its p1 placeholder column is
        # skipped), crash instant at its cap
        assert seg(1, "uplink[p0]")["ts"] == 101.0 * us
        assert seg(1, "uplink[p1]")["ts"] == 102.0 * us
        assert seg(1, "uplink[p2]")["ts"] == 103.0 * us
        assert seg(1, "downlink")["ts"] == 103.5 * us
        crash = seg(1, "crash")
        assert crash["ph"] == "i" and crash["ts"] == 103.5 * us
        assert not any(e["tid"] == 1 and ".a1]" in e["name"] for e in evs)

        # exactly the hand-enumerated event set, nothing else
        assert len([e for e in evs if e["tid"] == 0]) == 7
        assert len([e for e in evs if e["tid"] == 1]) == 7

    def test_export_is_deterministic(self):
        tl = _two_client_fault_round()
        a = timeline_to_trace_events(tl, 0)
        b = timeline_to_trace_events(tl, 0)
        assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)

    def test_clients_mask_hides_tracks(self):
        tl = _two_client_fault_round()
        evs = timeline_to_trace_events(tl, 0, clients=[True, False])
        assert {e["tid"] for e in evs} == {0}

    def test_fault_free_timeline_has_no_fault_fields(self):
        U = 2
        link = LinkState(np.full(U, 100.0), np.full(U, 200.0), np.zeros(U))
        tl = build_timeline(link, RoundBits(uplink=100.0, downlink=50.0),
                            np.zeros(U), np.inf, U)
        assert tl.tx_payload is None and tl.crashed is None
        evs = timeline_to_trace_events(tl, 0)
        names = {e["name"] for e in evs}
        assert names == {"compute", "uplink", "downlink"}


# ------------------------------------------- trace vs scheduler timeline
def _fault_scheduler(U=4):
    from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
    from repro.core.comm import comm_for_cnn
    cfg = WirelessConfig(model="static", mean_uplink_mbps=20.0,
                         mean_downlink_mbps=80.0, deadline_s=3.0,
                         pipeline=True, staleness_lambda=0.5,
                         faults=FaultConfig(erasure_prob=0.4, max_retries=2,
                                            backoff_s=0.1, crash_hazard=0.2),
                         seed=0)
    comm = comm_for_cnn(CNN_CFG, dataset_size=400, batch_size=16,
                        batches_per_epoch=2)
    return make_scheduler(cfg, U, comm, 2, es_assign=np.arange(U) // 2)


class TestTraceVsTimeline:
    def test_streamed_trace_reproduces_timeline_exactly(self, tmp_path):
        """Every segment number in the streamed trace of a REAL
        fault-injected pipelined run equals the scheduler's RoundTimeline
        (exact float equality — export and JSON never round)."""
        sched = _fault_scheduler()
        w = TraceWriter(tmp_path / "trace.json")
        rounds = []
        for r in range(4):
            t0 = w.clock_s
            rep = sched.step(r)
            w.add_round(rep, sched.last_timeline, es_assign=sched.es_assign,
                        deadline_s=sched.cfg.deadline_s)
            rounds.append((t0, rep, sched.last_timeline))
        w.close()
        evs = json.load(open(tmp_path / "trace.json"))
        assert any(".a1]" in e["name"] for e in evs), "no retx in scenario"
        for t0, rep, tl in rounds:
            r = int(rep.round_idx)
            mine = [e for e in evs if e.get("ph") == "X" and e["pid"] == 1
                    and e["args"]["round"] == r]
            for u in np.flatnonzero(rep.scheduled):
                # uplink starts: trace == timeline, exact float equality
                # in microsecond space (the exporter's own formula)
                got = sorted(e["ts"] for e in mine
                             if e["tid"] == u and "uplink" in e["name"])
                want = sorted(
                    (t0 + float(s)) * 1e6
                    for s, b in zip(tl.tx_start[u], tl.tx_bits[u])
                    if b > 0 and math.isfinite(s))
                assert got == want, (r, u)
                gd = sorted(e["dur"] for e in mine
                            if e["tid"] == u and "uplink" in e["name"])
                wd = sorted(
                    float(e - s) * 1e6 for s, e, b in
                    zip(tl.tx_start[u], tl.tx_end[u], tl.tx_bits[u])
                    if b > 0 and math.isfinite(e))
                assert gd == wd, (r, u)
                down = [e for e in mine if e["tid"] == u
                        and e["name"] == "downlink"]
                if math.isfinite(tl.down_end[u]):
                    assert down[0]["ts"] == (t0 + float(
                        tl.down_start[u])) * 1e6
            # crashes appear as instants at the cap
            if rep.crashed is not None:
                for u in np.flatnonzero(rep.crashed):
                    cr = [e for e in evs if e.get("ph") == "i"
                          and e.get("tid") == u and e["name"] == "crash"
                          and e["args"]["round"] == r]
                    assert cr and cr[0]["ts"] == (t0 + float(
                        tl.cap_s[u])) * 1e6

    def test_round_span_covers_segments(self):
        sched = _fault_scheduler()
        rep = sched.step(0)
        span = round_span_s(rep, sched.last_timeline)
        assert span >= rep.round_time_s
        assert math.isfinite(span)

    def test_writer_tracks_and_markers(self, tmp_path):
        sched = _fault_scheduler()
        w = TraceWriter(tmp_path / "t.json")
        for r in range(2):
            rep = sched.step(r)
            w.add_round(rep, sched.last_timeline, es_assign=sched.es_assign,
                        deadline_s=3.0)
        w.close()
        evs = json.load(open(tmp_path / "t.json"))
        meta = {(e["pid"], e.get("tid"), e["args"]["name"]) for e in evs
                if e["ph"] == "M"}
        assert (0, None, "round markers") in meta
        assert (2, 0, "ES 0") in meta and (2, 1, "ES 1") in meta
        marks = [e for e in evs if e["ph"] == "i" and e["pid"] == 0]
        assert {m["name"] for m in marks} >= {"round 0", "round 1",
                                              "deadline"}
        es_spans = [e for e in evs if e["pid"] == 2 and e["ph"] == "X"]
        assert len(es_spans) == 4                   # 2 ES x 2 rounds

    def test_streamed_file_valid_without_close(self, tmp_path):
        # crash-safety: the JSON Array Format's trailing ] is optional
        sched = _fault_scheduler()
        w = TraceWriter(tmp_path / "t.json")
        rep = sched.step(0)
        w.add_round(rep, sched.last_timeline)
        w._fh.flush()
        text = open(tmp_path / "t.json").read()
        evs = json.loads(text + "]")                # viewer-equivalent fixup
        assert len(evs) > 0


# ----------------------------------------------------- scheduler metrics
class TestSchedulerTelemetry:
    def test_record_round_instruments(self, tmp_path):
        tel = Telemetry(str(tmp_path))
        sched = _fault_scheduler()
        sched.telemetry = tel
        for r in range(3):
            sched.step(r)
        snap = tel.metrics.snapshot()
        assert snap["sched.rounds"]["value"] == 3
        assert snap["sched.round_time_s"]["count"] == 3
        assert (snap["sched.goodput_bits"]["value"]
                + snap["sched.retx_bits"]["value"]
                == pytest.approx(snap["sched.bits_moved"]["value"]))
        assert "stale.bank_depth" in snap
        tel.close()
        lines = [json.loads(l) for l in
                 open(tmp_path / "metrics.jsonl")]
        assert len(lines) == 4                       # 3 rounds + final
        assert lines[0]["step"] == 0

    def test_disabled_is_inert_no_op(self):
        tel = Telemetry.disabled()
        assert tel is Telemetry.disabled()           # shared singleton
        assert not tel.enabled
        assert tel.record_round(None, None) is None  # never touches args
        assert tel.close() is None
        assert tel.write_manifest(config={"x": 1}) is None

    def test_scheduler_results_identical_with_telemetry(self, tmp_path):
        a, b = _fault_scheduler(), _fault_scheduler()
        b.telemetry = Telemetry(str(tmp_path))
        for r in range(3):
            ra, rb = a.step(r), b.step(r)
            assert ra.round_time_s == rb.round_time_s
            assert ra.bits_tx == rb.bits_tx
            np.testing.assert_array_equal(ra.mask, rb.mask)
            np.testing.assert_array_equal(ra.energy_left_j, rb.energy_left_j)


# --------------------------------------------------------- kernel probes
class TestKernelProbes:
    def teardown_method(self):
        set_kernel_sink(None)

    def test_no_sink_zero_overhead_path(self):
        assert kernel_probe("x") is None

    def test_concrete_call_records(self):
        from repro.kernels.quantize.ops import quantize_dequantize
        reg = MetricsRegistry()
        set_kernel_sink(reg)
        x = jnp.arange(16.0).reshape(4, 4)
        quantize_dequantize(x, jax.random.PRNGKey(0), bits=8)
        snap = reg.snapshot()
        assert snap["kernel.quantize.calls"]["value"] == 1
        assert snap["kernel.quantize.flops"]["value"] == 4.0 * 16
        assert snap["kernel.quantize.bytes"]["value"] > 0
        assert snap["kernel.quantize.wall_s"]["count"] == 1

    def test_traced_call_counted_not_timed(self):
        from repro.kernels.quantize.ops import quantize_dequantize
        reg = MetricsRegistry()
        set_kernel_sink(reg)
        f = jax.jit(lambda x, k: quantize_dequantize(x, k, bits=8))
        x = jnp.arange(16.0).reshape(4, 4)
        f(x, jax.random.PRNGKey(0))
        snap = reg.snapshot()
        assert snap["kernel.quantize.traced_calls"]["value"] >= 1
        assert "kernel.quantize.wall_s" not in snap

    def test_numerics_identical_with_probe(self):
        from repro.kernels.quantize.ops import quantize_dequantize
        x = jnp.linspace(-1, 1, 64)
        k = jax.random.PRNGKey(3)
        base = quantize_dequantize(x, k, bits=4)
        set_kernel_sink(MetricsRegistry())
        probed = quantize_dequantize(x, k, bits=4)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(probed))


# ------------------------------------------------------------- manifest
class TestManifest:
    def test_config_hash_stable_and_distinct(self):
        w1 = WirelessConfig(model="static")
        w2 = WirelessConfig(model="static")
        w3 = WirelessConfig(model="rayleigh")
        assert config_hash(w1) == config_hash(w2)
        assert config_hash(w1) != config_hash(w3)
        assert config_hash(None) is None

    def test_collect_manifest_fields(self):
        man = collect_manifest(config={"a": 1}, seeds={"seed": 7},
                               extra={"note": "x"})
        assert man["seeds"] == {"seed": 7}
        assert man["note"] == "x"
        assert man["python"] and man["platform"]
        json.dumps(man, default=repr)                # JSON-serializable


# ---------------------------------------------------------- bench report
class TestBenchReport:
    def test_normalizes_drifted_schemas(self, tmp_path):
        (tmp_path / "BENCH_a.json").write_text(json.dumps([
            {"mode": "serial", "total_bits": 10.0, "final_acc": 0.5},
            {"policy": "harq", "erasure_prob": 0.3, "bits": 20.0,
             "failed": [0, 1, 1, 0], "crashed": 2},
        ]))
        (tmp_path / "BENCH_b.json").write_text(json.dumps([
            {"name": "lm", "bits_tx": 30.0, "stale_delivered": [1, 0]},
        ]))
        rows = bench_report.load_all(str(tmp_path))
        assert [r["source"] for r in rows] == ["a", "a", "b"]
        assert rows[0]["label"] == "serial"
        assert rows[1]["label"] == "harq @ p=0.3"
        assert [r["total_bits"] for r in rows] == [10.0, 20.0, 30.0]
        assert rows[1]["failed"] == 2 and rows[1]["crashed"] == 2
        assert rows[2]["stale_delivered"] == 1
        md = bench_report.to_markdown(rows)
        assert md.splitlines()[0].startswith("| source | label |")
        buf = io.StringIO()
        bench_report.write_csv(rows, buf)
        assert len(buf.getvalue().splitlines()) == 4

    def test_malformed_records_fail(self, tmp_path):
        (tmp_path / "BENCH_bad.json").write_text('{"not": "a list"}')
        with pytest.raises(bench_report.MalformedRecord):
            bench_report.load_all(str(tmp_path))
        (tmp_path / "BENCH_bad.json").write_text(
            json.dumps([{"mode": "x", "final_acc": "high"}]))
        with pytest.raises(bench_report.MalformedRecord):
            bench_report.load_all(str(tmp_path))
        (tmp_path / "BENCH_bad.json").write_text("not json")
        with pytest.raises(bench_report.MalformedRecord):
            bench_report.load_all(str(tmp_path))

    def test_cli_on_real_repo_files(self, tmp_path, capsys):
        assert bench_report.main(["--dir", ".", "--csv",
                                  str(tmp_path / "r.csv")]) == 0
        out = capsys.readouterr().out
        assert out.startswith("| source | label |")
        assert (tmp_path / "r.csv").exists()

    def test_cli_empty_dir_fails(self, tmp_path):
        assert bench_report.main(["--dir", str(tmp_path)]) == 1


# --------------------------------------------- FedSim golden bit-identity
@pytest.mark.slow
class TestFedSimGolden:
    def test_disabled_telemetry_bit_identical_to_pre_telemetry_head(self):
        """The telemetry-off default reproduces the golden FedSim history
        captured at the pre-telemetry HEAD, bit for bit — and running the
        SAME simulation with telemetry ON changes nothing either."""
        from repro.configs.phsfl_cnn import CONFIG
        from repro.configs.sweeps import (sweep_hierarchy, sweep_train,
                                          sweep_wireless)
        from repro.core.fedsim import FedSim
        from repro.data.synthetic import make_federated_image_data

        golden = json.load(open("tests/golden_fedsim_history.json"))
        data = make_federated_image_data(8, alpha=0.3, train_per_class=40,
                                         test_per_class=20, seed=0)
        h, t = sweep_hierarchy(2), sweep_train()
        w = sweep_wireless("static", deadline_s=3.0, pipeline=True,
                           staleness_lambda=0.5,
                           faults=FaultConfig(erasure_prob=0.3,
                                              max_retries=2,
                                              crash_hazard=0.2), seed=0)
        sim = FedSim(CONFIG, data, h, t, batches_per_epoch=2, seed=0,
                     wireless=w)                     # telemetry DEFAULT off
        res = sim.run(rounds=2, log_every=1)
        assert res.history == golden["history"]
        assert res.network == golden["network"]
        assert res.total_sim_time_s == golden["total_sim_time_s"]
        psum = float(sum(np.asarray(x, np.float64).sum()
                         for x in jax.tree.leaves(res.global_params)))
        assert psum == golden["global_params_sum"]
