"""Parity of the degenerate 1-device launch path (make_host_round) with
make_phsfl_round semantics, plus its participation-mask behavior.

The fast tests check the host round against an explicit per-client loop on a
tiny model (single device).  The slow test runs the mesh path on 8 fake
devices in a child process and asserts the two paths agree numerically.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import HierarchyConfig, TrainConfig
from repro.configs.registry import get_arch
from repro.core import (build_optimizer, edge_aggregate, init_stacked_params,
                        make_host_round)
from repro.data.synthetic import synthetic_token_batch
from repro.models import build_model
from repro.optim import apply_updates


C, K, MICRO, SEQ = 4, 2, 2, 32


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("xlstm-350m").reduced()
    model = build_model(cfg)
    hcfg = HierarchyConfig(num_edge_servers=1, clients_per_es=C, kappa0=K,
                           kappa1=1)
    tcfg = TrainConfig(learning_rate=0.05, freeze_head=True, remat=False)
    params = init_stacked_params(model, jax.random.PRNGKey(0), C)
    opt, _ = build_optimizer(model, tcfg)
    state1 = opt.init(jax.tree.map(lambda x: x[0], params))
    opt_state = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (C,) + x.shape), state1)
    nb = synthetic_token_batch(0, C * K * MICRO, SEQ, cfg.vocab_size)
    batch = {k: jnp.asarray(v).reshape(C, K, MICRO, SEQ)
             for k, v in nb.items()}
    au = jnp.full((C,), 1.0 / C, jnp.float32)
    ab = jnp.ones((C,), jnp.float32)
    return cfg, model, hcfg, tcfg, params, opt_state, batch, au, ab, opt


def _host_reference(model, opt, params, batch, weights):
    """Per-client local SGD loop + Eq. 14-15 weighted aggregation."""
    client_params = []
    for c in range(C):
        p = jax.tree.map(lambda x: x[c], params)
        s = opt.init(p)
        for k in range(K):
            mb = {kk: vv[c, k] for kk, vv in batch.items()}
            loss, g = jax.value_and_grad(lambda q: model.loss(q, mb))(p)
            upd, s = opt.update(g, s, p)
            p = apply_updates(p, upd)
        client_params.append(p)
    return client_params, edge_aggregate(
        [client_params[i] for i in np.flatnonzero(weights)],
        weights[weights > 0] / weights[weights > 0].sum())


def test_host_round_matches_per_client_reference(setup):
    cfg, model, hcfg, tcfg, params, opt_state, batch, au, ab, opt = setup
    rnd = make_host_round(model, hcfg, tcfg, num_clients=C,
                          global_sync=False)
    p2, s2, metrics = jax.jit(rnd.fn)(params, opt_state, batch, au, ab)
    _, ref = _host_reference(model, opt, params, batch,
                             np.full(C, 1.0 / C))
    got = jax.tree.map(lambda x: x[0], p2)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
    # head frozen (Eq. 12), all clients synced
    assert jnp.array_equal(params["lm_head"]["w"][0], p2["lm_head"]["w"][0])
    for x in jax.tree.leaves(p2):
        for i in range(1, C):
            assert jnp.array_equal(x[0], x[i])
    assert np.isfinite(float(metrics["loss"]))


def test_host_round_full_mask_bit_identical(setup):
    """The launch-path regression: an all-ones participation mask reproduces
    the unmasked round bit-for-bit (ideal-network trajectory)."""
    cfg, model, hcfg, tcfg, params, opt_state, batch, au, ab, opt = setup
    base = make_host_round(model, hcfg, tcfg, num_clients=C,
                           global_sync=False)
    masked = make_host_round(model, hcfg, tcfg, num_clients=C,
                             global_sync=False, participation=True)
    p_ref, s_ref, _ = jax.jit(base.fn)(params, opt_state, batch, au, ab)
    ones = jnp.ones((C,), jnp.float32)
    p_m, s_m, _ = jax.jit(masked.fn)(params, opt_state, batch, au, ab, ones)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_m)):
        assert jnp.array_equal(a, b)
    for a, b in zip(jax.tree.leaves(s_ref), jax.tree.leaves(s_m)):
        assert jnp.array_equal(a, b)


def test_host_round_partial_mask_renormalizes(setup):
    cfg, model, hcfg, tcfg, params, opt_state, batch, au, ab, opt = setup
    masked = make_host_round(model, hcfg, tcfg, num_clients=C,
                             global_sync=False, participation=True)
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0], jnp.float32)
    p_m, _, _ = jax.jit(masked.fn)(params, opt_state, batch, au, ab, mask)
    _, ref = _host_reference(model, opt, params, batch,
                             np.array([0.25, 0.0, 0.25, 0.0]))
    got = jax.tree.map(lambda x: x[0], p_m)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_host_round_empty_mask_keeps_previous_edge_model(setup):
    cfg, model, hcfg, tcfg, params, opt_state, batch, au, ab, opt = setup
    masked = make_host_round(model, hcfg, tcfg, num_clients=C,
                             global_sync=False, participation=True)
    zeros = jnp.zeros((C,), jnp.float32)
    p_m, _, _ = jax.jit(masked.fn)(params, opt_state, batch, au, ab, zeros)
    for a, b in zip(jax.tree.leaves(p_m), jax.tree.leaves(params)):
        assert jnp.array_equal(a, b)


# --------------------------- mesh vs host parity (8 fake devices) ----------
_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           "--xla_disable_hlo_passes=all-reduce-promotion")
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.registry import get_arch
from repro.configs.base import HierarchyConfig, TrainConfig
from repro.models import build_model
from repro.core import (make_phsfl_round, make_host_round,
                        init_stacked_params, build_optimizer)
from repro.data.synthetic import synthetic_token_batch

# model axis size 1: XLA's partial-manual TP subgroup aborts on this
# jax/XLA version; the pod/data manual aggregation is what parity tests.
mesh = jax.make_mesh((2, 4, 1), ("pod", "data", "model"))
cfg = get_arch("mistral-large-123b").reduced()
model = build_model(cfg)
h = HierarchyConfig(num_edge_servers=2, clients_per_es=4, kappa0=2, kappa1=1)
t = TrainConfig(learning_rate=0.05, freeze_head=True, local_steps_in_step=2,
                remat=False)
C = 8
params = init_stacked_params(model, jax.random.PRNGKey(0), C)
opt, _ = build_optimizer(model, t)
state1 = opt.init(jax.tree.map(lambda x: x[0], params))
opt_state = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (C,) + x.shape),
                         state1)
nb = synthetic_token_batch(0, C * 2 * 2, 32, cfg.vocab_size)
batch = {k: jnp.asarray(v).reshape(C, 2, 2, 32) for k, v in nb.items()}
au = jnp.full((C,), 0.25, jnp.float32)
ab = jnp.full((C,), 0.5, jnp.float32)
# ES 0 loses two clients, ES 1 keeps all four
mask = jnp.asarray([1.0, 0.0, 1.0, 0.0, 1.0, 1.0, 1.0, 1.0], jnp.float32)

with mesh:
    rnd = make_phsfl_round(model, h, t, mesh, global_sync=True,
                           participation=True)
    p_mesh, s_mesh, m_mesh = jax.jit(rnd.fn)(params, opt_state, batch,
                                             au, ab, mask)

host = make_host_round(model, h, t, num_clients=C, global_sync=True,
                       participation=True)
p_host, s_host, m_host = jax.jit(host.fn)(params, opt_state, batch,
                                          au, ab, mask)

def maxerr(a, b):
    return max(float(jnp.abs(x.astype(jnp.float32)
                             - y.astype(jnp.float32)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

print(json.dumps({
    "param_err": maxerr(p_mesh, p_host),
    "loss_mesh": float(m_mesh["loss"]),
    "loss_host": float(m_host["loss"]),
}))
"""


@pytest.mark.slow
def test_mesh_and_host_rounds_agree_under_partial_mask():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["param_err"] < 5e-3, rec
    assert abs(rec["loss_mesh"] - rec["loss_host"]) < 1e-5, rec
