"""Remark-1 communication accounting + Theorem-1 bound calculator."""

import numpy as np
import pytest

from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.configs.registry import get_arch
from repro.core import (BoundInputs, bound_terms, comm_for_cnn, comm_for_lm,
                        lr_limit, uniform_weights)


def test_cnn_comm_model_paper_inequality():
    """Remark 1 scrutinized: for the paper's OWN CNN (2.2M params, cut-layer
    activations 16384 floats/sample), the per-round activation traffic
    DOMINATES and Phi_PHSFL > Phi_HFL at the paper's kappa0=5, N=32 —
    the 'Z >> Z_0 + Z_c' claim holds for Z but the N*Z_c term does not
    vanish.  Recorded as a finding in EXPERIMENTS.md; the inequality DOES
    hold for the 100B-scale LMs (test_lm_comm_model)."""
    import dataclasses
    cm = comm_for_cnn(CNN_CFG, dataset_size=500)
    assert not cm.phsfl_wins(kappa0=5)
    # ...but the inequality flips in the regime the remark actually
    # describes: a much bigger model with the same cut activations.
    big = dataclasses.replace(cm, total_params=cm.total_params * 1000)
    assert big.phsfl_wins(kappa0=5)


@pytest.mark.parametrize("k0", list(range(1, 21)))
def test_comm_monotone_in_kappa0(k0):
    cm = comm_for_cnn(CNN_CFG, dataset_size=500)
    assert cm.phi_phsfl_bits(k0 + 1) > cm.phi_phsfl_bits(k0)


def test_lm_comm_model():
    cfg = get_arch("mistral-large-123b")
    cm = comm_for_lm(cfg, seq_len=4096, dataset_size=10_000)
    # for LMs with a 2-block client side, shipping activations is cheaper
    # than shipping the full 123B model
    assert cm.phi_hfl_bits() > cm.phi_phsfl_bits(kappa0=5)
    assert cm.client_params < cm.total_params * 0.2


def _bi(eta=1e-3, beta=1.0, k0=5, k1=3):
    au, ab = uniform_weights(4, 25)
    return BoundInputs(eta=eta, beta=beta, sigma2=1.0, eps0_2=0.5, eps1_2=0.5,
                       kappa0=k0, kappa1=k1, T=1500, f0_minus_fT=2.0,
                       alpha_u=au, alpha_b=ab)


def test_bound_terms_positive_and_finite():
    t = bound_terms(_bi())
    for k, v in t.items():
        if k == "eta_ok":
            continue
        assert np.isfinite(v), k
        assert v >= -1e-12, (k, v)
    assert t["eta_ok"]


def test_bound_lr_condition():
    assert lr_limit(1.0, 5, 3) == pytest.approx(1 / (2 * np.sqrt(5) * 15))
    t = bound_terms(_bi(eta=0.1))
    assert not t["eta_ok"]


def test_heterogeneity_terms_scale_with_divergence():
    """eps0/eps1 terms grow with data heterogeneity — the paper's motivation
    for personalization under skewed Dirichlet splits."""
    lo = bound_terms(_bi())
    bi_hi = BoundInputs(**{**_bi().__dict__, "eps0_2": 5.0, "eps1_2": 5.0})
    hi = bound_terms(bi_hi)
    assert hi["eps0_divergence"] > lo["eps0_divergence"]
    assert hi["eps1_divergence"] > lo["eps1_divergence"]
    assert hi["total"] > lo["total"]


def test_more_local_steps_loosen_bound():
    """Larger kappa0*kappa1 (less frequent sync) increases the variance and
    divergence terms at fixed eta — Remark 3."""
    small = bound_terms(_bi(k0=2, k1=1))
    big = bound_terms(_bi(k0=8, k1=3))
    assert big["eps0_divergence"] > small["eps0_divergence"]
    assert big["eps1_divergence"] > small["eps1_divergence"]
