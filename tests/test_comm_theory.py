"""Remark-1 communication accounting + Theorem-1 bound calculator."""

import numpy as np
import pytest

from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.configs.registry import get_arch
from repro.core import (BoundInputs, bound_terms, comm_for_cnn, comm_for_lm,
                        lr_limit, uniform_weights)
from repro.core.comm import CommModel, comm_table_for_cnn, comm_table_for_lm


def test_cnn_comm_model_paper_inequality():
    """Remark 1 scrutinized: for the paper's OWN CNN (2.2M params, cut-layer
    activations 16384 floats/sample), the per-round activation traffic
    DOMINATES and Phi_PHSFL > Phi_HFL at the paper's kappa0=5, N=32 —
    the 'Z >> Z_0 + Z_c' claim holds for Z but the N*Z_c term does not
    vanish.  Recorded as a finding in EXPERIMENTS.md; the inequality DOES
    hold for the 100B-scale LMs (test_lm_comm_model)."""
    import dataclasses
    cm = comm_for_cnn(CNN_CFG, dataset_size=500)
    assert not cm.phsfl_wins(kappa0=5)
    # ...but the inequality flips in the regime the remark actually
    # describes: a much bigger model with the same cut activations.
    big = dataclasses.replace(cm, total_params=cm.total_params * 1000)
    assert big.phsfl_wins(kappa0=5)


@pytest.mark.parametrize("k0", list(range(1, 21)))
def test_comm_monotone_in_kappa0(k0):
    cm = comm_for_cnn(CNN_CFG, dataset_size=500)
    assert cm.phi_phsfl_bits(k0 + 1) > cm.phi_phsfl_bits(k0)


# ----------------------------------------------- degenerate inputs ---------
@pytest.mark.parametrize("ds", [0, 1, 2])
def test_index_bits_at_tiny_dataset(ds):
    """A one-sample (or empty) fine-tuning set must not blow up the
    ceil(log2 |D_u|) index accounting: the size clamps to 2, so every
    sampled index costs exactly 1+1 bits."""
    cm = CommModel(batch_size=16, dataset_size=ds)
    assert cm.phi_indices_bits() == 16 * 2
    assert cm.phi_local_bits() >= 0
    big = CommModel(batch_size=16, dataset_size=1 << 20)
    assert big.phi_indices_bits() == 16 * 21
    assert big.phi_indices_bits() > cm.phi_indices_bits()


def test_comm_table_empty_cuts():
    """CNN tables treat an empty cuts tuple as 'all candidates' (there is a
    canonical list); the LM has none, so empty cuts is an error, not a
    silently empty table the cut controller would choke on."""
    from repro.models.cnn import CUT_CANDIDATES

    table = comm_table_for_cnn(CNN_CFG, dataset_size=400, cuts=())
    assert tuple(table) == CUT_CANDIDATES
    cfg = get_arch("xlstm-350m").reduced()
    with pytest.raises(ValueError, match="cuts"):
        comm_table_for_lm(cfg, seq_len=64, dataset_size=100, cuts=())


def test_encdec_rejects_cut_depth_candidates():
    """The encoder-decoder split is the modality frontend, not a depth
    prefix: a cut-depth table would price identical (Z_0, Z_c) cells and
    the cut controller would 'adapt' over indistinguishable candidates —
    fail loudly instead."""
    cfg = get_arch("seamless-m4t-medium").reduced()
    with pytest.raises(ValueError, match="frontend"):
        comm_for_lm(cfg, seq_len=32, dataset_size=100,
                    cut=cfg.n_client_layers + 1)
    with pytest.raises(ValueError, match="frontend"):
        comm_table_for_lm(cfg, seq_len=32, dataset_size=100, cuts=(1, 2))
    # the config's own depth is fine (the frontend split is the one cell)
    cm = comm_for_lm(cfg, seq_len=32, dataset_size=100,
                     cut=cfg.n_client_layers)
    assert cm.client_params > 0


@pytest.mark.parametrize("seed", range(8))
def test_phi_phsfl_monotone_in_kappa0_property(seed):
    """Property (seeded-parametrize style, no hypothesis dep): for ANY comm
    model — random geometry, random codecs included — one more local epoch
    strictly adds bits, because every epoch ships at least the minibatch
    indices."""
    from repro.compress import get_codec

    rng = np.random.default_rng(seed)
    pick = lambda: get_codec(
        str(rng.choice(["fp32", "int8", "int4", "topk", "fp8"])))
    cm = CommModel(omega=int(rng.integers(8, 33)),
                   batch_size=int(rng.integers(1, 64)),
                   batches_per_epoch=int(rng.integers(1, 8)),
                   cut_size=int(rng.integers(0, 20_000)),
                   client_params=int(rng.integers(0, 3_000_000)),
                   total_params=int(rng.integers(1, 5_000_000)),
                   dataset_size=int(rng.integers(0, 10_000)),
                   act_codec=pick(), grad_codec=pick(), off_codec=pick())
    for k0 in (1, 2, 5, 13):
        assert cm.phi_phsfl_bits(k0 + 1) > cm.phi_phsfl_bits(k0)


def test_lm_comm_model():
    cfg = get_arch("mistral-large-123b")
    cm = comm_for_lm(cfg, seq_len=4096, dataset_size=10_000)
    # for LMs with a 2-block client side, shipping activations is cheaper
    # than shipping the full 123B model
    assert cm.phi_hfl_bits() > cm.phi_phsfl_bits(kappa0=5)
    assert cm.client_params < cm.total_params * 0.2


def _bi(eta=1e-3, beta=1.0, k0=5, k1=3):
    au, ab = uniform_weights(4, 25)
    return BoundInputs(eta=eta, beta=beta, sigma2=1.0, eps0_2=0.5, eps1_2=0.5,
                       kappa0=k0, kappa1=k1, T=1500, f0_minus_fT=2.0,
                       alpha_u=au, alpha_b=ab)


def test_bound_terms_positive_and_finite():
    t = bound_terms(_bi())
    for k, v in t.items():
        if k == "eta_ok":
            continue
        assert np.isfinite(v), k
        assert v >= -1e-12, (k, v)
    assert t["eta_ok"]


def test_bound_lr_condition():
    assert lr_limit(1.0, 5, 3) == pytest.approx(1 / (2 * np.sqrt(5) * 15))
    t = bound_terms(_bi(eta=0.1))
    assert not t["eta_ok"]


def test_heterogeneity_terms_scale_with_divergence():
    """eps0/eps1 terms grow with data heterogeneity — the paper's motivation
    for personalization under skewed Dirichlet splits."""
    lo = bound_terms(_bi())
    bi_hi = BoundInputs(**{**_bi().__dict__, "eps0_2": 5.0, "eps1_2": 5.0})
    hi = bound_terms(bi_hi)
    assert hi["eps0_divergence"] > lo["eps0_divergence"]
    assert hi["eps1_divergence"] > lo["eps1_divergence"]
    assert hi["total"] > lo["total"]


def test_more_local_steps_loosen_bound():
    """Larger kappa0*kappa1 (less frequent sync) increases the variance and
    divergence terms at fixed eta — Remark 3."""
    small = bound_terms(_bi(k0=2, k1=1))
    big = bound_terms(_bi(k0=8, k1=3))
    assert big["eps0_divergence"] > small["eps0_divergence"]
    assert big["eps1_divergence"] > small["eps1_divergence"]
