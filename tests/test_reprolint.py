"""reprolint: each checker layer catches a seeded violation; suppressions
and the JSON report work; the real tree is clean.

Layer 1 (AST) and layer 2 (Pallas contracts) are driven by known-bad
fixture snippets written to tmp_path; layer 3 (the eval_shape accounting
audit) is driven by tampering with the formula side of the cross-check
(monkeypatched ``cut_activation_size``, a codec whose ``payload_bits``
disagrees with its declared fields) and asserting the auditor notices.
"""

import json
import textwrap
from pathlib import Path

import pytest

from tools.reprolint import astchecks, engine
from tools.reprolint import pallas_contracts as pc


def _findings(snippet: str):
    return astchecks.check_source(textwrap.dedent(snippet), "fixture.py")


def _rules(snippet: str):
    return {f.rule for f in _findings(snippet)}


# ---------------------------------------------------------------- layer 1
class TestAstCheckers:
    def test_prng_reuse_caught(self):
        assert "prng-reuse" in _rules("""
            import jax
            def f(key):
                a = jax.random.normal(key, (3,))
                b = jax.random.uniform(key, (3,))
                return a + b
        """)

    def test_prng_reuse_in_loop_caught(self):
        # the key is consumed once per iteration without a re-derivation:
        # invisible to a single linear pass, caught by the walk-twice pass
        assert "prng-reuse" in _rules("""
            import jax
            def f():
                k = jax.random.PRNGKey(0)
                for i in range(3):
                    x = jax.random.normal(k, (2,))
                return x
        """)

    def test_split_clears_consumption(self):
        assert not _rules("""
            import jax
            def f(key):
                a = jax.random.normal(key, (3,))
                key, sub = jax.random.split(key)
                b = jax.random.uniform(key, (3,))
                return a + b
        """)

    def test_fold_in_loop_is_clean(self):
        assert not _rules("""
            import jax
            def f(key):
                out = []
                for i in range(3):
                    out.append(jax.random.normal(
                        jax.random.fold_in(key, i), (2,)))
                return out
        """)

    def test_lossy_codec_none_key_caught(self):
        assert "lossy-codec-no-key" in _rules("""
            def f(codec, x):
                return codec.apply(None, x)
        """)
        assert "lossy-codec-no-key" in _rules("""
            from repro.kernels.quantize.ops import quantize_dequantize
            def f(x):
                return quantize_dequantize(x, None, bits=8)
        """)

    def test_host_np_in_jit_caught(self):
        assert "host-np-in-jit" in _rules("""
            import jax, numpy as np
            @jax.jit
            def f(x):
                return np.sum(x)
        """)

    def test_host_np_in_pallas_body_caught(self):
        assert "host-np-in-jit" in _rules("""
            import numpy as np
            from jax.experimental import pallas as pl
            def _body(x_ref, o_ref):
                o_ref[...] = np.tanh(x_ref[...])
            def run(x):
                return pl.pallas_call(_body, out_shape=x)(x)
        """)

    def test_host_np_outside_jit_ok(self):
        assert not _rules("""
            import numpy as np
            def f(x):
                return np.sum(x)
        """)

    def test_nonfrozen_static_caught(self):
        assert "nonfrozen-static" in _rules("""
            import jax
            from dataclasses import dataclass
            from functools import partial
            @dataclass
            class Cfg:
                a: int = 1
            @partial(jax.jit, static_argnames=("cfg",))
            def step(x, cfg: Cfg):
                return x
        """)

    def test_frozen_static_ok(self):
        assert not _rules("""
            import jax
            from dataclasses import dataclass
            from functools import partial
            @dataclass(frozen=True)
            class Cfg:
                a: int = 1
            @partial(jax.jit, static_argnames=("cfg",))
            def step(x, cfg: Cfg):
                return x
        """)

    def test_mutable_default_caught(self):
        assert "mutable-default" in _rules("""
            def f(x, acc=[]):
                return acc
        """)

    def test_float64_literal_caught(self):
        assert "float64-literal" in _rules("""
            import jax.numpy as jnp
            def f(x):
                return x.astype(jnp.float64)
        """)

    def test_host_np_float64_not_flagged(self):
        # np.float64 on the host (scheduler masks, fedsim weights) is fine
        assert not _rules("""
            import numpy as np
            def f(x):
                return np.asarray(x, np.float64)
        """)

    def test_fault_default_on_hazard_caught(self):
        # a default-on hazard (or a hazard with no default at all) forks
        # every fault-free golden the moment FaultConfig() is constructed
        assert "fault-free-default" in _rules("""
            from dataclasses import dataclass
            @dataclass(frozen=True)
            class FaultConfig:
                erasure_prob: float = 0.1
        """)
        assert "fault-free-default" in _rules("""
            class FaultConfig:
                es_outage_trace: tuple = ((0, 1),)
        """)
        assert "fault-free-default" in _rules("""
            class FaultConfig:
                crash_hazard: float
        """)

    def test_fault_free_defaults_clean(self):
        # zero/empty hazard defaults pass; non-hazard knobs are free
        assert not _rules("""
            from dataclasses import dataclass
            @dataclass(frozen=True)
            class FaultConfig:
                erasure_prob: float = 0.0
                max_retries: int = 2
                backoff_s: float = 0.0
                es_outage_trace: tuple = ()
                crash_hazard: float = 0.0
                failover: str = "reassoc"
        """)

    def test_telemetry_required_flagged(self):
        # a required telemetry handle makes observability load-bearing
        assert "telemetry-off-default" in _rules("""
            def make_scheduler(cfg, telemetry):
                return telemetry
        """)

    def test_telemetry_default_enabled_flagged(self):
        # defaulting to a LIVE handle would run the goldens instrumented
        assert "telemetry-off-default" in _rules("""
            from repro.telemetry import Telemetry
            def run(rounds, *, telemetry=Telemetry("/tmp/t")):
                return rounds
        """)

    def test_telemetry_off_defaults_clean(self):
        # None and the canonical disabled() handle are both the OFF state;
        # unrelated parameters are free
        assert not _rules("""
            from repro.telemetry import Telemetry
            def make_scheduler(cfg, telemetry=None):
                return cfg
            def run(rounds, *, telemetry=Telemetry.disabled()):
                return rounds
            def other(telemetry_dir="/tmp"):
                return telemetry_dir
        """)


# ----------------------------------------- client loops in vectorized code
class TestClientLoopInWireless:
    WIRELESS = "src/repro/wireless/population.py"

    def _rules(self, snippet, path=None):
        findings = astchecks.check_source(textwrap.dedent(snippet),
                                          path or self.WIRELESS)
        return [f.rule for f in findings]

    def test_range_over_client_axis_flagged(self):
        # the exact regression the struct-of-arrays refactor removed
        assert "client-loop-in-wireless" in self._rules("""
            def step(self):
                for u in range(self.U):
                    self.energy_left[u] -= 1.0
        """)

    def test_comprehension_over_cohort_flagged(self):
        assert "client-loop-in-wireless" in self._rules("""
            def masks(self, cohort):
                return [self.one_mask(c) for c in cohort]
        """)

    def test_enumerate_num_clients_flagged(self):
        assert "client-loop-in-wireless" in self._rules("""
            def scan(num_clients):
                for i, _ in enumerate(range(num_clients)):
                    pass
        """)

    def test_non_client_loops_clean(self):
        # ES loops, Lloyd iterations, and chunk tails are NOT client loops
        assert not self._rules("""
            def kmeans(self, coords, k, iters):
                for _ in range(int(iters)):
                    pass
                for b in range(k):
                    pass
                for es in range(self.num_es):
                    pass
                for i in range(1, n_chunks):
                    pass
                return [pool for pool in self._by_es]
        """)

    def test_other_modules_unconstrained(self):
        # the oracle scheduler and everything else may loop freely
        snippet = """
            def step(self):
                for u in range(self.U):
                    pass
        """
        assert not self._rules(snippet,
                               path="src/repro/wireless/scheduler.py")
        assert not self._rules(snippet, path="src/repro/core/fedsim.py")

    def test_real_vectorized_modules_stay_clean(self):
        import pathlib
        root = pathlib.Path(__file__).resolve().parents[1]
        for mod in ("population.py", "scheduler_core.py"):
            p = root / "src" / "repro" / "wireless" / mod
            src = p.read_text()
            assert not [f for f in astchecks.check_source(src, str(p))
                        if f.rule == "client-loop-in-wireless"], mod


# ----------------------------------------------------------- suppressions
class TestSuppressions:
    SNIPPET = textwrap.dedent("""
        import jax
        def f(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))  # reprolint: disable=prng-reuse
            return a + b
    """)

    def test_line_suppression(self):
        findings = astchecks.check_source(self.SNIPPET, "s.py")
        sup = engine.Suppressions.scan(self.SNIPPET)
        assert findings and all(sup.covers(f) for f in findings)

    def test_file_suppression(self):
        src = "# reprolint: disable-file=prng-reuse\n" + self.SNIPPET
        sup = engine.Suppressions.scan(src)
        assert all(sup.covers(f)
                   for f in astchecks.check_source(src, "s.py"))

    def test_unrelated_rule_not_covered(self):
        sup = engine.Suppressions.scan(self.SNIPPET)
        other = engine.Finding("mutable-default", "s.py", 5, "x")
        assert not sup.covers(other)

    def test_report_separates_suppressed(self):
        findings = astchecks.check_source(self.SNIPPET, "s.py")
        rep = engine.Report()
        rep.extend(findings, engine.Suppressions.scan(self.SNIPPET))
        assert rep.ok and rep.suppressed


# ---------------------------------------------------------------- layer 2
BAD_KERNEL = textwrap.dedent("""
    import jax
    from jax.experimental import pallas as pl
    BLOCK = 96
    def _body(x_ref, o_ref):
        o_ref[...] = x_ref[...]
    def run(x):
        return pl.pallas_call(
            _body,
            grid=(x.shape[0] // BLOCK,),
            in_specs=[pl.BlockSpec((BLOCK, 70000), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((BLOCK, 70000), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        )(x)
""")


class TestPallasContracts:
    def _mk(self, tmp_path: Path, kernel=BAD_KERNEL,
            ref="def run_ref(x, extra):\n    return x\n", ops="x = 1\n"):
        pkg = tmp_path / "kernels" / "badk"
        pkg.mkdir(parents=True)
        if kernel is not None:
            (pkg / "kernel.py").write_text(kernel)
        if ref is not None:
            (pkg / "ref.py").write_text(ref)
        if ops is not None:
            (pkg / "ops.py").write_text(ops)
        return tmp_path / "kernels"

    def _rules(self, root, tmp_path):
        out = set()
        for entry in pc.check_kernels_root(root, tmp_path):
            out |= {f.rule for f in entry["findings"]}
        return out

    def test_missing_triplet_member(self, tmp_path):
        root = self._mk(tmp_path, ops=None)
        assert self._rules(root, tmp_path) == {"pallas-triplet"}

    def test_bad_kernel_all_rules(self, tmp_path):
        rules = self._rules(self._mk(tmp_path), tmp_path)
        assert {"pallas-interpret", "pallas-lane", "pallas-divisibility",
                "pallas-vmem", "kernel-ref-signature"} <= rules

    def test_good_kernel_clean(self, tmp_path):
        good = textwrap.dedent("""
            import jax
            from jax.experimental import pallas as pl
            BLOCK = 256
            def _body(x_ref, o_ref):
                o_ref[...] = x_ref[...]
            def run(x, *, block=BLOCK, interpret=False):
                m, n = x.shape
                assert m % block == 0
                return pl.pallas_call(
                    _body,
                    grid=(m // block,),
                    in_specs=[pl.BlockSpec((block, 128), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((block, 128), lambda i: (i, 0)),
                    out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                    interpret=interpret,
                )(x)
        """)
        ref = "def run_ref(x):\n    return x\n"
        root = self._mk(tmp_path, kernel=good, ref=ref)
        assert not self._rules(root, tmp_path)

    def test_real_kernels_clean(self):
        repo = Path(__file__).resolve().parents[1]
        root = repo / "src" / "repro" / "kernels"
        entries = pc.check_kernels_root(root, repo)
        assert len(entries) >= 4        # quantize, flash, mlstm, rglru
        assert not [f for e in entries for f in e["findings"]]


# ---------------------------------------------------------------- layer 3
class TestShapeAudit:
    def test_real_tree_clean(self):
        from tools.reprolint import shape_audit
        assert shape_audit.audit_cnn() == []

    def test_tampered_formula_caught(self, monkeypatch):
        from repro.models import cnn
        from tools.reprolint import shape_audit
        real = cnn.cut_activation_size
        monkeypatch.setattr(cnn, "cut_activation_size",
                            lambda cfg, b, cut=None: real(cfg, b, cut) + 7)
        rules = {f.rule for f in shape_audit.audit_cnn()}
        assert "comm-cut-size" in rules

    def test_lying_codec_caught(self):
        from repro.compress.codecs import UniformQuantCodec
        from repro.core.comm import comm_for_cnn
        from repro.configs.phsfl_cnn import CONFIG
        from repro.compress import LinkCodecs
        from tools.reprolint import shape_audit

        class LyingCodec(UniformQuantCodec):
            def payload_bits(self, n_elements):
                return super().payload_bits(n_elements) - 1

        codecs = LinkCodecs(activations=LyingCodec())
        comm = comm_for_cnn(CONFIG, 1000, codecs=codecs)
        findings = shape_audit._check_bits(comm, codecs, "<fixture>")
        assert any(f.rule == "comm-bits" for f in findings)

    def test_lm_audit_clean_without_concrete_params(self):
        import jax
        from repro.configs.registry import ARCHS
        from tools.reprolint import shape_audit

        cfg = ARCHS["xlstm-350m"]
        with jax.checking_leaks():
            assert shape_audit.audit_lm(cfg, seq_len=32) == []

    def test_encdec_audits_default_cut_only(self):
        from repro.configs.registry import ARCHS
        from tools.reprolint import shape_audit

        cfg = ARCHS["seamless-m4t-medium"]
        assert shape_audit.lm_cut_candidates(cfg) == (None,)
        assert shape_audit.audit_lm(cfg, seq_len=32) == []


# ------------------------------------------------------------- CLI + JSON
class TestCli:
    def test_json_report_and_exit_code(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("def f(x, acc=[]):\n    return acc\n")
        out = tmp_path / "report.json"
        from tools.reprolint.__main__ import main
        rc = main([str(bad), "--json", str(out), "--no-shape-audit"])
        assert rc == 1
        rep = json.loads(out.read_text())
        assert rep["counts"] == {"mutable-default": 1}
        assert not rep["ok"] and rep["files_checked"] == 1
        assert rep["findings"][0]["rule"] == "mutable-default"

    def test_clean_file_exits_zero(self, tmp_path):
        good = tmp_path / "good.py"
        good.write_text("def f(x):\n    return x\n")
        from tools.reprolint.__main__ import main
        assert main([str(good), "--no-shape-audit"]) == 0

    def test_rule_catalog_matches_readme(self):
        repo = Path(__file__).resolve().parents[1]
        readme = (repo / "tools" / "reprolint" / "README.md").read_text()
        for rule_id in engine.RULES:
            assert f"`{rule_id}`" in readme, f"{rule_id} missing from README"
