"""Checkpoint roundtrip."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs.registry import get_arch
from repro.models import build_model


def test_roundtrip(tmp_path):
    cfg = get_arch("xlstm-350m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, params)
    save_checkpoint(d, 7, params)
    assert latest_step(d) == 7
    target = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    restored = load_checkpoint(d, 7, target)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(tmp_path):
    import pytest
    params = {"w": jnp.ones((3, 3))}
    d = str(tmp_path / "c")
    save_checkpoint(d, 0, params)
    with pytest.raises(ValueError):
        load_checkpoint(d, 0, {"w": jnp.ones((2, 2))})
    with pytest.raises(KeyError):
        load_checkpoint(d, 0, {"w2": jnp.ones((3, 3))})
