"""Checkpoint roundtrip."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs.registry import get_arch
from repro.models import build_model


def test_roundtrip(tmp_path):
    cfg = get_arch("xlstm-350m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, params)
    save_checkpoint(d, 7, params)
    assert latest_step(d) == 7
    target = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    restored = load_checkpoint(d, 7, target)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(tmp_path):
    import pytest
    params = {"w": jnp.ones((3, 3))}
    d = str(tmp_path / "c")
    save_checkpoint(d, 0, params)
    with pytest.raises(ValueError):
        load_checkpoint(d, 0, {"w": jnp.ones((2, 2))})
    with pytest.raises(KeyError):
        load_checkpoint(d, 0, {"w2": jnp.ones((3, 3))})


def test_dtype_mismatch_raises_unless_cast(tmp_path):
    """A silent astype can truncate (fp32 -> int8); the load refuses dtype
    drift unless the caller opts in with cast=True."""
    import pytest
    d = str(tmp_path / "c")
    save_checkpoint(d, 0, {"w": jnp.full((2,), 1.5, jnp.float32)})
    with pytest.raises(ValueError, match="cast=True"):
        load_checkpoint(d, 0, {"w": jnp.zeros((2,), jnp.int8)})
    # host-side np target: jnp would silently flatten float64 to float32
    out = load_checkpoint(d, 0, {"w": np.zeros((2,), np.float64)},
                          cast=True)
    assert np.asarray(out["w"]).dtype == np.float64
    np.testing.assert_array_equal(np.asarray(out["w"]), [1.5, 1.5])


def test_crash_mid_save_leaves_no_torn_checkpoint(tmp_path):
    """Simulated crash: a stranded ``.tmp.npz`` sidecar (written but never
    os.replace'd) is invisible to latest_step — the previous complete
    checkpoint stays current — and the next save sweeps it away."""
    import os
    d = str(tmp_path / "c")
    save_checkpoint(d, 1, {"w": jnp.ones((2,))})
    # crash mid-save of step 2: the sidecar exists, the real file doesn't
    torn = os.path.join(d, "ckpt_00000002.npz.tmp.npz")
    np.savez(torn, w=np.zeros((2,)))
    assert latest_step(d) == 1
    restored = load_checkpoint(d, 1, {"w": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(restored["w"]), [1.0, 1.0])
    save_checkpoint(d, 2, {"w": jnp.full((2,), 2.0)})
    assert not os.path.exists(torn)             # swept on the next save
    assert latest_step(d) == 2


def test_rng_state_round_trip():
    """rng_state_array/restore_rng_state reproduce the stream exactly,
    including the cached-uint32 half-word state."""
    import pytest
    from repro.checkpoint import restore_rng_state, rng_state_array
    rng = np.random.default_rng(7)
    rng.standard_normal(13)
    rng.integers(0, 10)          # leaves a cached uint32 in the generator
    arr = rng_state_array(rng)
    assert arr.shape == (6,) and arr.dtype == np.uint64
    want = rng.standard_normal(8)
    other = np.random.default_rng(0)
    restore_rng_state(other, arr)
    np.testing.assert_array_equal(other.standard_normal(8), want)
    with pytest.raises(ValueError):
        restore_rng_state(other, np.zeros(4, np.uint64))
    with pytest.raises(TypeError):
        rng_state_array(np.random.Generator(np.random.MT19937(0)))
