"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_hmajor
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_pallas
from repro.kernels.mlstm_chunk.ref import mlstm_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref


# ------------------------------------------------------- flash attention ---
@pytest.mark.parametrize("b,h,kvh,s,d", [
    (2, 4, 2, 256, 64),
    (1, 4, 4, 512, 32),
    (1, 2, 1, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(b, h, kvh, s, d, dtype, causal, window, rng):
    q = jnp.asarray(rng.normal(size=(b, h, s, d))).astype(dtype)
    k = jnp.asarray(rng.normal(size=(b, kvh, s, d))).astype(dtype)
    v = jnp.asarray(rng.normal(size=(b, kvh, s, d))).astype(dtype)
    out = flash_attention_hmajor(q, k, v, causal=causal, window=window,
                                 block_q=128, block_k=128)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_softcap(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 32)).astype(np.float32))
    out = flash_attention_hmajor(q, k, v, causal=True, softcap=20.0,
                                 block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_model_layout_and_grad(rng):
    """ops wrapper: (B,S,H,d) layout + ref-backed VJP runs."""
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 32)).astype(np.float32))
    out = flash_attention(q, k, v, True, 0, 0.0)
    assert out.shape == q.shape
    g = jax.grad(lambda q_: flash_attention(q_, k, v, True, 0, 0.0).sum())(q)
    assert bool(jnp.isfinite(g).all())


# ------------------------------------------------------------ rglru --------
@pytest.mark.parametrize("b,s,w,bt,bw", [
    (2, 128, 64, 32, 64),
    (1, 256, 512, 64, 256),
    (3, 64, 128, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_kernel_sweep(b, s, w, bt, bw, dtype, rng):
    la = (-jnp.abs(jnp.asarray(rng.normal(size=(b, s, w)))) * 0.1).astype(dtype)
    bb = jnp.asarray(rng.normal(size=(b, s, w))).astype(dtype)
    h0 = jnp.asarray(rng.normal(size=(b, w))).astype(jnp.float32)
    out = rglru_scan_pallas(la, bb, h0, block_t=bt, block_w=bw)
    ref = rglru_scan_ref(la, bb, h0)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_rglru_ops_grad(rng):
    la = -jnp.abs(jnp.asarray(rng.normal(size=(1, 32, 16)).astype(np.float32)))
    bb = jnp.asarray(rng.normal(size=(1, 32, 16)).astype(np.float32))
    h0 = jnp.zeros((1, 16), jnp.float32)
    g = jax.grad(lambda b_: rglru_scan(la, b_, h0).sum())(bb)
    assert bool(jnp.isfinite(g).all())


# ------------------------------------------------------------ mlstm --------
@pytest.mark.parametrize("b,h,s,dh,ck", [
    (2, 2, 128, 32, 32),
    (1, 4, 256, 64, 64),
    (1, 1, 64, 16, 16),
])
def test_mlstm_kernel_sweep(b, h, s, dh, ck, rng):
    q = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32)) / np.sqrt(dh)
    v = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    li = jnp.asarray(rng.normal(size=(b, h, s)).astype(np.float32))
    lf = jnp.log(jax.nn.sigmoid(
        jnp.asarray(rng.normal(size=(b, h, s)).astype(np.float32))))
    out = mlstm_chunk_pallas(q, k, v, li, lf, chunk=ck)
    ref = mlstm_ref(q, k, v, li, lf, chunk=ck)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)
