"""Pipelined streaming timeline, staleness-weighted async aggregation, and
the straggler-accounting bugfix sweep.

The timeline refactor's contracts, pinned here:

- the pipelined makespan closes to ``c + u + (n-1)*max(c, u) + tail`` (one
  hand-computed case, segment by segment) and is NEVER worse than the
  serial ``n*c + n*u + tail`` (elementwise, property-style), collapsing to
  it when compute is free or there is a single chunk;
- ``pipeline=False`` + ``staleness_lambda=0`` is the pre-PR scheduler
  (the golden-report regressions in tests/test_device.py pin the serial
  path bit-for-bit; here we pin that the async machinery is genuinely
  inert at lambda=0);
- the staleness bank's ledger: banked on straggle, delivered on idle
  rounds with age >= 1, superseded by fresh completions, replaced by newer
  straggles, energy-charged background pushes;
- the bugfix satellites: water-filled contention shares, top-k backfill
  after contention withdrawal, personalize() invariance to preceding
  training rounds.
"""

import numpy as np
import pytest

import jax

from repro.configs.base import HierarchyConfig, TrainConfig, WirelessConfig
from repro.configs.phsfl_cnn import CONFIG as CNN_CFG
from repro.core.fedsim import FedSim
from repro.data.synthetic import make_federated_image_data
from repro.models import cnn
from repro.wireless import (ChannelModel, ParticipationScheduler, RoundBits,
                            build_timeline, waterfill_shares)
from repro.wireless.channel import LinkState


def _link(up, down=4e6, latency=0.01, U=1):
    return LinkState(np.full(U, float(up)), np.full(U, float(down)),
                     np.full(U, float(latency)))


# ------------------------------------------------- pipelined makespan ------
def test_pipelined_makespan_hand_computed():
    """n=4 chunks, c=1s per chunk, u=2s per payload, 1s tail, 1s downlink:
    the streaming recurrence gives tx windows [1,3) [3,5) [5,7) [7,9), the
    tail [9,10), so the uplink finishes at c + u + 3*max(c,u) + tail = 10
    and the round clock reads 2*latency + 10 + t_down = 11.02."""
    bits = RoundBits(uplink=9_000_000, downlink=4_000_000,
                     up_stream=2_000_000, up_tail=1_000_000, chunks=4)
    link = _link(1e6)
    tl = build_timeline(link, bits, np.array([4.0]), np.inf, 1,
                        pipeline=True)
    np.testing.assert_allclose(tl.tx_start[0], [1.0, 3.0, 5.0, 7.0, 9.0])
    np.testing.assert_allclose(tl.tx_end[0], [3.0, 5.0, 7.0, 9.0, 10.0])
    np.testing.assert_allclose(tl.down_start[0], 10.0)
    np.testing.assert_allclose(tl.times_s[0], 0.02 + 10.0 + 1.0)
    serial = build_timeline(link, bits, np.array([4.0]), np.inf, 1)
    # serial: compute 4 + uplink 9 + downlink 1 (+ latency); pipelining
    # saves exactly (n-1) * min(c, u) = 3 * 1
    np.testing.assert_allclose(serial.times_s[0], 0.02 + 4.0 + 9.0 + 1.0)
    np.testing.assert_allclose(serial.times_s[0] - tl.times_s[0], 3.0)


def test_pipelined_deadline_caps_by_segment_overlap():
    """A deadline mid-stream charges each uplink segment its overlap with
    [0, T): at T=6 the windows [1,3) [3,5) [5,7) [7,9) [9,10) contribute
    2 + 2 + 1 + 0 + 0 = 5 s, and compute charges min(4, 6) = 4 s."""
    bits = RoundBits(uplink=9_000_000, downlink=4_000_000,
                     up_stream=2_000_000, up_tail=1_000_000, chunks=4)
    tl = build_timeline(_link(1e6), bits, np.array([4.0]), 6.0, 1,
                        pipeline=True)
    np.testing.assert_allclose(tl.tx_charged_s[0], 5.0)
    np.testing.assert_allclose(tl.compute_charged_s[0], 4.0)
    assert tl.can_tx[0]          # first chunk (1 s) computes inside 6 s


def test_pipelined_never_worse_than_serial():
    """Property sweep: for random rates, compute loads, and chunk counts,
    the pipelined completion is <= serial everywhere, and equals it when
    compute is free (c=0) or there is one chunk (nothing to overlap)."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        U = 8
        n = int(rng.integers(1, 9))
        stream = rng.uniform(1e5, 1e7)
        tail = rng.uniform(0, 1e6)
        bits = RoundBits(uplink=n * stream + tail, downlink=1e6,
                         up_stream=stream, up_tail=tail, chunks=n)
        link = LinkState(rng.uniform(1e5, 1e8, U), rng.uniform(1e6, 1e8, U),
                         np.full(U, 0.01))
        comp = rng.uniform(0, 10, U)
        piped = build_timeline(link, bits, comp, np.inf, U, pipeline=True)
        serial = build_timeline(link, bits, comp, np.inf, U)
        assert (piped.times_s <= serial.times_s + 1e-9).all()
        free = build_timeline(link, bits, np.zeros(U), np.inf, U,
                              pipeline=True)
        free_serial = build_timeline(link, bits, np.zeros(U), np.inf, U)
        np.testing.assert_allclose(free.times_s, free_serial.times_s,
                                   rtol=1e-12)
        if n == 1:
            np.testing.assert_allclose(piped.times_s, serial.times_s,
                                       rtol=1e-12)


# ----------------------------------------------- scheduler integration -----
def _sched(U=8, **kw):
    kw.setdefault("model", "static")
    kw.setdefault("mean_uplink_mbps", 20.0)
    kw.setdefault("mean_downlink_mbps", 80.0)
    kw.setdefault("heterogeneity", 1.0)
    cfg = WirelessConfig(**kw)
    bits = RoundBits(uplink=40_000_000, downlink=10_000_000,
                     up_stream=9_000_000, up_tail=4_000_000, chunks=4)
    # ~1.4 s of compute at 0.5 GFLOP/s: comparable to the ~2 s uplink, so
    # the pipelined overlap is worth ~(n-1) * min(c, u) ~ 1 s
    return ParticipationScheduler(cfg, ChannelModel(cfg, U), bits,
                                  flops=7e8)


def test_pipeline_lifts_participation_under_tight_deadline():
    """The PR's headline effect at scheduler scale: with non-trivial
    compute, the same deadline admits strictly more pipelined clients."""
    kw = dict(compute_gflops=0.5, compute_power_w=0.2, deadline_s=3.0,
              seed=0)
    serial = _sched(**kw).step(0)
    piped = _sched(pipeline=True, **kw).step(0)
    assert (piped.times_s <= serial.times_s + 1e-9).all()
    assert piped.num_participants > serial.num_participants
    assert piped.round_time_s <= serial.round_time_s + 1e-9


def test_lambda_zero_keeps_async_machinery_inert():
    """staleness_lambda=0 must not even materialize the stale report
    arrays, and lambda>0 must not change WHO participates live (with an
    infinite energy budget the background pushes cost nothing gateable)."""
    kw = dict(deadline_s=2.2, selection="random", participation_prob=0.6,
              seed=1)
    s0, s1 = _sched(**kw), _sched(staleness_lambda=0.7, **kw)
    for r in range(10):
        r0, r1 = s0.step(r), s1.step(r)
        assert r0.stale_banked is None and r0.stale_delivered is None
        assert r1.stale_banked is not None
        np.testing.assert_array_equal(r0.mask, r1.mask)
        np.testing.assert_array_equal(r0.times_s, r1.times_s)
        assert r0.round_time_s == r1.round_time_s
        assert r1.bits_tx >= r0.bits_tx     # background pushes only ADD bits


def test_stale_bank_ledger():
    """Bank on straggle, deliver on an idle round with age >= 1, never
    deliver and bank in the same round, drain energy for the pushes."""
    s = _sched(deadline_s=2.2, selection="random", participation_prob=0.6,
               staleness_lambda=0.5, energy_budget_j=1e6, tx_power_w=0.5,
               seed=1)
    banked_ever = np.zeros(8, bool)
    delivered_any = False
    prev_energy = s.energy_left.copy()
    for r in range(30):
        rep = s.step(r)
        banked, deliv = rep.stale_banked, rep.stale_delivered > 0
        # a bank comes only from a scheduled straggler; a delivery only
        # from an idle (unscheduled) client — the sets cannot intersect
        assert (banked <= (rep.scheduled & (rep.mask == 0))).all()
        assert (deliv <= ~rep.scheduled).all()
        assert not (banked & deliv).any()
        assert (rep.stale_delivered[deliv] >= 1).all()
        # deliveries require an earlier banking of that client
        assert (deliv <= banked_ever).all()
        banked_ever |= banked
        delivered_any |= deliv.any()
        assert (s.energy_left <= prev_energy + 1e-12).all()
        prev_energy = s.energy_left.copy()
    assert banked_ever.any() and delivered_any


# --------------------------------------------------- FedSim async fold -----
@pytest.fixture(scope="module")
def small_fed():
    return make_federated_image_data(4, alpha=0.5, train_per_class=20,
                                     test_per_class=10, seed=0)


def _fedsim(fed, wireless=None, seed=0, rounds=2):
    h = HierarchyConfig(num_edge_servers=2, clients_per_es=2, kappa0=1,
                        kappa1=2, global_rounds=rounds)
    t = TrainConfig(learning_rate=0.05, batch_size=8, freeze_head=True)
    return FedSim(CNN_CFG, fed, h, t, batches_per_epoch=1, seed=seed,
                  wireless=wireless)


def _async_wireless(lam):
    # deadline tuned so the slowest of the 4 heterogeneous clients
    # straggles whenever scheduled; random thinning gives it idle rounds
    # to background-push the banked remainder
    return WirelessConfig(model="static", mean_uplink_mbps=8.0,
                          mean_downlink_mbps=32.0, latency_s=0.01,
                          heterogeneity=1.0, deadline_s=6.0,
                          selection="random", participation_prob=0.6,
                          staleness_lambda=lam, seed=3)


def test_fedsim_stale_fold_changes_aggregation(small_fed):
    """With lambda > 0 a delivered bank joins the edge average (weight
    alpha_u * lambda**s), so the trajectory must diverge from the
    hard-dropout run FROM THE FIRST DELIVERY — while the live
    participation stays identical (same channel, same thinning draws)."""
    sim0 = _fedsim(small_fed, _async_wireless(0.0), rounds=3)
    sim1 = _fedsim(small_fed, _async_wireless(0.5), rounds=3)
    r0, r1 = sim0.run(log_every=1), sim1.run(log_every=1)
    deliveries = sum(n.get("stale_delivered", 0) for n in r1.network)
    assert deliveries > 0, "scenario must exercise at least one delivery"
    assert sum(n.get("stale_banked", 0) for n in r1.network) > 0
    for n0, n1 in zip(r0.network, r1.network):
        assert n0["participants"] == n1["participants"]
    assert r0.history[-1]["test_loss"] != r1.history[-1]["test_loss"]


def test_fedsim_lambda_zero_logs_no_stale_fields(small_fed):
    res = _fedsim(small_fed, _async_wireless(0.0)).run()
    assert all("stale_banked" not in n for n in res.network)


# ------------------------------------------- personalize reproducibility ---
def test_personalize_invariant_to_preceding_rounds(small_fed):
    """Regression (bugfix): personalize() used to sample its fine-tuning
    minibatches from self.rng, ALREADY ADVANCED by run() — so the same
    global params personalized differently depending on how much training
    preceded the call.  With the dedicated seed+3 stream the heads depend
    only on (seed, params): bit-identical across different run lengths."""
    sim1, sim2 = _fedsim(small_fed), _fedsim(small_fed)
    sim1.run(rounds=1)
    sim2.run(rounds=2)                     # different rng advancement
    params = cnn.init(jax.random.PRNGKey(42), CNN_CFG)
    h1, e1 = sim1.personalize(params, steps=2)
    h2, e2 = sim2.personalize(params, steps=2)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), h1, h2)
    np.testing.assert_array_equal(e1["acc"], e2["acc"])


# --------------------------------------------------- water-filling ---------
def test_waterfill_no_caps_equals_one_shot_split():
    w = np.array([1.0, 2.0, 1.0])
    limits = np.full(3, 1e9)
    share = waterfill_shares(4.0, w, limits, np.zeros(3, int),
                             np.ones(3, bool))
    np.testing.assert_allclose(share, [1.0, 2.0, 1.0])


def test_waterfill_redistributes_capped_excess():
    """A member whose limit is below its proportional share caps there and
    the excess re-shares: no capacity strands while someone can use it."""
    w = np.ones(3)
    limits = np.array([0.5, 10.0, 10.0])
    share = waterfill_shares(6.0, w, limits, np.zeros(3, int),
                             np.ones(3, bool))
    # one-shot would give 2.0 each, stranding 1.5 behind member 0's cap;
    # water-filling re-shares it: 0.5 + 2.75 + 2.75 = 6.0 (full pipe)
    np.testing.assert_allclose(share, [0.5, 2.75, 2.75])
    assert share.sum() == pytest.approx(6.0)


def test_waterfill_rate_proportional_weights_match_legacy_min():
    """With weights == limits (the proportional contention profile) the
    share/limit ratio is uniform per group, so the water-filled result is
    exactly the legacy min(limit, one-shot share) — the reduction that
    keeps the contention path bit-compatible."""
    rng = np.random.default_rng(7)
    for _ in range(20):
        rates = rng.uniform(1.0, 100.0, 6)
        groups = rng.integers(0, 2, 6)
        active = rng.random(6) < 0.7
        cap = rng.uniform(5.0, 300.0)
        got = waterfill_shares(cap, rates, rates, groups, active)
        tot = np.array([rates[active & (groups == g)].sum() for g in groups])
        legacy = np.minimum(rates, cap * rates / np.maximum(tot, 1e-300))
        np.testing.assert_allclose(got[active], legacy[active], rtol=1e-12)


def test_waterfill_groups_are_independent():
    w = np.ones(4)
    limits = np.array([0.1, 10.0, 10.0, 10.0])
    groups = np.array([0, 0, 1, 1])
    share = waterfill_shares(2.0, w, limits, groups, np.ones(4, bool))
    np.testing.assert_allclose(share, [0.1, 1.9, 1.0, 1.0])


# ------------------------------------------------------ top-k backfill -----
def test_topk_backfill_refills_contention_withdrawal():
    """When the contended price forces a chosen client to withdraw, the
    freed top-k slot is backfilled by the next-fastest affordable client
    instead of silently running the round under k."""
    cfg = WirelessConfig(model="static", mean_uplink_mbps=20.0,
                         mean_downlink_mbps=80.0, heterogeneity=1.0,
                         selection="topk", topk=2, es_uplink_mbps=20.0,
                         tx_power_w=0.5, seed=0)
    bits = RoundBits(uplink=40_000_000, downlink=10_000_000)
    s = ParticipationScheduler(cfg, ChannelModel(cfg, 8), bits)
    # private vs contended airtime of the two fastest clients: under the
    # shared 20 Mbps pipe each pays more than on its private link; give
    # the FASTEST client a budget that covers its private charge but not
    # its contended one, so it must withdraw at contention time
    link = s.channel.sample(0)
    order = np.argsort(s.channel.round_time_s(link, bits))
    fastest, second, third = order[0], order[1], order[2]
    t_priv = bits.uplink / link.uplink_bps[fastest]
    both = np.zeros(8, bool)
    both[[fastest, second]] = True
    t_cont = bits.uplink / s.channel.contended_uplink(
        link, both, s.es_assign)[fastest]
    assert t_cont > t_priv
    # charges are tx_power_w * airtime; a budget strictly between the
    # private and the contended charge passes gate 1 but not contention
    budget = 0.5 * (cfg.tx_power_w * t_priv + cfg.tx_power_w * t_cont)
    s.energy_left = np.full(8, 1e9)
    s.energy_left[fastest] = budget
    rep = s.step(0)
    assert not rep.scheduled[fastest]          # withdrew at contended price
    assert rep.scheduled[second] and rep.scheduled[third]  # backfilled
    assert int(rep.scheduled.sum()) == 2                   # k held
    # the withdrawer never transmitted: its budget is untouched
    np.testing.assert_allclose(s.energy_left[fastest], budget)
