"""utils/tree.py: path rendering, masking, and round-trip identities."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.utils import tree as tu


@pytest.fixture()
def params():
    return {
        "embed": {"table": jnp.ones((8, 4))},
        "stage0": {"b0": {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}},
        "head": {"w": jnp.full((4, 2), 2.0)},
    }


class TestPaths:
    def test_tree_paths(self, params):
        paths = tu.tree_paths(params)
        assert "embed/table" in paths and "stage0/b0/w" in paths
        assert len(paths) == len(jax.tree.leaves(params))

    def test_map_with_path_preserves_structure(self, params):
        seen = []
        out = tu.map_with_path(lambda p, x: seen.append(p) or x * 2, params)
        assert sorted(seen) == sorted(tu.tree_paths(params))
        assert jax.tree.structure(out) == jax.tree.structure(params)
        np.testing.assert_array_equal(out["head"]["w"],
                                      params["head"]["w"] * 2)

    def test_mask_by_path(self, params):
        mask = tu.mask_by_path(params, [r"^embed(/|$)"])
        flat = dict(zip(tu.tree_paths(mask), jax.tree.leaves(mask)))
        assert flat["embed/table"] is True
        assert flat["head/w"] is False


class TestRoundTrips:
    def test_flatten_unflatten_identity(self, params):
        leaves, treedef = jax.tree.flatten(params)
        back = jax.tree.unflatten(treedef, leaves)
        assert jax.tree.structure(back) == jax.tree.structure(params)
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(params)):
            np.testing.assert_array_equal(a, b)

    def test_merge_select_round_trip(self, params):
        mask = tu.mask_by_path(params, [r"^stage0(/|$)"])
        zeros = tu.tree_zeros_like(params)
        merged = tu.merge_trees(mask, params, zeros)
        # merging the selected part back over zeros keeps exactly that part
        np.testing.assert_array_equal(merged["stage0"]["b0"]["w"],
                                      params["stage0"]["b0"]["w"])
        np.testing.assert_array_equal(merged["head"]["w"],
                                      np.zeros_like(params["head"]["w"]))
        # and merging twice is idempotent
        again = tu.merge_trees(mask, merged, zeros)
        for a, b in zip(jax.tree.leaves(again), jax.tree.leaves(merged)):
            np.testing.assert_array_equal(a, b)

    def test_add_scale_inverse(self, params):
        doubled = tu.tree_add(params, params)
        halved = tu.tree_scale(doubled, 0.5)
        assert float(tu.tree_l2_distance(halved, params)) == pytest.approx(
            0.0, abs=1e-6)

    def test_weighted_sum_matches_manual(self, params):
        other = tu.tree_scale(params, 3.0)
        ws = tu.tree_weighted_sum([params, other], [0.25, 0.75])
        expect = tu.tree_add(tu.tree_scale(params, 0.25),
                             tu.tree_scale(other, 0.75))
        assert float(tu.tree_l2_distance(ws, expect)) == pytest.approx(
            0.0, abs=1e-6)


class TestSizes:
    def test_tree_size_counts_elements(self, params):
        assert tu.tree_size(params) == 8 * 4 + 4 * 4 + 4 + 4 * 2

    def test_tree_bytes_counts_dtype_width(self, params):
        assert tu.tree_bytes(params) == 4 * tu.tree_size(params)

    def test_allfinite(self, params):
        assert bool(tu.tree_allfinite(params))
        bad = dict(params, head={"w": jnp.array([np.nan, 1.0])})
        assert not bool(tu.tree_allfinite(bad))


class TestAxesLeaves:
    def test_axes_leaf_detection(self):
        assert tu.axes_leaf(("embed", "mlp"))
        assert tu.axes_leaf((None, "mlp"))
        assert not tu.axes_leaf(("embed", 3))
        assert not tu.axes_leaf([1, 2])

    def test_map_with_path_over_axes_tree(self):
        axes = {"fc1": {"w": (None, "mlp"), "b": ("mlp",)}}
        paths = tu.tree_paths(axes, is_leaf=tu.axes_leaf)
        assert sorted(paths) == ["fc1/b", "fc1/w"]
