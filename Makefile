# Local mirrors of the CI gates (.github/workflows/ci.yml).
#   make lint   — tier 0: reprolint, the static contract gate (seconds)
#   make test   — tier 1: fast pytest suite (slow marker deselected)
#   make slow   — tier 2: the long end-to-end suite
#   make check  — tier 0 then tier 1, the pre-commit sequence

PY ?= python

.PHONY: lint test slow check

lint:
	$(PY) -m tools.reprolint src tests benchmarks examples

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

slow:
	PYTHONPATH=src $(PY) -m pytest -m slow

check: lint test
