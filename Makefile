# Local mirrors of the CI gates (.github/workflows/ci.yml).
#   make lint         — tier 0: reprolint, the static contract gate (seconds)
#   make test         — tier 1: fast pytest suite (slow marker deselected)
#   make slow         — tier 2: the long end-to-end suite
#   make check        — tier 0 then tier 1, the pre-commit sequence
#   make report       — combined markdown+CSV table over every BENCH_*.json
#   make resume-smoke — kill-and-resume bit-identity: a 2-round train run
#                       vs the same run aborted after round 1 and resumed;
#                       the final state checkpoints must be byte-identical
#   make trace-smoke  — telemetry end-to-end: a tiny fault-injected train
#                       run with --trace-dir, then a schema check over the
#                       emitted trace.json / metrics.jsonl / manifest.json

PY ?= python

.PHONY: lint test slow check report resume-smoke trace-smoke

lint:
	$(PY) -m tools.reprolint src tests benchmarks examples

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

slow:
	PYTHONPATH=src $(PY) -m pytest -m slow

check: lint test

report:
	$(PY) -m tools.bench_report --csv BENCH_report.csv

# tiny but REAL: static channel + erasures + crashes, so the resumed run
# must also replay the fault stream exactly to pass the bitwise diff
RESUME_ARGS = --rounds 2 --clients 2 --seq 32 --micro 1 --local-steps 1 \
	--channel static --erasure-prob 0.3 --crash-hazard 0.2 --ckpt-every 1

resume-smoke:
	rm -rf /tmp/resume_smoke && mkdir -p /tmp/resume_smoke
	PYTHONPATH=src $(PY) -m repro.launch.train $(RESUME_ARGS) \
		--ckpt-dir /tmp/resume_smoke/full
	PYTHONPATH=src $(PY) -m repro.launch.train $(RESUME_ARGS) \
		--ckpt-dir /tmp/resume_smoke/killed --abort-after 1
	PYTHONPATH=src $(PY) -m repro.launch.train $(RESUME_ARGS) \
		--ckpt-dir /tmp/resume_smoke/killed --resume
	$(PY) -m tools.ckpt_diff /tmp/resume_smoke/full/state \
		/tmp/resume_smoke/killed/state

trace-smoke:
	rm -rf /tmp/trace_smoke
	PYTHONPATH=src $(PY) -m repro.launch.train $(RESUME_ARGS) \
		--trace-dir /tmp/trace_smoke
	PYTHONPATH=src $(PY) tools/check_trace.py /tmp/trace_smoke
