"""Seeded, deterministic fault injection for the wireless simulator.

Three hazards, all drawn from ONE dedicated RNG stream (``cfg.seed +
FAULT_SEED_OFFSET``, disjoint from the channel's ``seed``, the scheduler's
``seed+1``, the device model's ``seed+2`` and personalization's ``seed+3``
streams, so switching faults on never perturbs fading, thinning, or device
heterogeneity draws):

- **Payload erasures + HARQ** (``erasure_prob``/``max_retries``/
  ``backoff_s``): every uplink payload segment and the downlink broadcast
  is erased i.i.d. with ``erasure_prob`` per attempt and retransmitted —
  after a ``backoff_s`` radio gap — up to ``max_retries`` times.  The
  attempt count per payload is truncated-geometric; a payload whose every
  attempt is erased is FAILED.  The retransmitted copies become real
  segments of the round's :class:`repro.wireless.timeline.RoundTimeline`,
  so their airtime/energy/bits are priced by the same deadline gate,
  energy charge, and moved-bits ledger as any first transmission.
- **ES outages** (``es_outage_trace``): a round-major 0/1 trace (cycled
  over rounds, resized over ESs) marks edge servers down for whole rounds.
  ``failover="reassoc"`` re-associates a dead ES's clients to the nearest
  live ES (by index distance, ties to the lower index), where they re-enter
  that ES's contention pass; ``failover="skip"`` sits them out.
- **Client crashes** (``crash_hazard``): each round every client draws a
  Bernoulli(``crash_hazard``) crash and a uniform crash INSTANT; a crashed
  client's timeline is truncated at that instant — partial compute and
  partial airtime are charged, partial uplink credits moved bits, exactly
  the PR-7 straggler rules applied at the crash time instead of the
  deadline.

Draw shapes are FIXED per round (every client, every payload slot, every
potential attempt), so the stream position after round ``r`` is a function
of ``r`` alone — never of who was scheduled — which is what makes
checkpoint/resume bit-identical (``ParticipationScheduler.state_dict``
captures the stream).

``FaultConfig()`` defaults encode zero faults; :attr:`FaultConfig.active`
is False and the scheduler never constructs an injector, keeping the
fault-free path bit-identical to the pre-fault scheduler (golden-pinned).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import FaultConfig

__all__ = ["FAULT_SEED_OFFSET", "FaultConfig", "FaultPlan", "FaultInjector",
           "expected_attempts"]

# RNG stream allocation (see module docstring): channel = seed, scheduler
# thinning = seed+1, device = seed+2, personalize = seed+3, faults = seed+4
FAULT_SEED_OFFSET = 4

FAILOVER_POLICIES = ("reassoc", "skip")


def expected_attempts(erasure_prob: float, max_retries: int) -> float:
    """Mean transmissions per payload under truncated-geometric HARQ.

    With per-attempt erasure probability ``p`` and at most ``n = 1 +
    max_retries`` attempts, the attempt count is ``min(Geometric(1-p), n)``
    and its mean is ``(1 - p**n) / (1 - p)`` (``n`` at ``p=1``).  The cut
    controller expands its airtime/energy estimates by this factor so
    adaptive policies price retransmissions before they happen.
    """
    p, n = float(erasure_prob), int(max_retries) + 1
    if p <= 0.0:
        return 1.0
    if p >= 1.0:
        return float(n)
    return (1.0 - p ** n) / (1.0 - p)


@dataclass
class FaultPlan:
    """One round's pre-drawn erasure/crash outcomes (fixed shapes).

    Drawn ONCE at the top of ``ParticipationScheduler.step`` and reused by
    every timeline rebuild of the round (contention re-prices the SAME
    erasure fates at different rates), so outcomes never depend on the
    contended rates.
    """
    up_attempts: np.ndarray    # (U, S) int >= 1: transmissions per payload
    up_ok: np.ndarray          # (U, S) bool: payload delivered by its last try
    down_attempts: np.ndarray  # (U,) int >= 1: downlink broadcast attempts
    down_ok: np.ndarray        # (U,) bool: downlink eventually delivered
    crash_frac: np.ndarray     # (U,) float: crash instant as a fraction of
    #                            the deadline (finite) or of the client's own
    #                            activity span (inf deadline); inf = no crash
    backoff_s: float           # radio gap before each retransmission


class FaultInjector:
    """Draws per-round fault plans and resolves ES outages/failover."""

    def __init__(self, cfg: FaultConfig, num_clients: int, n_up_seg: int,
                 num_es: int, seed: int):
        if not 0.0 <= cfg.erasure_prob <= 1.0:
            raise ValueError(f"erasure_prob must be in [0, 1], got "
                             f"{cfg.erasure_prob}")
        if not 0.0 <= cfg.crash_hazard <= 1.0:
            raise ValueError(f"crash_hazard must be in [0, 1], got "
                             f"{cfg.crash_hazard}")
        if cfg.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{cfg.max_retries}")
        if cfg.backoff_s < 0.0:
            raise ValueError(f"backoff_s must be >= 0, got {cfg.backoff_s}")
        if cfg.failover not in FAILOVER_POLICIES:
            raise ValueError(f"unknown failover policy {cfg.failover!r}; "
                             f"one of {FAILOVER_POLICIES}")
        self.cfg = cfg
        self.U = int(num_clients)
        self.S = int(n_up_seg)           # uplink payload slots per client
        self.B = int(num_es)
        self._rng = np.random.default_rng(seed + FAULT_SEED_OFFSET)

    @property
    def needs_plan(self) -> bool:
        """True when per-round timeline faults (erasures/crashes) exist;
        outage-only configs keep the exact fault-free timeline builders."""
        return self.cfg.erasure_prob > 0.0 or self.cfg.crash_hazard > 0.0

    # ------------------------------------------------------------ drawing --
    def round_plan(self) -> FaultPlan | None:
        """Draw one round's erasure fates and crash instants.

        Consumes a FIXED number of draws — (U, S, R+1) uplink uniforms,
        (U, R+1) downlink uniforms, U crash Bernoullis, U crash fractions —
        regardless of scheduling, so the stream position is a pure function
        of the round count (resume-safe).  Returns None when neither
        erasures nor crashes are configured (the rng is not consumed and
        the timeline stays on the exact fault-free builders).
        """
        if not self.needs_plan:
            return None
        cfg, U, S = self.cfg, self.U, self.S
        tries = cfg.max_retries + 1
        up_u = self._rng.random((U, S, tries))
        down_u = self._rng.random((U, tries))
        crash_b = self._rng.random(U)
        crash_f = self._rng.random(U)
        up_att, up_ok = self._attempts(up_u, cfg.erasure_prob)
        down_att, down_ok = self._attempts(down_u[:, None, :],
                                           cfg.erasure_prob)
        crashed = (crash_b < cfg.crash_hazard) if cfg.crash_hazard > 0 \
            else np.zeros(U, bool)
        crash_frac = np.where(crashed, crash_f, np.inf)
        return FaultPlan(up_attempts=up_att, up_ok=up_ok,
                         down_attempts=down_att[:, 0],
                         down_ok=down_ok[:, 0], crash_frac=crash_frac,
                         backoff_s=float(cfg.backoff_s))

    @staticmethod
    def _attempts(uniforms: np.ndarray, p: float):
        """Truncated-geometric attempt counts from per-attempt uniforms.

        Attempt ``j`` is erased iff ``uniforms[..., j] < p``; the payload
        lands on its first non-erased attempt and gives up after the last
        column.  Returns (attempts, ok) dropping the attempt axis.
        """
        erased = uniforms < p
        success = ~erased
        any_ok = success.any(axis=-1)
        first = np.argmax(success, axis=-1)          # 0 when none succeed
        tries = uniforms.shape[-1]
        attempts = np.where(any_ok, first + 1, tries)
        return attempts.astype(int), any_ok

    # ------------------------------------------------------------ outages --
    def es_down(self, round_idx: int) -> np.ndarray | None:
        """(B,) bool outage mask for this round, from the cycled trace.

        Rows cycle modulo the trace length and resize over the B edge
        servers (the same shape rules as the channel's rate traces); no
        trace -> None (no outage machinery at all).
        """
        trace = self.cfg.es_outage_trace
        if not trace:
            return None
        row = np.asarray(trace[round_idx % len(trace)], float)
        return np.resize(row, self.B) > 0.5

    def failover(self, down_b: np.ndarray, es_assign: np.ndarray):
        """Resolve an outage round: (effective es map, skip mask).

        ``reassoc``: each dead ES's clients re-associate to the nearest
        LIVE ES by index distance (ties to the lower index) and re-enter
        that ES's contention; with every ES down nobody can re-associate
        and the whole round is skipped.  ``skip``: a dead ES's clients sit
        the round out (their banked stale pushes pause too — the scheduler
        gates background pushes on a live effective ES).
        """
        es_assign = np.asarray(es_assign, int)
        client_down = down_b[es_assign]
        if not client_down.any():
            return es_assign, np.zeros(len(es_assign), bool)
        live = np.flatnonzero(~down_b)
        if self.cfg.failover == "skip" or len(live) == 0:
            return es_assign, client_down
        # nearest live ES per dead ES; argmin ties break to the lower index
        remap = np.arange(self.B)
        for b in np.flatnonzero(down_b):
            remap[b] = live[np.argmin(np.abs(live - b))]
        return remap[es_assign], np.zeros(len(es_assign), bool)
