"""Per-client wireless channel model (rates, latency, time, energy).

The channel turns the byte accounting of :mod:`repro.core.comm` (Remark 1:
cut-layer activations up, cut-layer gradients down, client-block offloads at
the round boundary) into per-client, per-edge-round transmission TIMES and
ENERGIES.  Three rate processes are supported:

- ``static``:   rate_u(t) = mean * scale_u — a fixed, possibly heterogeneous
                rate per client (``heterogeneity`` is the lognormal sigma of
                scale_u, drawn once at construction);
- ``rayleigh``: rate_u(t) = mean * scale_u * E_t where E_t ~ Exp(1) i.i.d.
                per round — Rayleigh-amplitude fading makes the received
                POWER exponential, and we model the achievable rate as
                proportional to it (interference-limited linear regime);
- ``trace``:    rate_u(t) read from ``WirelessConfig.trace`` (round-major,
                cycled), for replaying measured traces.  The downlink comes
                from ``WirelessConfig.trace_down`` (same shape rules) when
                recorded; without one it FALLS BACK to the uplink trace
                rescaled by the configured mean downlink/uplink ratio;
- ``ideal``:    infinite rates, zero latency — the pre-wireless simulator.

All rates are in Mbps in the config and bits/s internally.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import WirelessConfig
from repro.core.comm import CommModel


@dataclass
class LinkState:
    """Per-client link quality for one edge round (all arrays shape (U,))."""
    uplink_bps: np.ndarray
    downlink_bps: np.ndarray
    latency_s: np.ndarray


@dataclass(frozen=True)
class RoundBits:
    """Bits each client moves in one edge round (split-learning dataflow).

    Scalar for a shared fixed cut, or per-client ``(U,)`` arrays when a
    :class:`repro.wireless.cutter.CutController` picks per-client cuts.

    The optional STREAM decomposition carries the minibatch granularity the
    pipelined timeline needs: the uplink is ``chunks`` equal per-minibatch
    payloads of ``up_stream`` bits (activations + indices), each eligible to
    transmit as soon as its minibatch's compute finishes, plus one
    ``up_tail`` payload (the client-block offload Phi_off) that only ships
    after the last minibatch.  ``chunks * up_stream + up_tail == uplink``
    whenever the decomposition is present; legacy two-field construction
    (``up_stream=None``) degenerates to one monolithic chunk, under which
    the pipelined timeline equals the serial one exactly."""
    uplink: int | np.ndarray
    downlink: int | np.ndarray
    up_stream: int | np.ndarray | None = None   # bits per minibatch payload
    up_tail: int | np.ndarray = 0               # offload bits, after chunks
    chunks: int = 1                             # kappa0 * batches_per_epoch


def client_round_bits(comm: CommModel, kappa0: int) -> RoundBits:
    """Per-edge-round traffic of ONE client under the paper's Eq. 17 terms.

    Uplink:   kappa0 local epochs of (activations o_fp + minibatch indices)
              per minibatch, plus one client-block offload (Phi_off).
    Downlink: the matching cut-layer gradients o_bp, plus the refreshed
              client block broadcast at the aggregation boundary.

    Each payload travels through the CommModel's configured codec
    (repro.compress) — with no codecs this is the original (omega+1)-bit
    accounting exactly.  The uplink's minibatch decomposition is recorded
    (``up_stream``/``up_tail``/``chunks``) so the pipelined timeline can
    stream each minibatch payload as soon as its compute finishes.
    """
    per_batch_up = comm.phi_activation_up_bits() + comm.phi_indices_bits()
    per_batch_down = comm.phi_grad_down_bits()
    nb = comm.batches_per_epoch
    return RoundBits(
        uplink=kappa0 * nb * per_batch_up + comm.phi_off_bits(),
        downlink=kappa0 * nb * per_batch_down + comm.phi_off_bits(),
        up_stream=per_batch_up, up_tail=comm.phi_off_bits(),
        chunks=kappa0 * nb,
    )


class ChannelModel:
    """Samples per-round link states and converts bits to time/energy."""

    def __init__(self, cfg: WirelessConfig, num_clients: int):
        if cfg.model not in ("ideal", "static", "rayleigh", "trace"):
            raise ValueError(f"unknown channel model {cfg.model!r}")
        if cfg.model == "trace" and not cfg.trace:
            raise ValueError("trace channel requires WirelessConfig.trace")
        if (cfg.model == "trace" and cfg.trace_down
                and len(cfg.trace_down) != len(cfg.trace)):
            # both traces cycle modulo their own length; unequal lengths
            # would silently desynchronize the measured (up, down) pairs
            raise ValueError(
                f"trace_down has {len(cfg.trace_down)} rounds but trace has "
                f"{len(cfg.trace)}; a measured pair must align round-for-"
                f"round (both cycle together)")
        if cfg.contention not in ("equal", "proportional"):
            raise ValueError(f"unknown contention rule {cfg.contention!r}; "
                             f"one of ('equal', 'proportional')")
        self.cfg = cfg
        self.U = num_clients
        self._rng = np.random.default_rng(cfg.seed)
        # fixed per-client heterogeneity scale (lognormal, mean-1 median)
        if cfg.heterogeneity > 0:
            self._scale = self._rng.lognormal(
                mean=0.0, sigma=cfg.heterogeneity, size=num_clients)
        else:
            self._scale = np.ones(num_clients)

    # ----------------------------------------------------------- sampling --
    def fades(self, round_idx: int):
        """This round's fading entropy: ``(fade, down_row)``.

        The ONLY per-round stochastic draw of the channel, factored out so
        the vectorized cohort path (``repro.wireless.scheduler_core``) can
        consume the same stream and rebuild the same rates in-trace:
        ``fade`` is ones (static), Exp(1) draws (rayleigh), or the resized
        trace row rescaled to a fade factor; ``down_row`` is the resized
        measured downlink trace row (None without one).  ``sample`` is
        defined in terms of this method, so both paths advance ``_rng``
        identically.  Returns ``(None, None)`` for the ideal model."""
        cfg, U = self.cfg, self.U
        if cfg.model == "ideal":
            return None, None
        if cfg.model == "static":
            fade = np.ones(U)
        elif cfg.model == "rayleigh":
            fade = self._rng.exponential(1.0, size=U)
        else:  # trace
            row = np.asarray(cfg.trace[round_idx % len(cfg.trace)], float)
            up_mean = cfg.mean_uplink_mbps * 1e6
            fade = np.resize(row, U) * 1e6 / up_mean  # trace IS the uplink
        down_row = None
        if cfg.model == "trace" and cfg.trace_down:
            drow = np.asarray(
                cfg.trace_down[round_idx % len(cfg.trace_down)], float)
            down_row = np.resize(drow, U)
        return fade, down_row

    def sample(self, round_idx: int) -> LinkState:
        cfg, U = self.cfg, self.U
        up_mean = cfg.mean_uplink_mbps * 1e6
        down_mean = cfg.mean_downlink_mbps * 1e6
        if cfg.model == "ideal":
            inf = np.full(U, np.inf)
            return LinkState(inf, inf, np.zeros(U))
        fade, down_row = self.fades(round_idx)
        up = np.maximum(up_mean * self._scale * fade, 1.0)
        down = np.maximum(down_mean * self._scale * fade, 1.0)
        if down_row is not None:
            # a measured downlink trace (round-major, cycled, resized — the
            # same shape rules as ``trace``) is honored as-is.  Without one,
            # the ``down`` above is the documented FALLBACK: the uplink
            # trace rescaled by the configured mean downlink/uplink ratio —
            # fabricated fading perfectly correlated with the uplink; record
            # a trace_down pair whenever up/down asymmetry matters.
            down = np.maximum(down_row * 1e6 * self._scale, 1.0)
        return LinkState(up, down, np.full(U, cfg.latency_s))

    # -------------------------------------------------------- contention --
    def contended_uplink(self, link: LinkState, active: np.ndarray,
                         es_assign: np.ndarray) -> np.ndarray:
        """Effective uplink rates when each ES's uplink is a SHARED pipe.

        The ``active`` (scheduled) clients of one ES split its capacity
        ``es_uplink_mbps``; each client gets the smaller of its own link
        rate and its share, so the per-ES aggregate never exceeds the ES
        capacity.  ``WirelessConfig.contention`` picks the sharing rule:
        ``"equal"`` gives every active client the same share,
        ``"proportional"`` weights shares by the clients' PRIVATE rates and
        WATER-FILLS (:func:`waterfill_shares`): a client whose private link
        saturates below its proportional share is capped at its link rate
        and the excess re-shares among its capacity-hungry peers, so a
        finite pipe is never stranded behind a slow client's cap.  (With
        private-rate weights the share/limit ratio ``cap / sum(rates)`` is
        the same for every active client of an ES, so all of them cap
        together or none do and the water-filling reduces to the one-shot
        proportional split — the redistribution only bites for weight
        profiles that differ from the limits, but the invariant "per-ES
        aggregate <= cap, no strandable excess" now holds for any of them.)
        Inactive clients keep their private rate (they do not transmit, so
        they occupy no share).  An ideal channel or an infinite ES capacity
        bypasses contention entirely.
        """
        cap = self.cfg.es_uplink_mbps * 1e6
        if self.cfg.model == "ideal" or not np.isfinite(cap):
            return link.uplink_bps
        active = np.asarray(active, bool)
        es = np.asarray(es_assign, int)
        if self.cfg.contention == "proportional":
            share = waterfill_shares(cap, link.uplink_bps, link.uplink_bps,
                                     es, active)
        else:                                    # "equal"
            counts = np.bincount(es[active], minlength=es.max() + 1)
            share = cap / np.maximum(counts[es], 1)
        return np.where(active, np.minimum(link.uplink_bps, share),
                        link.uplink_bps)

    # ------------------------------------------------------ time / energy --
    def round_time_s(self, link: LinkState, bits: RoundBits) -> np.ndarray:
        """Per-client completion time of one edge round's traffic."""
        with np.errstate(divide="ignore"):
            t_up = bits.uplink / link.uplink_bps
            t_down = bits.downlink / link.downlink_bps
        return 2 * link.latency_s + t_up + t_down

    def round_energy_j(self, link: LinkState, bits: RoundBits) -> np.ndarray:
        """Per-client uplink transmit energy (P_tx * airtime), UNCAPPED.

        This is the full-transmission estimate; the scheduler's
        authoritative charge is its deadline-capped timeline charge (which
        also adds compute joules) — see the scheduler docstring's timeline
        straggler semantics."""
        with np.errstate(divide="ignore"):
            t_up = bits.uplink / link.uplink_bps
        return self.cfg.tx_power_w * np.where(np.isfinite(t_up), t_up, 0.0)


def waterfill_shares(cap: float, weights: np.ndarray, limits: np.ndarray,
                     groups: np.ndarray, active: np.ndarray) -> np.ndarray:
    """Weighted proportional shares of ``cap`` per group, water-filled.

    Each group's capacity ``cap`` is split among its active members in
    proportion to ``weights``; a member whose ``limits`` (e.g. its private
    link rate) falls below its share is CAPPED there, and the capacity it
    cannot use re-shares among the remaining uncapped members by the same
    weights — repeated until no new member caps (at most one new cap per
    pass, so at most U passes; in practice the loop exits after one or
    two).  Guarantees, per group: every active member's share <= its limit;
    the aggregate over active members <= cap; and the aggregate equals
    ``min(cap, sum of active limits)`` whenever weights are positive, i.e.
    no capacity is stranded while some member could still use more.  The
    first pass is exactly the one-shot ``cap * w / sum(w)`` split, so when
    nothing caps the result is bit-identical to it.

    Returns the (U,) share array; entries of inactive members are their
    (uncapped, unclaimed) one-shot shares and should be ignored.
    """
    weights = np.asarray(weights, float)
    limits = np.asarray(limits, float)
    groups = np.asarray(groups, int)
    active = np.asarray(active, bool)
    ngroups = groups.max() + 1 if groups.size else 1
    capped = np.zeros(weights.shape, bool)
    share = np.full(weights.shape, cap, float)
    for _ in range(weights.size):
        w_unc = np.where(active & ~capped, weights, 0.0)
        totals = np.bincount(groups, weights=w_unc, minlength=ngroups)
        used = np.bincount(groups,
                           weights=np.where(active & capped, limits, 0.0),
                           minlength=ngroups)
        remaining = np.maximum(cap - used, 0.0)
        share = remaining[groups] * weights / np.maximum(totals[groups], 1.0)
        newly = active & ~capped & (limits <= share)
        if not newly.any():
            break
        capped |= newly
    return np.where(active & capped, limits, share)
