"""Per-round, channel-aware cut-layer selection (ASFL-style).

The paper's Remark 2 proves the cut-layer choice does not change learning
dynamics; Remark 1 shows it changes who pays which bits — the cut trades the
per-minibatch activation tensor (N * Z_c, shrinking as the cut deepens in
the CNN) against the client-block offload (Z_0, growing with depth).  That
makes the cut a pure resource-allocation knob, and this module is the
controller that turns per-round channel state into a per-client cut choice:

- ``fixed``:    every client always uses one declared cut (the pre-cutter
                behavior, now just the degenerate policy);
- ``greedy``:   per client, the cut with the smallest ESTIMATED round time
                whose uplink energy the client can still afford (per-client
                argmin of time subject to the energy budget);
- ``deadline``: per client, the DEEPEST affordable cut that still makes the
                edge-round deadline at the offered rate — deeper cuts ship
                fewer activation bits per minibatch but a bigger client
                block, so under a tight deadline the controller walks down
                exactly as far as the channel allows.

The candidate list may also be a joint (cut, codec) GRID: a CommModel table
built with a dict of named ``repro.compress.LinkCodecs`` prices every
cut x codec cell, and ``decide`` searches the flat cell list under the same
greedy/deadline policies — compression is just more candidate cells with
fewer bits.  ``cut_pos``/``codec_pos`` map the chosen cell index back to
its cut depth and codec so reports stay interpretable.

Every cell also carries its client-side FLOPs (``CutSpec.flops``, from
``repro.wireless.device.client_round_flops``): given a device model's
``sec_per_flop``, ``decide`` prices each candidate's COMPUTE time and
energy next to its bits — the full ASFL computation+communication
trade-off, under which a deep cut's smaller activation tensor is no longer
free for a compute-starved client.

The controller is stateless: :class:`~repro.wireless.scheduler.
ParticipationScheduler` calls :meth:`CutController.decide` twice per round —
once on the private (uncontended) rates to make scheduling decisions, and
again on the contended per-ES rates so ``deadline``/``greedy`` adapt to the
bandwidth actually available after the ES uplink is shared.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.comm import CommModel
from repro.wireless.channel import RoundBits, client_round_bits

POLICIES = ("fixed", "greedy", "deadline")


@dataclass(frozen=True)
class CutSpec:
    """One candidate (cut, codec) cell: name + Remark-1 byte accounting."""
    name: str | int          # "conv1" (CNN) or n_client_layers (LM)
    bits: RoundBits          # per-edge-round traffic at this cut x codec
    z0: int                  # Z_0: client-block parameters
    z_c: int                 # Z_c: cut-layer activation elements per sample
    codec: str = "fp32"      # codec-set name ("fp32" = uncompressed)
    flops: float = 0.0       # per-edge-round client compute at this cell
    #                          (client-block training + codec work)


def cut_specs(comms: dict, kappa0: int, *,
              codec_cycles_per_element: float = 0.0) -> tuple[CutSpec, ...]:
    """Build the candidate list from a per-cut CommModel table (the output
    of ``comm_table_for_cnn`` / ``comm_table_for_lm``), preserving its
    shallow-to-deep order.  Tables built with a codecs dict key their cells
    ``(cut, codec_name)``; plain tables get the ``"fp32"`` codec label.
    Each cell also carries its client-side FLOPs so the controller can price
    compute alongside bits (``repro.wireless.device``)."""
    from repro.wireless.device import client_round_flops

    specs = []
    for key, cm in comms.items():
        assert isinstance(cm, CommModel)
        name, codec = key if isinstance(key, tuple) else (key, "fp32")
        specs.append(CutSpec(
            name=name, bits=client_round_bits(cm, kappa0),
            z0=cm.client_params, z_c=cm.cut_size, codec=codec,
            flops=client_round_flops(
                cm, kappa0,
                codec_cycles_per_element=codec_cycles_per_element)))
    return tuple(specs)


class CutController:
    """Maps per-client link state to a per-client candidate-cut index."""

    def __init__(self, specs: tuple[CutSpec, ...], policy: str = "fixed", *,
                 fixed_cut: int = 0, deadline_s: float = float("inf"),
                 tx_power_w: float = 0.5, compute_power_w: float = 0.0,
                 pipeline: bool = False, expected_attempts: float = 1.0,
                 harq_backoff_s: float = 0.0):
        if policy not in POLICIES:
            raise ValueError(f"unknown cut policy {policy!r}; one of {POLICIES}")
        if expected_attempts < 1.0:
            raise ValueError(f"expected_attempts must be >= 1, got "
                             f"{expected_attempts}")
        if not specs:
            raise ValueError("need at least one candidate cut")
        if not 0 <= fixed_cut < len(specs):
            raise ValueError(f"fixed_cut {fixed_cut} out of range for "
                             f"{len(specs)} candidates")
        self.specs = tuple(specs)
        self.policy = policy
        self.fixed_cut = fixed_cut
        self.deadline_s = deadline_s
        self.tx_power_w = tx_power_w
        self.compute_power_w = compute_power_w
        self.pipeline = pipeline
        # HARQ pricing (repro.wireless.faults.expected_attempts): under an
        # erasure channel every transmission repeats ``expected_attempts``
        # times in expectation, with a backoff gap before each retry —
        # adaptive policies must price retransmissions BEFORE they happen
        # or they systematically pick cuts the channel cannot carry
        self.expected_attempts = float(expected_attempts)
        self.harq_backoff_s = float(harq_backoff_s)
        self.up_bits = np.array([s.bits.uplink for s in specs], np.float64)
        self.down_bits = np.array([s.bits.downlink for s in specs], np.float64)
        self.flops = np.array([s.flops for s in specs], np.float64)
        # minibatch decomposition of the uplink (pipelined streaming): every
        # cell shares one chunk count (kappa0 * batches_per_epoch of the one
        # comm table); cells without it degenerate to a single chunk, under
        # which the pipelined estimates equal the serial ones exactly
        if all(s.bits.up_stream is not None for s in specs):
            self.up_stream = np.array([s.bits.up_stream for s in specs],
                                      np.float64)
            self.up_tail = np.array([s.bits.up_tail for s in specs],
                                    np.float64)
            chunkset = {int(s.bits.chunks) for s in specs}
            assert len(chunkset) == 1, \
                f"cells disagree on chunk count: {sorted(chunkset)}"
            self.chunks = chunkset.pop()
        else:
            self.up_stream = self.up_bits
            self.up_tail = np.zeros(len(specs))
            self.chunks = 1
        # joint (cut, codec) grids: map each spec index back to its cut
        # position (shallow -> deep) and its codec position, so reports can
        # say WHICH split and WHICH codec a client got, not just the cell
        self.cut_names = tuple(dict.fromkeys(s.name for s in specs))
        self.codec_names = tuple(dict.fromkeys(s.codec for s in specs))
        self.cut_pos = np.array([self.cut_names.index(s.name) for s in specs])
        self.codec_pos = np.array([self.codec_names.index(s.codec)
                                   for s in specs])

    @property
    def num_cuts(self) -> int:
        return len(self.specs)

    @property
    def has_codec_grid(self) -> bool:
        """True when the candidate grid spans more than one codec set."""
        return len(self.codec_names) > 1

    def bits_for(self, cuts: np.ndarray) -> RoundBits:
        """Per-client (uplink, downlink) bit arrays for a cut-index vector,
        carrying the minibatch decomposition the pipelined timeline needs."""
        cuts = np.asarray(cuts, int)
        return RoundBits(uplink=self.up_bits[cuts],
                         downlink=self.down_bits[cuts],
                         up_stream=self.up_stream[cuts],
                         up_tail=self.up_tail[cuts], chunks=self.chunks)

    def flops_for(self, cuts: np.ndarray) -> np.ndarray:
        """Per-client client-side FLOPs for a cut-index vector."""
        return self.flops[np.asarray(cuts, int)]

    # ------------------------------------------------------------ policy --
    def _estimates(self, up_bps, down_bps, latency_s, sec_per_flop=None):
        """(num_cuts, U) estimated round time and client energy matrices.

        ``sec_per_flop`` (a (U,) array from ``DeviceModel.sec_per_flop``)
        prices each cell's client-side COMPUTE alongside its bits: a deeper
        cut ships fewer activation bits but burns more client FLOPs, and
        only with both terms does the controller see the full ASFL
        trade-off.  ``None`` (or all-zero, i.e. infinite compute) reproduces
        the bits-only estimates exactly.

        With ``pipeline=True`` the TIME estimate prices the overlapped
        streaming timeline instead of the serial sum: per-chunk compute
        ``c = t_comp / chunks`` and per-payload airtime ``u`` close to an
        uplink finish of ``c + u + (chunks-1)*max(c, u) + tail`` (see
        ``repro.wireless.timeline``), which shifts every greedy/deadline
        (cut, codec) trade-off — a compute-heavy deep cut hides its FLOPs
        behind the radio.  The ENERGY estimate is unchanged: overlap moves
        segments earlier but the total compute and airtime (and therefore
        the joules) are identical."""
        with np.errstate(divide="ignore", invalid="ignore"):
            t_up = self.up_bits[:, None] / up_bps[None, :]
            t_down = self.down_bits[:, None] / down_bps[None, :]
        t_up = np.nan_to_num(t_up, nan=0.0)        # inf rate: 0 airtime
        t_down = np.nan_to_num(t_down, nan=0.0)
        # HARQ expansion: airtime repeats ea times in expectation; the TIME
        # also pays (ea - 1) backoff gaps, the ENERGY only the airtime (the
        # radio idles through backoff).  ea == 1, backoff == 0 leaves every
        # expression bit-untouched (fault-free pricing).
        ea, hb = self.expected_attempts, self.harq_backoff_s
        t_up_air = t_up
        harq = ea != 1.0 or hb != 0.0
        if harq:
            gap = (ea - 1.0) * hb
            t_up_air = ea * t_up
            t_up = t_up_air + gap
            t_down = ea * t_down + gap
        t_comp = 0.0
        if sec_per_flop is not None:
            t_comp = self.flops[:, None] * np.asarray(sec_per_flop)[None, :]
        if self.pipeline:
            with np.errstate(divide="ignore", invalid="ignore"):
                u = self.up_stream[:, None] / up_bps[None, :]
                t_tail = self.up_tail[:, None] / up_bps[None, :]
            u = np.nan_to_num(u, nan=0.0)
            t_tail = np.nan_to_num(t_tail, nan=0.0)
            if harq:
                # every stream payload and the tail repeat independently
                u = ea * u + gap
                t_tail = ea * t_tail + gap
            c = t_comp / self.chunks
            up_finish = c + u + (self.chunks - 1) * np.maximum(c, u) + t_tail
            times = 2 * np.asarray(latency_s)[None, :] + up_finish + t_down
        else:
            times = 2 * np.asarray(latency_s)[None, :] + t_up + t_down
            if sec_per_flop is not None:
                times = times + t_comp
        energy = self.tx_power_w * t_up_air
        if sec_per_flop is not None:
            energy = energy + self.compute_power_w * t_comp
        return times, energy

    def decide(self, up_bps, down_bps, latency_s, energy_left,
               sec_per_flop=None) -> np.ndarray:
        """Per-client candidate index under the configured policy.

        All policies fall back in two stages when their primary criterion is
        infeasible: an unaffordable/deadline-missing client first takes the
        fastest affordable cut, and a client that can afford NO cut takes
        the one with the least estimated energy (tx + compute joules at the
        full, uncapped workload).  The scheduler's gate then re-judges that
        pick against the DEADLINE-CAPPED charge it would actually deduct —
        a cell unaffordable at full airtime may still be scheduled as a
        straggler it can afford — so the choice here only has to be sane,
        not feasible.
        """
        U = np.asarray(up_bps).shape[0]
        if self.policy == "fixed" or self.num_cuts == 1:
            return np.full(U, self.fixed_cut, int)
        times, energy = self._estimates(np.asarray(up_bps, float),
                                        np.asarray(down_bps, float),
                                        np.broadcast_to(
                                            np.asarray(latency_s, float), (U,)),
                                        sec_per_flop)
        affordable = energy <= np.asarray(energy_left, float)[None, :]
        t_aff = np.where(affordable, times, np.inf)
        fastest_aff = np.argmin(t_aff, axis=0)     # greedy's primary answer
        cheapest = np.argmin(energy, axis=0)       # last-resort fallback
        none_affordable = ~affordable.any(axis=0)
        if self.policy == "greedy":
            return np.where(none_affordable, cheapest, fastest_aff)
        # deadline: deepest affordable cut meeting the deadline (candidates
        # are ordered shallow -> deep, so the highest feasible index wins;
        # on a cut x codec grid the cut-major order means the deepest cut
        # wins first and, within it, the LAST-listed feasible codec — list
        # codecs cheapest-last to prefer compression at the frontier)
        feasible = affordable & (times <= self.deadline_s)
        idx = np.arange(self.num_cuts)[:, None]
        deepest = np.where(feasible, idx, -1).max(axis=0)
        out = np.where(deepest >= 0, deepest, fastest_aff)
        return np.where(none_affordable, cheapest, out).astype(int)


def make_cut_controller(comms: dict, kappa0: int, *, policy: str = "fixed",
                        fixed_cut: int | str = 0,
                        deadline_s: float = float("inf"),
                        tx_power_w: float = 0.5,
                        compute_power_w: float = 0.0,
                        codec_cycles_per_element: float = 0.0,
                        pipeline: bool = False,
                        expected_attempts: float = 1.0,
                        harq_backoff_s: float = 0.0) -> CutController:
    """Convenience: per-cut CommModel table -> controller.

    ``fixed_cut`` may be a candidate NAME (e.g. ``"conv1"``, an LM depth, or
    a ``(cut, codec_name)`` cell of a cut x codec table — name matches win
    over index interpretation) instead of an index.  A bare cut name against
    a codec grid picks that cut's FIRST-listed codec.
    """
    specs = cut_specs(comms, kappa0,
                      codec_cycles_per_element=codec_cycles_per_element)
    cells = [(s.name, s.codec) for s in specs]
    names = [s.name for s in specs]
    if fixed_cut in cells:
        fixed_cut = cells.index(fixed_cut)
    elif fixed_cut in names:
        fixed_cut = names.index(fixed_cut)
    elif not (isinstance(fixed_cut, int) and 0 <= fixed_cut < len(specs)):
        raise ValueError(f"fixed_cut {fixed_cut!r} not among {cells}")
    return CutController(specs, policy, fixed_cut=fixed_cut,
                         deadline_s=deadline_s, tx_power_w=tx_power_w,
                         compute_power_w=compute_power_w, pipeline=pipeline,
                         expected_attempts=expected_attempts,
                         harq_backoff_s=harq_backoff_s)
