"""Per-client event timelines for one edge round (serial and pipelined).

This module is the scheduler's event model: instead of one scalar per
client ("round time = 2*latency + uplink airtime + downlink airtime +
compute"), each client's round is an explicit sequence of SEGMENTS —
compute chunks, uplink transmissions, and the downlink reception — each
with a start, an end, a bit count, and the joules burned while it runs.
Every scheduler quantity (the deadline gate, the energy charge, the
moved-bits ledger, the round clock) is derived from the same timeline, so
they can never disagree.

Two builders share one dataclass:

- **serial** (``pipeline=False``): the paper's Eq.-17 model.  One compute
  segment (kappa0 local epochs), then one uplink segment (the whole round's
  uplink traffic), then the downlink.  The aggregate arithmetic is kept in
  the exact historical expression order, so the serial timeline reproduces
  the pre-timeline scheduler bit-for-bit (the golden regression pins it).
- **pipelined** (``pipeline=True``): minibatch-granular streaming
  (Accelerating SFL, Xu et al.).  The compute splits into ``bits.chunks``
  equal chunks (one per minibatch of the kappa0 local epochs); chunk ``i``'s
  activation payload (``bits.up_stream`` bits) is eligible to transmit as
  soon as chunk ``i``'s compute finishes AND the radio finished payload
  ``i-1``.  With per-chunk compute ``c = compute_s / n`` and per-payload
  airtime ``u = up_stream / rate`` the recurrence closes to

        tx_start[i] = max((i+1) * c, c + i * u)
        tx_end[i]   = tx_start[i] + u

    (induction: the radio is busy ``u`` per payload once it starts, and can
    never start before the payload exists), so the uplink finishes at

        c + u + (n - 1) * max(c, u) + tail_airtime

    — ``max(compute, tx)`` per steady-state slot plus one fill bubble of
    ``min(c, u)``, plus the client-block offload tail (``bits.up_tail``,
    ready only after the last minibatch).  The serial uplink finish is
    ``n*c + n*u + tail``, so pipelining saves exactly ``(n-1) * min(c, u)``
    >= 0: the pipelined completion time is NEVER worse, and degenerates to
    the serial one when ``n == 1``, when compute is free (``c == 0``), or
    when the decomposition is absent.

Deadline semantics (both builders): activity segments are LATENCY-FREE,
exactly like the pre-timeline straggler charge — latency is charged on the
round CLOCK (``times_s``), not against the transmit window.  A deadline at
``T`` freezes every segment at ``T``: ``compute_charged_s`` /
``tx_charged_s`` / ``down_window_s`` are the per-segment overlaps with
``[0, T]``, and the moved-bits ledger prices ``rate * overlap``.

Fault-injected rounds (``plan`` from ``repro.wireless.faults``) route to a
THIRD builder that expands each payload into its HARQ attempt segments
(erased attempts retransmit after a backoff gap) and truncates a crashed
client's cap below the deadline — the per-segment overlap machinery above
then prices retransmissions and crashes with no new accounting rules.  The
fault-free builders are never touched by a ``plan=None`` call, preserving
their bit-identity guarantees.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.wireless.channel import LinkState, RoundBits


@dataclass
class RoundTimeline:
    """Explicit per-client activity timeline of one edge round.

    All segment clocks are LATENCY-FREE activity time (t=0 is when the
    client starts computing); ``times_s`` is the only field on the round
    clock (it adds the 2*latency propagation term).  Segment arrays are
    ``(U, n)`` with ``n`` segments per client; scalars broadcast.
    """
    pipelined: bool
    # compute segments: chunk i runs over [comp_start[:, i], comp_end[:, i])
    comp_start: np.ndarray     # (U, n)
    comp_end: np.ndarray       # (U, n)
    # uplink segments: payload i transmits over [tx_start[:, i], tx_end[:, i])
    tx_start: np.ndarray       # (U, m)  (m = n + 1 with an offload tail)
    tx_end: np.ndarray         # (U, m)
    tx_bits: np.ndarray        # (U, m) bits of each uplink payload
    # downlink segment (starts when the uplink finishes, latency-free)
    down_start: np.ndarray     # (U,)
    down_end: np.ndarray       # (U,)
    # authoritative aggregates (the scheduler's decision quantities)
    times_s: np.ndarray        # (U,) round-clock completion (2*latency + act)
    compute_s: np.ndarray      # (U,) total compute time (uncapped)
    compute_charged_s: np.ndarray  # (U,) compute seconds within the deadline
    tx_charged_s: np.ndarray   # (U,) uplink seconds within the deadline
    down_window_s: np.ndarray  # (U,) downlink seconds within the deadline
    can_tx: np.ndarray         # (U,) bool: >= 1 uplink bit movable in window
    # ---- fault extension (None on the fault-free builders) ----
    cap_s: np.ndarray = None       # (U,) per-client activity cutoff actually
    #                                charged: min(deadline, crash instant)
    crashed: np.ndarray = None     # (U,) bool: crashed before finishing
    up_ok_all: np.ndarray = None   # (U,) bool: every uplink payload was
    #                                DELIVERED (erasure-survived) within cap
    down_ok: np.ndarray = None     # (U,) bool: downlink delivered within cap
    up_done: np.ndarray = None     # (U,) bool: uplink ACTIVITY (all attempts,
    #                                delivered or not) finished within cap
    down_done: np.ndarray = None   # (U,) bool: downlink activity finished
    air_up_bits: np.ndarray = None    # (U,) exact uplink AIR bits (every
    #                                attempt counts; retransmits included)
    air_down_bits: np.ndarray = None  # (U,) exact downlink air bits
    goodput_up_bits: np.ndarray = None  # (U,) nominal bits of the uplink
    #                                payloads actually DELIVERED within cap
    first_tx_s: np.ndarray = None  # (U,) capped airtime of FIRST attempts
    #                                only (tx_charged_s minus this prices
    #                                the retransmission overhead)
    first_down_s: np.ndarray = None  # (U,) capped first-attempt downlink s
    tx_payload: np.ndarray = None  # (m,) payload index of each uplink
    #                                column (attempt-expanded fault rounds
    #                                have several columns per payload)
    tx_attempt: np.ndarray = None  # (m,) HARQ attempt index per column
    #                                (0 = first transmission, >0 = retx)

    def charge_j(self, tx_power_w: float, compute_power_w: float):
        """Deadline-capped joules: what a scheduled client actually pays."""
        return (tx_power_w * self.tx_charged_s
                + compute_power_w * self.compute_charged_s)

    def segments(self, u: int) -> list[dict]:
        """Client ``u``'s timeline as readable rows (for reports/examples)."""
        rows = []
        for i in range(self.comp_start.shape[1]):
            rows.append({"kind": "compute", "start": float(self.comp_start[u, i]),
                         "end": float(self.comp_end[u, i])})
        for i in range(self.tx_start.shape[1]):
            if self.tx_bits[u, i] > 0 or self.tx_start.shape[1] == 1:
                rows.append({"kind": "uplink", "start": float(self.tx_start[u, i]),
                             "end": float(self.tx_end[u, i]),
                             "bits": float(self.tx_bits[u, i])})
        rows.append({"kind": "downlink", "start": float(self.down_start[u]),
                     "end": float(self.down_end[u])})
        return sorted(rows, key=lambda r: (r["start"], r["end"]))


def _overlap(start, length, deadline):
    """Per-segment overlap of [start, start+length) with [0, deadline)."""
    return np.clip(deadline - start, 0.0, length)


def build_timeline(link: LinkState, bits: RoundBits, comp_s: np.ndarray,
                   deadline_s: float, U: int, *, pipeline: bool = False,
                   plan=None) -> RoundTimeline:
    """Build one round's per-client timeline at the given link rates.

    ``pipeline=False`` keeps the serial aggregates in the exact historical
    expression order (2*latency + t_up + t_down + compute; the capped
    window ``min(airtime, max(deadline - compute, 0))``) so the serial path
    is bit-identical to the pre-timeline scheduler.

    ``plan`` (a :class:`repro.wireless.faults.FaultPlan`) routes to the
    fault builder: every payload expands into its HARQ attempt segments and
    a crashed client's cap truncates below the deadline.  ``plan=None``
    (default, and every fault-free config) never touches this branch.
    """
    if plan is not None:
        return _faulty(link, bits, comp_s, deadline_s, U, plan, pipeline)
    if pipeline:
        return _pipelined(link, bits, comp_s, deadline_s, U)
    return _serial(link, bits, comp_s, deadline_s, U)


def _serial(link, bits, comp_s, deadline_s, U):
    comp_s = np.broadcast_to(np.asarray(comp_s, float), (U,))
    with np.errstate(divide="ignore"):
        t_up_clock = bits.uplink / link.uplink_bps
        t_down = bits.downlink / link.downlink_bps
        t_up = np.asarray(bits.uplink, float) / link.uplink_bps
    t_up = np.where(np.isfinite(t_up), t_up, 0.0)
    t_down_f = np.where(np.isfinite(t_down), t_down, 0.0)
    # the historical round-clock expression, verbatim association order
    times = 2 * link.latency_s + t_up_clock + t_down + comp_s
    c_s = np.minimum(comp_s, deadline_s)
    window = np.maximum(deadline_s - comp_s, 0.0)
    tx_s = np.minimum(t_up, window)
    up_end = comp_s + t_up
    down_start = up_end                   # downlink follows the full uplink
    return RoundTimeline(
        pipelined=False,
        comp_start=np.zeros((U, 1)), comp_end=comp_s.reshape(U, 1),
        tx_start=comp_s.reshape(U, 1), tx_end=up_end.reshape(U, 1),
        tx_bits=np.broadcast_to(np.asarray(bits.uplink, float),
                                (U,)).reshape(U, 1),
        down_start=down_start, down_end=down_start + t_down_f,
        times_s=np.broadcast_to(np.asarray(times, float), (U,)),
        compute_s=comp_s, compute_charged_s=c_s, tx_charged_s=tx_s,
        down_window_s=_overlap(down_start, t_down_f, deadline_s),
        can_tx=window > 0)


def _pipelined(link, bits, comp_s, deadline_s, U):
    comp_s = np.broadcast_to(np.asarray(comp_s, float), (U,))
    n = max(int(bits.chunks), 1)
    stream = bits.up_stream if bits.up_stream is not None else bits.uplink
    tail = bits.up_tail if bits.up_stream is not None else 0.0
    stream = np.broadcast_to(np.asarray(stream, float), (U,))
    tail = np.broadcast_to(np.asarray(tail, float), (U,))
    with np.errstate(divide="ignore"):
        u = stream / link.uplink_bps
        t_tail = tail / link.uplink_bps
        t_down = np.asarray(bits.downlink, float) / link.downlink_bps
    u = np.where(np.isfinite(u), u, 0.0)
    t_tail = np.where(np.isfinite(t_tail), t_tail, 0.0)
    t_down = np.where(np.isfinite(t_down), t_down, 0.0)
    c = comp_s / n                                   # per-minibatch compute
    i = np.arange(n)[None, :]                        # (1, n) chunk index
    comp_start = i * c[:, None]
    comp_end = (i + 1) * c[:, None]
    # closed form of the streaming recurrence (see module docstring)
    tx_start = np.maximum((i + 1) * c[:, None], c[:, None] + i * u[:, None])
    tx_end = tx_start + u[:, None]
    tail_start = tx_end[:, -1]                       # offload after last chunk
    tail_end = tail_start + t_tail
    up_finish = tail_end
    down_start = up_finish
    times = 2 * link.latency_s + up_finish + t_down
    c_s = np.minimum(comp_s, deadline_s)
    tx_s = (_overlap(tx_start, u[:, None], deadline_s).sum(axis=1)
            + _overlap(tail_start, t_tail, deadline_s))
    # a pipelined client can move a bit as soon as its FIRST chunk computes
    can_tx = c < deadline_s
    return RoundTimeline(
        pipelined=True,
        comp_start=comp_start, comp_end=comp_end,
        tx_start=np.concatenate([tx_start, tail_start[:, None]], axis=1),
        tx_end=np.concatenate([tx_end, tail_end[:, None]], axis=1),
        tx_bits=np.concatenate([np.broadcast_to(stream[:, None], (U, n)),
                                tail[:, None]], axis=1),
        down_start=down_start, down_end=down_start + t_down,
        times_s=np.broadcast_to(np.asarray(times, float), (U,)),
        compute_s=comp_s, compute_charged_s=c_s, tx_charged_s=tx_s,
        down_window_s=_overlap(down_start, t_down, deadline_s),
        can_tx=can_tx)


def _faulty(link, bits, comp_s, deadline_s, U, plan, pipeline):
    """Fault-expanded timeline: HARQ attempt segments + crash truncation.

    Each uplink payload (one monolithic payload serially; ``chunks`` stream
    payloads plus the offload tail pipelined) becomes ``plan.up_attempts``
    back-to-back attempt segments — each retransmission waits ``backoff_s``
    after the previous attempt ends — and the downlink broadcast likewise.
    A crashed client's cap is ``min(deadline, crash instant)``; every
    charge/credit is the per-segment overlap with ``[0, cap)``, so
    retransmissions and crashes are priced by the SAME freeze rule as
    deadline stragglers.  Compute runs contiguously over ``[0, comp_s)`` in
    both shapes, so its capped charge stays ``min(comp_s, cap)``.
    """
    comp_s = np.asarray(np.broadcast_to(np.asarray(comp_s, float), (U,)),
                        float)
    back = float(plan.backoff_s)
    up_rate = np.broadcast_to(np.asarray(link.uplink_bps, float), (U,))
    down_bits = np.broadcast_to(np.asarray(bits.downlink, float), (U,))
    with np.errstate(divide="ignore", invalid="ignore"):
        t_down1 = down_bits / link.downlink_bps
    t_down1 = np.where(np.isfinite(t_down1), t_down1, 0.0)

    # payload decomposition: (U, m) ready times and nominal bit counts
    if pipeline:
        n = max(int(bits.chunks), 1)
        stream = bits.up_stream if bits.up_stream is not None else bits.uplink
        tail = bits.up_tail if bits.up_stream is not None else 0.0
        stream = np.broadcast_to(np.asarray(stream, float), (U,))
        tail = np.broadcast_to(np.asarray(tail, float), (U,))
        c = comp_s / n
        i = np.arange(n)
        ready = np.concatenate([(i + 1)[None, :] * c[:, None],
                                comp_s[:, None]], axis=1)        # (U, n+1)
        pay_bits = np.concatenate(
            [np.broadcast_to(stream[:, None], (U, n)), tail[:, None]], axis=1)
        comp_start = i[None, :] * c[:, None]
        comp_end = (i + 1)[None, :] * c[:, None]
        can_tx = c < deadline_s
    else:
        up = np.broadcast_to(np.asarray(bits.uplink, float), (U,))
        ready = comp_s[:, None]
        pay_bits = up[:, None]
        comp_start = np.zeros((U, 1))
        comp_end = comp_s.reshape(U, 1)
        can_tx = comp_s < deadline_s
    m = pay_bits.shape[1]
    assert plan.up_attempts.shape == (U, m), \
        f"fault plan has {plan.up_attempts.shape[1]} uplink payload slots " \
        f"but the timeline needs {m}"
    with np.errstate(divide="ignore", invalid="ignore"):
        dur = pay_bits / up_rate[:, None]
    dur = np.where(np.isfinite(dur), dur, 0.0)

    # expand payloads into attempt segments; the radio is strictly serial
    radio = np.zeros(U)
    tx_starts, tx_ends, tx_bits_cols, first_cols = [], [], [], []
    payload_ids, attempt_ids = [], []
    for i in range(m):
        a = plan.up_attempts[:, i]
        for j in range(int(a.max())):
            live = j < a
            gap = back if j > 0 else 0.0
            start = np.where(live, np.maximum(ready[:, i], radio + gap),
                             radio)
            end = start + np.where(live, dur[:, i], 0.0)
            tx_starts.append(start)
            tx_ends.append(end)
            tx_bits_cols.append(np.where(live, pay_bits[:, i], 0.0))
            first_cols.append(j == 0)
            payload_ids.append(i)
            attempt_ids.append(j)
            radio = end
    up_finish = radio                       # all uplink attempts done
    tx_start = np.stack(tx_starts, axis=1)
    tx_end = np.stack(tx_ends, axis=1)
    tx_bits = np.stack(tx_bits_cols, axis=1)
    first = np.asarray(first_cols, bool)

    # downlink attempts follow the full uplink
    ad = plan.down_attempts
    d_starts, d_ends = [], []
    radio_d = up_finish
    for j in range(int(ad.max())):
        live = j < ad
        gap = back if j > 0 else 0.0
        start = np.where(live, radio_d + gap, radio_d)
        end = start + np.where(live, t_down1, 0.0)
        d_starts.append(start)
        d_ends.append(end)
        radio_d = end
    down_end_act = radio_d
    d_start = np.stack(d_starts, axis=1)
    d_end = np.stack(d_ends, axis=1)

    # crash cap: the activity-clock instant the client dies (inf = never).
    # Finite deadline: a fraction of the deadline window; infinite deadline:
    # a fraction of the client's own activity span (always mid-round).
    span = deadline_s if np.isfinite(deadline_s) else down_end_act
    with np.errstate(invalid="ignore"):
        crash_t = np.where(np.isfinite(plan.crash_frac),
                           plan.crash_frac * span, np.inf)
    cap = np.minimum(deadline_s, crash_t)
    crashed = crash_t < down_end_act

    # per-segment overlaps with [0, cap): the one freeze rule prices
    # compute, every uplink attempt, and every downlink attempt
    ov = _overlap(tx_start, tx_end - tx_start, cap[:, None])
    tx_charged = ov.sum(axis=1)
    first_tx_s = (ov * first[None, :]).sum(axis=1)
    ovd = _overlap(d_start, d_end - d_start, cap[:, None])
    down_window = ovd.sum(axis=1)
    first_down_s = ovd[:, 0]
    compute_charged = np.minimum(comp_s, cap)

    # a payload is delivered iff it erasure-survived AND its last attempt
    # ends within the cap
    pay_end = np.empty((U, m))
    col = 0
    for i in range(m):
        a = plan.up_attempts[:, i]
        width = int(a.max())
        ends = tx_end[:, col:col + width]
        pay_end[:, i] = ends[np.arange(U), a - 1]
        col += width
    delivered = plan.up_ok & (pay_end <= cap[:, None])
    goodput_up = (pay_bits * delivered).sum(axis=1)
    up_ok_all = delivered.all(axis=1)
    up_done = up_finish <= cap
    down_done = down_end_act <= cap
    down_ok = plan.down_ok & down_done

    times = 2 * link.latency_s + down_end_act
    air_up = (pay_bits * plan.up_attempts).sum(axis=1)
    air_down = down_bits * ad
    return RoundTimeline(
        pipelined=bool(pipeline),
        comp_start=comp_start, comp_end=comp_end,
        tx_start=tx_start, tx_end=tx_end, tx_bits=tx_bits,
        down_start=d_start[:, 0], down_end=down_end_act,
        times_s=np.broadcast_to(np.asarray(times, float), (U,)),
        compute_s=comp_s, compute_charged_s=compute_charged,
        tx_charged_s=tx_charged, down_window_s=down_window,
        can_tx=can_tx,
        cap_s=cap, crashed=crashed, up_ok_all=up_ok_all, down_ok=down_ok,
        up_done=up_done, down_done=down_done,
        air_up_bits=air_up, air_down_bits=air_down,
        goodput_up_bits=goodput_up,
        first_tx_s=first_tx_s, first_down_s=first_down_s,
        tx_payload=np.asarray(payload_ids, int),
        tx_attempt=np.asarray(attempt_ids, int))
