"""Per-client event timelines for one edge round (serial and pipelined).

This module is the scheduler's event model: instead of one scalar per
client ("round time = 2*latency + uplink airtime + downlink airtime +
compute"), each client's round is an explicit sequence of SEGMENTS —
compute chunks, uplink transmissions, and the downlink reception — each
with a start, an end, a bit count, and the joules burned while it runs.
Every scheduler quantity (the deadline gate, the energy charge, the
moved-bits ledger, the round clock) is derived from the same timeline, so
they can never disagree.

Two builders share one dataclass:

- **serial** (``pipeline=False``): the paper's Eq.-17 model.  One compute
  segment (kappa0 local epochs), then one uplink segment (the whole round's
  uplink traffic), then the downlink.  The aggregate arithmetic is kept in
  the exact historical expression order, so the serial timeline reproduces
  the pre-timeline scheduler bit-for-bit (the golden regression pins it).
- **pipelined** (``pipeline=True``): minibatch-granular streaming
  (Accelerating SFL, Xu et al.).  The compute splits into ``bits.chunks``
  equal chunks (one per minibatch of the kappa0 local epochs); chunk ``i``'s
  activation payload (``bits.up_stream`` bits) is eligible to transmit as
  soon as chunk ``i``'s compute finishes AND the radio finished payload
  ``i-1``.  With per-chunk compute ``c = compute_s / n`` and per-payload
  airtime ``u = up_stream / rate`` the recurrence closes to

        tx_start[i] = max((i+1) * c, c + i * u)
        tx_end[i]   = tx_start[i] + u

    (induction: the radio is busy ``u`` per payload once it starts, and can
    never start before the payload exists), so the uplink finishes at

        c + u + (n - 1) * max(c, u) + tail_airtime

    — ``max(compute, tx)`` per steady-state slot plus one fill bubble of
    ``min(c, u)``, plus the client-block offload tail (``bits.up_tail``,
    ready only after the last minibatch).  The serial uplink finish is
    ``n*c + n*u + tail``, so pipelining saves exactly ``(n-1) * min(c, u)``
    >= 0: the pipelined completion time is NEVER worse, and degenerates to
    the serial one when ``n == 1``, when compute is free (``c == 0``), or
    when the decomposition is absent.

Deadline semantics (both builders): activity segments are LATENCY-FREE,
exactly like the pre-timeline straggler charge — latency is charged on the
round CLOCK (``times_s``), not against the transmit window.  A deadline at
``T`` freezes every segment at ``T``: ``compute_charged_s`` /
``tx_charged_s`` / ``down_window_s`` are the per-segment overlaps with
``[0, T]``, and the moved-bits ledger prices ``rate * overlap``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.wireless.channel import LinkState, RoundBits


@dataclass
class RoundTimeline:
    """Explicit per-client activity timeline of one edge round.

    All segment clocks are LATENCY-FREE activity time (t=0 is when the
    client starts computing); ``times_s`` is the only field on the round
    clock (it adds the 2*latency propagation term).  Segment arrays are
    ``(U, n)`` with ``n`` segments per client; scalars broadcast.
    """
    pipelined: bool
    # compute segments: chunk i runs over [comp_start[:, i], comp_end[:, i])
    comp_start: np.ndarray     # (U, n)
    comp_end: np.ndarray       # (U, n)
    # uplink segments: payload i transmits over [tx_start[:, i], tx_end[:, i])
    tx_start: np.ndarray       # (U, m)  (m = n + 1 with an offload tail)
    tx_end: np.ndarray         # (U, m)
    tx_bits: np.ndarray        # (U, m) bits of each uplink payload
    # downlink segment (starts when the uplink finishes, latency-free)
    down_start: np.ndarray     # (U,)
    down_end: np.ndarray       # (U,)
    # authoritative aggregates (the scheduler's decision quantities)
    times_s: np.ndarray        # (U,) round-clock completion (2*latency + act)
    compute_s: np.ndarray      # (U,) total compute time (uncapped)
    compute_charged_s: np.ndarray  # (U,) compute seconds within the deadline
    tx_charged_s: np.ndarray   # (U,) uplink seconds within the deadline
    down_window_s: np.ndarray  # (U,) downlink seconds within the deadline
    can_tx: np.ndarray         # (U,) bool: >= 1 uplink bit movable in window

    def charge_j(self, tx_power_w: float, compute_power_w: float):
        """Deadline-capped joules: what a scheduled client actually pays."""
        return (tx_power_w * self.tx_charged_s
                + compute_power_w * self.compute_charged_s)

    def segments(self, u: int) -> list[dict]:
        """Client ``u``'s timeline as readable rows (for reports/examples)."""
        rows = []
        for i in range(self.comp_start.shape[1]):
            rows.append({"kind": "compute", "start": float(self.comp_start[u, i]),
                         "end": float(self.comp_end[u, i])})
        for i in range(self.tx_start.shape[1]):
            if self.tx_bits[u, i] > 0 or self.tx_start.shape[1] == 1:
                rows.append({"kind": "uplink", "start": float(self.tx_start[u, i]),
                             "end": float(self.tx_end[u, i]),
                             "bits": float(self.tx_bits[u, i])})
        rows.append({"kind": "downlink", "start": float(self.down_start[u]),
                     "end": float(self.down_end[u])})
        return sorted(rows, key=lambda r: (r["start"], r["end"]))


def _overlap(start, length, deadline):
    """Per-segment overlap of [start, start+length) with [0, deadline)."""
    return np.clip(deadline - start, 0.0, length)


def build_timeline(link: LinkState, bits: RoundBits, comp_s: np.ndarray,
                   deadline_s: float, U: int, *,
                   pipeline: bool = False) -> RoundTimeline:
    """Build one round's per-client timeline at the given link rates.

    ``pipeline=False`` keeps the serial aggregates in the exact historical
    expression order (2*latency + t_up + t_down + compute; the capped
    window ``min(airtime, max(deadline - compute, 0))``) so the serial path
    is bit-identical to the pre-timeline scheduler.
    """
    if pipeline:
        return _pipelined(link, bits, comp_s, deadline_s, U)
    return _serial(link, bits, comp_s, deadline_s, U)


def _serial(link, bits, comp_s, deadline_s, U):
    comp_s = np.broadcast_to(np.asarray(comp_s, float), (U,))
    with np.errstate(divide="ignore"):
        t_up_clock = bits.uplink / link.uplink_bps
        t_down = bits.downlink / link.downlink_bps
        t_up = np.asarray(bits.uplink, float) / link.uplink_bps
    t_up = np.where(np.isfinite(t_up), t_up, 0.0)
    t_down_f = np.where(np.isfinite(t_down), t_down, 0.0)
    # the historical round-clock expression, verbatim association order
    times = 2 * link.latency_s + t_up_clock + t_down + comp_s
    c_s = np.minimum(comp_s, deadline_s)
    window = np.maximum(deadline_s - comp_s, 0.0)
    tx_s = np.minimum(t_up, window)
    up_end = comp_s + t_up
    down_start = up_end                   # downlink follows the full uplink
    return RoundTimeline(
        pipelined=False,
        comp_start=np.zeros((U, 1)), comp_end=comp_s.reshape(U, 1),
        tx_start=comp_s.reshape(U, 1), tx_end=up_end.reshape(U, 1),
        tx_bits=np.broadcast_to(np.asarray(bits.uplink, float),
                                (U,)).reshape(U, 1),
        down_start=down_start, down_end=down_start + t_down_f,
        times_s=np.broadcast_to(np.asarray(times, float), (U,)),
        compute_s=comp_s, compute_charged_s=c_s, tx_charged_s=tx_s,
        down_window_s=_overlap(down_start, t_down_f, deadline_s),
        can_tx=window > 0)


def _pipelined(link, bits, comp_s, deadline_s, U):
    comp_s = np.broadcast_to(np.asarray(comp_s, float), (U,))
    n = max(int(bits.chunks), 1)
    stream = bits.up_stream if bits.up_stream is not None else bits.uplink
    tail = bits.up_tail if bits.up_stream is not None else 0.0
    stream = np.broadcast_to(np.asarray(stream, float), (U,))
    tail = np.broadcast_to(np.asarray(tail, float), (U,))
    with np.errstate(divide="ignore"):
        u = stream / link.uplink_bps
        t_tail = tail / link.uplink_bps
        t_down = np.asarray(bits.downlink, float) / link.downlink_bps
    u = np.where(np.isfinite(u), u, 0.0)
    t_tail = np.where(np.isfinite(t_tail), t_tail, 0.0)
    t_down = np.where(np.isfinite(t_down), t_down, 0.0)
    c = comp_s / n                                   # per-minibatch compute
    i = np.arange(n)[None, :]                        # (1, n) chunk index
    comp_start = i * c[:, None]
    comp_end = (i + 1) * c[:, None]
    # closed form of the streaming recurrence (see module docstring)
    tx_start = np.maximum((i + 1) * c[:, None], c[:, None] + i * u[:, None])
    tx_end = tx_start + u[:, None]
    tail_start = tx_end[:, -1]                       # offload after last chunk
    tail_end = tail_start + t_tail
    up_finish = tail_end
    down_start = up_finish
    times = 2 * link.latency_s + up_finish + t_down
    c_s = np.minimum(comp_s, deadline_s)
    tx_s = (_overlap(tx_start, u[:, None], deadline_s).sum(axis=1)
            + _overlap(tail_start, t_tail, deadline_s))
    # a pipelined client can move a bit as soon as its FIRST chunk computes
    can_tx = c < deadline_s
    return RoundTimeline(
        pipelined=True,
        comp_start=comp_start, comp_end=comp_end,
        tx_start=np.concatenate([tx_start, tail_start[:, None]], axis=1),
        tx_end=np.concatenate([tx_end, tail_end[:, None]], axis=1),
        tx_bits=np.concatenate([np.broadcast_to(stream[:, None], (U, n)),
                                tail[:, None]], axis=1),
        down_start=down_start, down_end=down_start + t_down,
        times_s=np.broadcast_to(np.asarray(times, float), (U,)),
        compute_s=comp_s, compute_charged_s=c_s, tx_charged_s=tx_s,
        down_window_s=_overlap(down_start, t_down, deadline_s),
        can_tx=can_tx)
