"""Wireless channel + client-participation subsystem.

Turns the ideal-network PHSFL simulator into a network-aware one: every
client gets a per-edge-round uplink/downlink rate, latency, and energy
budget; a scheduler drops stragglers against a deadline and emits a 0/1
participation mask that the aggregation paths (``repro.core.fedsim``,
``repro.core.phsfl``) consume by renormalizing the Eq. 14-16 weights over
the participating clients only.

``WirelessConfig`` knobs (``repro.configs.base``)
=================================================

Channel (``repro.wireless.channel.ChannelModel``):

- ``model``: rate process — ``"ideal"`` (infinite rate, zero latency: the
  pre-wireless simulator, and the default), ``"static"`` (constant rates),
  ``"rayleigh"`` (per-round exponential fading of the received power, i.e.
  Rayleigh amplitude), ``"trace"`` (replay ``trace`` rows).
- ``mean_uplink_mbps`` / ``mean_downlink_mbps``: mean per-client rates.
- ``latency_s``: per-message latency, charged once per direction per round.
- ``heterogeneity``: sigma of a lognormal per-client rate scale drawn once
  at construction — 0 means all clients statistically identical.
- ``trace``: round-major tuple of per-client uplink-Mbps tuples (cycled
  over rounds, resized over clients); downlink scales by the configured
  downlink/uplink ratio.

Participation (``repro.wireless.scheduler.ParticipationScheduler``):

- ``deadline_s``: edge-round deadline; a scheduled client whose simulated
  round time (2*latency + uplink airtime + downlink airtime for the
  Remark-1 traffic of ``client_round_bits``) exceeds it is dropped from
  that aggregation, and the ES waits the deadline out.
- ``selection``: ``"deadline"`` (energy+deadline gates only), ``"topk"``
  (schedule only the ``topk`` fastest affordable clients), ``"random"``
  (thin schedulable clients i.i.d. with ``participation_prob``).
- ``energy_budget_j`` / ``tx_power_w``: lifetime uplink energy budget and
  transmit power; budgets never recharge, and a client skips any round it
  cannot afford (under fading it may re-join a later, cheaper round).
- ``seed``: RNG seed for fading draws, heterogeneity, and thinning.

Aggregation semantics under a partial mask: participating clients keep
their Eq. 4/6 weights, renormalized to sum to 1; an edge round with ZERO
participants keeps the previous edge model; with a full (all-ones) mask
every path is bit-identical to the ideal-network simulator.
"""

from repro.wireless.channel import (ChannelModel, LinkState, RoundBits,
                                    client_round_bits)
from repro.wireless.scheduler import ParticipationScheduler, RoundReport

__all__ = [
    "ChannelModel", "LinkState", "RoundBits", "client_round_bits",
    "ParticipationScheduler", "RoundReport", "make_scheduler",
]


def make_scheduler(cfg, num_clients: int, comm, kappa0: int):
    """Convenience: CommModel byte accounting -> channel -> scheduler."""
    bits = client_round_bits(comm, kappa0)
    return ParticipationScheduler(cfg, ChannelModel(cfg, num_clients), bits)
