"""Wireless channel + client-participation subsystem.

Turns the ideal-network PHSFL simulator into a network-aware one: every
client gets a per-edge-round uplink/downlink rate, latency, and energy
budget; a scheduler drops stragglers against a deadline and emits a 0/1
participation mask that the aggregation paths (``repro.core.fedsim``,
``repro.core.phsfl``) consume by renormalizing the Eq. 14-16 weights over
the participating clients only.  A per-round cut-layer controller
(``repro.wireless.cutter``) exploits the paper's Remark 2 — the cut choice
never changes learning dynamics, only who pays which bits (Remark 1) — to
adapt the split point to channel state, ASFL-style.

``WirelessConfig`` knobs (``repro.configs.base``)
=================================================

Channel (``repro.wireless.channel.ChannelModel``):

- ``model``: rate process — ``"ideal"`` (infinite rate, zero latency: the
  pre-wireless simulator, and the default), ``"static"`` (constant rates),
  ``"rayleigh"`` (per-round exponential fading of the received power, i.e.
  Rayleigh amplitude), ``"trace"`` (replay ``trace`` rows).
- ``mean_uplink_mbps`` / ``mean_downlink_mbps``: mean per-client rates.
- ``latency_s``: per-message latency, charged once per direction per round.
- ``heterogeneity``: sigma of a lognormal per-client rate scale drawn once
  at construction — 0 means all clients statistically identical.
- ``trace``: round-major tuple of per-client uplink-Mbps tuples (cycled
  over rounds, resized over clients).
- ``trace_down``: optional round-major downlink trace (same shape rules);
  without one the downlink FALLS BACK to the uplink trace rescaled by the
  configured downlink/uplink mean ratio (fabricated, perfectly-correlated
  fading — record a real pair whenever asymmetry matters).
- ``es_uplink_mbps``: SHARED uplink capacity of each edge server.  The
  scheduled clients of one ES split it — each gets the smaller of its
  private rate and its share, so the per-ES aggregate rate never exceeds
  the capacity.  ``inf`` (default) keeps every uplink private; an ideal
  channel bypasses contention entirely.
- ``contention``: the sharing rule — ``"equal"`` (default) splits the pipe
  evenly among that round's scheduled clients; ``"proportional"`` weights
  shares by each client's private rate (proportional-fair scheduling).
- ``reshare_uplink``: after the contended price forces some clients to
  withdraw, a second contention pass (default True) re-shares the freed
  capacity among the survivors — their rates only rise, so one pass
  suffices; False reproduces the original conservative single pass.

Cut selection (``repro.wireless.cutter.CutController``):

- ``cut_policy``: ``"fixed"`` (one declared cut — the pre-cutter behavior),
  ``"greedy"`` (per client, the cut minimizing estimated round time subject
  to the energy budget), ``"deadline"`` (the deepest affordable cut that
  still makes ``deadline_s`` at the contended rate).
- ``cut_candidates``: the candidate cuts, shallow to deep — CNN cut names
  (``repro.models.cnn.CUT_CANDIDATES``) or LM client depths; ``()`` means
  the model's single default cut.  ``repro.core.comm`` builds the per-cut
  ``(Z_0, Z_c)`` byte table (``comm_table_for_cnn``/``comm_table_for_lm``)
  the controller prices cuts with.  A table built with a dict of named
  ``repro.compress.LinkCodecs`` prices the joint (cut, codec) GRID instead:
  the controller searches the flat cell list under the same policies and
  ``RoundReport.codecs`` carries each client's chosen codec.

Device / compute (``repro.wireless.device.DeviceModel``):

- ``compute_gflops``: per-client compute rate in GFLOP/s.  The device model
  converts each round's client-side workload — ``client_round_flops``:
  kappa0 local epochs of client-block forward+backward at the chosen cut
  (per-cut conv/dense counts from ``repro.utils.flops`` via
  ``CommModel.client_flops_per_sample``) plus codec encode/decode work —
  into per-round compute TIME (added to the round time the deadline gates
  on) and ENERGY (added to the transmit joules the budget gates on).
  ``inf`` (default) zeroes every compute term: the bits-only simulator,
  bit-for-bit.
- ``compute_heterogeneity``: lognormal sigma of a FIXED per-client compute
  scale (the compute twin of ``heterogeneity``; drawn once from an RNG
  stream disjoint from the channel's, so enabling it never perturbs fading).
- ``compute_power_w``: power drawn while computing; a scheduled client is
  charged ``compute_power_w * compute_s + tx_power_w * tx_s``, both capped
  at the deadline (see the scheduler docstring's straggler semantics).
- ``codec_cycles_per_element``: FLOPs per element crossing a LOSSY codec on
  the client (activations encoded up and gradients decoded down each
  minibatch, the client block encoded/decoded at the offload boundary) —
  the codec-aware energy model; 0 keeps codecs compute-free.

With finite compute the cut controller prices every (cut, codec) cell's
FLOPs next to its bits, so ``greedy``/``deadline`` see the full ASFL
trade-off: a deep cut ships fewer activation bits but burns more client
FLOPs, and a compute-starved client is steered to a shallower cut than its
fast-channel peer (``examples/device_aware_cut.py``,
``benchmarks/device_sweep.py``).

Pipelined streaming (``repro.wireless.timeline``):

- ``pipeline``: overlap client compute with uplink streaming at minibatch
  granularity (Accelerating SFL-style).  Each of the round's ``kappa0 x
  batches_per_epoch`` minibatch activation payloads transmits as soon as
  its minibatch's compute finishes and the radio is free, so the uplink
  finishes at ``c + u + (n-1)*max(c, u) + tail`` instead of the serial
  ``n*c + n*u + tail`` — round time moves from compute + tx toward
  max(compute, tx) plus one fill bubble, saving exactly ``(n-1)*min(c, u)``
  per client (never worse, equal when compute is free or n == 1).  The
  deadline/energy gates, the charge, the moved-bits ledger, and the cut
  controller's estimates all price the overlapped timeline.  False
  (default) is the serial Eq.-17 model, bit-for-bit.

Staleness-weighted async edge aggregation (scheduler + ``core.fedsim``):

- ``staleness_lambda``: lambda in [0, 1].  When > 0, a deadline-cut
  straggler's undelivered uplink remainder is BANKED; on later rounds in
  which the client is idle its radio background-pushes the remainder at
  its private rate inside the round's wall-clock window (energy-charged
  like any transmission), and when the remainder lands the banked update
  is folded into THAT round's edge aggregation with weight
  ``alpha_u * lambda**staleness`` (staleness = edge rounds late, >= 1).
  A bank dies unfolded when a fresh completed round supersedes it or a
  newer straggle replaces it.  0 (default) disables the machinery and
  reproduces hard dropout bit-for-bit.  The aggregation fold lives in the
  CNN simulator (``FedSim``); the LM launcher prices the scheduler side
  only.

Fault injection + recovery (``repro.wireless.faults``; all knobs live on
``WirelessConfig.faults``, a ``FaultConfig`` whose all-defaults instance is
the exact fault-free scheduler, bit-for-bit — the ``fault-free-default``
regression pins this):

- ``erasure_prob``: per-ATTEMPT probability that an uplink payload or the
  downlink broadcast is erased.  Erased transmissions retransmit (HARQ) up
  to ``max_retries`` times, each retry waiting ``backoff_s`` of radio idle
  first; the retransmitted copies are real timeline segments, priced by
  the same deadline gate / energy charge / moved-bits ledger as first
  transmissions, and ``RoundReport.retx_bits``/``retx_j`` isolate the
  overhead.  Graceful here means: a payload that exhausts its retries is
  REPORTED failed (``RoundReport.failed``) and — with ``staleness_lambda``
  > 0 — its undelivered remainder flows into the stale bank to land late
  and discounted, never silently lost.  The cut controller prices the
  expected HARQ expansion (``expected_attempts`` airtime multiplier) so
  adaptive cuts stay honest under lossy channels.
- ``es_outage_trace``: round-major 0/1 rows (cycled over rounds, resized
  over ESs) marking edge servers DOWN for whole rounds.  ``failover``
  picks the recovery: ``"reassoc"`` (default) re-associates a dead ES's
  clients to the nearest live ES — they re-enter ITS contention pass and
  join its aggregation — while ``"skip"`` sits them out (cost nothing).
  Graceful here means: the dead ES's edge model is carried forward
  unchanged (FedSim's zero-participant path) and banked stale pushes
  pause while their target ES is down.
- ``crash_hazard``: per-round probability a scheduled client dies at a
  uniform instant mid-round.  Its timeline freezes at the crash cap —
  partial compute charged, partial uplink credited as moved bits, the
  straggler freeze rule at the crash instant — and its local state is
  lost, so nothing is banked.  Graceful here means: the crash costs
  exactly what was spent, the ES never waits past the silence, and the
  report says who died (``RoundReport.crashed``).
- All fault draws come from a dedicated ``seed+4`` stream with fixed
  per-round shapes: enabling faults never perturbs fading/thinning/device
  draws, and checkpoint/resume replays the exact fault schedule.

Participation (``repro.wireless.scheduler.ParticipationScheduler``):

- ``deadline_s``: edge-round deadline; a scheduled client whose simulated
  round time (2*latency + uplink airtime + downlink airtime for the
  Remark-1 traffic of ``client_round_bits`` at its chosen cut) exceeds it
  is dropped from that aggregation, and the ES waits the deadline out.
- ``selection``: ``"deadline"`` (energy+deadline gates only), ``"topk"``
  (schedule only the ``topk`` fastest affordable clients), ``"random"``
  (thin schedulable clients i.i.d. with ``participation_prob``).
- ``energy_budget_j`` / ``tx_power_w``: lifetime uplink energy budget and
  transmit power; budgets never recharge, and a client skips any round it
  cannot afford (under fading it may re-join a later, cheaper round).
  Every client that TRANSMITS pays for its airtime — a deadline-missing
  straggler is charged up to the deadline even though its update is
  discarded.
- ``seed``: RNG seed for fading draws, heterogeneity, and thinning.

Population & cohorts (``repro.wireless.population``):

- ``Population(num_clients, num_es=, assignment=, seed=)``: the
  struct-of-arrays registry for population-scale runs — packed per-client
  coordinates, ES assignment (``"round_robin"`` via
  ``repro.core.hierarchy.es_assignment`` or ``"kmeans"`` location
  clusters), Dirichlet data-skew sizes, a personalized-head round pointer,
  and a participation counter, sized for 10**5..10**6 registered clients.
  All population draws come from a dedicated ``seed + 5`` stream (channel
  = ``seed``, thinning ``+1``, device ``+2``, personalize ``+3``, faults
  ``+4``), so registering a population never perturbs the other streams.
- ``sampling``: per-round cohort selection over the registry —
  ``"uniform"`` (i.i.d.), ``"rate"`` (mean-uplink-biased), ``"pareto"``
  (participation-capped: the least-served eligible clients first, so
  coverage is Pareto-balanced across rounds); ``es_balanced=True`` keeps
  each ES's slot count fixed so the hierarchy shape never changes.
- ``CohortScheduler`` / ``make_cohort_scheduler``: a drop-in
  :class:`ParticipationScheduler` subclass whose fault-free and
  ES-outage-only rounds run as two fused jit/vmap float64 computations
  over (N,) arrays (``repro.wireless.scheduler_core``) instead of the
  host numpy loop — BIT-IDENTICAL to the oracle at any U (pinned across
  every channel/contention/pipeline/fault config by
  ``tests/test_population.py``), single-digit seconds per 10**6-client
  round on CPU (``benchmarks/cohort_bench.py`` -> ``BENCH_cohort.json``).
  Rounds carrying an erasure/crash fault plan delegate to the inherited
  oracle ``step()`` verbatim, sharing all mutable state.
- ``FedSim(..., population=, sampling=)`` / ``launch/train.py
  --population N --cohort-size C --sampling``: train over a registered
  population by sampling an ES-balanced cohort of ``hcfg.num_clients``
  training slots each round; ``cohort_report`` slices the (N,)-shaped
  :class:`RoundReport` down to the cohort's slots.  Requires a non-ideal
  channel and ``staleness_lambda == 0`` (the stale bank keys by client
  identity, which cohort slots remap per round).

Observability (``repro.telemetry``):

- ``make_scheduler(..., telemetry=)`` / ``ParticipationScheduler(...,
  telemetry=)`` / ``FedSim(..., telemetry=)`` accept a
  :class:`repro.telemetry.Telemetry` handle.  When enabled, every
  ``step()`` exports the round's :class:`RoundTimeline` — compute chunks,
  uplink payloads with their individual HARQ retransmission attempts,
  downlink, crash instants, ES outage spans — as Chrome/Perfetto trace
  events (one track per client and per ES; open the file at
  https://ui.perfetto.dev), and updates a typed metrics registry
  (participation, withdrawals/backfills, goodput vs retransmit bits,
  stale-bank depth/age, per-phase energy) flushed as JSONL.
  ``launch/train.py --trace-dir OUT`` wires all of it plus a run manifest.
- The default (``telemetry=None``) is the OFF state and is bit-inert: the
  hooks read the report and timeline, never scheduler state, draw no RNG,
  and are skipped entirely — the golden regressions and the
  ``telemetry-off-default`` reprolint rule pin this.

Aggregation semantics under a partial mask: participating clients keep
their Eq. 4/6 weights, renormalized to sum to 1; an edge round with ZERO
participants keeps the previous edge model; with a full (all-ones) mask
every path is bit-identical to the ideal-network simulator.
"""

from repro.wireless.channel import (ChannelModel, LinkState, RoundBits,
                                    client_round_bits, waterfill_shares)
from repro.wireless.cutter import (CutController, CutSpec, cut_specs,
                                   make_cut_controller)
from repro.wireless.device import DeviceModel, client_round_flops
from repro.wireless.faults import (FaultConfig, FaultInjector, FaultPlan,
                                   expected_attempts)
from repro.wireless.scheduler import ParticipationScheduler, RoundReport
from repro.wireless.population import (CohortScheduler, Population,
                                       cohort_report, kmeans_assign,
                                       make_cohort_scheduler)
from repro.wireless.timeline import RoundTimeline, build_timeline

__all__ = [
    "ChannelModel", "LinkState", "RoundBits", "client_round_bits",
    "waterfill_shares",
    "CutController", "CutSpec", "cut_specs", "make_cut_controller",
    "DeviceModel", "client_round_flops",
    "FaultConfig", "FaultInjector", "FaultPlan", "expected_attempts",
    "ParticipationScheduler", "RoundReport", "make_scheduler",
    "CohortScheduler", "Population", "cohort_report", "kmeans_assign",
    "make_cohort_scheduler",
    "RoundTimeline", "build_timeline",
]


def make_scheduler(cfg, num_clients: int, comm=None, kappa0: int = 1, *,
                   comm_table=None, es_assign=None, fixed_cut=0,
                   telemetry=None, cls=None, **extra):
    """Convenience: CommModel byte accounting -> channel -> scheduler.

    Pass either one ``comm`` (a single fixed cut, the original behavior) or
    a ``comm_table`` — an ORDERED shallow-to-deep dict of cut -> CommModel
    from ``comm_table_for_cnn``/``comm_table_for_lm`` — in which case a
    :class:`CutController` with policy ``cfg.cut_policy`` prices the cuts
    per round.  ``es_assign`` maps each client to its edge server for the
    shared-uplink contention (default: all clients on one ES).  A
    :class:`DeviceModel` built from the same config prices client compute
    alongside the bits (free when ``compute_gflops`` is inf).
    ``telemetry`` (a :class:`repro.telemetry.Telemetry`, default off) makes
    the scheduler record every round's trace and metrics.  ``cls`` swaps
    the scheduler class (``repro.wireless.population.CohortScheduler``
    uses it, forwarding its population knobs through ``extra``); the
    default is :class:`ParticipationScheduler`, byte-for-byte.
    """
    cls = ParticipationScheduler if cls is None else cls
    channel = ChannelModel(cfg, num_clients)
    device = DeviceModel(cfg, num_clients)
    # HARQ pricing for the cut controller: only a lossy channel changes the
    # estimates (ea == 1, backoff == 0 keeps them bit-identical)
    ea, backoff = 1.0, 0.0
    if cfg.faults.erasure_prob > 0.0:
        ea = expected_attempts(cfg.faults.erasure_prob,
                               cfg.faults.max_retries)
        backoff = cfg.faults.backoff_s
    if comm_table is not None:
        cutter = make_cut_controller(
            comm_table, kappa0, policy=cfg.cut_policy, fixed_cut=fixed_cut,
            deadline_s=cfg.deadline_s, tx_power_w=cfg.tx_power_w,
            compute_power_w=cfg.compute_power_w,
            codec_cycles_per_element=cfg.codec_cycles_per_element,
            pipeline=cfg.pipeline, expected_attempts=ea,
            harq_backoff_s=backoff)
        return cls(cfg, channel, cutter=cutter, es_assign=es_assign,
                   device=device, telemetry=telemetry, **extra)
    bits = client_round_bits(comm, kappa0)
    flops = client_round_flops(
        comm, kappa0, codec_cycles_per_element=cfg.codec_cycles_per_element)
    return cls(cfg, channel, bits, es_assign=es_assign, device=device,
               flops=flops, telemetry=telemetry, **extra)
