"""Participation scheduling: who makes it into each edge aggregation.

The scheduler composes three gates, applied in order, and emits a 0/1
participation mask per edge round:

1. **energy**  — a client skips any round whose uplink energy it can no
   longer afford (budgets deplete by P_tx * uplink airtime each round the
   client participates and never recharge; under a fading channel a client
   priced out of a deep-fade round may still afford a later cheap one);
2. **selection** — an optional scheduling cap: ``topk`` keeps the k
   fastest affordable clients (rate-aware scheduling), ``random`` thins
   them i.i.d. with ``participation_prob`` (unbiased client sampling);
3. **deadline** — a scheduled client completes only if its simulated round
   time (channel latency + uplink + downlink airtime for this round's
   traffic) is within ``deadline_s`` (straggler dropout).

The simulated edge-round wall clock is the slowest scheduled client's time
when every scheduled client made the deadline, else the full deadline (the
ES waits it out).  Clients the scheduler never scheduled (energy, top-k,
thinning) cost no waiting.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import WirelessConfig
from repro.wireless.channel import ChannelModel, RoundBits


@dataclass
class RoundReport:
    """What the network did in one edge round."""
    round_idx: int
    mask: np.ndarray           # (U,) float64 in {0, 1}
    times_s: np.ndarray        # (U,) per-client completion time
    round_time_s: float        # simulated wall clock of this edge round
    energy_left_j: np.ndarray  # (U,) remaining budgets AFTER this round

    @property
    def num_participants(self) -> int:
        return int(self.mask.sum())


class ParticipationScheduler:
    """Stateful per-edge-round participation decisions for U clients."""

    def __init__(self, cfg: WirelessConfig, channel: ChannelModel,
                 bits: RoundBits):
        if cfg.selection not in ("deadline", "topk", "random"):
            raise ValueError(f"unknown selection policy {cfg.selection!r}")
        self.cfg = cfg
        self.channel = channel
        self.bits = bits
        self.U = channel.U
        self.energy_left = np.full(self.U, cfg.energy_budget_j)
        self._rng = np.random.default_rng(cfg.seed + 1)

    def step(self, round_idx: int) -> RoundReport:
        cfg = self.cfg
        link = self.channel.sample(round_idx)
        times = self.channel.round_time_s(link, self.bits)
        energy = self.channel.round_energy_j(link, self.bits)

        scheduled = self.energy_left >= energy           # gate 1: energy
        if cfg.selection == "topk" and cfg.topk > 0:     # gate 2a: k fastest
            order = np.argsort(np.where(scheduled, times, np.inf))
            keep = np.zeros(self.U, bool)
            keep[order[:cfg.topk]] = True
            scheduled &= keep
        elif cfg.selection == "random" and cfg.participation_prob < 1.0:
            scheduled &= self._rng.random(self.U) < cfg.participation_prob
        alive = scheduled & (times <= cfg.deadline_s)    # gate 3: deadline

        self.energy_left = np.where(alive, self.energy_left - energy,
                                    self.energy_left)

        if not alive.any():
            # a scheduled-but-straggling client still makes the ES wait
            round_time = (float(cfg.deadline_s)
                          if scheduled.any() and np.isfinite(cfg.deadline_s)
                          else 0.0)
        elif (scheduled & ~alive).any():
            round_time = float(cfg.deadline_s)           # ES waits it out
        else:
            t = times[alive].max()
            round_time = float(t) if np.isfinite(t) else 0.0
        return RoundReport(round_idx=round_idx, mask=alive.astype(np.float64),
                           times_s=times, round_time_s=round_time,
                           energy_left_j=self.energy_left.copy())
