"""Participation scheduling: who makes it into each edge aggregation.

The scheduler composes three gates, applied in order, and emits a 0/1
participation mask per edge round:

1. **energy**  — a client skips any round whose energy it can no longer
   afford (budgets deplete each round the client transmits and never
   recharge; under a fading channel a client priced out of a deep-fade
   round may still afford a later cheap one).  The gate compares the budget
   against the DEADLINE-CAPPED charge the client would actually pay (see
   "straggler semantics" below) — gating on the uncapped full airtime would
   silently bar a client that can afford the capped charge while a richer
   client is scheduled and burns exactly that capped amount;
2. **selection** — an optional scheduling cap: ``topk`` keeps the k
   fastest affordable clients (rate-aware scheduling), ``random`` thins
   them i.i.d. with ``participation_prob`` (unbiased client sampling);
3. **deadline** — a scheduled client completes only if its simulated round
   time (channel latency + uplink + downlink airtime for this round's
   traffic) is within ``deadline_s`` (straggler dropout).

Two optional refinements sit between gates 2 and 3:

- **cut selection** (``cutter``): a :class:`repro.wireless.cutter.
  CutController` picks a per-client cut each round, making the traffic
  (and therefore times, energies, and the deadline outcome) cut-indexed;
- **per-ES contention** (``es_uplink_mbps`` finite): the scheduled clients
  of one ES split its uplink capacity (evenly, or rate-proportionally under
  ``contention="proportional"``), so times/energies are recomputed at the
  contended rates, adaptive cut policies re-decide, and clients the
  contended price makes unaffordable withdraw (they never transmit, cost
  nothing, and make nobody wait).  With ``reshare_uplink=True`` (default) a
  SECOND contention pass then re-shares the capacity the withdrawn clients
  freed among the survivors — survivor rates can only rise (fewer clients
  split the same pipe), so no further withdrawals are possible and one
  extra pass suffices; the survivors keep the cuts they chose at the
  first-pass rates (the freed capacity only speeds them up).
  ``reshare_uplink=False`` reproduces the conservative single pass.

A per-client **device model** (``repro.wireless.device``) adds client-side
COMPUTE to every decision: the round time is compute + channel time, the
energy charge is compute joules + transmit joules, and adaptive cut
policies price each candidate's FLOPs next to its bits — so a deep cut's
smaller activation tensor no longer looks free on a compute-starved
client.  ``compute_gflops=inf`` (the default) zeroes every compute term:
the pre-device scheduler bit-for-bit, EXCEPT where the straggler-semantics
bugfixes below intentionally changed the accounting (the deadline-capped
energy gate and the moved-bits ledger differ from the old code whenever
``deadline_s`` is finite; the golden regression pins the inf-deadline
scenarios where no fix applies).

Straggler semantics (the single source of truth for gate, charge, and
traffic accounting): a scheduled client first COMPUTES (kappa0 local
epochs of client-block work at ``compute_power_w``), then TRANSMITS (at
``tx_power_w``) until it finishes or the deadline cuts it off.  Its
deadline-capped activity is therefore

    compute_s = min(full compute time, deadline)
    tx_s      = min(uplink airtime, max(deadline - compute time, 0))

(deliberately latency-free, like the pre-device straggler charge and the
Eq.-17 traffic terms: latency is charged on the round CLOCK, not against
the transmit window, so the capped window slightly over-credits a
straggler whose deadline slack is mostly propagation delay)

and the energy charge is ``compute_power_w * compute_s + tx_power_w *
tx_s`` — paid by EVERY scheduled client, deadline-missing stragglers
included (their update is discarded but the joules are spent).  The energy
gate admits exactly the clients whose budget covers this charge, so the
gate and the deduction can never disagree and budgets never go negative.
A client whose compute alone consumes the whole deadline window (tx window
zero) is never scheduled at all: it could not push a single bit before the
cutoff, so scheduling it would only burn a contention share and pin the
round clock at the deadline.
``RoundReport.bits_tx`` counts the bits that actually MOVED: a straggler
moved only ``uplink_bps * tx_s`` uplink bits and never received its
downlink, so it contributes that, not its full offered up+down traffic.

The simulated edge-round wall clock is the slowest scheduled client's time
when every scheduled client made the deadline, else the full deadline (the
ES waits it out).  Clients the scheduler never scheduled (energy, top-k,
thinning) cost no waiting.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import WirelessConfig
from repro.wireless.channel import ChannelModel, LinkState, RoundBits
from repro.wireless.device import DeviceModel


@dataclass
class RoundReport:
    """What the network did in one edge round."""
    round_idx: int
    mask: np.ndarray           # (U,) float64 in {0, 1}
    times_s: np.ndarray        # (U,) per-client completion time (compute +
    #                            latency + airtime)
    round_time_s: float        # simulated wall clock of this edge round
    energy_left_j: np.ndarray  # (U,) remaining budgets AFTER this round
    scheduled: np.ndarray = None   # (U,) bool: transmitted this round
    cuts: np.ndarray = None        # (U,) int cut indices (None: fixed bits)
    uplink_bps: np.ndarray = None  # (U,) effective (contended) uplink rates
    codecs: np.ndarray = None      # (U,) int codec indices into the
    #                                controller's codec_names (None unless a
    #                                cut x codec grid is in play)
    bits_tx: float = 0.0           # total bits actually MOVED this round by
    #                                scheduled clients (a deadline-cut
    #                                straggler counts only the uplink bits
    #                                it pushed before the cutoff, and no
    #                                downlink)
    compute_s: np.ndarray = None   # (U,) per-client local compute time of
    #                                this round's workload (device model)
    compute_j: np.ndarray = None   # (U,) compute joules actually charged
    #                                (zero for unscheduled clients)

    @property
    def num_participants(self) -> int:
        return int(self.mask.sum())

    @property
    def mean_cut(self) -> float | None:
        """Mean cut position of the clients that actually transmitted (all
        clients when nobody did — their entries are the hypothetical
        private-rate picks).  None without a cut controller."""
        if self.cuts is None:
            return None
        sel = (self.scheduled if self.scheduled is not None
               and self.scheduled.any() else np.ones(len(self.cuts), bool))
        return float(self.cuts[sel].mean())


class ParticipationScheduler:
    """Stateful per-edge-round participation decisions for U clients."""

    def __init__(self, cfg: WirelessConfig, channel: ChannelModel,
                 bits: RoundBits | None = None, *, cutter=None,
                 es_assign: np.ndarray | None = None,
                 device: DeviceModel | None = None, flops: float = 0.0):
        if cfg.selection not in ("deadline", "topk", "random"):
            raise ValueError(f"unknown selection policy {cfg.selection!r}")
        if (bits is None) == (cutter is None):
            raise ValueError("pass exactly one of bits= or cutter=")
        self.cfg = cfg
        self.channel = channel
        self.bits = bits
        self.cutter = cutter
        self.U = channel.U
        # device (compute) model; ``flops`` is the fixed-bits path's per-round
        # client workload (the cutter path carries per-cell FLOPs itself)
        self.device = device if device is not None else DeviceModel(cfg,
                                                                    self.U)
        self.flops = flops
        # ES attachment for the shared-uplink contention; default: one pool
        self.es_assign = (np.zeros(self.U, int) if es_assign is None
                          else np.asarray(es_assign, int))
        assert self.es_assign.shape == (self.U,)
        self.energy_left = np.full(self.U, cfg.energy_budget_j)
        self._rng = np.random.default_rng(cfg.seed + 1)

    def _bits_cuts(self, up_bps, down_bps, latency_s):
        """Cut decision (or the fixed bits) at the given rates."""
        if self.cutter is None:
            return self.bits, None
        cuts = self.cutter.decide(up_bps, down_bps, latency_s,
                                  self.energy_left,
                                  self.device.sec_per_flop)
        return self.cutter.bits_for(cuts), cuts

    def _compute_s(self, cuts) -> np.ndarray:
        """Per-client local compute time of this round's workload."""
        flops = self.flops if cuts is None else self.cutter.flops_for(cuts)
        return np.broadcast_to(self.device.compute_time_s(flops), (self.U,))

    def _charge(self, link: LinkState, bits: RoundBits, comp_s: np.ndarray):
        """Deadline-capped (charge, tx_s, comp_charged_s, can_tx) per client.

        The straggler semantics of the module docstring: compute first,
        transmit until done or cut off, pay for both.  This one quantity
        drives the energy GATE, the energy DEDUCTION, and the moved-bits
        accounting, so they can never disagree.  ``can_tx`` is False for a
        client whose compute alone consumes the whole deadline window — it
        could not push a single bit before the cutoff, so scheduling it
        would only burn a contention share and pin the round clock (at
        ``compute_power_w=0`` its charge is 0, so without this flag the
        energy gate would schedule it forever).
        """
        cfg = self.cfg
        with np.errstate(divide="ignore"):
            t_up = np.asarray(bits.uplink, float) / link.uplink_bps
        t_up = np.where(np.isfinite(t_up), t_up, 0.0)
        c_s = np.minimum(comp_s, cfg.deadline_s)
        window = np.maximum(cfg.deadline_s - comp_s, 0.0)
        tx_s = np.minimum(t_up, window)
        charge = cfg.tx_power_w * tx_s + cfg.compute_power_w * c_s
        return charge, tx_s, c_s, window > 0

    def step(self, round_idx: int) -> RoundReport:
        cfg = self.cfg
        link = self.channel.sample(round_idx)
        bits, cuts = self._bits_cuts(link.uplink_bps, link.downlink_bps,
                                     link.latency_s)
        comp_s = self._compute_s(cuts)
        times = self.channel.round_time_s(link, bits) + comp_s
        charge, tx_s, c_s, can_tx = self._charge(link, bits, comp_s)

        # gate 1: energy (deadline-capped charge) + a transmit window at all
        scheduled = (self.energy_left >= charge) & can_tx
        if cfg.selection == "topk" and cfg.topk > 0:     # gate 2a: k fastest
            order = np.argsort(np.where(scheduled, times, np.inf))
            keep = np.zeros(self.U, bool)
            keep[order[:cfg.topk]] = True
            scheduled &= keep
        elif cfg.selection == "random" and cfg.participation_prob < 1.0:
            scheduled &= self._rng.random(self.U) < cfg.participation_prob

        # ---- per-ES uplink contention among the scheduled clients ----
        private = link
        eff_up = self.channel.contended_uplink(link, scheduled,
                                               self.es_assign)
        if eff_up is not link.uplink_bps:
            link = LinkState(eff_up, link.downlink_bps, link.latency_s)
            if self.cutter is not None and self.cutter.policy != "fixed":
                # adaptive policies re-decide at the rate actually available
                bits2, cuts2 = self._bits_cuts(eff_up, link.downlink_bps,
                                               link.latency_s)
                cuts = np.where(scheduled, cuts2, cuts)
                bits = self.cutter.bits_for(cuts)
                comp_s = self._compute_s(cuts)
            times = self.channel.round_time_s(link, bits) + comp_s
            charge, tx_s, c_s, can_tx = self._charge(link, bits, comp_s)
            # the contended price can only be higher; a client that can no
            # longer afford it (or whose re-decided cut left it no transmit
            # window) withdraws before transmitting
            withdrawn = scheduled & ~((self.energy_left >= charge) & can_tx)
            scheduled &= (self.energy_left >= charge) & can_tx
            if (self.cfg.reshare_uplink and withdrawn.any()
                    and scheduled.any()):
                # second pass: survivors absorb the capacity the withdrawn
                # clients freed.  Rates can only rise (fewer clients share
                # the same pipe), so times/energies only fall and no new
                # withdrawal is possible; the survivors keep their
                # first-pass cut/codec choices.
                eff_up = self.channel.contended_uplink(private, scheduled,
                                                       self.es_assign)
                link = LinkState(eff_up, private.downlink_bps,
                                 private.latency_s)
                times = self.channel.round_time_s(link, bits) + comp_s
                charge, tx_s, c_s, _ = self._charge(link, bits, comp_s)

        alive = scheduled & (times <= cfg.deadline_s)    # gate 3: deadline

        # every scheduled client pays the deadline-capped charge (compute
        # joules + transmit joules) — the SAME quantity the energy gate
        # admitted it on, so the budget can never go negative
        self.energy_left = np.where(scheduled, self.energy_left - charge,
                                    self.energy_left)

        if not alive.any():
            # a scheduled-but-straggling client still makes the ES wait
            round_time = (float(cfg.deadline_s)
                          if scheduled.any() and np.isfinite(cfg.deadline_s)
                          else 0.0)
        elif (scheduled & ~alive).any():
            round_time = float(cfg.deadline_s)           # ES waits it out
        else:
            t = times[alive].max()
            round_time = float(t) if np.isfinite(t) else 0.0
        # translate internal candidate-cell indices into cut depth / codec
        # positions so the report reads "which split, which codec", and sum
        # the bits that actually MOVED: a completing client moved its full
        # up+down traffic, a deadline-cut straggler only the uplink bits it
        # pushed before the cutoff (uplink_bps * tx_s) and no downlink
        rep_cuts = rep_codecs = None
        if cuts is not None:
            rep_cuts = self.cutter.cut_pos[cuts]
            if self.cutter.has_codec_grid:
                rep_codecs = self.cutter.codec_pos[cuts]
        up = np.broadcast_to(np.asarray(bits.uplink, float), (self.U,))
        down = np.broadcast_to(np.asarray(bits.downlink, float), (self.U,))
        up_rate = np.broadcast_to(np.asarray(link.uplink_bps, float),
                                  (self.U,))
        with np.errstate(invalid="ignore"):      # ideal channel: inf * 0
            moved_up = np.where(alive, up,
                                np.where(tx_s > 0, up_rate * tx_s, 0.0))
        moved = moved_up + np.where(alive, down, 0.0)
        bits_tx = float(moved[scheduled].sum())
        compute_j = np.where(scheduled, cfg.compute_power_w * c_s, 0.0)
        return RoundReport(round_idx=round_idx, mask=alive.astype(np.float64),
                           times_s=times, round_time_s=round_time,
                           energy_left_j=self.energy_left.copy(),
                           scheduled=scheduled.copy(), cuts=rep_cuts,
                           uplink_bps=np.asarray(link.uplink_bps).copy(),
                           codecs=rep_codecs, bits_tx=bits_tx,
                           compute_s=np.asarray(comp_s, float).copy(),
                           compute_j=compute_j)
