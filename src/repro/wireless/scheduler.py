"""Participation scheduling: who makes it into each edge aggregation.

The scheduler composes three gates, applied in order, and emits a 0/1
participation mask per edge round:

1. **energy**  — a client skips any round whose energy it can no longer
   afford (budgets deplete each round the client transmits and never
   recharge; under a fading channel a client priced out of a deep-fade
   round may still afford a later cheap one).  The gate compares the budget
   against the DEADLINE-CAPPED charge the client would actually pay (see
   "timeline straggler semantics" below) — gating on the uncapped full
   airtime would silently bar a client that can afford the capped charge
   while a richer client is scheduled and burns exactly that capped amount;
2. **selection** — an optional scheduling cap: ``topk`` keeps the k
   fastest affordable clients (rate-aware scheduling), ``random`` thins
   them i.i.d. with ``participation_prob`` (unbiased client sampling);
3. **deadline** — a scheduled client completes only if its simulated round
   time (channel latency + its timeline's uplink/downlink/compute activity)
   is within ``deadline_s`` (straggler dropout).

Two optional refinements sit between gates 2 and 3:

- **cut selection** (``cutter``): a :class:`repro.wireless.cutter.
  CutController` picks a per-client cut each round, making the traffic
  (and therefore times, energies, and the deadline outcome) cut-indexed;
- **per-ES contention** (``es_uplink_mbps`` finite): the scheduled clients
  of one ES split its uplink capacity (evenly, or rate-proportionally with
  water-filling under ``contention="proportional"``), so times/energies are
  recomputed at the contended rates, adaptive cut policies re-decide, and
  clients the contended price makes unaffordable withdraw (they never
  transmit, cost nothing, and make nobody wait).  With
  ``reshare_uplink=True`` (default) a SECOND contention pass then re-shares
  the capacity the withdrawn clients freed among the survivors — survivor
  rates can only rise (fewer clients split the same pipe), so no further
  withdrawals are possible and one extra pass suffices; the survivors keep
  the cuts they chose at the first-pass rates (the freed capacity only
  speeds them up).  ``reshare_uplink=False`` reproduces the conservative
  single pass.  Under ``selection="topk"``, a withdrawal no longer silently
  shrinks the round below k: a single BACKFILL pass promotes the
  next-fastest affordable clients (by their pre-contention private times)
  into the freed slots and re-runs the contention round on the refilled
  set — any client the refilled price makes unaffordable (backfilled or
  original) withdraws, and the pass does not iterate further, so the
  round is bounded at two contention rounds and can still end under k if
  the refilled prices bite.

Timeline event model (``repro.wireless.timeline``): every per-client
quantity — completion time, deadline-capped charge, moved bits — is read
off ONE explicit per-client event timeline of compute segments, uplink
segments, and the downlink segment, so the gate, the deduction, and the
ledger can never disagree.  Two timeline shapes exist:

- **serial** (``WirelessConfig.pipeline=False``, default): compute first
  (kappa0 local epochs), then transmit, then receive — the paper's Eq.-17
  model, bit-for-bit identical to the pre-timeline scheduler;
- **pipelined** (``pipeline=True``): the kappa0 x batches_per_epoch
  minibatch activations STREAM — each payload transmits as soon as its
  minibatch's compute finishes and the radio is free, so the uplink
  finishes at ``c + u + (n-1)*max(c, u) + tail`` instead of ``n*c + n*u +
  tail`` (per-chunk compute c, per-payload airtime u): pipelining saves
  exactly ``(n-1)*min(c, u) >= 0`` and the round time moves from
  ``compute + tx`` toward ``max(compute, tx)`` plus one fill bubble.

Timeline straggler semantics (the single source of truth for gate, charge,
and traffic accounting): activity segments are LATENCY-FREE — latency is
charged on the round CLOCK (``times_s``), not against the transmit window,
so the capped window slightly over-credits a straggler whose deadline
slack is mostly propagation delay.  A deadline at ``T`` freezes the
timeline at ``T``: each segment is charged its overlap with ``[0, T)``, so

    compute_charged_s = min(total compute, T)
    tx_charged_s      = sum over uplink segments of their overlap with T
    down_window_s     = overlap of the downlink segment with T

(serial: ``tx_charged_s = min(uplink airtime, max(T - compute, 0))``
exactly as before; pipelined: the per-segment sum credits the airtime
actually spent under the overlapped schedule) and the energy charge is
``compute_power_w * compute_charged_s + tx_power_w * tx_charged_s`` — paid
by EVERY scheduled client, deadline-missing stragglers included (their
update is discarded, unless staleness banking folds it in late — below).
The energy gate admits exactly the clients whose budget covers this
charge, so the gate and the deduction can never disagree and budgets never
go negative.  A client that could not push a single uplink bit before the
cutoff (serial: compute alone eats the window; pipelined: even the FIRST
chunk's compute does) is never scheduled at all: scheduling it would only
burn a contention share and pin the round clock at the deadline.
``RoundReport.bits_tx`` counts the bits that actually MOVED, both ways: a
straggler counts ``uplink_bps * tx_charged_s`` uplink bits plus
``downlink_bps * down_window_s`` downlink bits (a client cut mid-downlink
is credited the partial broadcast it did receive — the downlink twin of
the pro-rated uplink credit).

Staleness banking (``WirelessConfig.staleness_lambda > 0``): a deadline-cut
straggler's undelivered uplink remainder is BANKED (``uplink bits -
moved uplink bits``) instead of discarded.  In each later round the banked
client is idle (unscheduled), its radio background-pushes the remainder at
its PRIVATE rate inside that round's wall-clock window, energy-gated and
energy-charged like any transmission; when the remainder reaches zero the
update is DELIVERED at staleness ``s`` = the number of edge rounds since
it was banked (``RoundReport.stale_delivered[u] = s``, always >= 1), and
``repro.core.fedsim`` folds the banked model into that round's edge
aggregation with weight ``alpha_u * lambda**s``.  A bank dies without
delivering when its client completes a FRESH round (the fresh update
supersedes it) or straggles again (the new remainder replaces it) —
``RoundReport.stale_dropped``.  ``staleness_lambda=0`` (default) disables
the machinery entirely and reproduces the hard-dropout scheduler
bit-for-bit.

The simulated edge-round wall clock is the slowest scheduled client's time
when every scheduled client made the deadline, else the full deadline (the
ES waits it out).  Clients the scheduler never scheduled (energy, top-k,
thinning) cost no waiting, and background stale pushes ride inside the
existing window.

Failure semantics (``WirelessConfig.faults``; repro.wireless.faults):

- **Erasures + HARQ**: every uplink payload and the downlink broadcast is
  erased i.i.d. per attempt with ``erasure_prob`` and retransmitted (after
  ``backoff_s`` of radio idle) up to ``max_retries`` times.  Retransmitted
  copies are ordinary timeline segments, so the deadline gate, the energy
  charge, and the moved-bits ledger price them with the SAME freeze rule
  as first transmissions; ``RoundReport.bits_tx`` counts AIR bits (every
  attempt), and ``retx_bits``/``retx_j`` isolate the overhead beyond the
  first attempts.  A client whose payload exhausts its retries is FAILED
  (``RoundReport.failed``): not alive, but with ``staleness_lambda > 0``
  its NOT-yet-delivered remainder (nominal bits minus erasure-survived
  goodput) flows into the stale bank and can still land late — graceful
  means "late and discounted", never "silently lost".  A client that
  delivered its uplink but lost every downlink attempt (``down_failed``)
  still participates in the aggregation (the ES has its update) but keeps
  its own local model instead of the refreshed edge model (the FedSim
  fold).
- **ES outage + failover**: ``es_outage_trace`` marks whole ESs down for
  whole rounds (``RoundReport.es_down``).  ``failover="reassoc"`` moves
  the dead ES's clients to the nearest live ES (``RoundReport.es_map``),
  where they re-enter that ES's contention pass and join ITS aggregation;
  ``"skip"`` sits them out (never scheduled, cost nothing).  Banked stale
  pushes pause while the client's effective ES is down.  A dead ES's edge
  model is simply carried forward by FedSim's existing zero-participant
  fallback.
- **Client crash**: with probability ``crash_hazard`` per round, a
  scheduled client dies at a uniform instant; its timeline freezes at
  ``min(deadline, crash instant)`` — partial compute charged, partial
  uplink credited as moved bits, exactly the straggler freeze applied at
  the crash cap (``RoundReport.crashed``).  A crashed client loses its
  local state, so its remainder is NOT banked (unlike a straggler or an
  erasure failure).  The energy gate admits on the SAME crash-capped
  charge it deducts, preserving gate == deduction (the simulator is
  omniscient about its own fault draws; a conservative no-crash gate
  would break that invariant).
- ``FaultConfig()`` (all defaults) builds no injector at all: every code
  path above is skipped and the scheduler is bit-identical to the
  fault-free one (golden-pinned).  Fault draws come from the dedicated
  ``seed+4`` stream with FIXED per-round shapes, so enabling faults never
  perturbs fading/thinning draws and checkpoint/resume (``state_dict`` /
  ``load_state_dict``) replays the exact fault schedule.

Oracle contract (population-scale twin): this numpy scheduler is the
REFERENCE ORACLE for the vectorized cohort path — ``repro.wireless.
population.CohortScheduler`` re-derives the same per-round decisions as
fused float64 jax ops (``repro.wireless.scheduler_core``) and must
reproduce this class's :class:`RoundReport` BIT-IDENTICALLY on every
fault-free (and outage-only) configuration; rounds with an erasure/crash
fault plan are delegated back to this implementation.  The equivalence
is pinned by the U=8 property test in ``tests/test_population.py``
across channel models, contention rules, pipeline on/off, selection
policies, and fault-injected rounds.  When changing any per-round
expression here, keep ``scheduler_core`` in lockstep (or the property
test will say so).  ``cohort_mask`` (set per round by CohortScheduler,
None otherwise) restricts gate 1 to a sampled cohort; the default None
leaves this class's behavior byte-for-byte unchanged.
"""

from __future__ import annotations

from dataclasses import dataclass, fields

import numpy as np

from repro.configs.base import WirelessConfig
from repro.wireless.channel import ChannelModel, LinkState, RoundBits
from repro.wireless.device import DeviceModel
from repro.wireless.faults import FaultInjector
from repro.wireless.timeline import RoundTimeline, build_timeline


@dataclass
class RoundReport:
    """What the network did in one edge round."""
    round_idx: int
    mask: np.ndarray           # (U,) float64 in {0, 1}
    times_s: np.ndarray        # (U,) per-client completion time (compute +
    #                            latency + airtime)
    round_time_s: float        # simulated wall clock of this edge round
    energy_left_j: np.ndarray  # (U,) remaining budgets AFTER this round
    scheduled: np.ndarray = None   # (U,) bool: transmitted this round
    cuts: np.ndarray = None        # (U,) int cut indices (None: fixed bits)
    uplink_bps: np.ndarray = None  # (U,) effective (contended) uplink rates
    codecs: np.ndarray = None      # (U,) int codec indices into the
    #                                controller's codec_names (None unless a
    #                                cut x codec grid is in play)
    bits_tx: float = 0.0           # total bits actually MOVED this round by
    #                                scheduled clients (a deadline-cut
    #                                straggler counts the uplink bits it
    #                                pushed and the downlink bits it received
    #                                before the cutoff) plus background
    #                                stale-bank pushes
    compute_s: np.ndarray = None   # (U,) per-client local compute time of
    #                                this round's workload (device model)
    compute_j: np.ndarray = None   # (U,) compute joules actually charged
    #                                (zero for unscheduled clients)
    stale_banked: np.ndarray = None     # (U,) bool: this round's straggler
    #                                remainder was banked for late delivery
    #                                (None unless staleness_lambda > 0)
    stale_delivered: np.ndarray = None  # (U,) int: a banked update finished
    #                                arriving this round, value = staleness
    #                                in edge rounds (0 = nothing delivered)
    stale_dropped: np.ndarray = None    # (U,) bool: a bank died unfolded
    #                                (superseded by a fresh round or
    #                                replaced by a newer straggle)
    crashed: np.ndarray = None     # (U,) bool: died mid-round at the crash
    #                                cap (None unless erasures/crashes on)
    failed: np.ndarray = None      # (U,) bool: an uplink payload exhausted
    #                                its HARQ retries (update never arrived)
    down_failed: np.ndarray = None  # (U,) bool: alive (uplink delivered)
    #                                but every downlink attempt was lost —
    #                                FedSim keeps this client's local model
    es_down: np.ndarray = None     # (B,) bool outage mask of this round
    #                                (None: no outage this round)
    es_map: np.ndarray = None      # (U,) int effective ES after failover
    #                                (None except reassoc outage rounds)
    retx_bits: float = 0.0         # air bits beyond first attempts (HARQ
    #                                overhead; included in bits_tx)
    retx_j: float = 0.0            # transmit joules beyond first attempts

    # dtypes for from_json_dict (JSON erases them); absent keys default to
    # float.  NOT a dataclass field (no annotation).
    _DTYPES = {"mask": np.float64, "scheduled": bool, "cuts": int,
               "codecs": int, "stale_banked": bool, "stale_delivered": int,
               "stale_dropped": bool, "crashed": bool, "failed": bool,
               "down_failed": bool, "es_down": bool, "es_map": int}

    @property
    def num_participants(self) -> int:
        return int(self.mask.sum())

    @property
    def mean_cut(self) -> float | None:
        """Mean cut position of the clients that actually transmitted (all
        clients when nobody did — their entries are the hypothetical
        private-rate picks).  None without a cut controller."""
        if self.cuts is None:
            return None
        sel = (self.scheduled if self.scheduled is not None
               and self.scheduled.any() else np.ones(len(self.cuts), bool))
        return float(self.cuts[sel].mean())

    def to_json_dict(self) -> dict:
        """JSON-safe dict: every field (ndarrays -> lists) plus the derived
        ``participants`` and ``mean_cut`` the sweep benchmarks table.  The
        inverse is :meth:`from_json_dict`."""
        out = {}
        for f in fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                v = v.tolist()
            elif isinstance(v, (np.floating, np.integer, np.bool_)):
                v = v.item()
            out[f.name] = v
        out["participants"] = self.num_participants
        out["mean_cut"] = self.mean_cut
        return out

    @classmethod
    def from_json_dict(cls, d: dict) -> "RoundReport":
        """Rebuild a report from :meth:`to_json_dict` output (derived keys
        are ignored; list fields come back as arrays of their native
        dtype)."""
        kw = {}
        for f in fields(cls):
            if f.name not in d:
                continue
            v = d[f.name]
            if isinstance(v, list):
                v = np.asarray(v, cls._DTYPES.get(f.name, float))
            kw[f.name] = v
        return cls(**kw)


class ParticipationScheduler:
    """Stateful per-edge-round participation decisions for U clients."""

    def __init__(self, cfg: WirelessConfig, channel: ChannelModel,
                 bits: RoundBits | None = None, *, cutter=None,
                 es_assign: np.ndarray | None = None,
                 device: DeviceModel | None = None, flops: float = 0.0,
                 telemetry=None):
        if cfg.selection not in ("deadline", "topk", "random"):
            raise ValueError(f"unknown selection policy {cfg.selection!r}")
        if (bits is None) == (cutter is None):
            raise ValueError("pass exactly one of bits= or cutter=")
        if not 0.0 <= cfg.staleness_lambda <= 1.0:
            raise ValueError(f"staleness_lambda must be in [0, 1], got "
                             f"{cfg.staleness_lambda}")
        self.cfg = cfg
        self.channel = channel
        self.bits = bits
        self.cutter = cutter
        self.U = channel.U
        # device (compute) model; ``flops`` is the fixed-bits path's per-round
        # client workload (the cutter path carries per-cell FLOPs itself)
        self.device = device if device is not None else DeviceModel(cfg,
                                                                    self.U)
        self.flops = flops
        # ES attachment for the shared-uplink contention; default: one pool
        self.es_assign = (np.zeros(self.U, int) if es_assign is None
                          else np.asarray(es_assign, int))
        assert self.es_assign.shape == (self.U,)
        self.energy_left = np.full(self.U, cfg.energy_budget_j)
        self._rng = np.random.default_rng(cfg.seed + 1)
        # staleness banking state: the undelivered uplink remainder of each
        # client's last straggle, and its age in edge rounds (-1 = no bank)
        self._stale_pending = np.zeros(self.U)
        self._stale_age = np.full(self.U, -1)
        # fault injection (module docstring "Failure semantics"); the
        # all-defaults FaultConfig builds NO injector and every fault code
        # path below is skipped (bit-identity to the fault-free scheduler)
        self.injector = None
        if cfg.faults.active:
            chunks = (self.cutter.chunks if self.cutter is not None
                      else int(bits.chunks))
            n_seg = (int(chunks) + 1) if cfg.pipeline else 1
            self.injector = FaultInjector(
                cfg.faults, self.U, n_seg,
                int(self.es_assign.max()) + 1, cfg.seed)
        self._plan = None                  # this round's FaultPlan (or None)
        self._es_eff = self.es_assign      # effective ES map after failover
        # observability (repro.telemetry): a purely-read-only observer of
        # each round's report + timeline.  None (the default, enforced by
        # reprolint's telemetry-off-default) skips every hook — no file
        # I/O, no RNG, no arithmetic on scheduler state
        self.telemetry = telemetry
        self.last_timeline = None          # the most recent step's timeline
        # cohort restriction (population-scale runs): a (U,) bool mask
        # ANDed into gate 1 each round, so only the sampled cohort can be
        # scheduled while everyone else's state (energy, banks) advances.
        # None (the default) is byte-for-byte the unrestricted scheduler.
        self.cohort_mask = None

    def _bits_cuts(self, up_bps, down_bps, latency_s):
        """Cut decision (or the fixed bits) at the given rates."""
        if self.cutter is None:
            return self.bits, None
        cuts = self.cutter.decide(up_bps, down_bps, latency_s,
                                  self.energy_left,
                                  self.device.sec_per_flop)
        return self.cutter.bits_for(cuts), cuts

    def _compute_s(self, cuts) -> np.ndarray:
        """Per-client local compute time of this round's workload."""
        flops = self.flops if cuts is None else self.cutter.flops_for(cuts)
        return np.broadcast_to(self.device.compute_time_s(flops), (self.U,))

    def _timeline(self, link: LinkState, bits: RoundBits,
                  comp_s: np.ndarray) -> RoundTimeline:
        """The round's per-client event timeline at the given rates — the
        single source of truth for times, charges, and moved bits (module
        docstring's timeline straggler semantics).  ``self._plan`` (drawn
        once at the top of ``step``) routes fault rounds to the HARQ/crash
        builder; every rebuild of the round re-prices the SAME fates."""
        return build_timeline(link, bits, comp_s, self.cfg.deadline_s,
                              self.U, pipeline=self.cfg.pipeline,
                              plan=self._plan)

    def _contend(self, private: LinkState, scheduled: np.ndarray, bits, cuts,
                 comp_s, tl: RoundTimeline):
        """One full contention round over the ``scheduled`` set.

        Shares the per-ES pipe, lets adaptive cut policies re-decide at the
        contended rates, withdraws clients the contended price makes
        unaffordable, and (``reshare_uplink``) re-shares their freed
        capacity among the survivors.  Returns the (possibly shrunk)
        scheduled set plus everything priced at the final rates; a bypassed
        contention (ideal channel / infinite capacity) returns the inputs
        untouched with ``contended=False``.
        """
        cfg = self.cfg
        link = private
        eff_up = self.channel.contended_uplink(private, scheduled,
                                               self._es_eff)
        if eff_up is private.uplink_bps:
            return (link, bits, cuts, comp_s, tl, scheduled,
                    np.zeros(self.U, bool), False)
        link = LinkState(eff_up, private.downlink_bps, private.latency_s)
        if self.cutter is not None and self.cutter.policy != "fixed":
            # adaptive policies re-decide at the rate actually available
            bits2, cuts2 = self._bits_cuts(eff_up, link.downlink_bps,
                                           link.latency_s)
            cuts = np.where(scheduled, cuts2, cuts)
            bits = self.cutter.bits_for(cuts)
            comp_s = self._compute_s(cuts)
        tl = self._timeline(link, bits, comp_s)
        charge = tl.charge_j(cfg.tx_power_w, cfg.compute_power_w)
        # the contended price can only be higher; a client that can no
        # longer afford it (or whose re-decided cut left it no transmit
        # window) withdraws before transmitting
        ok = (self.energy_left >= charge) & tl.can_tx
        withdrawn = scheduled & ~ok
        scheduled = scheduled & ok
        if cfg.reshare_uplink and withdrawn.any() and scheduled.any():
            # second pass: survivors absorb the capacity the withdrawn
            # clients freed.  Rates can only rise (fewer clients share
            # the same pipe), so times/energies only fall and no new
            # withdrawal is possible; the survivors keep their
            # first-pass cut/codec choices.
            eff_up = self.channel.contended_uplink(private, scheduled,
                                                   self._es_eff)
            link = LinkState(eff_up, private.downlink_bps,
                             private.latency_s)
            tl = self._timeline(link, bits, comp_s)
        return link, bits, cuts, comp_s, tl, scheduled, withdrawn, True

    def step(self, round_idx: int) -> RoundReport:
        cfg = self.cfg
        link = self.channel.sample(round_idx)
        private = link
        # ---- fault round state (module docstring "Failure semantics"):
        # erasure fates and crash instants are drawn ONCE, before any
        # timeline, so contention re-pricing re-uses the same outcomes;
        # an ES outage remaps (reassoc) or sidelines (skip) its clients
        self._plan = None
        self._es_eff = self.es_assign
        es_down = None
        client_down = None
        if self.injector is not None:
            self._plan = self.injector.round_plan()
            es_down = self.injector.es_down(round_idx)
            if es_down is not None and es_down.any():
                self._es_eff, client_down = self.injector.failover(
                    es_down, self.es_assign)
            else:
                es_down = None
        bits, cuts = self._bits_cuts(link.uplink_bps, link.downlink_bps,
                                     link.latency_s)
        comp_s = self._compute_s(cuts)
        tl = self._timeline(link, bits, comp_s)
        charge = tl.charge_j(cfg.tx_power_w, cfg.compute_power_w)
        times0 = tl.times_s                     # private-rate times (topk)

        # gate 1: energy (deadline-capped charge) + a transmit window at all
        gate1 = (self.energy_left >= charge) & tl.can_tx
        if client_down is not None:
            gate1 &= ~client_down        # outage-skipped: never scheduled
        if self.cohort_mask is not None:
            gate1 &= self.cohort_mask    # population runs: sampled cohort
        scheduled = gate1.copy()
        if cfg.selection == "topk" and cfg.topk > 0:     # gate 2a: k fastest
            order = np.argsort(np.where(scheduled, times0, np.inf))
            keep = np.zeros(self.U, bool)
            keep[order[:cfg.topk]] = True
            scheduled &= keep
        elif cfg.selection == "random" and cfg.participation_prob < 1.0:
            scheduled &= self._rng.random(self.U) < cfg.participation_prob

        # ---- per-ES uplink contention among the scheduled clients ----
        bits0, cuts0, comp0, tl0 = bits, cuts, comp_s, tl
        (link, bits, cuts, comp_s, tl, scheduled, withdrawn,
         contended) = self._contend(private, scheduled, bits, cuts, comp_s,
                                    tl)
        n_backfilled = 0
        if (contended and cfg.selection == "topk" and cfg.topk > 0
                and int(scheduled.sum()) < cfg.topk):
            # topk BACKFILL (single pass, see module docstring): promote the
            # next-fastest affordable never-withdrawn clients into the freed
            # slots and re-run the contention round on the refilled set
            pool = gate1 & ~scheduled & ~withdrawn
            if pool.any():
                order = np.argsort(np.where(pool, times0, np.inf))
                extra = np.zeros(self.U, bool)
                extra[order[:cfg.topk - int(scheduled.sum())]] = True
                extra &= pool
                if extra.any():
                    (link, bits, cuts, comp_s, tl, scheduled, withdrawn,
                     _) = self._contend(private, scheduled | extra, bits0,
                                        cuts0, comp0, tl0)
                    n_backfilled = int((scheduled & extra).sum())
        times = tl.times_s
        charge = tl.charge_j(cfg.tx_power_w, cfg.compute_power_w)

        alive = scheduled & (times <= cfg.deadline_s)    # gate 3: deadline
        crashed = failed = down_failed = None
        if self._plan is not None:
            # gates 3b/3c: a crashed or HARQ-exhausted client's update never
            # arrives; a lost downlink does NOT kill participation (the ES
            # holds the uplink — the client just keeps its local model)
            crashed = scheduled & tl.crashed
            failed = scheduled & ~tl.crashed & ~self._plan.up_ok.all(axis=1)
            alive &= tl.up_ok_all & ~tl.crashed
            down_failed = alive & ~tl.down_ok

        # every scheduled client pays the deadline-capped charge (compute
        # joules + transmit joules) — the SAME quantity the energy gate
        # admitted it on, so the budget can never go negative (crash rounds:
        # the charge is already crash-capped, gate == deduction still)
        self.energy_left = np.where(scheduled, self.energy_left - charge,
                                    self.energy_left)

        if self._plan is not None:
            # fault rounds: the ES waits the deadline out only for a
            # DEADLINE straggler; a crashed client goes silent at its cap
            # and a HARQ failure finishing early ends with its last attempt
            if not scheduled.any():
                round_time = 0.0
            else:
                strag = scheduled & ~tl.crashed & (times > cfg.deadline_s)
                if strag.any() and np.isfinite(cfg.deadline_s):
                    round_time = float(cfg.deadline_s)
                else:
                    eff_end = np.where(
                        tl.crashed, 2 * link.latency_s + tl.cap_s, times)
                    t = eff_end[scheduled].max()
                    round_time = float(t) if np.isfinite(t) else 0.0
        elif not alive.any():
            # a scheduled-but-straggling client still makes the ES wait
            round_time = (float(cfg.deadline_s)
                          if scheduled.any() and np.isfinite(cfg.deadline_s)
                          else 0.0)
        elif (scheduled & ~alive).any():
            round_time = float(cfg.deadline_s)           # ES waits it out
        else:
            t = times[alive].max()
            round_time = float(t) if np.isfinite(t) else 0.0
        # translate internal candidate-cell indices into cut depth / codec
        # positions so the report reads "which split, which codec", and sum
        # the bits that actually MOVED off the timeline: a completing client
        # moved its full up+down traffic, a deadline-cut straggler the
        # uplink bits it pushed (uplink_bps * tx_charged_s) and the downlink
        # bits it received (downlink_bps * down_window_s) before the cutoff
        rep_cuts = rep_codecs = None
        if cuts is not None:
            rep_cuts = self.cutter.cut_pos[cuts]
            if self.cutter.has_codec_grid:
                rep_codecs = self.cutter.codec_pos[cuts]
        up = np.broadcast_to(np.asarray(bits.uplink, float), (self.U,))
        down = np.broadcast_to(np.asarray(bits.downlink, float), (self.U,))
        up_rate = np.broadcast_to(np.asarray(link.uplink_bps, float),
                                  (self.U,))
        down_rate = np.broadcast_to(np.asarray(link.downlink_bps, float),
                                    (self.U,))
        tx_s, down_win = tl.tx_charged_s, tl.down_window_s
        retx_bits = retx_j = 0.0
        if self._plan is not None:
            # AIR accounting: every HARQ attempt moves bits (that's what the
            # radio transmitted); a cap-truncated client credits rate x its
            # charged airtime — the same freeze rule as first transmissions.
            # The retransmit overhead is the airtime beyond FIRST attempts
            # (``tl.first_tx_s``), priced in bits and transmit joules.
            with np.errstate(invalid="ignore"):  # ideal channel: inf * 0
                moved_up = np.where(tl.up_done, tl.air_up_bits,
                                    np.where(tx_s > 0, up_rate * tx_s, 0.0))
                moved_down = np.where(tl.down_done, tl.air_down_bits,
                                      np.where(down_win > 0,
                                               down_rate * down_win, 0.0))
                d_up = np.maximum(tx_s - tl.first_tx_s, 0.0)
                d_down = np.maximum(down_win - tl.first_down_s, 0.0)
                retx_up = np.where(tl.up_done, tl.air_up_bits - up,
                                   np.where(d_up > 0, up_rate * d_up, 0.0))
                retx_down = np.where(tl.down_done, tl.air_down_bits - down,
                                     np.where(d_down > 0,
                                              down_rate * d_down, 0.0))
            retx_bits = float((retx_up + retx_down)[scheduled].sum())
            retx_j = float(cfg.tx_power_w
                           * (d_up + d_down)[scheduled].sum())
            # the stale bank holds what was never DELIVERED (nominal minus
            # erasure-survived goodput), not what was never transmitted
            bank_up = tl.goodput_up_bits
        else:
            with np.errstate(invalid="ignore"):      # ideal channel: inf * 0
                moved_up = np.where(alive, up,
                                    np.where(tx_s > 0, up_rate * tx_s, 0.0))
                moved_down = np.where(alive, down,
                                      np.where(down_win > 0,
                                               down_rate * down_win, 0.0))
            bank_up = moved_up
        moved = moved_up + moved_down
        bits_tx = float(moved[scheduled].sum())

        # ---- staleness banking (module docstring; lambda=0: no machinery)
        stale_banked = stale_delivered = stale_dropped = None
        if cfg.staleness_lambda > 0.0:
            stale_banked, stale_delivered, stale_dropped, bg_bits = \
                self._stale_update(
                    private, scheduled, alive, up, bank_up, round_time,
                    push_ok=(None if es_down is None
                             else ~es_down[self._es_eff]),
                    bankable=None if self._plan is None else ~tl.crashed)
            bits_tx += bg_bits

        compute_j = np.where(scheduled,
                             cfg.compute_power_w * tl.compute_charged_s, 0.0)
        es_map = (self._es_eff.copy()
                  if es_down is not None
                  and not np.array_equal(self._es_eff, self.es_assign)
                  else None)
        rep = RoundReport(round_idx=round_idx, mask=alive.astype(np.float64),
                          times_s=times, round_time_s=round_time,
                          energy_left_j=self.energy_left.copy(),
                          scheduled=scheduled.copy(), cuts=rep_cuts,
                          uplink_bps=np.asarray(link.uplink_bps).copy(),
                          codecs=rep_codecs, bits_tx=bits_tx,
                          compute_s=np.asarray(comp_s, float).copy(),
                          compute_j=compute_j, stale_banked=stale_banked,
                          stale_delivered=stale_delivered,
                          stale_dropped=stale_dropped,
                          crashed=crashed, failed=failed,
                          down_failed=down_failed,
                          es_down=None if es_down is None
                          else es_down.copy(),
                          es_map=es_map, retx_bits=retx_bits, retx_j=retx_j)
        self.last_timeline = tl
        tel = self.telemetry
        if tel is not None and getattr(tel, "enabled", False):
            has_bank = self._stale_age >= 0
            tel.record_round(
                rep, tl, es_assign=self._es_eff,
                deadline_s=float(cfg.deadline_s),
                withdrawn=int(withdrawn.sum()),
                backfilled=n_backfilled,
                tx_j=float(cfg.tx_power_w * tl.tx_charged_s[scheduled].sum()),
                bank_depth=int(has_bank.sum()),
                bank_age_max=(int(self._stale_age[has_bank].max())
                              if has_bank.any() else 0))
        return rep

    def _stale_update(self, private: LinkState, scheduled, alive, up,
                      moved_up, round_time: float, *, push_ok=None,
                      bankable=None):
        """One round of the staleness bank's state machine.

        Ages every bank; background-pushes idle banks' remainders at the
        clients' PRIVATE rates inside this round's wall-clock window
        (energy-gated and charged like any transmission); marks banks
        DELIVERED when the remainder reaches zero; drops banks a fresh
        completion supersedes; banks this round's new straggler remainders
        (replacing any older bank).  Returns the three (U,) report arrays
        plus the background bits moved.

        Fault hooks: ``push_ok`` (a (U,) bool, default all-True) pauses
        background pushes whose effective ES is down this round (the bank
        survives, aging); ``bankable`` masks out clients whose remainder
        must NOT be banked (a crashed client lost its local state).  On a
        fault round ``moved_up`` is the GOODPUT (delivered nominal bits),
        so the remainder banked is exactly what never arrived.
        """
        cfg, U = self.cfg, self.U
        stale_banked = np.zeros(U, bool)
        stale_delivered = np.zeros(U, int)
        stale_dropped = np.zeros(U, bool)
        bg_bits = 0.0
        has_bank = self._stale_age >= 0
        if has_bank.any():
            self._stale_age = np.where(has_bank, self._stale_age + 1,
                                       self._stale_age)
            superseded = has_bank & alive    # a fresh update landed instead
            idle = has_bank & ~scheduled     # radio free: background push
            if push_ok is not None:
                idle &= push_ok              # effective ES down: push waits
            rate = np.broadcast_to(np.asarray(private.uplink_bps, float),
                                   (U,))
            with np.errstate(divide="ignore", invalid="ignore"):
                need = self._stale_pending / rate
            need = np.where(np.isfinite(need), need, 0.0)
            afford = (self.energy_left / cfg.tx_power_w
                      if cfg.tx_power_w > 0 else np.full(U, np.inf))
            air = np.minimum(np.minimum(need, round_time), afford)
            air = np.where(idle, np.maximum(air, 0.0), 0.0)
            with np.errstate(invalid="ignore"):  # ideal channel: inf * 0
                moved_bg = np.where(air >= need, self._stale_pending,
                                    np.where(air > 0, rate * air, 0.0))
            moved_bg = np.where(idle, moved_bg, 0.0)
            # air <= budget/power by construction; the maximum() only mops
            # up the one-ulp rounding of power * (budget / power)
            self.energy_left = np.where(
                air > 0,
                np.maximum(self.energy_left - cfg.tx_power_w * air, 0.0),
                self.energy_left)
            self._stale_pending = self._stale_pending - moved_bg
            bg_bits = float(moved_bg.sum())
            delivered = idle & (self._stale_pending <= 0.0)
            stale_delivered = np.where(delivered, self._stale_age, 0)
            stale_dropped |= superseded
            clear = delivered | superseded
            self._stale_age = np.where(clear, -1, self._stale_age)
            self._stale_pending = np.where(clear, 0.0, self._stale_pending)
        strag = scheduled & ~alive
        if bankable is not None:
            strag &= bankable                # crashed: nothing left to bank
        if strag.any():
            # a newer straggle replaces any surviving older bank
            stale_dropped |= strag & (self._stale_age >= 0)
            remainder = np.maximum(up - moved_up, 0.0)
            self._stale_pending = np.where(strag, remainder,
                                           self._stale_pending)
            self._stale_age = np.where(strag, 0, self._stale_age)
            stale_banked |= strag
        return stale_banked, stale_delivered, stale_dropped, bg_bits

    # ------------------------------------------------------ checkpointing --
    def state_dict(self) -> dict:
        """Everything mutable, as flat numpy arrays (checkpoint-ready):
        energy budgets, the staleness bank, and every RNG stream the
        scheduler's trajectory depends on (thinning, channel fading, fault
        draws).  ``load_state_dict`` on a freshly built scheduler of the
        same config resumes the trajectory bit-identically."""
        from repro.checkpoint.ckpt import rng_state_array
        out = {"energy_left_j": self.energy_left.copy(),
               "stale_pending": self._stale_pending.copy(),
               "stale_age": self._stale_age.copy(),
               "rng": rng_state_array(self._rng),
               "channel_rng": rng_state_array(self.channel._rng)}
        if self.injector is not None:
            out["fault_rng"] = rng_state_array(self.injector._rng)
        return out

    def load_state_dict(self, state: dict) -> None:
        from repro.checkpoint.ckpt import restore_rng_state
        self.energy_left = np.asarray(state["energy_left_j"], float).copy()
        self._stale_pending = np.asarray(state["stale_pending"],
                                         float).copy()
        self._stale_age = np.asarray(state["stale_age"], int).copy()
        restore_rng_state(self._rng, state["rng"])
        restore_rng_state(self.channel._rng, state["channel_rng"])
        if self.injector is not None:
            if "fault_rng" not in state:
                raise ValueError("checkpoint has no fault RNG state but "
                                 "faults are configured — resuming would "
                                 "fork the fault schedule")
            restore_rng_state(self.injector._rng, state["fault_rng"])
