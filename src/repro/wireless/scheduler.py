"""Participation scheduling: who makes it into each edge aggregation.

The scheduler composes three gates, applied in order, and emits a 0/1
participation mask per edge round:

1. **energy**  — a client skips any round whose uplink energy it can no
   longer afford (budgets deplete by P_tx * uplink airtime each round the
   client participates and never recharge; under a fading channel a client
   priced out of a deep-fade round may still afford a later cheap one);
2. **selection** — an optional scheduling cap: ``topk`` keeps the k
   fastest affordable clients (rate-aware scheduling), ``random`` thins
   them i.i.d. with ``participation_prob`` (unbiased client sampling);
3. **deadline** — a scheduled client completes only if its simulated round
   time (channel latency + uplink + downlink airtime for this round's
   traffic) is within ``deadline_s`` (straggler dropout).

Two optional refinements sit between gates 2 and 3:

- **cut selection** (``cutter``): a :class:`repro.wireless.cutter.
  CutController` picks a per-client cut each round, making the traffic
  (and therefore times, energies, and the deadline outcome) cut-indexed;
- **per-ES contention** (``es_uplink_mbps`` finite): the scheduled clients
  of one ES split its uplink capacity (evenly, or rate-proportionally under
  ``contention="proportional"``), so times/energies are recomputed at the
  contended rates, adaptive cut policies re-decide, and clients the
  contended price makes unaffordable withdraw (they never transmit, cost
  nothing, and make nobody wait).  With ``reshare_uplink=True`` (default) a
  SECOND contention pass then re-shares the capacity the withdrawn clients
  freed among the survivors — survivor rates can only rise (fewer clients
  split the same pipe), so no further withdrawals are possible and one
  extra pass suffices; the survivors keep the cuts they chose at the
  first-pass rates (the freed capacity only speeds them up).
  ``reshare_uplink=False`` reproduces the conservative single pass.

Energy accounting: every client that TRANSMITS pays for the airtime it
actually burns — a scheduled client that misses the deadline transmitted
until the deadline cut it off, so it pays P_tx * min(uplink airtime,
deadline) even though its update is discarded.

The simulated edge-round wall clock is the slowest scheduled client's time
when every scheduled client made the deadline, else the full deadline (the
ES waits it out).  Clients the scheduler never scheduled (energy, top-k,
thinning) cost no waiting.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import WirelessConfig
from repro.wireless.channel import ChannelModel, LinkState, RoundBits


@dataclass
class RoundReport:
    """What the network did in one edge round."""
    round_idx: int
    mask: np.ndarray           # (U,) float64 in {0, 1}
    times_s: np.ndarray        # (U,) per-client completion time
    round_time_s: float        # simulated wall clock of this edge round
    energy_left_j: np.ndarray  # (U,) remaining budgets AFTER this round
    scheduled: np.ndarray = None   # (U,) bool: transmitted this round
    cuts: np.ndarray = None        # (U,) int cut indices (None: fixed bits)
    uplink_bps: np.ndarray = None  # (U,) effective (contended) uplink rates
    codecs: np.ndarray = None      # (U,) int codec indices into the
    #                                controller's codec_names (None unless a
    #                                cut x codec grid is in play)
    bits_tx: float = 0.0           # total offered traffic (up+down bits) of
    #                                this round's scheduled clients

    @property
    def num_participants(self) -> int:
        return int(self.mask.sum())

    @property
    def mean_cut(self) -> float | None:
        """Mean cut position of the clients that actually transmitted (all
        clients when nobody did — their entries are the hypothetical
        private-rate picks).  None without a cut controller."""
        if self.cuts is None:
            return None
        sel = (self.scheduled if self.scheduled is not None
               and self.scheduled.any() else np.ones(len(self.cuts), bool))
        return float(self.cuts[sel].mean())


class ParticipationScheduler:
    """Stateful per-edge-round participation decisions for U clients."""

    def __init__(self, cfg: WirelessConfig, channel: ChannelModel,
                 bits: RoundBits | None = None, *, cutter=None,
                 es_assign: np.ndarray | None = None):
        if cfg.selection not in ("deadline", "topk", "random"):
            raise ValueError(f"unknown selection policy {cfg.selection!r}")
        if (bits is None) == (cutter is None):
            raise ValueError("pass exactly one of bits= or cutter=")
        self.cfg = cfg
        self.channel = channel
        self.bits = bits
        self.cutter = cutter
        self.U = channel.U
        # ES attachment for the shared-uplink contention; default: one pool
        self.es_assign = (np.zeros(self.U, int) if es_assign is None
                          else np.asarray(es_assign, int))
        assert self.es_assign.shape == (self.U,)
        self.energy_left = np.full(self.U, cfg.energy_budget_j)
        self._rng = np.random.default_rng(cfg.seed + 1)

    def _bits_cuts(self, up_bps, down_bps, latency_s):
        """Cut decision (or the fixed bits) at the given rates."""
        if self.cutter is None:
            return self.bits, None
        cuts = self.cutter.decide(up_bps, down_bps, latency_s,
                                  self.energy_left)
        return self.cutter.bits_for(cuts), cuts

    def step(self, round_idx: int) -> RoundReport:
        cfg = self.cfg
        link = self.channel.sample(round_idx)
        bits, cuts = self._bits_cuts(link.uplink_bps, link.downlink_bps,
                                     link.latency_s)
        times = self.channel.round_time_s(link, bits)
        energy = self.channel.round_energy_j(link, bits)

        scheduled = self.energy_left >= energy           # gate 1: energy
        if cfg.selection == "topk" and cfg.topk > 0:     # gate 2a: k fastest
            order = np.argsort(np.where(scheduled, times, np.inf))
            keep = np.zeros(self.U, bool)
            keep[order[:cfg.topk]] = True
            scheduled &= keep
        elif cfg.selection == "random" and cfg.participation_prob < 1.0:
            scheduled &= self._rng.random(self.U) < cfg.participation_prob

        # ---- per-ES uplink contention among the scheduled clients ----
        private = link
        eff_up = self.channel.contended_uplink(link, scheduled,
                                               self.es_assign)
        if eff_up is not link.uplink_bps:
            link = LinkState(eff_up, link.downlink_bps, link.latency_s)
            if self.cutter is not None and self.cutter.policy != "fixed":
                # adaptive policies re-decide at the rate actually available
                bits2, cuts2 = self._bits_cuts(eff_up, link.downlink_bps,
                                               link.latency_s)
                cuts = np.where(scheduled, cuts2, cuts)
                bits = self.cutter.bits_for(cuts)
            times = self.channel.round_time_s(link, bits)
            energy = self.channel.round_energy_j(link, bits)
            # the contended price can only be higher; a client that can no
            # longer afford it withdraws before transmitting
            withdrawn = scheduled & (self.energy_left < energy)
            scheduled &= self.energy_left >= energy
            if (self.cfg.reshare_uplink and withdrawn.any()
                    and scheduled.any()):
                # second pass: survivors absorb the capacity the withdrawn
                # clients freed.  Rates can only rise (fewer clients share
                # the same pipe), so times/energies only fall and no new
                # withdrawal is possible; the survivors keep their
                # first-pass cut/codec choices.
                eff_up = self.channel.contended_uplink(private, scheduled,
                                                       self.es_assign)
                link = LinkState(eff_up, private.downlink_bps,
                                 private.latency_s)
                times = self.channel.round_time_s(link, bits)
                energy = self.channel.round_energy_j(link, bits)

        alive = scheduled & (times <= cfg.deadline_s)    # gate 3: deadline

        # every transmitting client burns airtime, capped at the deadline
        # for stragglers (their transmission is cut off, but the energy is
        # spent); the energy gate above guarantees the charge is affordable
        with np.errstate(divide="ignore"):
            t_up = np.asarray(bits.uplink, float) / link.uplink_bps
        burn = np.minimum(np.where(np.isfinite(t_up), t_up, 0.0),
                          cfg.deadline_s)
        self.energy_left = np.where(
            scheduled, self.energy_left - cfg.tx_power_w * burn,
            self.energy_left)

        if not alive.any():
            # a scheduled-but-straggling client still makes the ES wait
            round_time = (float(cfg.deadline_s)
                          if scheduled.any() and np.isfinite(cfg.deadline_s)
                          else 0.0)
        elif (scheduled & ~alive).any():
            round_time = float(cfg.deadline_s)           # ES waits it out
        else:
            t = times[alive].max()
            round_time = float(t) if np.isfinite(t) else 0.0
        # translate internal candidate-cell indices into cut depth / codec
        # positions so the report reads "which split, which codec", and sum
        # the offered traffic of everyone who transmitted
        rep_cuts = rep_codecs = None
        if cuts is not None:
            rep_cuts = self.cutter.cut_pos[cuts]
            if self.cutter.has_codec_grid:
                rep_codecs = self.cutter.codec_pos[cuts]
        up = np.broadcast_to(np.asarray(bits.uplink, float), (self.U,))
        down = np.broadcast_to(np.asarray(bits.downlink, float), (self.U,))
        bits_tx = float((up + down)[scheduled].sum())
        return RoundReport(round_idx=round_idx, mask=alive.astype(np.float64),
                           times_s=times, round_time_s=round_time,
                           energy_left_j=self.energy_left.copy(),
                           scheduled=scheduled.copy(), cuts=rep_cuts,
                           uplink_bps=np.asarray(link.uplink_bps).copy(),
                           codecs=rep_codecs, bits_tx=bits_tx)
