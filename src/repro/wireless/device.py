"""Per-client device (compute) model: FLOPs -> time and energy.

The paper's premise is that clients "have limited battery and computation
powers"; the channel model alone prices only *bits*, so a deeper cut —
which keeps more layers (and therefore more FLOPs) on the client — looked
free on the compute side.  This module is the compute twin of
:mod:`repro.wireless.channel`:

- :func:`client_round_flops` is the sibling of ``client_round_bits``: the
  FLOPs ONE client burns per edge round at a given cut/codec choice —
  ``kappa0`` local epochs of client-block forward+backward per minibatch
  (``CommModel.client_flops_per_sample``, filled in by
  ``comm_for_cnn``/``comm_for_lm`` from the per-cut conv/dense counts in
  ``repro.utils.flops``), plus the codec encode/decode work for every
  element that crosses a LOSSY codec (``codec_cycles_per_element``);
- :class:`DeviceModel` converts FLOPs to per-client TIME (a fixed lognormal
  compute-speed scale mirrors the channel's rate heterogeneity) and ENERGY
  (``compute_power_w`` joules per second of computing).

``compute_gflops=inf`` (the default) makes every conversion exactly zero,
reproducing the bits-only simulator bit-for-bit — that is the regression
anchor for the whole device model (tests/test_device.py).
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import WirelessConfig
from repro.core.comm import CommModel


def _codec_is_costly(codec) -> bool:
    """A payload costs codec compute only when a LOSSY codec transforms it:
    ``None`` and the identity passthrough move bits without touching them."""
    from repro.compress import IdentityCodec
    return codec is not None and not isinstance(codec, IdentityCodec)


def client_round_flops(comm: CommModel, kappa0: int, *,
                       codec_cycles_per_element: float = 0.0) -> float:
    """Per-edge-round compute of ONE client — ``client_round_bits``'s twin.

    Training: kappa0 local epochs x batches_per_epoch minibatches of
    client-block forward+backward (``client_flops_per_sample`` per sample).
    Codec: every element crossing a lossy codec on the client side costs
    ``codec_cycles_per_element`` FLOPs — activations are ENCODED up and
    gradients DECODED down each minibatch, and the client block is encoded
    for the offload and decoded from the refresh broadcast (2 * Z_0).
    """
    n_batches = kappa0 * comm.batches_per_epoch
    flops = n_batches * comm.batch_size * comm.client_flops_per_sample
    if codec_cycles_per_element:
        act_elems = comm.batch_size * comm.cut_size
        elems = 0.0
        if _codec_is_costly(comm.act_codec):
            elems += n_batches * act_elems          # encode o_fp, uplink
        if _codec_is_costly(comm.grad_codec):
            elems += n_batches * act_elems          # decode o_bp, downlink
        if _codec_is_costly(comm.off_codec):
            elems += 2 * comm.client_params         # offload + refresh
        flops += codec_cycles_per_element * elems
    return float(flops)


class DeviceModel:
    """Converts per-round client FLOPs into per-client time and energy.

    Mirrors :class:`~repro.wireless.channel.ChannelModel`'s construction:
    a fixed per-client lognormal compute-speed scale is drawn once (sigma =
    ``compute_heterogeneity``), from an RNG stream disjoint from the
    channel's (``seed + 2``) so enabling the device model never perturbs
    the fading draws.
    """

    def __init__(self, cfg: WirelessConfig, num_clients: int):
        if not cfg.compute_gflops > 0:       # rejects 0, negatives, and NaN
            # 0 would make sec_per_flop infinite and deadline-inf charges
            # NaN — every client silently unscheduled with no explanation
            raise ValueError(f"compute_gflops must be positive (inf = free "
                             f"compute), got {cfg.compute_gflops}")
        self.cfg = cfg
        self.U = num_clients
        rng = np.random.default_rng(cfg.seed + 2)
        if cfg.compute_heterogeneity > 0:
            self._scale = rng.lognormal(mean=0.0,
                                        sigma=cfg.compute_heterogeneity,
                                        size=num_clients)
        else:
            self._scale = np.ones(num_clients)
        self.flops_per_s = cfg.compute_gflops * 1e9 * self._scale
        # inf rate -> exactly 0 s/FLOP, so every downstream term vanishes
        self.sec_per_flop = np.where(np.isfinite(self.flops_per_s),
                                     1.0 / self.flops_per_s, 0.0)

    def compute_time_s(self, flops) -> np.ndarray:
        """Per-client seconds to burn ``flops`` (scalar or (U,))."""
        return np.asarray(flops, float) * self.sec_per_flop

    def chunk_time_s(self, flops, chunks: int) -> np.ndarray:
        """Per-client seconds of ONE minibatch chunk of a round's workload.

        The pipelined timeline (``repro.wireless.timeline``) models the
        round's ``kappa0 * batches_per_epoch`` minibatches as EQUAL compute
        chunks — the client block runs the same forward+backward on every
        same-sized minibatch, so the split is uniform by construction."""
        return self.compute_time_s(flops) / max(int(chunks), 1)

    def compute_energy_j(self, compute_s) -> np.ndarray:
        """Joules drawn while computing for ``compute_s`` seconds."""
        return self.cfg.compute_power_w * np.asarray(compute_s, float)
