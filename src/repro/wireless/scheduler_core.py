"""Fused jax decision core for population-scale cohort scheduling.

This module re-expresses the fault-free per-round decision path of
:class:`repro.wireless.scheduler.ParticipationScheduler` — channel rate
construction, the :class:`~repro.wireless.cutter.CutController` (cut,
codec) grid argmin, device compute times, the serial/pipelined timeline
aggregates, per-ES contention (equal and water-filled proportional), the
withdrawal + reshare pass, and the deadline/energy gates with the
moved-bits ledger — as jit-compiled jax ops over the whole client axis,
so one round's scheduling for 10**5..10**6 registered clients is two
fused XLA computations (plus a tiny host step between them for the
selection gate).  The numpy scheduler stays the reference ORACLE; this
core's contract is bit-identity to it, pinned by the U=8 property test
(``tests/test_population.py``).

Bit-identity strategy
---------------------
* Everything runs in float64: callers wrap invocations in
  ``jax.experimental.enable_x64()`` (see :func:`x64`), and all array
  inputs arrive as host ``np.float64``/``bool``/``int`` arrays.  No
  explicit jax dtype literals appear here — weak python scalars promote
  to the f64 inputs, exactly like numpy.
* Elementwise f64 arithmetic, ``argmin`` (first-minimum tie-break),
  ``nan_to_num`` defaults, and ``segment_sum`` vs
  ``np.bincount(weights=...)`` are bitwise-identical to numpy on CPU XLA
  (empirically verified for this pinned jax build, including under jit).
* Reductions whose float association ORDER numpy fixes are replicated
  explicitly: the pipelined per-chunk overlap sum uses
  :func:`_rowsum_np_order` (numpy's pairwise summation for a trailing
  axis), and the water-filling loop is a ``lax.while_loop`` with the
  oracle's exact per-iteration expressions.
* Entropy stays HOST-side: fading draws, thinning draws, and fault plans
  come from the same numpy ``Generator`` streams the oracle uses, and are
  fed in as arrays — the core is a pure function of them.
* Control flow the oracle makes data-dependent (the conditional reshare
  second pass) is computed unconditionally in-trace and selected with
  ``where`` on the traced predicate; control flow that is irreproducible
  in-trace (``np.argsort``'s quicksort tie order for top-k) stays on the
  host between the two stages, operating on bit-identical inputs.

Fault-plan rounds (erasures/crashes) have data-dependent attempt-column
shapes and are delegated by :class:`repro.wireless.population.
CohortScheduler` to the numpy oracle path; ES-outage-only rounds stay on
this core (the outage masks are host inputs).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64


def x64():
    """The double-precision context every core invocation must run in."""
    return enable_x64()


# Pipelined chunk sums replicate numpy's pairwise summation, whose simple
# closed forms cover n <= 128 columns; beyond that numpy recurses and the
# replication (and any sane chunk count) ends.
MAX_CHUNKS = 128


@dataclass(frozen=True)
class CoreSpec:
    """Static (trace-time) configuration of one cohort scheduling round.

    Frozen so it can be a jit ``static_argnames`` argument; every field
    mirrors the oracle knob it is named after.  ``contend`` is the
    oracle's contention-bypass predicate evaluated statically (ideal
    channel or infinite ES capacity never contends)."""

    model: str               # "ideal" | "static" | "rayleigh" | "trace"
    up_mean_bps: float
    down_mean_bps: float
    latency_s: float
    has_down_trace: bool     # trace model with a measured downlink trace
    contend: bool
    contention: str          # "equal" | "proportional"
    es_cap_bps: float
    num_es: int
    reshare: bool
    has_cutter: bool
    adaptive: bool           # cutter present and policy != "fixed"
    policy: str              # "fixed" | "greedy" | "deadline"
    fixed_cut: int
    num_cells: int
    cutter_deadline_s: float
    cutter_tx_power_w: float
    cutter_compute_power_w: float
    cutter_pipeline: bool
    cutter_ea: float         # expected HARQ attempts priced by the cutter
    cutter_hb: float         # HARQ backoff seconds priced by the cutter
    deadline_s: float
    tx_power_w: float
    compute_power_w: float
    pipeline: bool
    chunks: int


def _rowsum_np_order(cols):
    """Sum n (U,) columns in numpy's np.sum(axis=1) association order.

    numpy reduces a C-contiguous trailing axis with pairwise summation:
    a zero-seeded sequential loop for n < 8, and the 8-accumulator
    unrolled block (with a sequential remainder) for 8 <= n <= 128.
    Replicating the exact order keeps the pipelined timeline aggregates
    bitwise-identical to the oracle's ``.sum(axis=1)``.
    """
    n = len(cols)
    assert 1 <= n <= MAX_CHUNKS
    if n < 8:
        res = 0.0 + cols[0]
        for k in range(1, n):
            res = res + cols[k]
        return res
    r = list(cols[:8])
    i = 8
    while i + 8 <= n:
        for j in range(8):
            r[j] = r[j] + cols[i + j]
        i += 8
    res = ((r[0] + r[1]) + (r[2] + r[3])) + ((r[4] + r[5]) + (r[6] + r[7]))
    for k in range(i, n):
        res = res + cols[k]
    return res


# ---------------------------------------------------------------- rates --
def _rates(spec: CoreSpec, fade, down_row, scale):
    """ChannelModel.sample()'s rate expressions over host-drawn entropy.

    ``fade`` is the per-round fading array drawn host-side from the
    channel's own numpy stream (ones for static, Exp(1) for rayleigh, the
    resized trace row scaled by ``1e6 / up_mean`` for trace), so the rate
    VALUES equal the oracle's bit-for-bit."""
    if spec.model == "ideal":
        inf = jnp.full(scale.shape, jnp.inf)
        return inf, inf, jnp.zeros(scale.shape)
    up = jnp.maximum(spec.up_mean_bps * scale * fade, 1.0)
    down = jnp.maximum(spec.down_mean_bps * scale * fade, 1.0)
    if spec.has_down_trace:
        down = jnp.maximum(down_row * 1e6 * scale, 1.0)
    return up, down, jnp.full(scale.shape, spec.latency_s)


# ------------------------------------------------------------ cut decide --
def _estimates(spec: CoreSpec, tables, up, down, latency, spf):
    """CutController._estimates over the (cells, U) grid, verbatim."""
    t_up = tables["up_bits"][:, None] / up[None, :]
    t_down = tables["down_bits"][:, None] / down[None, :]
    t_up = jnp.nan_to_num(t_up, nan=0.0)
    t_down = jnp.nan_to_num(t_down, nan=0.0)
    ea, hb = spec.cutter_ea, spec.cutter_hb
    t_up_air = t_up
    harq = ea != 1.0 or hb != 0.0
    if harq:
        gap = (ea - 1.0) * hb
        t_up_air = ea * t_up
        t_up = t_up_air + gap
        t_down = ea * t_down + gap
    t_comp = tables["flops"][:, None] * spf[None, :]
    if spec.cutter_pipeline:
        u = jnp.nan_to_num(tables["up_stream"][:, None] / up[None, :],
                           nan=0.0)
        t_tail = jnp.nan_to_num(tables["up_tail"][:, None] / up[None, :],
                                nan=0.0)
        if harq:
            u = ea * u + gap
            t_tail = ea * t_tail + gap
        c = t_comp / spec.chunks
        up_finish = c + u + (spec.chunks - 1) * jnp.maximum(c, u) + t_tail
        times = 2 * latency[None, :] + up_finish + t_down
    else:
        times = 2 * latency[None, :] + t_up + t_down
        times = times + t_comp
    energy = spec.cutter_tx_power_w * t_up_air
    energy = energy + spec.cutter_compute_power_w * t_comp
    return times, energy


def _decide(spec: CoreSpec, tables, up, down, latency, energy_left, spf):
    """CutController.decide() over the cohort (fixed/greedy/deadline)."""
    if not spec.has_cutter or spec.policy == "fixed" or spec.num_cells == 1:
        return jnp.full(up.shape, spec.fixed_cut, dtype=int)
    times, energy = _estimates(spec, tables, up, down, latency, spf)
    affordable = energy <= energy_left[None, :]
    t_aff = jnp.where(affordable, times, jnp.inf)
    fastest_aff = jnp.argmin(t_aff, axis=0)
    cheapest = jnp.argmin(energy, axis=0)
    none_affordable = ~affordable.any(axis=0)
    if spec.policy == "greedy":
        return jnp.where(none_affordable, cheapest, fastest_aff)
    feasible = affordable & (times <= spec.cutter_deadline_s)
    idx = jnp.arange(spec.num_cells)[:, None]
    deepest = jnp.where(feasible, idx, -1).max(axis=0)
    out = jnp.where(deepest >= 0, deepest, fastest_aff)
    return jnp.where(none_affordable, cheapest, out)


def _bits_comp(spec: CoreSpec, tables, fixed, cuts, spf):
    """Per-client bit arrays + compute times of a cut-index vector."""
    if spec.has_cutter:
        b_up = tables["up_bits"][cuts]
        b_down = tables["down_bits"][cuts]
        b_stream = tables["up_stream"][cuts]
        b_tail = tables["up_tail"][cuts]
        comp_s = tables["flops"][cuts] * spf
    else:
        b_up = fixed["up_bits"]
        b_down = fixed["down_bits"]
        b_stream = fixed["up_stream"]
        b_tail = fixed["up_tail"]
        comp_s = fixed["flops"] * spf
    return b_up, b_down, b_stream, b_tail, comp_s


# --------------------------------------------------------------- timeline --
def _timeline_agg(spec: CoreSpec, up, down, latency, b_up, b_down,
                  b_stream, b_tail, comp_s):
    """The serial/pipelined RoundTimeline AGGREGATES (times, charged
    compute/tx seconds, downlink window, can_tx) in the oracle builders'
    exact expression order (repro.wireless.timeline._serial/_pipelined)."""
    deadline = spec.deadline_s
    if not spec.pipeline:
        t_up_clock = b_up / up
        t_down = b_down / down
        t_up = jnp.where(jnp.isfinite(t_up_clock), t_up_clock, 0.0)
        t_down_f = jnp.where(jnp.isfinite(t_down), t_down, 0.0)
        times = 2 * latency + t_up_clock + t_down + comp_s
        c_s = jnp.minimum(comp_s, deadline)
        window = jnp.maximum(deadline - comp_s, 0.0)
        tx_s = jnp.minimum(t_up, window)
        down_start = comp_s + t_up
        down_win = jnp.clip(deadline - down_start, 0.0, t_down_f)
        can_tx = window > 0
        return times, c_s, tx_s, down_win, can_tx
    n = spec.chunks
    u = b_stream / up
    t_tail = b_tail / up
    t_down = b_down / down
    u = jnp.where(jnp.isfinite(u), u, 0.0)
    t_tail = jnp.where(jnp.isfinite(t_tail), t_tail, 0.0)
    t_down = jnp.where(jnp.isfinite(t_down), t_down, 0.0)
    c = comp_s / n
    # per-chunk streaming columns, summed in numpy's association order
    ov_cols = []
    for i in range(n):
        tx_start_i = jnp.maximum((i + 1) * c, c + i * u)
        ov_cols.append(jnp.clip(deadline - tx_start_i, 0.0, u))
    tail_start = jnp.maximum(n * c, c + (n - 1) * u) + u
    up_finish = tail_start + t_tail
    times = 2 * latency + up_finish + t_down
    c_s = jnp.minimum(comp_s, deadline)
    tx_s = (_rowsum_np_order(ov_cols)
            + jnp.clip(deadline - tail_start, 0.0, t_tail))
    down_win = jnp.clip(deadline - up_finish, 0.0, t_down)
    can_tx = c < deadline
    return times, c_s, tx_s, down_win, can_tx


# -------------------------------------------------------------- contention --
def _waterfill(cap, w, limits, groups, active, num_groups):
    """channel.waterfill_shares as a while_loop, expression-for-expression."""
    def body(carry):
        capped, _, _ = carry
        w_unc = jnp.where(active & ~capped, w, 0.0)
        totals = jax.ops.segment_sum(w_unc, groups,
                                     num_segments=num_groups)
        used = jax.ops.segment_sum(
            jnp.where(active & capped, limits, 0.0), groups,
            num_segments=num_groups)
        remaining = jnp.maximum(cap - used, 0.0)
        share = remaining[groups] * w / jnp.maximum(totals[groups], 1.0)
        newly = active & ~capped & (limits <= share)
        return capped | newly, share, newly.any()

    def cond(carry):
        return carry[2]

    init = (jnp.zeros(w.shape, bool), jnp.full(w.shape, cap),
            jnp.asarray(True))
    capped, share, _ = jax.lax.while_loop(cond, body, init)
    return jnp.where(active & capped, limits, share)


def _contended_up(spec: CoreSpec, up, active, es):
    """ChannelModel.contended_uplink for a statically-contended spec."""
    cap = spec.es_cap_bps
    if spec.contention == "proportional":
        share = _waterfill(cap, up, up, es, active, spec.num_es)
    else:
        counts = jax.ops.segment_sum(jnp.where(active, 1.0, 0.0), es,
                                     num_segments=spec.num_es)
        share = cap / jnp.maximum(counts[es], 1.0)
    return jnp.where(active, jnp.minimum(up, share), up)


# ------------------------------------------------------------------ stages --
@partial(jax.jit, static_argnames=("spec",))
def cohort_stage_a(spec: CoreSpec, tables, fixed, fade, down_row, scale,
                   spf, energy_left, client_down):
    """Private-rate decision pass: rates, cut decide, timeline, gate 1.

    Returns (up, down, latency, cuts, comp_s, times0, charge0, gate1) —
    ``times0`` feeds the host's top-k argsort (whose quicksort tie order
    must be numpy's), ``gate1`` is the energy+window (+outage) gate."""
    up, down, latency = _rates(spec, fade, down_row, scale)
    cuts = _decide(spec, tables, up, down, latency, energy_left, spf)
    b_up, b_down, b_stream, b_tail, comp_s = _bits_comp(
        spec, tables, fixed, cuts, spf)
    times0, c_s, tx_s, _, can_tx = _timeline_agg(
        spec, up, down, latency, b_up, b_down, b_stream, b_tail, comp_s)
    charge0 = spec.tx_power_w * tx_s + spec.compute_power_w * c_s
    gate1 = (energy_left >= charge0) & can_tx & ~client_down
    return up, down, latency, cuts, comp_s, times0, charge0, gate1


@partial(jax.jit, static_argnames=("spec",))
def cohort_stage_b(spec: CoreSpec, tables, fixed, scheduled_in, up, down,
                   latency, cuts_in, energy_left, spf, es_assign):
    """Contention + final gates + ledger over a chosen scheduled set.

    Mirrors ParticipationScheduler._contend (adaptive re-decide at the
    contended rates, withdrawal, the conditional reshare second pass —
    computed unconditionally and selected on the traced predicate) and
    the oracle's post-contention body: the deadline gate, the energy
    deduction, and the fault-free moved-bits ledger.  Pure: the top-k
    backfill calls it a second time on the refilled set with the same
    private inputs."""
    if spec.contend:
        eff1 = _contended_up(spec, up, scheduled_in, es_assign)
        if spec.adaptive:
            cuts2 = _decide(spec, tables, eff1, down, latency, energy_left,
                            spf)
            cuts = jnp.where(scheduled_in, cuts2, cuts_in)
        else:
            cuts = cuts_in
        b_up, b_down, b_stream, b_tail, comp_s = _bits_comp(
            spec, tables, fixed, cuts, spf)
        _, c_s1, tx_s1, _, can1 = _timeline_agg(
            spec, eff1, down, latency, b_up, b_down, b_stream, b_tail,
            comp_s)
        charge1 = spec.tx_power_w * tx_s1 + spec.compute_power_w * c_s1
        ok = (energy_left >= charge1) & can1
        withdrawn = scheduled_in & ~ok
        sched = scheduled_in & ok
        if spec.reshare:
            do2 = withdrawn.any() & sched.any()
            eff2 = _contended_up(spec, up, sched, es_assign)
            eff = jnp.where(do2, eff2, eff1)
        else:
            eff = eff1
    else:
        eff = up
        cuts = cuts_in
        b_up, b_down, b_stream, b_tail, comp_s = _bits_comp(
            spec, tables, fixed, cuts, spf)
        withdrawn = jnp.zeros(up.shape, bool)
        sched = scheduled_in
    times, c_s, tx_s, down_win, _ = _timeline_agg(
        spec, eff, down, latency, b_up, b_down, b_stream, b_tail, comp_s)
    charge = spec.tx_power_w * tx_s + spec.compute_power_w * c_s
    alive = sched & (times <= spec.deadline_s)
    energy_after = jnp.where(sched, energy_left - charge, energy_left)
    # fault-free moved-bits ledger (oracle: full traffic when alive, else
    # rate x charged airtime / downlink window; the nan of inf*0 never
    # survives the where)
    moved_up = jnp.where(alive, b_up,
                         jnp.where(tx_s > 0, eff * tx_s, 0.0))
    moved_down = jnp.where(alive, b_down,
                           jnp.where(down_win > 0, down * down_win, 0.0))
    compute_j = jnp.where(sched, spec.compute_power_w * c_s, 0.0)
    return (eff, cuts, comp_s, times, sched, withdrawn, alive,
            energy_after, moved_up, moved_down, compute_j, tx_s, charge)


# ----------------------------------------------------------- spec builders --
def build_spec(cfg, *, cutter=None, bits=None, es_assign,
               num_clients) -> CoreSpec:
    """Derive the static CoreSpec of a scheduler configuration.

    ``cutter``/``bits`` follow the ParticipationScheduler constructor
    (exactly one).  Raises for shapes the vectorized path cannot
    reproduce bit-identically (pipelined chunk counts beyond numpy's
    non-recursive pairwise-summation range)."""
    del num_clients  # shape comes from the arrays; kept for call clarity
    cap = cfg.es_uplink_mbps * 1e6
    contend = cfg.model != "ideal" and bool(np.isfinite(cap))
    es = np.asarray(es_assign, int)
    num_es = int(es.max()) + 1 if es.size else 1
    if cutter is not None:
        chunks = max(int(cutter.chunks), 1)
        spec_kw = dict(
            has_cutter=True, adaptive=cutter.policy != "fixed",
            policy=cutter.policy, fixed_cut=int(cutter.fixed_cut),
            num_cells=cutter.num_cuts,
            cutter_deadline_s=float(cutter.deadline_s),
            cutter_tx_power_w=float(cutter.tx_power_w),
            cutter_compute_power_w=float(cutter.compute_power_w),
            cutter_pipeline=bool(cutter.pipeline),
            cutter_ea=float(cutter.expected_attempts),
            cutter_hb=float(cutter.harq_backoff_s))
    else:
        chunks = max(int(bits.chunks), 1)
        spec_kw = dict(
            has_cutter=False, adaptive=False, policy="fixed", fixed_cut=0,
            num_cells=1, cutter_deadline_s=float("inf"),
            cutter_tx_power_w=0.0, cutter_compute_power_w=0.0,
            cutter_pipeline=False, cutter_ea=1.0, cutter_hb=0.0)
    if cfg.pipeline and chunks > MAX_CHUNKS:
        raise ValueError(
            f"pipelined chunk count {chunks} exceeds {MAX_CHUNKS}: numpy "
            f"sums that many columns with recursive pairwise blocks, which "
            f"the vectorized path does not replicate")
    return CoreSpec(
        model=cfg.model, up_mean_bps=cfg.mean_uplink_mbps * 1e6,
        down_mean_bps=cfg.mean_downlink_mbps * 1e6,
        latency_s=float(cfg.latency_s),
        has_down_trace=bool(cfg.model == "trace" and cfg.trace_down),
        contend=contend, contention=cfg.contention, es_cap_bps=float(cap),
        num_es=num_es, reshare=bool(cfg.reshare_uplink),
        deadline_s=float(cfg.deadline_s), tx_power_w=float(cfg.tx_power_w),
        compute_power_w=float(cfg.compute_power_w),
        pipeline=bool(cfg.pipeline), chunks=chunks, **spec_kw)


def cell_tables(cutter) -> dict:
    """The cutter's per-cell arrays as the core's gather tables."""
    return {"up_bits": np.asarray(cutter.up_bits, np.float64),
            "down_bits": np.asarray(cutter.down_bits, np.float64),
            "up_stream": np.asarray(cutter.up_stream, np.float64),
            "up_tail": np.asarray(cutter.up_tail, np.float64),
            "flops": np.asarray(cutter.flops, np.float64)}


def fixed_tables(bits, flops: float, num_clients: int) -> dict:
    """Fixed-bits mode: per-client (U,) bit arrays + the scalar workload.

    Mirrors the oracle's broadcasting of scalar RoundBits and the
    pipelined builder's ``up_stream is None`` degeneration (the whole
    uplink as one stream payload, no tail)."""
    def bc(x):
        return np.ascontiguousarray(
            np.broadcast_to(np.asarray(x, np.float64), (num_clients,)))
    stream = bits.up_stream if bits.up_stream is not None else bits.uplink
    tail = bits.up_tail if bits.up_stream is not None else 0.0
    return {"up_bits": bc(bits.uplink), "down_bits": bc(bits.downlink),
            "up_stream": bc(stream), "up_tail": bc(tail),
            "flops": np.asarray(flops, np.float64)}


_DUMMY_TABLES = {"up_bits": np.zeros(1), "down_bits": np.zeros(1),
                 "up_stream": np.zeros(1), "up_tail": np.zeros(1),
                 "flops": np.zeros(1)}


def dummy_tables() -> dict:
    """Placeholder for whichever of tables/fixed a spec does not use (jit
    still traces both pytree slots)."""
    return dict(_DUMMY_TABLES)
