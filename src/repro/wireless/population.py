"""Population-scale cohort simulation: 10**5..10**6 registered clients.

The paper's premise is massive numbers of wireless clients, but the
reference :class:`~repro.wireless.scheduler.ParticipationScheduler` walks
host-side numpy expressions sized for U=8 study runs.  This module is the
population-scale twin:

- :class:`Population` — a struct-of-arrays registry of every client the
  simulation knows: packed per-client coordinates, ES assignment
  (round-robin or k-means location clusters), data-skew sizes, a
  personalized-head pointer, and a participation counter, plus per-round
  cohort SAMPLING (``uniform`` / ``rate``-biased / ``pareto``
  participation-capped) from the dedicated ``seed + 5`` stream (disjoint
  from channel ``seed``, thinning ``+1``, device ``+2``, personalize
  ``+3``, faults ``+4`` — enabling populations never perturbs them);
- :class:`CohortScheduler` — a drop-in ``ParticipationScheduler`` subclass
  whose ``step()`` re-derives the per-round decision path as the two fused
  float64 jax computations of :mod:`repro.wireless.scheduler_core`
  (rates -> cut grid argmin -> timeline aggregates -> gates -> contention
  -> withdrawal/reshare -> ledger), with only the selection gate (whose
  ``np.argsort`` quicksort tie order is host semantics) between them.

Bit-identity contract: on every fault-free and ES-outage-only
configuration the vectorized step returns a :class:`~repro.wireless.
scheduler.RoundReport` BIT-IDENTICAL to the numpy oracle's — same rates,
same cuts, same masks, same energies, same ledger sums — pinned by
``tests/test_population.py`` at U=8.  Rounds that carry an erasure/crash
fault plan (data-dependent HARQ attempt shapes) delegate to the inherited
oracle ``step()`` verbatim; both paths share every piece of mutable state
(energy budgets, stale bank, RNG streams), so a run may interleave them
freely.

Scale: the per-round cost is two jit-compiled XLA calls over (N,) arrays
plus an O(N) host selection step — ``benchmarks/cohort_bench.py`` records
a 10**6-client scheduled round in single-digit seconds on CPU.  The
telemetry trace exporter (which materializes per-client event segments) is
priced accordingly: with telemetry enabled the round builds the host
timeline as before; without it (the default) ``last_timeline`` stays None.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import WirelessConfig
from repro.wireless.channel import LinkState
from repro.wireless.scheduler import ParticipationScheduler, RoundReport
from repro.wireless import scheduler_core as core


# --------------------------------------------------------------- k-means --
def kmeans_assign(coords: np.ndarray, k: int, rng, iters: int = 25):
    """Seeded Lloyd's k-means over client coordinates -> (labels, centers).

    Deterministic in ``rng``: k-means++ seeding (first center uniform,
    each next center D**2-weighted away from the chosen ones), then Lloyd
    iterations; an emptied cluster re-seeds at the worst-served client.
    Small fixed iteration count — ES placement is scenario geometry, not
    an optimizer.
    """
    coords = np.asarray(coords, float)
    k = int(k)
    centers = coords[[rng.integers(len(coords))]]
    for _ in range(k - 1):
        d2 = ((coords[:, None, :] - centers[None, :, :]) ** 2
              ).sum(axis=-1).min(axis=1)
        tot = d2.sum()
        p = d2 / tot if tot > 0 else np.full(len(coords), 1 / len(coords))
        centers = np.concatenate(
            [centers, coords[[rng.choice(len(coords), p=p)]]])
    labels = np.zeros(len(coords), int)
    for _ in range(int(iters)):
        d = ((coords[:, None, :] - centers[None, :, :]) ** 2).sum(axis=-1)
        labels = d.argmin(axis=1)
        for b in range(k):
            sel = labels == b
            if sel.any():
                centers[b] = coords[sel].mean(axis=0)
            else:
                centers[b] = coords[d.min(axis=1).argmax()]
    return labels, centers


class Population:
    """Struct-of-arrays state for every REGISTERED client.

    All per-client state is packed (N,)/(N, 2) numpy arrays — no python
    object per client — so 10**6 registrations cost a few MB and every
    per-round operation is a vector op.  The scheduler owns the per-client
    wireless state (energy budgets, stale-bank age, channel/device scale
    draws); this class owns what the scheduler does not: geometry, the
    client -> ES map, data-skew sizes, cohort sampling, and the
    personalized-head bookkeeping.

    ``assignment="round_robin"`` (default) reproduces the historical
    ``HierarchyConfig`` layout via :func:`repro.core.hierarchy.
    es_assignment` — the single source of truth, regression-pinned;
    ``"kmeans"`` clusters the client coordinates into ``num_es``
    location cells (paper Sec. II's ES coverage areas).
    """

    SAMPLING = ("uniform", "rate", "pareto")

    def __init__(self, num_clients: int, *, num_es: int = 1, seed: int = 0,
                 assignment: str = "round_robin", data_sigma: float = 0.0,
                 kmeans_iters: int = 25):
        if assignment not in ("round_robin", "kmeans"):
            raise ValueError(f"unknown ES assignment {assignment!r}")
        N = int(num_clients)
        if N < int(num_es):
            raise ValueError(f"{N} clients cannot cover {num_es} ESs")
        self.N = N
        self.num_es = int(num_es)
        self.assignment = assignment
        # the dedicated population stream: seed+5 (see module docstring)
        self._rng = np.random.default_rng(seed + 5)
        # client geometry: unit-square placements the k-means cells cluster
        self.coords = self._rng.random((N, 2))
        # data-skew stats: lognormal dataset sizes (sigma=0 -> uniform),
        # the alpha_u weights of whatever cohort trains this round
        if data_sigma > 0:
            self.data_size = self._rng.lognormal(mean=0.0, sigma=data_sigma,
                                                 size=N)
        else:
            self.data_size = np.ones(N)
        if assignment == "kmeans":
            self.es_assign, self.es_centers = kmeans_assign(
                self.coords, self.num_es, self._rng, iters=kmeans_iters)
        else:
            from repro.core.hierarchy import es_assignment
            per_es = -(-N // self.num_es)            # ceil: labels < num_es
            self.es_assign = es_assignment(N, per_es)
            self.es_centers = None
        # per-ES member lists (index arrays) for balanced cohort draws
        self._by_es = [np.flatnonzero(self.es_assign == b)
                       for b in range(self.num_es)]
        # personalized-head pointer: the edge round whose head this client
        # last trained/refreshed (-1 = never participated; FedSim advances
        # it for each round's alive cohort members)
        self.head_slot = np.full(N, -1, dtype=np.int64)
        # participation counter (drives the pareto-style cap)
        self.part_count = np.zeros(N, dtype=np.int64)
        # per-client rate scale, bound by the CohortScheduler from its
        # channel (drives the "rate"-biased sampling); ones until bound
        self.rate_scale = np.ones(N)

    # ------------------------------------------------------- sampling -----
    def _draw(self, pool: np.ndarray, k: int, method: str) -> np.ndarray:
        """k clients from ``pool`` under one sampling rule (no count
        update; ``sample_cohort`` owns the bookkeeping)."""
        if k >= len(pool):
            return pool.copy()
        if method == "uniform":
            idx = self._rng.choice(len(pool), size=k, replace=False)
        elif method == "rate":
            # biased-by-rate: fast-channel clients proportionally likelier
            # (Pareto-optimality-style throughput bias)
            w = np.asarray(self.rate_scale, float)[pool]
            idx = self._rng.choice(len(pool), size=k, replace=False,
                                   p=w / w.sum())
        else:                                        # "pareto"
            # participation cap: the least-served clients first, random
            # tie-break, so lifetime participation stays near-uniform
            # however skewed the gates are
            jitter = self._rng.random(len(pool))
            order = np.lexsort((jitter, self.part_count[pool]))
            idx = order[:k]
        return pool[idx]

    def sample_cohort(self, size: int, method: str = "uniform", *,
                      es_balanced: bool = False) -> np.ndarray:
        """Draw one round's cohort (client ids) and count participation.

        ``es_balanced=True`` draws ``size / num_es`` clients from EACH
        ES's member pool, concatenated in ES order — the layout FedSim's
        (B, Ub) slot hierarchy needs (slot ``i`` belongs to ES
        ``i // Ub``).  Unbalanced draws sample the whole registry.
        """
        if method not in self.SAMPLING:
            raise ValueError(f"unknown sampling method {method!r}; one of "
                             f"{self.SAMPLING}")
        size = int(size)
        if es_balanced:
            if size % self.num_es:
                raise ValueError(f"es_balanced cohort size {size} is not a "
                                 f"multiple of num_es={self.num_es}")
            per = size // self.num_es
            short = [b for b, pool in enumerate(self._by_es)
                     if len(pool) < per]
            if short:
                raise ValueError(f"ESs {short} have fewer than {per} "
                                 f"registered clients")
            ids = np.concatenate([self._draw(pool, per, method)
                                  for pool in self._by_es])
        else:
            ids = self._draw(np.arange(self.N), min(size, self.N), method)
        self.part_count[ids] += 1
        return ids

    def cohort_mask(self, ids: np.ndarray) -> np.ndarray:
        """(N,) bool mask of a cohort id array."""
        mask = np.zeros(self.N, bool)
        mask[np.asarray(ids, int)] = True
        return mask


# ---------------------------------------------------------------------------
class CohortScheduler(ParticipationScheduler):
    """Population-scale scheduler: the oracle's decisions, vectorized.

    A strict subclass — construction, mutable state (energy budgets, stale
    bank, every RNG stream), checkpointing, and the fault-plan code path
    are inherited verbatim.  Only ``step()`` is rerouted: fault-free and
    ES-outage-only rounds run the two fused jax stages of
    :mod:`repro.wireless.scheduler_core` (bit-identical to the oracle —
    the class docstring contract in ``scheduler.py``); rounds that draw an
    erasure/crash :class:`~repro.wireless.faults.FaultPlan` fall back to
    ``super().step()`` on the same shared state.

    With a :class:`Population` attached, every ``step()`` restricts gate 1
    to a freshly sampled cohort (``sampling`` rule, ``cohort_size``
    clients) while the WHOLE registry's state advances — exactly the
    oracle's ``cohort_mask`` semantics.  ``sample_cohort()`` may be called
    ahead of ``step()`` (FedSim does, to know which clients to train);
    otherwise ``step()`` samples on entry.

    ``last_timeline`` is populated only when telemetry is enabled: the
    explicit per-client event timeline is O(N x chunks) host memory, which
    is precisely the cost this class exists to avoid.
    """

    def __init__(self, cfg: WirelessConfig, channel, bits=None, *,
                 cutter=None, es_assign=None, device=None, flops: float = 0.0,
                 telemetry=None, population: Population | None = None,
                 cohort_size: int | None = None, sampling: str = "uniform",
                 es_balanced: bool = False):
        super().__init__(cfg, channel, bits, cutter=cutter,
                         es_assign=es_assign, device=device, flops=flops,
                         telemetry=telemetry)
        if population is not None:
            if population.N != self.U:
                raise ValueError(f"population has {population.N} clients "
                                 f"but the channel was built for {self.U}")
            if cohort_size is None:
                raise ValueError("population runs need cohort_size")
            if sampling not in Population.SAMPLING:
                raise ValueError(f"unknown sampling method {sampling!r}")
            # bind the channel's heterogeneity scale as the rate bias
            population.rate_scale = self.channel._scale
        self.population = population
        self.cohort_size = cohort_size
        self.sampling = sampling
        self.es_balanced = es_balanced
        self._cohort = None          # pinned for the NEXT step() only
        self.last_cohort = None      # the cohort the LAST step() ran under
        # the static trace-time spec + gather tables of the fused core
        self._spec = core.build_spec(cfg, cutter=cutter, bits=bits,
                                     es_assign=self.es_assign,
                                     num_clients=self.U)
        if cutter is not None:
            self._tables = core.cell_tables(cutter)
            self._fixed = core.dummy_tables()
        else:
            self._tables = core.dummy_tables()
            self._fixed = core.fixed_tables(bits, flops, self.U)

    # ------------------------------------------------------- cohorts ------
    def sample_cohort(self) -> np.ndarray:
        """Draw the NEXT round's cohort now (population mode only) and pin
        its mask; ``step()`` consumes the pin instead of resampling."""
        if self.population is None:
            raise ValueError("no population attached")
        ids = self.population.sample_cohort(self.cohort_size, self.sampling,
                                            es_balanced=self.es_balanced)
        self.cohort_mask = self.population.cohort_mask(ids)
        self._cohort = ids
        return ids

    # ---------------------------------------------------------- stepping --
    def step(self, round_idx: int) -> RoundReport:
        if self.population is not None and self._cohort is None:
            self.sample_cohort()
        self.last_cohort, self._cohort = self._cohort, None
        if self.injector is not None and self.injector.needs_plan:
            # erasure/crash rounds: data-dependent HARQ attempt shapes —
            # the inherited oracle path runs on the same shared state
            return super().step(round_idx)
        return self._step_core(round_idx)

    def _step_core(self, round_idx: int) -> RoundReport:
        cfg, U = self.cfg, self.U
        # ---- outage state (the only fault machinery without a plan;
        # round_plan() draws nothing when needs_plan is False, so the
        # fault stream stays in lockstep with the oracle's)
        self._plan = None
        self._es_eff = self.es_assign
        es_down = None
        client_down = None
        if self.injector is not None:
            es_down = self.injector.es_down(round_idx)
            if es_down is not None and es_down.any():
                self._es_eff, client_down = self.injector.failover(
                    es_down, self.es_assign)
            else:
                es_down = None

        # ---- host entropy: the channel's per-round draw (same stream,
        # same consumption as the oracle's sample())
        fade, down_row = self.channel.fades(round_idx)
        if fade is None:
            fade = np.ones(U)                      # ideal: unused in-trace
        if down_row is None:
            down_row = np.zeros(U)                 # unused w/o a down trace
        cd = np.zeros(U, bool) if client_down is None else client_down

        spec = self._spec
        with core.x64():
            up, down, latency, cuts0, _, times0, _, gate1 = (
                np.asarray(o) for o in core.cohort_stage_a(
                    spec, self._tables, self._fixed, fade, down_row,
                    self.channel._scale, self.device.sec_per_flop,
                    self.energy_left, cd))
        if self.cohort_mask is not None:
            gate1 = gate1 & self.cohort_mask

        # ---- selection gate (host: np.argsort's quicksort tie order and
        # the thinning stream are host semantics, on bit-identical times0)
        scheduled = gate1.copy()
        if cfg.selection == "topk" and cfg.topk > 0:
            order = np.argsort(np.where(scheduled, times0, np.inf))
            keep = np.zeros(U, bool)
            keep[order[:cfg.topk]] = True
            scheduled &= keep
        elif cfg.selection == "random" and cfg.participation_prob < 1.0:
            scheduled &= self._rng.random(U) < cfg.participation_prob

        with core.x64():
            out = core.cohort_stage_b(
                spec, self._tables, self._fixed, scheduled, up, down,
                latency, cuts0, self.energy_left, self.device.sec_per_flop,
                self._es_eff)
            sched = np.asarray(out[4])
            n_backfilled = 0
            if (spec.contend and cfg.selection == "topk" and cfg.topk > 0
                    and int(sched.sum()) < cfg.topk):
                # topk backfill (single pass): promote the next-fastest
                # never-withdrawn clients and re-run the pure contention
                # stage from the ORIGINAL private-rate cuts
                withdrawn = np.asarray(out[5])
                pool = gate1 & ~sched & ~withdrawn
                if pool.any():
                    order = np.argsort(np.where(pool, times0, np.inf))
                    extra = np.zeros(U, bool)
                    extra[order[:cfg.topk - int(sched.sum())]] = True
                    extra &= pool
                    if extra.any():
                        out = core.cohort_stage_b(
                            spec, self._tables, self._fixed, sched | extra,
                            up, down, latency, cuts0, self.energy_left,
                            self.device.sec_per_flop, self._es_eff)
                        sched = np.asarray(out[4])
                        n_backfilled = int((sched & extra).sum())
        (eff, cuts, comp_s, times, _, withdrawn, alive, energy_after,
         moved_up, moved_down, compute_j, tx_s, _) = (
            np.asarray(o) for o in out)

        self.energy_left = energy_after
        if not alive.any():
            round_time = (float(cfg.deadline_s)
                          if sched.any() and np.isfinite(cfg.deadline_s)
                          else 0.0)
        elif (sched & ~alive).any():
            round_time = float(cfg.deadline_s)
        else:
            t = times[alive].max()
            round_time = float(t) if np.isfinite(t) else 0.0

        rep_cuts = rep_codecs = None
        up_bits = None
        if self.cutter is not None:
            rep_cuts = self.cutter.cut_pos[cuts]
            if self.cutter.has_codec_grid:
                rep_codecs = self.cutter.codec_pos[cuts]
            up_bits = np.asarray(self.cutter.up_bits, float)[cuts]
        else:
            up_bits = np.broadcast_to(
                np.asarray(self.bits.uplink, float), (U,))
        moved = moved_up + moved_down
        bits_tx = float(moved[sched].sum())

        stale_banked = stale_delivered = stale_dropped = None
        if cfg.staleness_lambda > 0.0:
            private = LinkState(up, down, latency)
            stale_banked, stale_delivered, stale_dropped, bg_bits = \
                self._stale_update(
                    private, sched, alive, up_bits, moved_up, round_time,
                    push_ok=(None if es_down is None
                             else ~es_down[self._es_eff]),
                    bankable=None)
            bits_tx += bg_bits

        es_map = (self._es_eff.copy()
                  if es_down is not None
                  and not np.array_equal(self._es_eff, self.es_assign)
                  else None)
        rep = RoundReport(round_idx=round_idx, mask=alive.astype(np.float64),
                          times_s=times, round_time_s=round_time,
                          energy_left_j=self.energy_left.copy(),
                          scheduled=sched.copy(), cuts=rep_cuts,
                          uplink_bps=eff.copy(), codecs=rep_codecs,
                          bits_tx=bits_tx,
                          compute_s=comp_s.copy(), compute_j=compute_j,
                          stale_banked=stale_banked,
                          stale_delivered=stale_delivered,
                          stale_dropped=stale_dropped,
                          es_down=None if es_down is None
                          else es_down.copy(),
                          es_map=es_map)
        self.last_timeline = None
        tel = self.telemetry
        if tel is not None and getattr(tel, "enabled", False):
            # observability opts back into the explicit event timeline
            # (O(N x chunks) host arrays — the price of a full trace)
            from repro.wireless.timeline import build_timeline
            bits = (self.cutter.bits_for(cuts) if self.cutter is not None
                    else self.bits)
            tl = build_timeline(LinkState(eff, down, latency), bits, comp_s,
                                cfg.deadline_s, U, pipeline=cfg.pipeline)
            self.last_timeline = tl
            has_bank = self._stale_age >= 0
            tel.record_round(
                rep, tl, es_assign=self._es_eff,
                deadline_s=float(cfg.deadline_s),
                withdrawn=int(withdrawn.sum()),
                backfilled=n_backfilled,
                tx_j=float(cfg.tx_power_w * tx_s[sched].sum()),
                bank_depth=int(has_bank.sum()),
                bank_age_max=(int(self._stale_age[has_bank].max())
                              if has_bank.any() else 0))
        return rep

    # ------------------------------------------------------ checkpointing --
    def state_dict(self) -> dict:
        out = super().state_dict()
        if self.population is not None:
            from repro.checkpoint.ckpt import rng_state_array
            out["population_rng"] = rng_state_array(self.population._rng)
            out["population_part"] = self.population.part_count.copy()
            out["population_head"] = self.population.head_slot.copy()
        return out

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        if self.population is not None:
            from repro.checkpoint.ckpt import restore_rng_state
            restore_rng_state(self.population._rng, state["population_rng"])
            self.population.part_count = np.asarray(
                state["population_part"], np.int64).copy()
            self.population.head_slot = np.asarray(
                state["population_head"], np.int64).copy()


# ------------------------------------------------------------- slot view --
def cohort_report(rep: RoundReport, cohort: np.ndarray) -> RoundReport:
    """Slice a population-wide (N,) :class:`RoundReport` down to the
    cohort's training SLOTS.

    FedSim trains ``len(cohort)`` stacked replicas ("slots"); the
    scheduler reports over the whole registry.  Slot ``i`` is population
    client ``cohort[i]``, so every per-client array is gathered by
    ``cohort`` — scalars (round time, bits moved) and the (B,) ES-outage
    mask pass through untouched.  Clients outside the cohort are never
    scheduled (gate 1 is masked), so no information is lost."""
    import dataclasses
    n = len(rep.mask)
    out = {}
    for f in dataclasses.fields(RoundReport):
        v = getattr(rep, f.name)
        if (f.name != "es_down" and isinstance(v, np.ndarray)
                and v.shape[:1] == (n,)):
            v = v[cohort]
        out[f.name] = v
    return RoundReport(**out)


# ---------------------------------------------------------------- factory --
def make_cohort_scheduler(cfg, num_clients: int, comm=None, kappa0: int = 1,
                          *, comm_table=None, es_assign=None, fixed_cut=0,
                          telemetry=None, population: Population | None = None,
                          cohort_size: int | None = None,
                          sampling: str = "uniform",
                          es_balanced: bool = False) -> CohortScheduler:
    """``repro.wireless.make_scheduler``'s population-scale twin.

    Identical byte accounting and construction, but the scheduler is a
    :class:`CohortScheduler` (optionally bound to a :class:`Population`
    whose ``es_assign`` should then be passed as ``es_assign``)."""
    from repro.wireless import make_scheduler
    if population is not None:
        if population.N != int(num_clients):
            raise ValueError(f"population has {population.N} clients but "
                             f"num_clients={num_clients}")
        if es_assign is None:
            es_assign = population.es_assign
    return make_scheduler(cfg, num_clients, comm, kappa0,
                          comm_table=comm_table, es_assign=es_assign,
                          fixed_cut=fixed_cut, telemetry=telemetry,
                          cls=CohortScheduler, population=population,
                          cohort_size=cohort_size, sampling=sampling,
                          es_balanced=es_balanced)
