"""Logical-axis -> mesh-axis partitioning rules.

Every model init returns a parallel *axes tree* whose leaves are tuples of
logical axis names, one per array dim (``("embed", "mlp")``); this module
turns those into ``PartitionSpec``s for a concrete mesh, with
divisibility-aware fallback (a dim that does not divide evenly over its
assigned mesh axes is replicated instead — GSPMD then propagates whatever is
cheapest).

Two modes:

- ``tp``       tensor-parallel only ("model" axis).  Used inside the
               paper-faithful PHSFL round, where the "data"/"pod" axes are
               *manual* client/ES axes and each client owns a full replica.
- ``fsdp_tp``  additionally shards the d_model ("embed") dim of the weights
               over the data axes (ZeRO-3/FSDP style).  Used for the shared
               -server beyond-paper mode and for serving.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.tree import axes_leaf

# canonical logical axis names used by the model zoo
LOGICAL_AXES = (
    "vocab",       # vocabulary dim
    "embed",       # d_model dim
    "mlp",         # d_ff dim
    "heads",       # query-head dim (fused heads*head_dim or head count)
    "kv_heads",    # kv-head count dim
    "head_dim",    # per-head feature dim
    "expert",      # MoE expert count dim
    "lru",         # RG-LRU width dim
    "stack",       # scanned-layer stack dim
    "conv",        # conv kernel spatial dims
)

# tensor-parallel rules: logical axis -> mesh axis
_TP_RULES = {
    "vocab": ("model",),
    "mlp": ("model",),
    "heads": ("model",),
    "expert": ("model",),
    "lru": ("model",),
}

# kv_heads shard over model only when the count divides; handled dynamically.
_TP_OPTIONAL = {
    "kv_heads": ("model",),
}


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The mesh axes that play the 'client/batch' role."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, names: tuple[str, ...]) -> int:
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def spec_for(shape: tuple[int, ...], axes: tuple[Any, ...], mesh: Mesh,
             mode: str = "tp") -> P:
    """PartitionSpec for one array given its logical axes."""
    assert len(shape) == len(axes), f"shape {shape} vs axes {axes}"
    used: set[str] = set()
    entries: list[Any] = []
    for dim, ax in zip(shape, axes):
        assigned = None
        candidates: tuple[str, ...] = ()
        if ax in _TP_RULES:
            candidates = _TP_RULES[ax]
        elif ax in _TP_OPTIONAL:
            candidates = _TP_OPTIONAL[ax]
        elif ax == "embed" and mode == "fsdp_tp":
            candidates = data_axes(mesh)
        if candidates and not (set(candidates) & used):
            if all(c in mesh.axis_names for c in candidates):
                if dim % _axis_size(mesh, candidates) == 0:
                    assigned = candidates if len(candidates) > 1 else candidates[0]
                    used.update(candidates)
        entries.append(assigned)
    return P(*entries)


def params_specs(params, axes_tree, mesh: Mesh, mode: str = "tp"):
    """Map a params tree + axes tree -> PartitionSpec tree."""
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_a = jax.tree_util.tree_flatten(axes_tree, is_leaf=axes_leaf)[0]
    assert len(flat_p) == len(flat_a), (
        f"params/axes trees disagree: {len(flat_p)} vs {len(flat_a)}")
    specs = [spec_for(tuple(p.shape), a, mesh, mode) for p, a in zip(flat_p, flat_a)]
    return jax.tree_util.tree_unflatten(treedef, specs)


def add_client_axis(spec_tree, mesh: Mesh):
    """Prefix every spec with the manual client axes (paper-faithful mode).

    Per-client parameter replicas carry a leading dim of size
    num_pods*clients_per_pod, sharded over ("pod","data").
    """
    ca = data_axes(mesh)
    lead = ca if len(ca) > 1 else ca[0]

    def _prefix(s: P) -> P:
        return P(lead, *tuple(s))

    return jax.tree.map(_prefix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def named_sharding(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
