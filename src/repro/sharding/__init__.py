from repro.sharding.rules import (
    LOGICAL_AXES,
    spec_for,
    params_specs,
    add_client_axis,
    data_axes,
    named_sharding,
    constrain,
)

__all__ = [
    "LOGICAL_AXES", "spec_for", "params_specs", "add_client_axis",
    "data_axes", "named_sharding", "constrain",
]
