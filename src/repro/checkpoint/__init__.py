from repro.checkpoint.ckpt import (save_checkpoint, load_checkpoint,
                                   latest_step, rng_state_array,
                                   restore_rng_state)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "rng_state_array", "restore_rng_state"]
