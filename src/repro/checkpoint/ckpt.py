"""Flat-npz pytree checkpointing (no external deps).

Leaves are stored under their '/'-joined key paths; restore rebuilds into a
caller-provided target structure (so dtypes/shardings can be re-imposed by
the caller — sharded restore re-uses jax.device_put with the target's
sharding).
"""

from __future__ import annotations

import os
import re

import jax
import numpy as np

from repro.utils.tree import path_str


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {path_str(p): np.asarray(v) for p, v in flat}


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # np.savez appends .npz unless already present
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for f in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, target):
    """Restore into the structure of ``target`` (shapes must match)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        leaves = []
        for p, tgt in flat:
            key = path_str(p)
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(f"{key}: shape {arr.shape} != {tgt.shape}")
            leaves.append(arr.astype(tgt.dtype))
    return jax.tree_util.tree_unflatten(treedef, [v for _, v in zip(flat, leaves)])
