"""Flat-npz pytree checkpointing (no external deps).

Leaves are stored under their '/'-joined key paths; restore rebuilds into a
caller-provided target structure (so dtypes/shardings can be re-imposed by
the caller — sharded restore re-uses jax.device_put with the target's
sharding).

Crash safety: ``save_checkpoint`` writes to a ``.tmp.npz`` sidecar and
``os.replace``s it into place, so ``latest_step`` (which matches only the
final ``ckpt_<step>.npz`` names) can never observe a torn checkpoint.  A
crash between the write and the rename strands the sidecar; the next
``save_checkpoint`` in the directory sweeps all stale ``.tmp.npz`` files
before writing its own.

``rng_state_array``/``restore_rng_state`` round-trip a numpy PCG64
``Generator``'s exact stream position through a plain uint64 array, so RNG
streams checkpoint like any other leaf.
"""

from __future__ import annotations

import os
import re

import jax
import numpy as np

from repro.utils.tree import path_str


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {path_str(p): np.asarray(v) for p, v in flat}


def save_checkpoint(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    # sweep sidecars stranded by a crash mid-save (never matched by
    # latest_step, but they'd otherwise accumulate forever)
    for f in os.listdir(directory):
        if f.endswith(".tmp.npz"):
            try:
                os.remove(os.path.join(directory, f))
            except OSError:
                pass                      # a concurrent saver won the race
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # np.savez appends .npz unless already present
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for f in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, target, *, cast: bool = False):
    """Restore into the structure of ``target`` (shapes must match).

    Dtypes must match too: a checkpoint leaf whose dtype differs from the
    target's raises unless ``cast=True`` explicitly opts into the
    conversion (a silent fp32 -> int8 astype truncates without complaint).
    """
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        leaves = []
        for p, tgt in flat:
            key = path_str(p)
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(f"{key}: shape {arr.shape} != {tgt.shape}")
            tgt_dtype = np.dtype(tgt.dtype)
            if arr.dtype != tgt_dtype and not cast:
                raise ValueError(
                    f"{key}: checkpoint dtype {arr.dtype} != target "
                    f"{tgt_dtype}; pass cast=True to convert explicitly")
            leaves.append(arr.astype(tgt_dtype))
    return jax.tree_util.tree_unflatten(treedef, [v for _, v in zip(flat, leaves)])


# ---------------------------------------------------------- RNG streams --
_MASK64 = (1 << 64) - 1


def rng_state_array(rng: np.random.Generator) -> np.ndarray:
    """A PCG64 Generator's exact state as a (6,) uint64 array.

    Layout: [state_hi, state_lo, inc_hi, inc_lo, has_uint32, uinteger] —
    the 128-bit state/inc words split into 64-bit halves so the array
    checkpoints losslessly through npz.
    """
    st = rng.bit_generator.state
    if st["bit_generator"] != "PCG64":
        raise TypeError(f"expected a PCG64 generator, got "
                        f"{st['bit_generator']}")
    s, inc = st["state"]["state"], st["state"]["inc"]
    return np.array([s >> 64, s & _MASK64, inc >> 64, inc & _MASK64,
                     st["has_uint32"], st["uinteger"]], dtype=np.uint64)


def restore_rng_state(rng: np.random.Generator, arr) -> None:
    """Restore a Generator's stream position from ``rng_state_array``."""
    a = [int(x) for x in np.asarray(arr, np.uint64)]
    if len(a) != 6:
        raise ValueError(f"expected a (6,) rng state array, got "
                         f"shape {np.asarray(arr).shape}")
    rng.bit_generator.state = {
        "bit_generator": "PCG64",
        "state": {"state": (a[0] << 64) | a[1], "inc": (a[2] << 64) | a[3]},
        "has_uint32": a[4], "uinteger": a[5]}
