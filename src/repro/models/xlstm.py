"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory with true hidden-to-hidden recurrence).

mLSTM training uses the *chunkwise-parallel* form (intra-chunk quadratic +
inter-chunk recurrent carry) — the same algorithm the Pallas
``mlstm_chunk`` kernel implements with VMEM tiling; decode uses the O(1)
recurrent form.  The two are numerically consistent (tested).

sLSTM is inherently sequential (hidden state feeds the gates); training is a
lax.scan over time — this is honest to the architecture and shows up as a
latency-bound term in the roofline analysis.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.init_utils import dense, dense_axes, norm, norm_axes, truncated_normal

MLSTM_CHUNK = 256


# =============================================================== mLSTM ======
def mlstm_init(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    x = cfg.xlstm
    d = cfg.d_model
    di = int(d * x.proj_factor_mlstm)
    h = x.num_heads
    dh = di // h
    ks = jax.random.split(key, 8)
    return {
        "up": dense(ks[0], d, 2 * di, dtype=dtype),        # [x_m ; z-gate]
        "conv": truncated_normal(ks[1], (x.conv_kernel, di), 1.0 / math.sqrt(x.conv_kernel), dtype),
        "q": dense(ks[2], di, di, dtype=dtype),
        "k": dense(ks[3], di, di, dtype=dtype),
        "v": dense(ks[4], di, di, dtype=dtype),
        "i_gate": dense(ks[5], di, h, dtype=jnp.float32),
        "f_gate": dense(ks[6], di, h, dtype=jnp.float32),
        "out_norm": norm(dh, "rmsnorm", dtype),            # per-head group norm
        "down": dense(ks[7], di, d, dtype=dtype),
    }


def mlstm_axes(cfg: ModelConfig):
    return {
        "up": dense_axes(("embed", "mlp")),
        "conv": ("conv", "mlp"),
        "q": dense_axes(("mlp", "mlp")),
        "k": dense_axes(("mlp", "mlp")),
        "v": dense_axes(("mlp", "mlp")),
        "i_gate": dense_axes(("mlp", None)),
        "f_gate": dense_axes(("mlp", None)),
        "out_norm": norm_axes("rmsnorm"),
        "down": dense_axes(("mlp", "embed")),
    }


def causal_conv1d(x, w, state=None):
    """Depthwise causal conv.  x: (B,S,C); w: (K,C).

    state: (B,K-1,C) trailing context from previous tokens (decode); returns
    (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (k - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y, new_state


def _mlstm_heads(p, cfg: ModelConfig, x_m, conv_state=None):
    """Project the mLSTM branch to per-head q,k,v and scalar gates."""
    xl = cfg.xlstm
    h = xl.num_heads
    conv_out, conv_state = causal_conv1d(x_m, p["conv"], conv_state)
    conv_act = jax.nn.silu(conv_out)
    b, s, di = x_m.shape
    dh = di // h
    q = (conv_act @ p["q"]["w"]).reshape(b, s, h, dh)
    k = (conv_act @ p["k"]["w"]).reshape(b, s, h, dh) / math.sqrt(dh)
    v = (x_m @ p["v"]["w"]).reshape(b, s, h, dh)
    li = (conv_act.astype(jnp.float32) @ p["i_gate"]["w"])          # (B,S,H)
    lf = jax.nn.log_sigmoid(conv_act.astype(jnp.float32) @ p["f_gate"]["w"])
    return q, k, v, li, lf, conv_state


def mlstm_chunkwise(q, k, v, li, lf, carry=None, chunk: int = MLSTM_CHUNK):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B,S,H,dh); li,lf: (B,S,H) input/forget log-gates.
    carry: optional (C (B,H,dk,dv), n (B,H,dk), m (B,H)).
    Returns (h (B,S,H,dh), carry').
    """
    b, s, h, dh = q.shape
    if s % chunk:  # fall back to one chunk == recurrent-free quadratic path
        chunk = s
    nc = s // chunk
    f32 = jnp.float32
    qc = q.reshape(b, nc, chunk, h, dh).astype(f32)
    kc = k.reshape(b, nc, chunk, h, dh).astype(f32)
    vc = v.reshape(b, nc, chunk, h, dh).astype(f32)
    lic = li.reshape(b, nc, chunk, h).astype(f32)
    lfc = lf.reshape(b, nc, chunk, h).astype(f32)

    if carry is None:
        C0 = jnp.zeros((b, h, dh, dh), f32)
        n0 = jnp.zeros((b, h, dh), f32)
        m0 = jnp.full((b, h), -1e30, f32)
    else:
        C0, n0, m0 = (c.astype(f32) for c in carry)

    def chunk_body(state, inp):
        C, n, m_prev = state
        qb, kb, vb, lib, lfb = inp                          # (B,chunk,H,*)
        a = jnp.cumsum(lfb, axis=1)                         # (B,chunk,H)
        g = lib - a                                         # g_s = li_s - a_s
        run_max = jax.lax.cummax(g, axis=1)
        M = jnp.maximum(m_prev[:, None, :], run_max)        # (B,chunk,H)
        m_t = a + M
        # intra-chunk: D[t,s] = exp(g_s - M_t) for s <= t
        Dlog = g[:, None, :, :] - M[:, :, None, :]          # (B,t,s,H)
        t_idx = jnp.arange(chunk)
        causal = t_idx[None, :, None, None] >= t_idx[None, None, :, None]
        D = jnp.where(causal, jnp.exp(Dlog), 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qb, kb) * D
        h_intra = jnp.einsum("btsh,bshd->bthd", scores, vb)
        n_intra = jnp.einsum("btsh,bshd->bthd", D, kb)
        # inter-chunk carry contribution, decayed by exp(m_prev - M_t)
        decay = jnp.exp(m_prev[:, None, :] - M)             # (B,chunk,H)
        h_inter = jnp.einsum("bthd,bhde->bthe", qb, C) * decay[..., None]
        n_inter = n[:, None, :, :] * decay[..., None]
        n_tot = n_intra + n_inter
        denom = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", qb, n_tot)),
                            jnp.exp(-m_t))[..., None]
        h_out = (h_intra + h_inter) / denom
        # ---- end-of-chunk carry update ----
        a_L = a[:, -1, :]                                   # (B,H)
        M_L = M[:, -1, :]
        m_new = m_t[:, -1, :]
        w_s = jnp.exp(g - M_L[:, None, :])                  # (B,chunk,H)
        C_new = C * jnp.exp(m_prev - M_L)[:, :, None, None] + \
            jnp.einsum("bsh,bshd,bshe->bhde", w_s, kb, vb)
        n_new = n * jnp.exp(m_prev - M_L)[:, :, None] + \
            jnp.einsum("bsh,bshd->bhd", w_s, kb)
        return (C_new, n_new, m_new), h_out

    (C, n, m), hs = jax.lax.scan(
        chunk_body, (C0, n0, m0),
        (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.moveaxis(lic, 1, 0), jnp.moveaxis(lfc, 1, 0)))
    h_all = jnp.moveaxis(hs, 0, 1).reshape(b, s, h, dh)
    return h_all.astype(q.dtype), (C, n, m)


def mlstm_step(q, k, v, li, lf, carry):
    """O(1) recurrent decode step.  q,k,v: (B,1,H,dh); li,lf: (B,1,H)."""
    C, n, m_prev = carry
    f32 = jnp.float32
    qs, ks, vs = (t[:, 0].astype(f32) for t in (q, k, v))
    lis, lfs = li[:, 0].astype(f32), lf[:, 0].astype(f32)
    m_new = jnp.maximum(lfs + m_prev, lis)
    fgate = jnp.exp(lfs + m_prev - m_new)[..., None]
    igate = jnp.exp(lis - m_new)[..., None]
    C = C * fgate[..., None] + igate[..., None] * ks[..., :, None] * vs[..., None, :]
    n = n * fgate + igate * ks
    h = jnp.einsum("bhd,bhde->bhe", qs, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n)),
                        jnp.exp(-m_new))[..., None]
    h = (h / denom)[:, None].astype(q.dtype)               # (B,1,H,dh)
    return h, (C, n, m_new)


def mlstm_block_apply(p, cfg: ModelConfig, x, *, cache=None, index=None):
    """Full mLSTM residual block.  x: (B,S,D).

    cache: None (training/prefill-from-scratch) or dict with conv/carry
    state for decode.  Returns (out, new_cache).
    """
    from repro.models.layers import apply_norm

    xl = cfg.xlstm
    di = int(cfg.d_model * xl.proj_factor_mlstm)
    up = x @ p["up"]["w"]
    x_m, z = up[..., :di], up[..., di:]
    conv_state = cache["conv"] if cache is not None else None
    q, k, v, li, lf, conv_state = _mlstm_heads(p, cfg, x_m, conv_state)
    if cache is None:
        h, carry = mlstm_chunkwise(q, k, v, li, lf)
    else:
        h, carry = mlstm_step(q, k, v, li, lf, cache["carry"])
    h = apply_norm(p["out_norm"], h, "rmsnorm")            # per-head norm
    b, s = x.shape[:2]
    h = h.reshape(b, s, di)
    out = (h * jax.nn.silu(z)) @ p["down"]["w"]
    new_cache = {"conv": conv_state, "carry": carry} if cache is not None else None
    return out, new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    xl = cfg.xlstm
    di = int(cfg.d_model * xl.proj_factor_mlstm)
    h = xl.num_heads
    dh = di // h
    return {
        "conv": jnp.zeros((batch, xl.conv_kernel - 1, di), dtype),
        "carry": (jnp.zeros((batch, h, dh, dh), jnp.float32),
                  jnp.zeros((batch, h, dh), jnp.float32),
                  jnp.full((batch, h), -1e30, jnp.float32)),
    }


# =============================================================== sLSTM ======
def slstm_init(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    xl = cfg.xlstm
    d = cfg.d_model
    h = xl.num_heads
    dh = d // h
    dff = int(d * xl.proj_factor_slstm)
    ks = jax.random.split(key, 6)
    return {
        "w": dense(ks[0], d, 4 * d, dtype=dtype),          # i,f,z,o all heads
        "r": truncated_normal(ks[1], (h, dh, 4 * dh), 1.0 / math.sqrt(dh), dtype),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "out_norm": norm(dh, "rmsnorm", dtype),
        "up_gate": dense(ks[2], d, dff, dtype=dtype),
        "up": dense(ks[3], d, dff, dtype=dtype),
        "down": dense(ks[4], dff, d, dtype=dtype),
    }


def slstm_axes(cfg: ModelConfig):
    return {
        "w": dense_axes(("embed", "mlp")),
        "r": (None, None, None),      # hidden-to-hidden; kept replicated
        "b": (None,),
        "out_norm": norm_axes("rmsnorm"),
        "up_gate": dense_axes(("embed", "mlp")),
        "up": dense_axes(("embed", "mlp")),
        "down": dense_axes(("mlp", "embed")),
    }


def _slstm_cell(p, cfg: ModelConfig, wx_t, state):
    """One sLSTM step.  wx_t: (B,H,4*dh) precomputed W x_t (+ b).

    state: (c, n, h, m) each (B,H,dh) except m (B,H,dh? scalar-per-unit) —
    xLSTM stabilizer is per *unit*: keep (B,H,dh).
    """
    c, n, hid, m = state
    rh = jnp.einsum("bhd,hdk->bhk", hid.astype(wx_t.dtype), p["r"])
    raw = (wx_t + rh).astype(jnp.float32)
    dh = c.shape[-1]
    i_t, f_t, z_t, o_t = (raw[..., j * dh:(j + 1) * dh] for j in range(4))
    lf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(lf + m, i_t)
    igate = jnp.exp(i_t - m_new)
    fgate = jnp.exp(lf + m - m_new)
    c_new = fgate * c + igate * jnp.tanh(z_t)
    n_new = fgate * n + igate
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_scan(p, cfg: ModelConfig, x, state=None):
    """x: (B,S,D) -> (h (B,S,D), final state).  Sequential over S."""
    xl = cfg.xlstm
    b, s, d = x.shape
    h = xl.num_heads
    dh = d // h
    wx = (x @ p["w"]["w"]).astype(jnp.float32) + p["b"]
    wx = wx.reshape(b, s, h, 4 * dh)
    if state is None:
        z = lambda: jnp.zeros((b, h, dh), jnp.float32)
        state = (z(), z(), z(), jnp.full((b, h, dh), -1e30, jnp.float32))

    def body(st, wx_t):
        return _slstm_cell(p, cfg, wx_t, st)

    state, hs = jax.lax.scan(body, state, jnp.moveaxis(wx, 1, 0))
    return jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype), state


def slstm_block_apply(p, cfg: ModelConfig, x, *, cache=None, index=None):
    """sLSTM residual block with post-up-projection MLP."""
    from repro.models.layers import apply_norm

    b, s, d = x.shape
    h, state = slstm_scan(p, cfg, x, None if cache is None else cache["state"])
    hh = apply_norm(p["out_norm"], h.reshape(b, s, cfg.xlstm.num_heads, -1),
                    "rmsnorm").reshape(b, s, d)
    y = (jax.nn.gelu(hh @ p["up_gate"]["w"]) * (hh @ p["up"]["w"])) @ p["down"]["w"]
    new_cache = {"state": state} if cache is not None else None
    return y, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    xl = cfg.xlstm
    dh = cfg.d_model // xl.num_heads
    z = lambda: jnp.zeros((batch, xl.num_heads, dh), jnp.float32)
    return {"state": (z(), z(), z(), jnp.full((batch, xl.num_heads, dh), -1e30,
                                              jnp.float32))}
