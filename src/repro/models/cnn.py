"""The paper's CNN (Sec. V-A) with explicit split-learning dataflow.

At the paper's default cut (after the first maxpool):

Client-side model  w_{u,0}:  conv1 -> relu -> maxpool          (trained on client)
Server-side body   w_{1,bd}: conv2 -> relu -> maxpool -> fc1 -> relu
Server-side head   w_{1,hd}: fc2  (classifier — random-init, FROZEN in training,
                                   fine-tuned per client for personalization)

The cut is a parameter: ``CUT_CANDIDATES`` names the layer boundaries the
split may fall on (shallow to deep), and ``client_forward`` /
``server_forward`` / ``cut_activation_size`` / ``client_keys_for`` all take
a ``cut`` argument.  Remark 2 of the paper proves the choice does not change
learning dynamics — it only moves the cut-layer tensor (Z_c) and the
client-block size (Z_0), i.e. who pays which bits (Remark 1) — which is what
makes the cut a pure resource-allocation knob (see repro.wireless.cutter).

``client_forward`` / ``server_forward`` mirror Steps 3.2–3.5: the client
computes the cut-layer activations o_fp, offloads them (plus mini-batch
indices) to the ES, which completes the forward pass with the labels it
holds.  The comm accounting in core/comm.py uses the o_fp shape here.

Note: the paper writes FC(512,256); with 3x3 same-padding convs and two 2x2
pools on 32x32 inputs, the flat dim is 8*8*128.  We keep the architecture
shape-generic via CNNConfig.flat_dim (deviation recorded in DESIGN.md).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.phsfl_cnn import CNNConfig
from repro.models.init_utils import truncated_normal


def _conv_init(key, k, cin, cout, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(k * k * cin)
    kw, kb = jax.random.split(key)
    return {"w": truncated_normal(kw, (k, k, cin, cout), scale, dtype),
            "b": jnp.zeros((cout,), dtype)}


def _fc_init(key, din, dout, dtype=jnp.float32):
    return {"w": truncated_normal(key, (din, dout), 1.0 / math.sqrt(din), dtype),
            "b": jnp.zeros((dout,), dtype)}


def init(key, cfg: CNNConfig, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "conv1": _conv_init(k1, 3, cfg.channels, cfg.conv1_filters, dtype),
        "conv2": _conv_init(k2, 3, cfg.conv1_filters, cfg.conv2_filters, dtype),
        "fc1": _fc_init(k3, cfg.flat_dim, cfg.fc_hidden, dtype),
        "fc2": _fc_init(k4, cfg.fc_hidden, cfg.num_labels, dtype),  # the head
    }


def axes(cfg: CNNConfig):
    return {
        "conv1": {"w": ("conv", "conv", None, None), "b": (None,)},
        "conv2": {"w": ("conv", "conv", None, None), "b": (None,)},
        "fc1": {"w": (None, "mlp"), "b": ("mlp",)},
        "fc2": {"w": ("mlp", None), "b": (None,)},
    }


# PHSFL pytree partition (core/split.py builds masks from these).  The cut
# candidates are the layer boundaries the split may fall on, shallow to deep;
# DEFAULT_CUT is the paper's own split (after the first maxpool).
CUT_CANDIDATES = ("conv1", "conv2", "fc1")
DEFAULT_CUT = "conv1"
CLIENT_KEYS = ("conv1",)
BODY_KEYS = ("conv2", "fc1")
HEAD_KEYS = ("fc2",)


def client_keys_for(cut: str) -> tuple[str, ...]:
    """Pytree keys of the client block w_{u,0} when cutting after ``cut``."""
    if cut not in CUT_CANDIDATES:
        raise ValueError(f"unknown cut {cut!r}; candidates: {CUT_CANDIDATES}")
    return CUT_CANDIDATES[:CUT_CANDIDATES.index(cut) + 1]


def _conv(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def client_forward(params, x, cut: str = DEFAULT_CUT):
    """w_{u,0}: images (B,H,W,C) -> cut-layer activations o_fp at ``cut``."""
    h = _maxpool(jax.nn.relu(_conv(params["conv1"], x)))
    if cut == "conv1":
        return h
    h = _maxpool(jax.nn.relu(_conv(params["conv2"], h)))
    if cut == "conv2":
        return h
    h = h.reshape(h.shape[0], -1)
    return jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])


def server_forward(params, o_fp, cut: str = DEFAULT_CUT):
    """w_{u,1} = [body; head]: cut activations at ``cut`` -> logits."""
    h = o_fp
    if cut == "conv1":
        h = _maxpool(jax.nn.relu(_conv(params["conv2"], h)))
    if cut in ("conv1", "conv2"):
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["fc2"]["w"] + params["fc2"]["b"]


def apply(params, x):
    return server_forward(params, client_forward(params, x))


def loss_and_acc(params, x, y):
    logits = apply(params, x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    acc = (logits.argmax(-1) == y).mean()
    return nll, acc


def loss_fn(params, x, y):
    return loss_and_acc(params, x, y)[0]


def cut_activation_size(cfg: CNNConfig, batch: int,
                        cut: str = DEFAULT_CUT) -> int:
    """Elements of o_fp for one mini-batch (Remark 1: N x Z_c) at ``cut``."""
    if cut == "conv1":
        s = cfg.image_size // 2
        return batch * s * s * cfg.conv1_filters
    if cut == "conv2":
        s = cfg.image_size // 4
        return batch * s * s * cfg.conv2_filters
    if cut == "fc1":
        return batch * cfg.fc_hidden
    raise ValueError(f"unknown cut {cut!r}; candidates: {CUT_CANDIDATES}")


def client_block_flops(cfg: CNNConfig, batch: int,
                       cut: str = DEFAULT_CUT) -> int:
    """Forward FLOPs of the client block w_{u,0} at ``cut`` for one
    mini-batch — the compute twin of :func:`cut_activation_size` (Remark 1
    prices the bits a cut moves; the wireless device model prices the FLOPs
    it keeps on the client).  Convolutions are priced per output position,
    so a deeper cut costs the client an order of magnitude more compute
    even though its activation tensor shrinks."""
    from repro.utils.flops import conv2d_flops, dense_layer_flops

    s = cfg.image_size
    f = conv2d_flops(batch, s, s, 3, cfg.channels, cfg.conv1_filters)
    if cut == "conv1":
        return f
    s2 = s // 2
    f += conv2d_flops(batch, s2, s2, 3, cfg.conv1_filters, cfg.conv2_filters)
    if cut == "conv2":
        return f
    if cut == "fc1":
        return f + dense_layer_flops(batch, cfg.flat_dim, cfg.fc_hidden)
    raise ValueError(f"unknown cut {cut!r}; candidates: {CUT_CANDIDATES}")
