"""Decoder-LM assembly for all assigned architectures.

Layers are grouped into *stages* so repeated block patterns lower as a
``lax.scan`` over stacked parameters (small HLO even for 88-layer models):

    lead  — unscanned leading layers (e.g. deepseek's first dense-FFN layer)
    scan  — (pattern of len p) x (repeats k), params stacked on a 'stack' dim
    tail  — unscanned remainder (e.g. gemma3-27b: 62 = 6*10 + 2)

The LM head is *always* a separate parameter ("lm_head") — the PHSFL frozen
random classifier requires an untied head even for configs whose source
model ties embeddings (noted in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, LOCAL_ATTN, MLA_ATTN, MLSTM, RGLRU,
                                SLSTM, ModelConfig)
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.init_utils import (dense, dense_axes, embedding,
                                     embedding_axes, norm, norm_axes,
                                     stack_axes)
from repro.models.layers import apply_norm, mlp_apply, mlp_axes, mlp_init, softcap

LOSS_CHUNK = 512  # seq chunk for the memory-bounded LM loss


# ------------------------------------------------------------- stages ------
@dataclasses.dataclass(frozen=True)
class Stage:
    which: str                 # "lead" | "scan" | "tail"
    layer_ids: tuple[int, ...] # absolute layer indices (first repeat for scan)
    repeats: int = 1


def compute_stages(cfg: ModelConfig) -> list[Stage]:
    kinds = cfg.layer_kinds()
    L = cfg.num_layers
    p = len(cfg.block_pattern)
    # lead layers are unscanned: (a) structurally distinct layers (deepseek's
    # first dense-FFN layer) and (b) the PHSFL *client-side* layers, so the
    # client/body split is a plain pytree partition even under layer scan.
    lead = max(cfg.moe.first_dense_layers if cfg.moe else 0,
               cfg.n_client_layers)
    lead = min(lead, L)
    k = (L - lead) // p
    rem = (L - lead) - k * p
    stages = []
    if lead:
        stages.append(Stage("lead", tuple(range(lead))))
    if k:
        first = tuple(range(lead, lead + p))
        # sanity: the pattern must actually repeat
        for r in range(k):
            for j in range(p):
                assert kinds[lead + r * p + j] == kinds[lead + j], (r, j)
        stages.append(Stage("scan", first, repeats=k))
    if rem:
        stages.append(Stage("tail", tuple(range(lead + k * p, L))))
    return stages


def _layer_is_moe(cfg: ModelConfig, layer_id: int) -> bool:
    return (cfg.moe is not None
            and layer_id >= (cfg.moe.first_dense_layers or 0))


def _layer_kind(cfg: ModelConfig, layer_id: int) -> str:
    return cfg.layer_kinds()[layer_id]


def _rope_theta_for(cfg: ModelConfig, kind: str) -> float:
    return cfg.local_rope_theta if kind == LOCAL_ATTN else cfg.rope_theta


# -------------------------------------------------------- layer params -----
def init_layer(key, cfg: ModelConfig, layer_id: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    kind = _layer_kind(cfg, layer_id)
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in (SLSTM, MLSTM):
        block_init = (xlstm_mod.slstm_init if kind == SLSTM
                      else xlstm_mod.mlstm_init)
        return {"ln1": norm(cfg.d_model, cfg.norm, dtype),
                "block": block_init(k1, cfg, dtype)}
    p = {"ln1": norm(cfg.d_model, cfg.norm, dtype),
         "ln2": norm(cfg.d_model, cfg.norm, dtype)}
    if kind == MLA_ATTN:
        p["mla"] = mla_mod.mla_init(k1, cfg, dtype)
    elif kind == RGLRU:
        p["rec"] = rglru_mod.rglru_init(k1, cfg, dtype)
    else:
        p["attn"] = attn_mod.attn_init(k1, cfg, dtype)
    if _layer_is_moe(cfg, layer_id):
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and not _layer_is_moe(cfg, layer_id):
            d_ff = cfg.moe.d_ff_dense
        p["mlp"] = mlp_init(k3, cfg, d_ff=d_ff, dtype=dtype)
    return p


def layer_axes(cfg: ModelConfig, layer_id: int):
    kind = _layer_kind(cfg, layer_id)
    if kind in (SLSTM, MLSTM):
        block_axes = (xlstm_mod.slstm_axes if kind == SLSTM
                      else xlstm_mod.mlstm_axes)
        return {"ln1": norm_axes(cfg.norm), "block": block_axes(cfg)}
    a = {"ln1": norm_axes(cfg.norm), "ln2": norm_axes(cfg.norm)}
    if kind == MLA_ATTN:
        a["mla"] = mla_mod.mla_axes(cfg)
    elif kind == RGLRU:
        a["rec"] = rglru_mod.rglru_axes(cfg)
    else:
        a["attn"] = attn_mod.attn_axes(cfg)
    if _layer_is_moe(cfg, layer_id):
        a["moe"] = moe_mod.moe_axes(cfg)
    else:
        a["mlp"] = mlp_axes()
    return a


# -------------------------------------------------------- layer apply ------
def apply_layer(p, cfg: ModelConfig, kind: str, layer_is_moe: bool, x, *,
                positions=None, positions3=None, impl: str = "auto"):
    """Full-sequence layer.  Returns (x, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (SLSTM, MLSTM):
        fn = (xlstm_mod.slstm_block_apply if kind == SLSTM
              else xlstm_mod.mlstm_block_apply)
        y, _ = fn(p["block"], cfg, apply_norm(p["ln1"], x, cfg.norm))
        return x + y, aux
    h = apply_norm(p["ln1"], x, cfg.norm)
    if kind == MLA_ATTN:
        y = mla_mod.mla_apply(p["mla"], cfg, h, positions=positions, impl=impl)
    elif kind == RGLRU:
        y, _ = rglru_mod.rglru_block_apply(p["rec"], cfg, h)
    else:
        window = cfg.sliding_window if kind == LOCAL_ATTN else 0
        y = attn_mod.attn_apply(
            p["attn"], cfg, h, window=window,
            rope_theta=_rope_theta_for(cfg, kind),
            softcap=cfg.attn_logit_softcap, positions=positions,
            positions3=positions3, impl=impl)
    x = x + y
    h = apply_norm(p["ln2"], x, cfg.norm)
    if layer_is_moe:
        y, aux = moe_mod.moe_apply(p["moe"], cfg, h)
    else:
        y = mlp_apply(p["mlp"], h, cfg.act)
    return x + y, aux


def decode_layer(p, cfg: ModelConfig, kind: str, layer_is_moe: bool, x,
                 cache, index, *, positions3=None):
    """One-token decode through a layer.  Returns (x, new_cache, aux)."""
    if kind in (SLSTM, MLSTM):
        fn = (xlstm_mod.slstm_block_apply if kind == SLSTM
              else xlstm_mod.mlstm_block_apply)
        y, new_cache = fn(p["block"], cfg, apply_norm(p["ln1"], x, cfg.norm),
                          cache=cache, index=index)
        return x + y, new_cache
    h = apply_norm(p["ln1"], x, cfg.norm)
    if kind == MLA_ATTN:
        y, new_cache = mla_mod.mla_decode_attend(p["mla"], cfg, h, cache, index)
    elif kind == RGLRU:
        y, new_cache = rglru_mod.rglru_block_apply(p["rec"], cfg, h,
                                                   cache=cache, index=index)
    else:
        window = cfg.sliding_window if kind == LOCAL_ATTN else 0
        y, new_cache = attn_mod.decode_attend(
            p["attn"], cfg, h, cache, index, window=window,
            rope_theta=_rope_theta_for(cfg, kind),
            softcap=cfg.attn_logit_softcap, positions3=positions3)
    x = x + y
    h = apply_norm(p["ln2"], x, cfg.norm)
    if layer_is_moe:
        y, _ = moe_mod.moe_apply(p["moe"], cfg, h)
    else:
        y = mlp_apply(p["mlp"], h, cfg.act)
    return x + y, new_cache


def init_layer_cache(cfg: ModelConfig, layer_id: int, batch: int, max_len: int,
                     dtype=jnp.bfloat16):
    kind = _layer_kind(cfg, layer_id)
    if kind == SLSTM:
        return xlstm_mod.init_slstm_cache(cfg, batch)
    if kind == MLSTM:
        return xlstm_mod.init_mlstm_cache(cfg, batch)
    if kind == RGLRU:
        return rglru_mod.init_rglru_cache(cfg, batch)
    if kind == MLA_ATTN:
        return mla_mod.init_mla_cache(cfg, batch, max_len, dtype)
    window = cfg.sliding_window if kind == LOCAL_ATTN else 0
    return attn_mod.init_kv_cache(cfg, batch, max_len, window=window,
                                  dtype=dtype)


# --------------------------------------------------------- whole model -----
def init(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    stages = compute_stages(cfg)
    keys = jax.random.split(key, cfg.num_layers + 3)
    params = {
        "embed": embedding(keys[-1], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": norm(cfg.d_model, cfg.norm, dtype),
        # the PHSFL head: randomly initialized; frozen during global training
        "lm_head": dense(keys[-2], cfg.d_model, cfg.padded_vocab, dtype=dtype),
    }
    for si, st in enumerate(stages):
        if st.which == "scan":
            blocks = {}
            for j, lid in enumerate(st.layer_ids):
                lkeys = jnp.stack([keys[lid + r * len(st.layer_ids)]
                                   for r in range(st.repeats)])
                blocks[f"b{j}"] = jax.vmap(
                    lambda k, lid=lid: init_layer(k, cfg, lid, dtype))(lkeys)
            params[f"stage{si}"] = blocks
        else:
            params[f"stage{si}"] = {
                f"b{j}": init_layer(keys[lid], cfg, lid, dtype)
                for j, lid in enumerate(st.layer_ids)}
    return params


def axes(cfg: ModelConfig):
    stages = compute_stages(cfg)
    ax = {
        "embed": embedding_axes(),
        "final_norm": norm_axes(cfg.norm),
        "lm_head": dense_axes(("embed", "vocab")),
    }
    for si, st in enumerate(stages):
        blocks = {}
        for j, lid in enumerate(st.layer_ids):
            la = layer_axes(cfg, lid)
            blocks[f"b{j}"] = stack_axes(la) if st.which == "scan" else la
        ax[f"stage{si}"] = blocks
    return ax


def embed_tokens(params, cfg: ModelConfig, tokens, patch_embeds=None):
    x = params["embed"]["table"][tokens]
    if cfg.embed_scale:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    if patch_embeds is not None:
        # VLM stub frontend: precomputed patch embeddings occupy the first
        # num_patch_tokens positions of the sequence.
        np_ = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, np_:]], axis=1)
    return x


def remat_wrapper(remat: bool, remat_policy: str | None = None):
    """Activation-checkpoint wrapper factory.

    remat_policy: None/'full' — save only block boundaries (max recompute);
    'dots' — save dot/matmul outputs (recompute only cheap elementwise ops,
    the §Perf selective-remat iteration).
    """
    if not remat:
        return lambda f: f
    if remat_policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return lambda f: jax.checkpoint(f, policy=pol)
    return jax.checkpoint


def apply(params, cfg: ModelConfig, batch, *, impl: str = "auto",
          remat: bool = False, remat_policy: str | None = None):
    """Full-sequence forward to final hidden states (B,S,D).

    batch: {"tokens": (B,S) int32, optional "patch_embeds", "positions3"}.
    Returns (hidden, moe_aux_loss).
    """
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens, batch.get("patch_embeds"))
    positions3 = batch.get("positions3")
    aux_total = jnp.zeros((), jnp.float32)
    stages = compute_stages(cfg)

    def one_layer(p, x, kind, is_moe):
        return apply_layer(p, cfg, kind, is_moe, x,
                           positions3=positions3, impl=impl)

    maybe_remat = remat_wrapper(remat, remat_policy)

    for si, st in enumerate(stages):
        sp = params[f"stage{si}"]
        kinds = [_layer_kind(cfg, lid) for lid in st.layer_ids]
        moes = [_layer_is_moe(cfg, lid) for lid in st.layer_ids]
        if st.which == "scan":
            @maybe_remat
            def body_fn(x, pslice, kinds=kinds, moes=moes):
                aux = jnp.zeros((), jnp.float32)
                for j in range(len(kinds)):
                    x, a = one_layer(pslice[f"b{j}"], x, kinds[j], moes[j])
                    aux = aux + a
                return x, aux

            x, auxs = jax.lax.scan(lambda c, p: body_fn(c, p), x, sp)
            aux_total = aux_total + auxs.sum()
        else:
            for j in range(len(kinds)):
                fn = maybe_remat(partial(one_layer, kind=kinds[j],
                                         is_moe=moes[j]))
                x, a = fn(sp[f"b{j}"], x)
                aux_total = aux_total + a
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux_total


def logits_from_hidden(params, cfg: ModelConfig, hidden):
    lg = hidden @ params["lm_head"]["w"]
    return softcap(lg.astype(jnp.float32), cfg.final_logit_softcap)


def lm_loss(params, cfg: ModelConfig, hidden, labels):
    """Memory-bounded cross-entropy: logits materialized per seq chunk."""
    b, s, d = hidden.shape
    chunk = LOSS_CHUNK if s % LOSS_CHUNK == 0 else s
    nc = s // chunk
    hc = hidden.reshape(b, nc, chunk, d)
    lc = labels.reshape(b, nc, chunk)

    @jax.checkpoint
    def chunk_loss(h, l):
        lg = logits_from_hidden(params, cfg, h)            # (B,c,V) f32
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, l[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    def body(acc, inp):
        h, l = inp
        return acc + chunk_loss(h, l), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    return total / (b * s)


# --------------------------------------------------------------- decode ----
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    stages = compute_stages(cfg)
    cache = {}
    for si, st in enumerate(stages):
        blocks = {}
        for j, lid in enumerate(st.layer_ids):
            c = init_layer_cache(cfg, lid, batch, max_len, dtype)
            if st.which == "scan":
                c = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (st.repeats,) + a.shape), c)
            blocks[f"b{j}"] = c
        cache[f"stage{si}"] = blocks
    return cache


def decode_step(params, cfg: ModelConfig, token, cache, index, *,
                positions3=None, return_hidden: bool = False):
    """One decode step.  token: (B,1) int32; index: scalar int32 = current
    position.  Returns (logits (B,1,V), new_cache); with return_hidden the
    first element is the final hidden state (B,1,D) instead (used by the
    personalized-head serving path)."""
    x = embed_tokens(params, cfg, token)
    stages = compute_stages(cfg)
    new_cache = {}
    for si, st in enumerate(stages):
        sp = params[f"stage{si}"]
        sc = cache[f"stage{si}"]
        kinds = [_layer_kind(cfg, lid) for lid in st.layer_ids]
        moes = [_layer_is_moe(cfg, lid) for lid in st.layer_ids]
        if st.which == "scan":
            def body(x, slices, kinds=kinds, moes=moes):
                pslice, cslice = slices
                ncs = {}
                for j in range(len(kinds)):
                    x, nc = decode_layer(pslice[f"b{j}"], cfg, kinds[j],
                                         moes[j], x, cslice[f"b{j}"], index,
                                         positions3=positions3)
                    ncs[f"b{j}"] = nc
                return x, ncs

            x, ncs = jax.lax.scan(body, x, (sp, sc))
            new_cache[f"stage{si}"] = ncs
        else:
            ncs = {}
            for j in range(len(kinds)):
                x, nc = decode_layer(sp[f"b{j}"], cfg, kinds[j], moes[j], x,
                                     sc[f"b{j}"], index,
                                     positions3=positions3)
                ncs[f"b{j}"] = nc
            new_cache[f"stage{si}"] = ncs
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if return_hidden:
        return x, new_cache
    return logits_from_hidden(params, cfg, x), new_cache


def prefill(params, cfg: ModelConfig, batch, *, max_len: int | None = None,
            impl: str = "auto"):
    """Full-sequence forward + populated decode cache.

    Implemented as apply() for hidden states plus per-layer cache fill for
    attention layers (recurrent layers re-scan their state).  Used by the
    serving example at small scale; the dry-run prefill shape lowers apply().
    """
    hidden, _ = apply(params, cfg, batch, impl=impl)
    return logits_from_hidden(params, cfg, hidden[:, -1:, :]), hidden
