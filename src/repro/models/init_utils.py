"""Parameter-creation helpers.

Convention used across the model zoo: every module provides

    init(key, ...)   -> params            (tree of arrays)
    axes(...)        -> axes tree         (same structure; leaves = tuples of
                                           logical axis names)

keeping the two separate lets us ``jax.vmap`` inits over a leading 'stack'
dim for scanned layer groups without tracing string metadata.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense(key, d_in: int, d_out, *, bias: bool = False, dtype=jnp.float32,
          scale: float | None = None):
    """Linear layer params; d_out may be a tuple for multi-dim outputs."""
    out_dims = d_out if isinstance(d_out, tuple) else (d_out,)
    shape = (d_in, *out_dims)
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    params = {"w": truncated_normal(key, shape, scale, dtype)}
    if bias:
        params["b"] = jnp.zeros(out_dims, dtype)
    return params


def dense_axes(axes: tuple, *, bias: bool = False):
    out = {"w": axes}
    if bias:
        out["b"] = axes[1:]
    return out


def norm(d: int, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def norm_axes(kind: str):
    if kind == "rmsnorm":
        return {"scale": ("embed",)}
    return {"scale": ("embed",), "bias": ("embed",)}


def embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": truncated_normal(key, (vocab, d), 1.0, dtype)}


def embedding_axes():
    return {"table": ("vocab", "embed")}


def stack_axes(axes_tree):
    """Prefix every axes leaf with the scanned 'stack' dim."""
    return jax.tree.map(
        lambda a: ("stack", *a), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))
