"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Train/prefill use the *expanded* form; decode uses the *absorbed* form that
attends directly in the kv_lora latent space — the whole point of MLA is the
(S, kv_lora + qk_rope) decode cache instead of (S, H, 2*head_dim).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import NEG_INF
from repro.models.init_utils import dense, dense_axes, norm, norm_axes
from repro.models.layers import apply_norm, apply_rope


def mla_init(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    m = cfg.mla
    h = cfg.num_heads
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        # query path: d -> q_lora -> H*(nope+rope)
        "q_a": dense(k1, cfg.d_model, m.q_lora_rank, dtype=dtype),
        "q_a_norm": norm(m.q_lora_rank, "rmsnorm", dtype),
        "q_b": dense(k2, m.q_lora_rank,
                     (h, m.qk_nope_head_dim + m.qk_rope_head_dim), dtype=dtype),
        # kv path: d -> (kv_lora + rope)
        "kv_a": dense(k3, cfg.d_model, m.kv_lora_rank + m.qk_rope_head_dim,
                      dtype=dtype),
        "kv_a_norm": norm(m.kv_lora_rank, "rmsnorm", dtype),
        "kv_b": dense(k4, m.kv_lora_rank,
                      (h, m.qk_nope_head_dim + m.v_head_dim), dtype=dtype),
        "o": dense(k5, h * m.v_head_dim, cfg.d_model, dtype=dtype,
                   scale=1.0 / math.sqrt(h * m.v_head_dim)),
    }


def mla_axes(cfg: ModelConfig):
    return {
        "q_a": dense_axes(("embed", None)),
        "q_a_norm": norm_axes("rmsnorm"),
        "q_b": dense_axes((None, "heads", "head_dim")),
        "kv_a": dense_axes(("embed", None)),
        "kv_a_norm": norm_axes("rmsnorm"),
        "kv_b": dense_axes((None, "heads", "head_dim")),
        "o": dense_axes(("heads", "embed")),
    }


def _project_q(p, cfg: ModelConfig, x, positions):
    m = cfg.mla
    qa = apply_norm(p["q_a_norm"], x @ p["q_a"]["w"], "rmsnorm")
    q = jnp.einsum("bsr,rhk->bshk", qa, p["q_b"]["w"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent_kv(p, cfg: ModelConfig, x, positions):
    m = cfg.mla
    kv = x @ p["kv_a"]["w"]                               # (B,S,kv_lora+rope)
    c_kv = apply_norm(p["kv_a_norm"], kv[..., :m.kv_lora_rank], "rmsnorm")
    k_rope = kv[..., None, m.kv_lora_rank:]               # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope                                   # (B,S,R), (B,S,rope)


def mla_apply(p, cfg: ModelConfig, x, *, positions=None, causal: bool = True,
              impl: str = "auto"):
    """Expanded-form full-sequence MLA (train / prefill).

    Routed through the shared self_attention machinery (dense for short
    sequences, chunked online-softmax for 32k prefill) by concatenating the
    rope and nope query/key components into one (nope+rope)-dim head.
    """
    m = cfg.mla
    b, s, _ = x.shape
    pos = positions if positions is not None \
        else jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q_nope, q_rope = _project_q(p, cfg, x, pos)
    c_kv, k_rope = _latent_kv(p, cfg, x, pos)
    kvb = jnp.einsum("bsr,rhk->bshk", c_kv, p["kv_b"]["w"])
    k_nope = kvb[..., :m.qk_nope_head_dim]                # (B,S,H,nope)
    v = kvb[..., m.qk_nope_head_dim:]                     # (B,S,H,v)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)        # (B,S,H,nope+rope)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:3] + (m.qk_rope_head_dim,))],
        axis=-1)
    from repro.models.attention import self_attention
    out = self_attention(q, k, v, causal=causal, impl=impl)
    out = out.reshape(b, s, cfg.num_heads * m.v_head_dim)
    return out @ p["o"]["w"]


# --------------------------------------------------------------- decode ----
def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def mla_decode_attend(p, cfg: ModelConfig, x, cache, index):
    """Absorbed-form one-token decode.

    q_nope is pushed through W_uk so attention happens in latent space:
      logit_s = (q_nope W_uk) . c_kv[s] + q_rope . k_rope[s]
      out     = (sum_s p_s c_kv[s]) W_uv
    Cache is (S, kv_lora + rope) — 576 floats/token instead of 2*H*hd.
    """
    m = cfg.mla
    b = x.shape[0]
    pos = jnp.full((b, 1), index, jnp.int32)
    q_nope, q_rope = _project_q(p, cfg, x, pos)           # (B,1,H,*)
    c_new, kr_new = _latent_kv(p, cfg, x, pos)            # (B,1,R), (B,1,rope)

    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), index, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), index, axis=1)

    w_uk = p["kv_b"]["w"][..., :m.qk_nope_head_dim]       # (R,H,nope)
    w_uv = p["kv_b"]["w"][..., m.qk_nope_head_dim:]       # (R,H,v)

    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)    # (B,1,H,R)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = (jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32),
                         ck.astype(jnp.float32))
              + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                           kr.astype(jnp.float32))) * scale
    valid = jnp.arange(ck.shape[1]) <= index
    logits = logits + jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", probs, ck.astype(jnp.float32))
    out = jnp.einsum("bqhr,rhv->bqhv", out_lat, w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.num_heads * m.v_head_dim).astype(x.dtype)
    return out @ p["o"]["w"], {"c_kv": ck, "k_rope": kr}
