"""Mixture-of-Experts FFN with token-choice top-k routing.

Implementation: sort-by-expert + ``jax.lax.ragged_dot`` grouped matmuls, so
compiled FLOPs equal the *active* expert FLOPs (top_k/E of dense), the way a
production MoE runtime (megablox-style) behaves — not the einsum-dispatch
formulation whose dispatch tensors explode at 32k tokens.

Covers both assigned MoE architectures:
  - olmoe-1b-7b: 64 experts, top-8, no shared experts.
  - deepseek-v2-236b: 160 routed top-6 + 2 shared experts + first dense layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.init_utils import dense, dense_axes, truncated_normal
from repro.models.layers import activation


def moe_init(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    moe = cfg.moe
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, d, f = moe.num_experts, cfg.d_model, moe.d_ff_expert
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": dense(kr, d, e, dtype=jnp.float32),  # router in f32 (standard)
        "w_gate": truncated_normal(kg, (e, d, f), scale, dtype),
        "w_up": truncated_normal(ku, (e, d, f), scale, dtype),
        "w_down": truncated_normal(kd, (e, f, d), 1.0 / jnp.sqrt(f), dtype),
    }
    if moe.num_shared_experts:
        fs = moe.d_ff_shared * moe.num_shared_experts
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {
            "gate": dense(k1, d, fs, dtype=dtype),
            "up": dense(k2, d, fs, dtype=dtype),
            "down": dense(k3, fs, d, dtype=dtype),
        }
    return p


def moe_axes(cfg: ModelConfig):
    a = {
        "router": dense_axes(("embed", None)),
        "w_gate": ("expert", "embed", "mlp"),
        "w_up": ("expert", "embed", "mlp"),
        "w_down": ("expert", "mlp", "embed"),
    }
    if cfg.moe.num_shared_experts:
        a["shared"] = {
            "gate": dense_axes(("embed", "mlp")),
            "up": dense_axes(("embed", "mlp")),
            "down": dense_axes(("mlp", "embed")),
        }
    return a


def moe_apply(p, cfg: ModelConfig, x, *, act_name: str | None = None):
    """x: (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    moe = cfg.moe
    act = activation(act_name or cfg.act)
    b, s, d = x.shape
    n = b * s
    flat = x.reshape(n, d)

    logits = flat.astype(jnp.float32) @ p["router"]["w"]          # (N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, moe.top_k)                # (N,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch-style) ----
    # fraction of tokens routed to each expert x mean router prob
    one_hot = jax.nn.one_hot(top_e, moe.num_experts, dtype=jnp.float32)
    tokens_per_expert = one_hot.sum(axis=(0, 1)) / (n * moe.top_k)
    prob_per_expert = probs.mean(axis=0)
    aux = moe.num_experts * jnp.sum(tokens_per_expert * prob_per_expert)

    # ---- sort token-expert pairs by expert ----
    flat_e = top_e.reshape(-1)                                    # (N*K,)
    flat_t = jnp.repeat(jnp.arange(n), moe.top_k)                 # (N*K,)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    xs = flat[st]                                                 # (N*K, D)
    group_sizes = jnp.bincount(se, length=moe.num_experts).astype(jnp.int32)

    # ---- grouped matmuls ----
    h = act(jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)) * \
        jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    y = jax.lax.ragged_dot(h, p["w_down"], group_sizes)           # (N*K, D)

    out = jnp.zeros((n, d), y.dtype).at[st].add(y * sw[:, None].astype(y.dtype))

    if moe.num_shared_experts:
        sh = p["shared"]
        hs = act(flat @ sh["gate"]["w"]) * (flat @ sh["up"]["w"])
        out = out + hs @ sh["down"]["w"]

    return out.reshape(b, s, d).astype(x.dtype), aux.astype(jnp.float32)
