"""Shared layer math: norms, activations, RoPE (incl. M-RoPE), gated MLPs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------- norms ----
def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        # plain scale (not gemma's "1+scale" convention; training dynamics we
        # study are insensitive to it)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
        return y.astype(x.dtype)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        return y.astype(x.dtype)
    raise ValueError(kind)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(name)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ----------------------------------------------------------------- RoPE ----
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., seq, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: tuple[int, int, int]):
    """Qwen2-VL multimodal RoPE [arXiv:2409.12191].

    positions3: (..., seq, 3) int — (temporal, height, width) position ids.
    The rotary spectrum (head_dim/2 frequencies) is split into ``sections``
    (t/h/w); each section rotates by its own position stream.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                  # (half,)
    # section id per frequency: each of the half rotary frequencies is driven
    # by one of the three (t, h, w) position streams
    sec_id = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    idx = jnp.broadcast_to(sec_id[None, :], positions3.shape[:-1] + (half,))
    pos = jnp.take_along_axis(positions3.astype(jnp.float32), idx, axis=-1)
    ang = pos * freqs                                       # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ gated MLP ----
def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None, dtype=None):
    from repro.models.init_utils import dense
    d_ff = d_ff or cfg.d_ff
    dtype = dtype or jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense(k1, cfg.d_model, d_ff, dtype=dtype),
        "up": dense(k2, cfg.d_model, d_ff, dtype=dtype),
        "down": dense(k3, d_ff, cfg.d_model, dtype=dtype),
    }


def mlp_axes():
    from repro.models.init_utils import dense_axes
    return {
        "gate": dense_axes(("embed", "mlp")),
        "up": dense_axes(("embed", "mlp")),
        "down": dense_axes(("mlp", "embed")),
    }


def mlp_apply(p, x, act_name: str):
    act = activation(act_name)
    h = act(x @ p["gate"]["w"]) * (x @ p["up"]["w"])
    return h @ p["down"]["w"]
