"""Encoder-decoder backbone (seamless-m4t-medium, arXiv:2308.11596).

The audio frontend (mel-spectrogram + conv feature extractor) is the allowed
stub: inputs carry precomputed ``source_embeds`` (B, S_src, d_model).  This
module implements the transformer encoder + autoregressive text/unit decoder
that consumes them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.init_utils import (dense, dense_axes, embedding,
                                     embedding_axes, norm, norm_axes,
                                     stack_axes)
from repro.models.layers import apply_norm, mlp_apply, mlp_axes, mlp_init
from repro.models.transformer import LOSS_CHUNK, logits_from_hidden  # reuse head


# ------------------------------------------------------------- layers ------
def _enc_layer_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": norm(cfg.d_model, cfg.norm, dtype),
            "attn": attn_mod.attn_init(k1, cfg, dtype),
            "ln2": norm(cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_init(k2, cfg, dtype=dtype)}


def _enc_layer_axes(cfg: ModelConfig):
    return {"ln1": norm_axes(cfg.norm), "attn": attn_mod.attn_axes(cfg),
            "ln2": norm_axes(cfg.norm), "mlp": mlp_axes()}


def _dec_layer_init(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": norm(cfg.d_model, cfg.norm, dtype),
            "self": attn_mod.attn_init(k1, cfg, dtype),
            "lnx": norm(cfg.d_model, cfg.norm, dtype),
            "cross": attn_mod.attn_init(k2, cfg, dtype),
            "ln2": norm(cfg.d_model, cfg.norm, dtype),
            "mlp": mlp_init(k3, cfg, dtype=dtype)}


def _dec_layer_axes(cfg: ModelConfig):
    return {"ln1": norm_axes(cfg.norm), "self": attn_mod.attn_axes(cfg),
            "lnx": norm_axes(cfg.norm), "cross": attn_mod.attn_axes(cfg),
            "ln2": norm_axes(cfg.norm), "mlp": mlp_axes()}


# ------------------------------------------------------------- init --------
def init(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    ne = cfg.encdec.num_encoder_layers
    nd = cfg.num_layers
    keys = jax.random.split(key, 5)
    enc_keys = jax.random.split(keys[0], ne)
    dec_keys = jax.random.split(keys[1], nd)
    return {
        "src_proj": dense(keys[2], cfg.d_model, cfg.d_model, dtype=dtype),
        "encoder": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "enc_norm": norm(cfg.d_model, cfg.norm, dtype),
        "embed": embedding(keys[3], cfg.padded_vocab, cfg.d_model, dtype),
        "decoder": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
        "final_norm": norm(cfg.d_model, cfg.norm, dtype),
        "lm_head": dense(keys[4], cfg.d_model, cfg.padded_vocab, dtype=dtype),
    }


def axes(cfg: ModelConfig):
    return {
        "src_proj": dense_axes(("embed", "embed")),
        "encoder": stack_axes(_enc_layer_axes(cfg)),
        "enc_norm": norm_axes(cfg.norm),
        "embed": embedding_axes(),
        "decoder": stack_axes(_dec_layer_axes(cfg)),
        "final_norm": norm_axes(cfg.norm),
        "lm_head": dense_axes(("embed", "vocab")),
    }


# ------------------------------------------------------------- apply -------
def encode(params, cfg: ModelConfig, source_embeds, *, impl: str = "auto",
           remat: bool = False, remat_policy: str | None = None):
    from repro.models.transformer import remat_wrapper
    x = source_embeds @ params["src_proj"]["w"]
    maybe_remat = remat_wrapper(remat, remat_policy)

    @maybe_remat
    def layer(x, p):
        h = apply_norm(p["ln1"], x, cfg.norm)
        x = x + attn_mod.attn_apply(p["attn"], cfg, h, causal=False,
                                    rope_theta=cfg.rope_theta, impl=impl)
        h = apply_norm(p["ln2"], x, cfg.norm)
        return x + mlp_apply(p["mlp"], h, cfg.act), None

    x, _ = jax.lax.scan(layer, x, params["encoder"])
    return apply_norm(params["enc_norm"], x, cfg.norm)


def apply(params, cfg: ModelConfig, batch, *, impl: str = "auto",
          remat: bool = False, remat_policy: str | None = None):
    """Teacher-forced full forward.  batch: {"source_embeds", "tokens"}.

    Returns (decoder hidden states, aux=0).
    """
    memory = encode(params, cfg, batch["source_embeds"], impl=impl,
                    remat=remat, remat_policy=remat_policy)
    from repro.models.transformer import remat_wrapper
    x = params["embed"]["table"][batch["tokens"]]
    maybe_remat = remat_wrapper(remat, remat_policy)

    @maybe_remat
    def layer(x, p):
        h = apply_norm(p["ln1"], x, cfg.norm)
        x = x + attn_mod.attn_apply(p["self"], cfg, h, causal=True,
                                    rope_theta=cfg.rope_theta, impl=impl)
        h = apply_norm(p["lnx"], x, cfg.norm)
        kv = attn_mod.cross_kv(p["cross"], cfg, memory)
        x = x + attn_mod.attn_apply(p["cross"], cfg, h, causal=False,
                                    rope_theta=0.0, kv_override=kv, impl=impl)
        h = apply_norm(p["ln2"], x, cfg.norm)
        return x + mlp_apply(p["mlp"], h, cfg.act), None

    x, _ = jax.lax.scan(layer, x, params["decoder"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, jnp.zeros((), jnp.float32)


# ------------------------------------------------------------- decode ------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Self-attention KV cache (stacked over decoder layers) + cross KV."""
    nd = cfg.num_layers
    self_c = attn_mod.init_kv_cache(cfg, batch, max_len, dtype=dtype)
    self_c = jax.tree.map(lambda a: jnp.broadcast_to(a, (nd,) + a.shape), self_c)
    src = cfg.encdec.max_source_len
    cross = {
        "k": jnp.zeros((nd, batch, src, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((nd, batch, src, cfg.num_kv_heads, cfg.head_dim), dtype),
    }
    return {"self": self_c, "cross": cross}


def precompute_cross(params, cfg: ModelConfig, memory, dtype=jnp.bfloat16):
    """Fill the cross-attention cache from encoder memory."""
    def per_layer(p):
        k, v = attn_mod.cross_kv(p, cfg, memory)
        return k.astype(dtype), v.astype(dtype)

    ks, vs = jax.vmap(per_layer)(params["decoder"]["cross"])
    return {"k": ks, "v": vs}


def decode_step(params, cfg: ModelConfig, token, cache, index, *,
                positions3=None, return_hidden: bool = False):
    """One decoder step with self KV cache + precomputed cross KV."""
    x = params["embed"]["table"][token]

    def body(x, slices):
        p, sc, ck, cv = slices
        h = apply_norm(p["ln1"], x, cfg.norm)
        y, nc = attn_mod.decode_attend(p["self"], cfg, h, sc, index, window=0,
                                       rope_theta=cfg.rope_theta)
        x = x + y
        h = apply_norm(p["lnx"], x, cfg.norm)
        # cross attention over the fixed encoder memory
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["q"]["w"])
        if cfg.attn_bias:
            q = q + p["cross"]["q"]["b"]
        out = attn_mod.dense_attention(q, ck, cv, causal=False, window=0,
                                       softcap=0.0)
        b = out.shape[0]
        o = out.reshape(b, 1, cfg.num_heads * cfg.head_dim) @ p["cross"]["o"]["w"]
        x = x + o
        h = apply_norm(p["ln2"], x, cfg.norm)
        return x + mlp_apply(p["mlp"], h, cfg.act), nc

    x, new_self = jax.lax.scan(
        body, x, (params["decoder"], cache["self"], cache["cross"]["k"],
                  cache["cross"]["v"]))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    new_cache = {"self": new_self, "cross": cache["cross"]}
    if return_hidden:
        return x, new_cache
    return logits_from_hidden(params, cfg, x), new_cache
