"""GQA attention with dense, chunked (flash-style online-softmax) and banded
(sliding-window) pure-JAX paths, plus KV-cache prefill/decode.

Path selection (``impl="auto"``):
  - decode (q_len == 1): dense dot over the cache (memory-bound anyway).
  - short sequences: dense masked softmax.
  - long sequences, full attention: chunked online softmax (O(chunk) memory).
  - long sequences, sliding window: banded — each query chunk only touches
    its (chunk + window) key band, so FLOPs are O(S*w) not O(S^2).

The Pallas flash kernel (repro.kernels.flash_attention) implements the same
contract with proper block skipping on TPU; ``impl="flash"`` routes there.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.init_utils import dense, dense_axes, norm, norm_axes

DENSE_MAX_SEQ = 4096          # longest seq for the dense path under "auto"
Q_CHUNK = 1024
KV_CHUNK = 1024

NEG_INF = -2.0 ** 30          # large-negative instead of -inf (NaN-safe masks)


# ------------------------------------------------------------- params ------
def attn_init(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "q": dense(kq, cfg.d_model, (cfg.num_heads, cfg.head_dim),
                   bias=cfg.attn_bias, dtype=dtype),
        "k": dense(kk, cfg.d_model, (cfg.num_kv_heads, cfg.head_dim),
                   bias=cfg.attn_bias, dtype=dtype),
        "v": dense(kv, cfg.d_model, (cfg.num_kv_heads, cfg.head_dim),
                   bias=cfg.attn_bias, dtype=dtype),
        "o": dense(ko, cfg.num_heads * cfg.head_dim, cfg.d_model, dtype=dtype,
                   scale=1.0 / math.sqrt(cfg.num_heads * cfg.head_dim)),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm(cfg.head_dim, "rmsnorm", dtype)
        p["k_norm"] = norm(cfg.head_dim, "rmsnorm", dtype)
    return p


def attn_axes(cfg: ModelConfig):
    a = {
        "q": dense_axes(("embed", "heads", "head_dim"), bias=cfg.attn_bias),
        "k": dense_axes(("embed", "kv_heads", "head_dim"), bias=cfg.attn_bias),
        "v": dense_axes(("embed", "kv_heads", "head_dim"), bias=cfg.attn_bias),
        "o": dense_axes(("heads", "embed")),
    }
    if cfg.qk_norm:
        a["q_norm"] = norm_axes("rmsnorm")
        a["k_norm"] = norm_axes("rmsnorm")
    return a


def _project_qkv(p, cfg: ModelConfig, x):
    """x: (B,S,D) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["q"]["w"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["k"]["w"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["v"]["w"])
    if cfg.attn_bias:
        q = q + p["q"]["b"]
        k = k + p["k"]["b"]
        v = v + p["v"]["b"]
    if cfg.qk_norm:
        from repro.models.layers import apply_norm
        q = apply_norm(p["q_norm"], q, "rmsnorm")
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    return q, k, v


def _out_proj(p, cfg: ModelConfig, o):
    """o: (B,S,H,hd) -> (B,S,D)."""
    b, s = o.shape[:2]
    return o.reshape(b, s, cfg.num_heads * cfg.head_dim) @ p["o"]["w"]


# ---------------------------------------------------------- core maths -----
def _expand_gqa(q, num_kv: int):
    """(B,S,H,hd) -> (B,S,KV,G,hd)."""
    b, s, h, d = q.shape
    g = h // num_kv
    return q.reshape(b, s, num_kv, g, d)


def _mask_bias(qpos, kpos, *, causal: bool, window: int, kv_valid=None):
    """Additive mask bias (..., q, k) from absolute positions."""
    qp = qpos[..., :, None]
    kp = kpos[..., None, :]
    keep = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        keep &= kp <= qp
    if window:
        keep &= kp > qp - window
    if kv_valid is not None:
        keep &= kv_valid[..., None, :]
    return jnp.where(keep, 0.0, NEG_INF)


def dense_attention(q, k, v, *, causal: bool, window: int, softcap: float,
                    q_offset=0, kv_valid=None):
    """Reference masked-softmax attention.

    q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd).  q_offset: absolute position of q[0]
    (int or (B,) array).  kv_valid: optional (B,Sk) bool.
    """
    b, sq, h, d = q.shape
    sk, kv_heads = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    qg = _expand_gqa(q, kv_heads)                        # (B,Sq,KV,G,hd)
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqngd,bknd->bngqk",
                        qg.astype(jnp.float32) * scale, k.astype(jnp.float32))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = (jnp.arange(sq)[None, :] + jnp.asarray(q_offset).reshape(-1, 1))
    kpos = jnp.broadcast_to(jnp.arange(sk)[None, :], (b, sk))
    bias = _mask_bias(qpos, kpos, causal=causal, window=window,
                      kv_valid=kv_valid)                 # (B,q,k)
    logits = logits + bias[:, None, None, :, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngqk,bknd->bqngd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, window: int, softcap: float,
                      q_chunk: int = Q_CHUNK, kv_chunk: int = KV_CHUNK):
    """Flash-style online-softmax attention, O(chunk^2) live memory.

    Full-rectangle compute with masking (no block skipping — the Pallas
    kernel does skipping on TPU; see DESIGN.md §Perf).
    """
    b, sq, h, d = q.shape
    sk, kv_heads = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    assert sq % q_chunk == 0 and sk % kv_chunk == 0, (sq, sk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = 1.0 / math.sqrt(d)
    qg = _expand_gqa(q, kv_heads).reshape(b, nq, q_chunk, kv_heads, h // kv_heads, d)
    kc = k.reshape(b, nk, kv_chunk, kv_heads, d)
    vc = v.reshape(b, nk, kv_chunk, kv_heads, dv)

    def per_q_chunk(qi, q_blk):
        # q_blk: (b, q_chunk, KV, G, d)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def body(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            logits = jnp.einsum("bqngd,bknd->bngqk",
                                q_blk.astype(jnp.float32) * scale,
                                k_blk.astype(jnp.float32))
            if softcap:
                logits = jnp.tanh(logits / softcap) * softcap
            keep = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                keep &= kpos[None, :] <= qpos[:, None]
            if window:
                keep &= kpos[None, :] > qpos[:, None] - window
            logits = logits + jnp.where(keep, 0.0, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bngqk,bknd->bngqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        g = h // kv_heads
        m0 = jnp.full((b, kv_heads, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv_heads, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kv_heads, g, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        # (b, KV, G, q_chunk, dv) -> (b, q_chunk, h, dv)
        return jnp.moveaxis(out, 3, 1).reshape(b, q_chunk, h, dv)

    out = jax.lax.map(lambda args: per_q_chunk(*args),
                      (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    # out: (nq, b, q_chunk, h, dv)
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, dv).astype(q.dtype)


def banded_attention(q, k, v, *, window: int, softcap: float,
                     q_chunk: int = Q_CHUNK):
    """Sliding-window attention with true O(S*(w+c)) FLOPs.

    Each query chunk attends only to its key band [start - w, start + c).
    """
    b, sq, h, d = q.shape
    sk, kv_heads = k.shape[1], k.shape[2]
    assert sq == sk, "banded path assumes self-attention"
    assert sq % q_chunk == 0
    nq = sq // q_chunk
    band = q_chunk + window
    scale = 1.0 / math.sqrt(d)
    qg = _expand_gqa(q, kv_heads).reshape(b, nq, q_chunk, kv_heads, h // kv_heads, d)

    def per_q_chunk(qi, q_blk):
        start = qi * q_chunk - window
        start_c = jnp.clip(start, 0, sk - band)
        k_band = jax.lax.dynamic_slice_in_dim(k, start_c, band, axis=1)
        v_band = jax.lax.dynamic_slice_in_dim(v, start_c, band, axis=1)
        qpos = qi * q_chunk + jnp.arange(q_chunk)
        kpos = start_c + jnp.arange(band)
        logits = jnp.einsum("bqngd,bknd->bngqk",
                            q_blk.astype(jnp.float32) * scale,
                            k_band.astype(jnp.float32))
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        keep = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - window)
        logits = logits + jnp.where(keep, 0.0, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bngqk,bknd->bngqd", probs, v_band.astype(jnp.float32))
        return jnp.moveaxis(out, 3, 1).reshape(b, q_chunk, h, d)

    out = jax.lax.map(lambda args: per_q_chunk(*args),
                      (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, d).astype(q.dtype)


def self_attention(q, k, v, *, causal: bool = True, window: int = 0,
                   softcap: float = 0.0, impl: str = "auto"):
    """Full-sequence self-attention with automatic path choice."""
    sq = q.shape[1]
    if impl == "flash":
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap)
    if impl == "dense" or (impl == "auto" and sq <= DENSE_MAX_SEQ):
        return dense_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap)
    if window and sq % Q_CHUNK == 0:
        return banded_attention(q, k, v, window=window, softcap=softcap)
    if sq % Q_CHUNK == 0:
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 softcap=softcap)
    return dense_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap)


def attn_apply(p, cfg: ModelConfig, x, *, window: int = 0,
               rope_theta: float = 10000.0, softcap: float = 0.0,
               positions=None, positions3=None, causal: bool = True,
               kv_override=None, impl: str = "auto"):
    """Full-sequence attention sublayer: proj -> rope -> attn -> out proj.

    kv_override: (k, v) from another sequence (cross-attention).
    """
    from repro.models.layers import apply_mrope, apply_rope

    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    if kv_override is not None:
        k, v = kv_override
    if positions3 is not None:
        q = apply_mrope(q, positions3, rope_theta, cfg.vlm.mrope_sections)
        if kv_override is None:
            k = apply_mrope(k, positions3, rope_theta, cfg.vlm.mrope_sections)
    elif rope_theta:
        pos = positions if positions is not None \
            else jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        q = apply_rope(q, pos, rope_theta)
        if kv_override is None:
            k = apply_rope(k, pos, rope_theta)
    out = self_attention(q, k, v, causal=causal, window=window,
                         softcap=softcap, impl=impl)
    return _out_proj(p, cfg, out)


def cross_kv(p, cfg: ModelConfig, memory):
    """Precompute cross-attention K/V from encoder memory (B,S_src,D)."""
    k = jnp.einsum("bsd,dhk->bshk", memory, p["k"]["w"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["v"]["w"])
    if cfg.attn_bias:
        k = k + p["k"]["b"]
        v = v + p["v"]["b"]
    return k, v


# ------------------------------------------------------------ KV cache -----
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                  window: int = 0, dtype=jnp.bfloat16):
    """Cache for one attention layer.  Sliding-window layers keep only a
    rolling ``window``-sized buffer (this is what makes long_500k decode
    memory bounded for gemma3/recurrentgemma local layers)."""
    length = min(window, max_len) if window else max_len
    shape = (batch, length, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def cache_axes():
    return {"k": (None, "length", "kv_heads", "head_dim"),
            "v": (None, "length", "kv_heads", "head_dim")}


def decode_attend(p, cfg: ModelConfig, x, cache, index, *, window: int,
                  rope_theta: float, softcap: float = 0.0, positions3=None):
    """One-token decode: append to cache, attend over valid prefix.

    x: (B,1,D); index: scalar int32 — number of tokens already in the cache.
    Returns (out (B,1,D), new_cache).
    """
    from repro.models.layers import apply_mrope, apply_rope

    b = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)
    pos = jnp.full((b, 1), index, jnp.int32)
    if positions3 is not None:
        q = apply_mrope(q, positions3, rope_theta, cfg.vlm.mrope_sections)
        k = apply_mrope(k, positions3, rope_theta, cfg.vlm.mrope_sections)
    elif rope_theta:
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)

    length = cache["k"].shape[1]
    slot = jnp.mod(index, length) if window else jnp.minimum(index, length - 1)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype),
                                             slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype),
                                             slot, axis=1)

    # absolute positions of cache slots
    slots = jnp.arange(length)
    if window:
        # ring buffer: slot s holds position index - ((slot - s) mod length)
        offset = jnp.mod(slot - slots, length)
        kpos = index - offset
        valid = (kpos >= 0) & (kpos >= index - window + 1) | (slots == slot)
        kpos = jnp.broadcast_to(kpos[None], (b, length))
        kv_valid = jnp.broadcast_to(valid[None], (b, length))
    else:
        kpos = jnp.broadcast_to(slots[None], (b, length))
        kv_valid = jnp.broadcast_to((slots <= index)[None], (b, length))

    qg = _expand_gqa(q, cfg.num_kv_heads)                 # (B,1,KV,G,hd)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    logits = jnp.einsum("bqngd,bknd->bngqk", qg.astype(jnp.float32) * scale,
                        ck.astype(jnp.float32))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = logits + jnp.where(kv_valid, 0.0, NEG_INF)[:, None, None, None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngqk,bknd->bqngd", probs, cv.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.num_heads, cfg.head_dim).astype(x.dtype)
    return _out_proj(p, cfg, out), {"k": ck, "v": cv}
