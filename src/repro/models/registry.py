"""Unified Model interface over the zoo (decoder-LM vs encoder-decoder)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable                    # (key) -> params
    axes: Callable                    # () -> axes tree
    apply: Callable                   # (params, batch, **kw) -> (hidden, aux)
    loss: Callable                    # (params, batch, **kw) -> scalar
    init_cache: Callable              # (batch, max_len, dtype) -> cache
    decode_step: Callable             # (params, token, cache, index) -> (logits, cache)
    logits: Callable                  # (params, hidden) -> logits


def build_model(cfg: ModelConfig) -> Model:
    is_encdec = cfg.encdec is not None
    mod: Any = encdec_mod if is_encdec else tf_mod

    def init(key, dtype=None):
        return mod.init(key, cfg, dtype=dtype)

    def axes():
        return mod.axes(cfg)

    def apply(params, batch, *, impl="auto", remat=False,
              remat_policy=None):
        return mod.apply(params, cfg, batch, impl=impl, remat=remat,
                         remat_policy=remat_policy)

    def loss(params, batch, *, impl="auto", remat=False, remat_policy=None):
        hidden, aux = mod.apply(params, cfg, batch, impl=impl, remat=remat,
                                remat_policy=remat_policy)
        ce = tf_mod.lm_loss(params, cfg, hidden, batch["labels"])
        if cfg.moe is not None:
            ce = ce + cfg.moe.router_aux_loss * aux
        return ce

    def init_cache(batch, max_len, dtype=jnp.bfloat16):
        return mod.init_cache(cfg, batch, max_len, dtype=dtype)

    def decode_step(params, token, cache, index, *, positions3=None,
                    return_hidden=False):
        return mod.decode_step(params, cfg, token, cache, index,
                               positions3=positions3,
                               return_hidden=return_hidden)

    def logits(params, hidden):
        return tf_mod.logits_from_hidden(params, cfg, hidden)

    return Model(cfg, init, axes, apply, loss, init_cache, decode_step, logits)
