"""RecurrentGemma / Griffin RG-LRU recurrent block (arXiv:2402.19427).

Training/prefill uses ``jax.lax.associative_scan`` over the diagonal linear
recurrence h_t = a_t * h_{t-1} + b_t (log-space-stable gates); decode is the
O(1) step.  The Pallas ``rglru_scan`` kernel implements the same recurrence
with blocked VMEM tiles.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.init_utils import dense, dense_axes, truncated_normal
from repro.models.xlstm import causal_conv1d

_C = 8.0  # the paper's fixed scalar c in a_t = exp(-c * softplus(Lambda) * r_t)


def rglru_init(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    g = cfg.rglru
    w = g.lru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    # Lambda init so that a^c spans (0.9, 0.999) roughly — standard LRU init
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "in_x": dense(ks[1], cfg.d_model, w, dtype=dtype),
        "in_gate": dense(ks[2], cfg.d_model, w, dtype=dtype),
        "conv": truncated_normal(ks[3], (g.conv_kernel, w),
                                 1.0 / math.sqrt(g.conv_kernel), dtype),
        "w_a": dense(ks[4], w, w, dtype=dtype, scale=1.0 / math.sqrt(w)),
        "w_x": dense(ks[5], w, w, dtype=dtype, scale=1.0 / math.sqrt(w)),
        "b_a": jnp.zeros((w,), jnp.float32),
        "b_x": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "out": dense(jax.random.fold_in(key, 7), w, cfg.d_model, dtype=dtype),
    }


def rglru_axes(cfg: ModelConfig):
    return {
        "in_x": dense_axes(("embed", "lru")),
        "in_gate": dense_axes(("embed", "lru")),
        "conv": ("conv", "lru"),
        "w_a": dense_axes(("lru", "lru")),
        "w_x": dense_axes(("lru", "lru")),
        "b_a": ("lru",),
        "b_x": ("lru",),
        "lam": ("lru",),
        "out": dense_axes(("lru", "embed")),
    }


def _gates(p, u):
    """log_a (B,S,W) and gated input b_t for the recurrence."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"]["w"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(uf @ p["w_x"]["w"].astype(jnp.float32) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # (B,S,W), <= 0
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * (i * uf)
    return log_a, b


def rglru_scan_assoc(log_a, b, h0=None):
    """h_t = exp(log_a_t) * h_{t-1} + b_t via associative scan over S."""
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(x, y):
        la1, b1 = x
        la2, b2 = y
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def rglru_block_apply(p, cfg: ModelConfig, x, *, cache=None, index=None):
    """Full recurrent sublayer: proj -> conv -> RG-LRU -> gated out proj."""
    xb = x @ p["in_x"]["w"]
    gate = jax.nn.gelu(x @ p["in_gate"]["w"])
    conv_state = cache["conv"] if cache is not None else None
    u, conv_state = causal_conv1d(xb, p["conv"], conv_state)
    log_a, b = _gates(p, u)
    if cache is None:
        h = rglru_scan_assoc(log_a, b)
        new_cache = None
    else:
        h_prev = cache["h"]
        h = jnp.exp(log_a[:, 0]) * h_prev + b[:, 0]
        new_cache = {"conv": conv_state, "h": h}
        h = h[:, None]
    out = (h.astype(x.dtype) * gate) @ p["out"]["w"]
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    g = cfg.rglru
    w = g.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, g.conv_kernel - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
