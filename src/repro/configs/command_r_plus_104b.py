"""command-r-plus-104b  [dense]  — GQA, no bias.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01]
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    block_pattern=(ATTN,),
    rope_theta=75_000_000.0,
    attn_bias=False,
    norm="layernorm",
    act="silu",
    tie_embeddings=True,
    n_client_layers=2,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
