"""Architecture registry: ``--arch <id>`` resolution."""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.configs import (
    command_r_plus_104b,
    olmoe_1b_7b,
    mistral_large_123b,
    qwen2_vl_7b,
    xlstm_350m,
    gemma3_27b,
    recurrentgemma_2b,
    gemma3_12b,
    seamless_m4t_medium,
    deepseek_v2_236b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        command_r_plus_104b,
        olmoe_1b_7b,
        mistral_large_123b,
        qwen2_vl_7b,
        xlstm_350m,
        gemma3_27b,
        recurrentgemma_2b,
        gemma3_12b,
        seamless_m4t_medium,
        deepseek_v2_236b,
    )
}

# archs with sub-quadratic / bounded-window sequence mixing that run long_500k
LONG_CONTEXT_OK = frozenset({
    "xlstm-350m",
    "recurrentgemma-2b",
    "gemma3-12b",
    "gemma3-27b",
})


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def supports_shape(arch: str, shape_name: str) -> bool:
    """Whether (arch, shape) is a supported dry-run combination."""
    if shape_name == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True
