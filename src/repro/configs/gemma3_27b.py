"""gemma3-27b  [dense]  — 5 local (sliding-window 1024) : 1 global, 128k ctx.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144 [hf:google/gemma-3-1b-pt]
"""

from repro.configs.base import ATTN, LOCAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    block_pattern=(LOCAL_ATTN, LOCAL_ATTN, LOCAL_ATTN,
                   LOCAL_ATTN, LOCAL_ATTN, ATTN),
    sliding_window=1024,
    rope_theta=1_000_000.0,       # global layers
    local_rope_theta=10_000.0,    # local layers
    qk_norm=True,
    final_logit_softcap=0.0,
    embed_scale=True,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
    n_client_layers=2,
    source="hf:google/gemma-3-1b-pt",
)
