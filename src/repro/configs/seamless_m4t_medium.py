"""seamless-m4t-medium  [audio]  — encoder-decoder, multimodal frontend stubbed.

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206 [arXiv:2308.11596]

The mel-spectrogram + conv feature extractor is the allowed stub:
``input_specs`` supplies precomputed frame embeddings of shape
(batch, source_len, d_model) consumed by the text/unit decoder backbone here.
"""

from repro.configs.base import ATTN, EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,                  # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,              # padded to 256256 internally for TP
    block_pattern=(ATTN,),
    rope_theta=10_000.0,
    encdec=EncDecConfig(num_encoder_layers=12, max_source_len=1024),
    norm="layernorm",
    act="gelu",
    attn_bias=True,
    n_client_layers=2,
    source="arXiv:2308.11596",
)
