from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    MLAConfig,
    EncDecConfig,
    VLMConfig,
    XLSTMConfig,
    RGLRUConfig,
    HierarchyConfig,
    TrainConfig,
    ShapeConfig,
    MeshConfig,
    ATTN,
    LOCAL_ATTN,
    MLA_ATTN,
    RGLRU,
    SLSTM,
    MLSTM,
    RECURRENT_KINDS,
)

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "EncDecConfig", "VLMConfig",
    "XLSTMConfig", "RGLRUConfig", "HierarchyConfig", "TrainConfig",
    "ShapeConfig", "MeshConfig",
    "ATTN", "LOCAL_ATTN", "MLA_ATTN", "RGLRU", "SLSTM", "MLSTM",
    "RECURRENT_KINDS",
]
