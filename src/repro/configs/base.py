"""Config dataclasses for the whole framework.

Everything is a frozen (hashable) dataclass so configs can be closed over by
jitted step functions as static data.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


# --------------------------------------------------------------------------
# Block kinds (per-layer), cycled from ``ModelConfig.block_pattern``
# --------------------------------------------------------------------------
ATTN = "attn"                # global full attention
LOCAL_ATTN = "local_attn"    # sliding-window attention
MLA_ATTN = "mla"             # DeepSeek-V2 multi-head latent attention
RGLRU = "rglru"              # RecurrentGemma RG-LRU recurrent block
SLSTM = "slstm"              # xLSTM sLSTM block
MLSTM = "mlstm"              # xLSTM mLSTM block

BLOCK_KINDS = (ATTN, LOCAL_ATTN, MLA_ATTN, RGLRU, SLSTM, MLSTM)

RECURRENT_KINDS = (RGLRU, SLSTM, MLSTM)  # O(1)-state decode blocks


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    router_aux_loss: float = 0.01
    # layers whose FFN is dense instead of MoE (e.g. deepseek first layer)
    first_dense_layers: int = 0
    d_ff_dense: int = 0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention [arXiv:2405.04434]."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (Seamless-M4T backbone)."""
    num_encoder_layers: int = 12
    # encoder input is a stubbed modality frontend: precomputed frame embeddings
    max_source_len: int = 1024


@dataclass(frozen=True)
class VLMConfig:
    """Vision frontend stub (Qwen2-VL): patch embeddings are precomputed."""
    num_patch_tokens: int = 1024
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t/h/w split of head_dim/2


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block geometry [arXiv:2405.04517]."""
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333333
    conv_kernel: int = 4
    num_heads: int = 4


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block [arXiv:2402.19427]."""
    lru_width: int = 0          # 0 -> d_model
    conv_kernel: int = 4
    block_width_multiplier: float = 3.0  # gated-MLP expansion in recurrent block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    block_pattern: tuple[str, ...] = (ATTN,)
    # attention details
    rope_theta: float = 10000.0
    local_rope_theta: float = 10000.0
    sliding_window: int = 0          # used by LOCAL_ATTN layers
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    attn_bias: bool = False
    qk_norm: bool = False
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # norm / act / embedding
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d_model) embedding scale
    # ---- PHSFL split (the paper's technique) ----
    n_client_layers: int = 2         # blocks in the client-side model w_0
    head_name: str = "lm_head"       # pytree key of the frozen head w_{1,hd}
    # numerics
    dtype: str = "bfloat16"          # compute/param dtype for the big runs
    # citation for the config values
    source: str = ""

    # ----- derived helpers -----
    def layer_kinds(self) -> tuple[str, ...]:
        """Expand block_pattern cyclically over num_layers."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP=16 sharding divides evenly."""
        pad_to = 256
        return ((self.vocab_size + pad_to - 1) // pad_to) * pad_to

    def reduced(self, *, num_layers: int = 2, d_model: int = 256,
                num_heads: int = 4, d_ff: int = 512, vocab_size: int = 512,
                max_experts: int = 4) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        head_dim = max(d_model // num_heads, 16)
        kv = max(1, min(self.num_kv_heads, num_heads))
        changes = dict(
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=d_ff if self.d_ff else 0,
            vocab_size=vocab_size,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            n_client_layers=1,
            dtype="float32",
        )
        if self.moe is not None:
            ne = min(self.moe.num_experts, max_experts)
            changes["moe"] = MoEConfig(
                num_experts=ne,
                top_k=min(self.moe.top_k, ne),
                d_ff_expert=128,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                d_ff_shared=128 if self.moe.num_shared_experts else 0,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                d_ff_dense=128 if self.moe.first_dense_layers else 0,
            )
        if self.mla is not None:
            changes["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32,
                qk_nope_head_dim=head_dim, qk_rope_head_dim=16,
                v_head_dim=head_dim)
        if self.encdec is not None:
            changes["encdec"] = EncDecConfig(num_encoder_layers=num_layers,
                                             max_source_len=32)
        if self.vlm is not None:
            half = head_dim // 2
            quarter = half // 4
            changes["vlm"] = VLMConfig(
                num_patch_tokens=16,
                mrope_sections=(half - 2 * quarter, quarter, quarter))
        if self.xlstm is not None:
            changes["xlstm"] = XLSTMConfig(num_heads=2)
        if self.rglru is not None:
            changes["rglru"] = RGLRUConfig(lru_width=d_model)
        return dataclasses.replace(self, **changes)


# --------------------------------------------------------------------------
# PHSFL hierarchy (Sec. II-B / III-A of the paper)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class HierarchyConfig:
    num_edge_servers: int = 4        # B
    clients_per_es: int = 25         # U_b (uniform here; weights may differ)
    kappa0: int = 5                  # local SGD steps per edge round
    kappa1: int = 3                  # edge rounds per global round
    global_rounds: int = 100         # R
    # aggregation weights: "uniform" or "data" (proportional to |D_u|)
    weighting: str = "data"

    @property
    def num_clients(self) -> int:
        return self.num_edge_servers * self.clients_per_es

    @property
    def steps_per_global_round(self) -> int:
        return self.kappa0 * self.kappa1


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 0.01      # eta (paper: SGD, eta=0.01)
    finetune_lr: float = 0.01        # eta~ for the head fine-tune (Eq. 18)
    finetune_steps: int = 10         # K
    batch_size: int = 32             # N
    optimizer: str = "sgd"           # sgd | momentum | adamw
    momentum: float = 0.0
    weight_decay: float = 0.0
    grad_clip: float = 0.0
    seed: int = 0
    freeze_head: bool = True         # PHSFL; False -> HSFL baseline
    # datacenter mode: microbatches per local round inside the fused step
    local_steps_in_step: int = 2
    remat: bool = True               # activation checkpointing per block
    remat_policy: str = "full"       # full | dots (selective, §Perf knob)
    shared_server: bool = False      # beyond-paper SFL-V2-style body sharing
    agg_dtype: str = "float32"       # aggregation psum dtype (perf knob)


# --------------------------------------------------------------------------
# Wireless network scenario (channel + participation; see repro.wireless)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class FaultConfig:
    """Fault-injection knobs (``repro.wireless.faults``).

    The DEFAULTS encode ZERO faults: ``erasure_prob=0``, ``crash_hazard=0``
    and an empty ``es_outage_trace`` leave the scheduler on its exact
    fault-free code path (the golden regressions pin this bit-for-bit);
    ``max_retries``/``backoff_s``/``failover`` are inert until one of the
    hazards is switched on.  See ``repro/wireless/__init__.py`` for the
    full semantics of each knob.
    """
    erasure_prob: float = 0.0        # per-attempt payload erasure probability
    max_retries: int = 2             # HARQ retransmissions per payload (the
    #                                  payload is sent at most 1 + max_retries
    #                                  times); inert while erasure_prob == 0
    backoff_s: float = 0.0           # radio idle gap before each retransmit
    es_outage_trace: tuple[tuple[int, ...], ...] = ()  # round-major rows of
    #                                  per-ES down flags (cycled over rounds,
    #                                  resized over ESs); () -> no outages
    crash_hazard: float = 0.0        # per-round probability a scheduled
    #                                  client dies mid-round
    failover: str = "reassoc"        # outage policy: "reassoc" moves a dead
    #                                  ES's clients to the nearest live ES,
    #                                  "skip" sits them out for the round

    @property
    def active(self) -> bool:
        """True when any hazard is enabled (the scheduler builds a
        FaultInjector); False keeps the fault-free path untouched."""
        return (self.erasure_prob > 0.0 or self.crash_hazard > 0.0
                or len(self.es_outage_trace) > 0)


@dataclass(frozen=True)
class WirelessConfig:
    """Per-client channel + participation knobs for the wireless simulator.

    See ``repro/wireless/__init__.py`` for the full knob documentation.
    """
    model: str = "ideal"             # ideal | static | rayleigh | trace
    mean_uplink_mbps: float = 10.0   # mean per-client uplink rate
    mean_downlink_mbps: float = 40.0  # mean per-client downlink rate
    latency_s: float = 0.02          # per-message propagation/queueing latency
    heterogeneity: float = 0.0       # lognormal sigma of a FIXED per-client
    #                                  rate scale (0 -> homogeneous clients)
    trace: tuple[tuple[float, ...], ...] = ()  # (round, client) uplink Mbps
    trace_down: tuple[tuple[float, ...], ...] = ()  # (round, client) downlink
    #                                  Mbps (same round-major/cycling rules as
    #                                  trace); () -> downlink is the uplink
    #                                  trace rescaled by the configured
    #                                  downlink/uplink mean ratio (fallback)
    # ---- per-ES shared uplink (contention) ----
    es_uplink_mbps: float = float("inf")  # shared ES uplink capacity, split
    #                                  among that round's scheduled clients
    #                                  (inf -> private uplinks)
    contention: str = "equal"        # sharing rule: "equal" splits the pipe
    #                                  evenly; "proportional" weights shares
    #                                  by each client's private rate
    reshare_uplink: bool = True      # after unaffordable clients withdraw,
    #                                  re-run contention so survivors absorb
    #                                  the freed capacity (False reproduces
    #                                  the conservative single pass)
    # ---- adaptive cut-layer selection (repro.wireless.cutter) ----
    cut_policy: str = "fixed"        # fixed | greedy | deadline
    cut_candidates: tuple = ()       # candidate cuts, shallow -> deep: CNN
    #                                  cut names or LM client depths; () ->
    #                                  the model's single default cut
    # ---- pipelined streaming (repro.wireless.timeline) ----
    pipeline: bool = False           # overlap client compute with uplink
    #                                  streaming at minibatch granularity:
    #                                  each minibatch's activations transmit
    #                                  as soon as its compute finishes, so
    #                                  round time ~ max(compute, tx) + one
    #                                  bubble instead of compute + tx.  False
    #                                  (default) is the serial Eq.-17 model,
    #                                  bit-for-bit
    # ---- staleness-weighted async edge aggregation ----
    staleness_lambda: float = 0.0    # lambda in [0, 1]: a deadline-cut
    #                                  straggler's partial update is BANKED
    #                                  and folded into the edge round where
    #                                  its remaining bits finally land, with
    #                                  weight alpha_u * lambda**staleness
    #                                  (staleness = edge rounds late).  0
    #                                  (default) reproduces today's hard
    #                                  dropout bit-for-bit
    # ---- participation policy (scheduler) ----
    deadline_s: float = float("inf")  # edge-round deadline; stragglers drop
    selection: str = "deadline"      # deadline | topk | random
    topk: int = 0                    # keep the k fastest (0 -> no cap)
    participation_prob: float = 1.0  # Bernoulli thinning (selection="random")
    # ---- energy ----
    energy_budget_j: float = float("inf")  # lifetime per-client budget
    tx_power_w: float = 0.5          # uplink transmit power
    # ---- device (compute) model (repro.wireless.device) ----
    compute_gflops: float = float("inf")  # per-client compute rate (GFLOP/s);
    #                                  inf (default) = free compute, i.e. the
    #                                  bits-only simulator exactly
    compute_heterogeneity: float = 0.0  # lognormal sigma of a FIXED per-client
    #                                  compute scale (0 -> identical devices)
    compute_power_w: float = 0.0     # power drawn while computing (J/s);
    #                                  joins tx energy in the budget gate
    codec_cycles_per_element: float = 0.0  # FLOPs a client spends per element
    #                                  crossing a LOSSY codec (encode up,
    #                                  decode down); 0 = codecs compute-free
    # ---- fault injection + recovery (repro.wireless.faults) ----
    faults: FaultConfig = FaultConfig()  # erasures/HARQ, ES outages, crashes;
    #                                  the all-defaults instance is the exact
    #                                  fault-free scheduler, bit-for-bit
    seed: int = 0


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")
