"""deepseek-v2-236b  [moe]  — MLA (kv_lora=512), 2 shared + 160 routed, top-6.

60L d_model=5120 128H d_ff=1536/expert vocab=102400 [arXiv:2405.04434]
"""

from repro.configs.base import MLA_ATTN, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,               # MLA: all heads share the latent KV
    head_dim=128,                   # = qk_nope_head_dim
    d_ff=1536,
    vocab_size=102400,
    block_pattern=(MLA_ATTN,),
    rope_theta=10_000.0,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536,
                  num_shared_experts=2, d_ff_shared=1536,
                  router_aux_loss=0.003,
                  first_dense_layers=1, d_ff_dense=12288),
    norm="rmsnorm",
    act="silu",
    n_client_layers=2,
    source="arXiv:2405.04434",
)
