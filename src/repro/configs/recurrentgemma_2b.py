"""recurrentgemma-2b  [hybrid]  — RG-LRU + local attention, 1 attn : 2 recurrent.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000 [arXiv:2402.19427]
"""

from repro.configs.base import LOCAL_ATTN, RGLRU, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    # Griffin pattern: (recurrent, recurrent, local attention)
    block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    sliding_window=2048,
    rope_theta=10_000.0,
    rglru=RGLRUConfig(lru_width=2560, conv_kernel=4),
    embed_scale=True,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
    n_client_layers=2,
    source="arXiv:2402.19427",
)
