"""gemma3-12b  [dense]  — 5 local (sliding-window 1024) : 1 global, 128k ctx.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 [hf:google/gemma-3-1b-pt]
"""

from repro.configs.base import ATTN, LOCAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    block_pattern=(LOCAL_ATTN, LOCAL_ATTN, LOCAL_ATTN,
                   LOCAL_ATTN, LOCAL_ATTN, ATTN),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    local_rope_theta=10_000.0,
    qk_norm=True,
    embed_scale=True,
    norm="rmsnorm",
    act="gelu",
    tie_embeddings=True,
    n_client_layers=2,
    source="hf:google/gemma-3-1b-pt",
)
