"""xlstm-350m  [ssm]  — alternating sLSTM + mLSTM blocks.

24L d_model=1024 4H d_ff=0 vocab=50304 [arXiv:2405.04517]

d_ff=0: xLSTM blocks carry their own up/down projections (pre-up-projection
backbone for mLSTM, post-up-projection for sLSTM), so there is no separate
FFN sublayer.
"""

from repro.configs.base import MLSTM, SLSTM, ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    # 1:1 alternation (the paper's xLSTM[a:b] notation; [1:1] mix)
    block_pattern=(MLSTM, SLSTM),
    xlstm=XLSTMConfig(num_heads=4),
    norm="layernorm",
    act="gelu",
    n_client_layers=2,
    source="arXiv:2405.04517",
)
