"""olmoe-1b-7b  [moe]  — 64 experts, top-8.

16L d_model=2048 16H (kv=16) d_ff=1024/expert vocab=50304, MoE 64e top-8
[arXiv:2409.02060]
"""

from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    block_pattern=(ATTN,),
    rope_theta=10_000.0,
    qk_norm=True,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024,
                  router_aux_loss=0.01),
    norm="rmsnorm",
    act="silu",
    n_client_layers=2,
    source="arXiv:2409.02060",
)
