"""qwen2-vl-7b  [vlm]  — M-RoPE, dynamic-resolution vision frontend (stubbed).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 [arXiv:2409.12191]

The ViT + projector frontend is the allowed stub: ``input_specs`` supplies
precomputed patch embeddings of shape (batch, num_patch_tokens, d_model) plus
3D M-RoPE position ids; this module implements the language backbone.
"""

from repro.configs.base import ATTN, ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    block_pattern=(ATTN,),
    rope_theta=1_000_000.0,
    attn_bias=True,          # qwen2 uses qkv bias
    vlm=VLMConfig(num_patch_tokens=1024, mrope_sections=(16, 24, 24)),
    norm="rmsnorm",
    act="silu",
    n_client_layers=2,
    source="arXiv:2409.12191",
)
