"""The canonical small-scale sweep scenario shared by the benchmark tables.

``benchmarks/cut_sweep.py`` (policy x channel) and
``benchmarks/compress_sweep.py`` (codec x channel) are meant to be
comparable cells of one experiment grid: same 2-ES x 4-client hierarchy,
same training recipe, same 20/80 Mbps channel.  Keeping the literals here
means tuning one sweep's scenario cannot silently de-calibrate it from the
other.
"""

from __future__ import annotations

from repro.configs.base import HierarchyConfig, TrainConfig, WirelessConfig


def sweep_hierarchy(rounds: int, *, kappa0: int = 2) -> HierarchyConfig:
    return HierarchyConfig(num_edge_servers=2, clients_per_es=4,
                           kappa0=kappa0, kappa1=2, global_rounds=rounds)


def sweep_train() -> TrainConfig:
    return TrainConfig(learning_rate=0.05, batch_size=16, freeze_head=True)


def sweep_wireless(channel: str, **overrides) -> WirelessConfig:
    """The sweeps' shared channel: 20/80 Mbps mean rates, 20 ms latency.
    Per-sweep knobs (deadline, ES capacity, energy budget, cut policy,
    seed, ...) ride in as overrides."""
    return WirelessConfig(model=channel, mean_uplink_mbps=20.0,
                          mean_downlink_mbps=80.0, latency_s=0.02,
                          **overrides)
