"""The paper's own model: a small CNN for CIFAR-10 (Sec. V-A).

Conv2d(C,64) -> ReLU -> MaxPool -> Conv2d(64,128) -> ReLU -> MaxPool
-> FC(512*?,256) -> ReLU -> FC(256, num_labels)

Split after the first MaxPool2d (client-side = first conv block).
Head = the final FC(256, num_labels) — randomly initialized, frozen during
global training, fine-tuned per client afterwards.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class CNNConfig:
    name: str = "phsfl-cnn"
    image_size: int = 32
    channels: int = 3
    conv1_filters: int = 64
    conv2_filters: int = 128
    fc_hidden: int = 256
    num_labels: int = 10
    # PHSFL split: client side = [conv1, pool1]; server body = [conv2, pool2,
    # fc1]; server head = fc2.
    source = "paper Sec. V-A"

    @property
    def flat_dim(self) -> int:
        # two stride-2 maxpools
        s = self.image_size // 4
        return s * s * self.conv2_filters


CONFIG = CNNConfig()
