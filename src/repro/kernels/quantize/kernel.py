"""Fused quantize-dequantize Pallas TPU kernel (fake quantization).

The per-minibatch hot path of the compression subsystem: every cut-layer
activation tensor (and gradient) is pushed through ``dq(q(x))`` once per
client per minibatch, so the round trip must stay a single streaming pass —
one read of (x, u), one write of x_hat, no intermediate int buffer in HBM.

The per-tensor scale is a global reduction, so it is computed OUTSIDE the
kernel (a cheap ``max(|x|)``) and fed in as a (1, 1) scalar operand; the
kernel body is purely elementwise (VPU work) over (block_m, 128) VMEM
tiles: ``clip(floor(x/scale + u), -qmax, qmax) * scale``.  ``u`` carries
the stochastic-rounding randomness (uniform [0,1) drawn by the caller from
a jax PRNG key), which keeps the kernel deterministic given its inputs and
bit-comparable with ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_M = 256


def _qdq_kernel(x_ref, u_ref, scale_ref, o_ref, *, qmax: int):
    s = scale_ref[0, 0]
    inv = jnp.where(s > 0, 1.0 / s, 0.0)
    q = jnp.floor(x_ref[...].astype(jnp.float32) * inv
                  + u_ref[...].astype(jnp.float32))
    q = jnp.clip(q, -float(qmax), float(qmax))
    o_ref[...] = (q * s).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("qmax", "block_m", "interpret"))
def quantize_dequantize_pallas(x, u, scale, *, qmax: int,
                               block_m: int = DEFAULT_BLOCK_M,
                               interpret: bool = True):
    """x, u: (M, 128) with M % block_m == 0; scale: (1, 1) float32."""
    m, lanes = x.shape
    assert lanes == LANES and u.shape == x.shape, (x.shape, u.shape)
    block_m = min(block_m, m)
    assert m % block_m == 0, (m, block_m)

    kernel = functools.partial(_qdq_kernel, qmax=qmax)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_m, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, LANES), x.dtype),
        interpret=interpret,
    )(x, u, scale)
