"""Shape-generic wrapper for the quantize-dequantize kernel with STE VJP.

Handles what the tiled kernel cannot: arbitrary input shapes (flatten + pad
to (M, 128) tiles), the per-tensor absmax scale, drawing the
stochastic-rounding uniforms from a PRNG key, and a straight-through
estimator so the fake-quantizer is transparent to autodiff (the quantizer
is piecewise constant, so its true derivative is 0 a.e.; STE passes the
cotangent through unchanged, the standard choice for quantization-aware
training).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import LANES, quantize_dequantize_pallas
from repro.kernels.quantize.ref import quantize_dequantize_ref
from repro.telemetry.kernels import kernel_probe


def tensor_scale(x, qmax: int):
    """Per-tensor symmetric step size: absmax / qmax (0 for a zero tensor)."""
    return (jnp.max(jnp.abs(x.astype(jnp.float32))) / qmax).reshape(1, 1)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _qdq_ste(x, u, scale, qmax, interpret):
    """Padded (M, 128) quantize-dequantize with straight-through gradient."""
    return quantize_dequantize_pallas(x, u, scale, qmax=qmax,
                                      interpret=interpret)


def _qdq_fwd(x, u, scale, qmax, interpret):
    return _qdq_ste(x, u, scale, qmax, interpret), (u.shape,)


def _qdq_bwd(qmax, interpret, res, g):
    (u_shape,) = res
    return g, jnp.zeros(u_shape, g.dtype), jnp.zeros((1, 1), jnp.float32)


_qdq_ste.defvjp(_qdq_fwd, _qdq_bwd)


def quantize_dequantize(x, key, *, bits: int = 8, stochastic: bool = True,
                        interpret: bool = True, use_ref: bool = False):
    """Fake-quantize ``x`` to ``bits``-bit symmetric integers, any shape.

    ``key`` drives the stochastic rounding (ignored when
    ``stochastic=False``, which rounds half-up).  ``use_ref`` bypasses the
    Pallas kernel for the pure-jnp oracle (same math, same bits).
    """
    probe = kernel_probe("quantize")
    qmax = 2 ** (bits - 1) - 1
    scale = tensor_scale(x, qmax)
    flat = x.reshape(-1)
    if stochastic:
        u_flat = jax.random.uniform(key, flat.shape, jnp.float32)
    else:
        u_flat = jnp.full(flat.shape, 0.5, jnp.float32)
    if use_ref:
        out = quantize_dequantize_ref(flat, u_flat, scale[0, 0],
                                      qmax).reshape(x.shape)
    else:
        n = flat.shape[0]
        # big tensors amortize the grid over 256-row tiles; small ones keep
        # the padding waste at one minimal (8, 128) tile
        block_m = 256 if n >= 256 * LANES else 8
        tile = block_m * LANES
        pad = (-n) % tile
        xp = jnp.pad(flat, (0, pad)).reshape(-1, LANES)
        up = jnp.pad(u_flat, (0, pad)).reshape(-1, LANES)
        out = _qdq_ste(xp, up, scale, qmax, interpret)
        out = out.reshape(-1)[:n].reshape(x.shape)
    if probe is not None:
        # scale + round + clip + dequant per element
        probe.finish(out, flops=4.0 * x.size, arrays=(x,))
    return out
