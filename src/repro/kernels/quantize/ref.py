"""Pure-jnp oracle for the fused quantize-dequantize kernel.

Stochastic rounding is ``floor(x/scale + u)`` with ``u ~ U[0, 1)``: the
result rounds up with probability equal to the fractional part, so the
quantizer is unbiased (E[dq(x)] = x away from the clip boundary).  A
constant ``u = 0.5`` degenerates to round-half-up (deterministic mode).
"""

from __future__ import annotations

import jax.numpy as jnp


def quantize_dequantize_ref(x, u, scale, qmax: int):
    """Fake-quantize ``x`` to the symmetric integer grid [-qmax, qmax].

    x: any shape; u: same shape, uniform in [0,1); scale: () per-tensor
    step size (absmax / qmax).  Returns x_hat with x's dtype.
    """
    scale = jnp.asarray(scale, jnp.float32)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.floor(x.astype(jnp.float32) * inv + u.astype(jnp.float32))
    q = jnp.clip(q, -float(qmax), float(qmax))
    return (q * scale).astype(x.dtype)
