"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q: (B,H,S,d); k,v: (B,KVH,S,d).  Dense masked softmax reference."""
    b, h, s, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    kx = jnp.repeat(k, g, axis=1)
    vx = jnp.repeat(v, g, axis=1)
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                        kx.astype(jnp.float32))
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    pos = jnp.arange(s)
    keep = jnp.ones((s, s), bool)
    if causal:
        keep &= pos[None, :] <= pos[:, None]
    if window:
        keep &= pos[None, :] > pos[:, None] - window
    logits = jnp.where(keep, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)
