"""Flash attention Pallas TPU kernel with causal + sliding-window block skip.

Layout: q (B,H,S,d), k/v (B,KVH,S,d) — head-major so BlockSpecs tile the
(seq, head_dim) plane in VMEM and GQA is folded into the k/v index_map
(kv head = q head // group) with no materialized expansion.

Grid: (B, H, nq, nk) — the kv-block dim is innermost; per-(b,h,i) online
softmax state (m, l, acc) lives in VMEM scratch across the nk iterations.
Block skipping is structural: for causal masks, kv blocks strictly above the
diagonal contribute nothing and are skipped with pl.when; for sliding-window
masks, kv blocks entirely left of the window are skipped too — this is what
the pure-JAX chunked path cannot do (it must compute the full rectangle and
mask), and is the measured compute-term win in EXPERIMENTS.md §Perf.

VMEM budget per program instance (f32 compute):
    q block  bq*d*4      k/v blocks 2*bk*d*4
    scores   bq*bk*4     scratch (2*bq + bq*d)*4
with the default bq=bk=512, d=128: ~1.8 MiB — comfortably inside the
~16 MiB/core VMEM, leaving room for double buffering.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -2.0 ** 30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 causal: bool, window: int, softcap: float, scale: float,
                 block_q: int, block_k: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q
    k_start = j * block_k

    # ---- structural block skip (the FLOP saving vs the masked rectangle) --
    diag_ok = True
    if causal:
        diag_ok = k_start <= q_start + block_q - 1          # not fully above diag
    win_ok = True
    if window:
        # kv block entirely out of every query's window?
        win_ok = k_start + block_k - 1 > q_start - window

    @pl.when(jnp.logical_and(diag_ok, win_ok))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        keep = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            keep &= kpos <= qpos
        if window:
            keep &= kpos > qpos - window
        s = jnp.where(keep, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-37)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"))
def flash_attention_hmajor(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True):
    """q: (B,H,S,d); k,v: (B,KVH,S,d).  Returns (B,H,S,d).

    interpret=True executes the kernel body on CPU (this container); on TPU
    pass interpret=False.
    """
    b, h, s, d = q.shape
    kvh = k.shape[1]
    assert h % kvh == 0
    g = h // kvh
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
