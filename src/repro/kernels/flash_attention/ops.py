"""Jitted wrapper exposing the kernel in the model's (B,S,H,d) layout, with
a custom VJP whose backward pass recomputes attention via the memory-safe
chunked reference (forward speed from the kernel, correctness from the ref;
a dedicated backward kernel is the standard next step on real hardware)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_hmajor
from repro.telemetry.kernels import kernel_probe


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_attention_core(q, k, v, causal: bool = True, window: int = 0,
                          softcap: float = 0.0):
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    out = flash_attention_hmajor(qh, kh, vh, causal=causal, window=window,
                                 softcap=softcap)
    return jnp.swapaxes(out, 1, 2)


def _ref(q, k, v, causal, window, softcap):
    from repro.models.attention import self_attention
    return self_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, impl="dense"
                          if q.shape[1] <= 4096 else "auto")


def _fwd(q, k, v, causal, window, softcap):
    return _flash_attention_core(q, k, v, causal, window, softcap), (q, k, v)


def _bwd(causal, window, softcap, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _ref(q_, k_, v_, causal, window,
                                             softcap), q, k, v)
    return vjp(g)


_flash_attention_core.defvjp(_fwd, _bwd)


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    softcap: float = 0.0):
    """q: (B,S,H,d); k,v: (B,S,KVH,d) — the model-zoo layout."""
    probe = kernel_probe("flash_attention")
    out = _flash_attention_core(q, k, v, causal, window, softcap)
    if probe is not None:
        B, S, H, d = q.shape
        kv = min(window, S) if window else S
        # QK^T and PV matmuls, 2 FLOPs/MAC; causal halves the rectangle
        flops = 4.0 * B * H * S * kv * d * (0.5 if causal and not window
                                            else 1.0)
        probe.finish(out, flops=flops, arrays=(q, k, v))
    return out
