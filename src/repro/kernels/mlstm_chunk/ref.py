"""Pure-jnp oracle for the mLSTM chunk kernel: the model's own chunkwise
implementation re-laid-out to head-major, plus a fully-recurrent oracle."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.xlstm import mlstm_chunkwise, mlstm_step


def mlstm_ref(q, k, v, li, lf, chunk: int = 128):
    """q,k,v: (B,H,S,dh); li,lf: (B,H,S) -> h (B,H,S,dh)."""
    qs = jnp.swapaxes(q, 1, 2)  # (B,S,H,dh)
    ks = jnp.swapaxes(k, 1, 2)
    vs = jnp.swapaxes(v, 1, 2)
    lis = jnp.swapaxes(li, 1, 2)
    lfs = jnp.swapaxes(lf, 1, 2)
    h, _ = mlstm_chunkwise(qs, ks, vs, lis, lfs, chunk=chunk)
    return jnp.swapaxes(h, 1, 2)


def mlstm_recurrent_ref(q, k, v, li, lf):
    """Step-by-step recurrent oracle (ground truth for both forms)."""
    b, h, s, dh = q.shape
    carry = (jnp.zeros((b, h, dh, dh), jnp.float32),
             jnp.zeros((b, h, dh), jnp.float32),
             jnp.full((b, h), -1e30, jnp.float32))
    outs = []
    for t in range(s):
        ht, carry = mlstm_step(q[:, :, t][:, None].swapaxes(1, 1).reshape(b, 1, h, dh),
                               k[:, :, t].reshape(b, 1, h, dh),
                               v[:, :, t].reshape(b, 1, h, dh),
                               li[:, :, t].reshape(b, 1, h),
                               lf[:, :, t].reshape(b, 1, h), carry)
        outs.append(ht[:, 0])
    return jnp.stack(outs, axis=2)  # (B,H,S,dh)
