"""Chunkwise-parallel mLSTM Pallas TPU kernel (xLSTM, arXiv:2405.04517).

One program instance processes one (batch, head) pair; the chunk dim is the
innermost grid axis and the inter-chunk carry (C: dk x dv matrix memory,
n: dk normalizer, m: stabilizer) lives in VMEM scratch.  Within a chunk the
math is the quadratic stabilized form on a (bt x bt) tile — MXU-friendly —
while cross-chunk state keeps total work linear in sequence length.

Matches repro.models.xlstm.mlstm_chunkwise (the jnp implementation used by
the model) and the recurrent decode step (tested).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128
NEG_BIG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
                  c_ref, n_ref, m_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)

    q = q_ref[0, 0].astype(jnp.float32)          # (bt, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    li = li_ref[0, 0].astype(jnp.float32)        # (bt,)
    lf = lf_ref[0, 0].astype(jnp.float32)

    m_prev = m_ref[0]
    a = jnp.cumsum(lf)                           # (bt,)
    g = li - a
    run_max = jax.lax.cummax(g, axis=0)
    M = jnp.maximum(m_prev, run_max)             # (bt,)
    m_t = a + M

    # intra-chunk decay matrix D[t,s] = exp(g_s - M_t), s <= t
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    D = jnp.where(t_idx >= s_idx, jnp.exp(g[None, :] - M[:, None]), 0.0)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * D  # (bt,bt)
    h_intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())))
    n_intra = jax.lax.dot_general(D, k, (((1,), (0,)), ((), ())))     # (bt,dh)

    decay = jnp.exp(m_prev - M)                  # (bt,)
    h_inter = jax.lax.dot_general(q, c_ref[...], (((1,), (0,)), ((), ()))) \
        * decay[:, None]
    n_tot = n_intra + n_ref[...][None, :] * decay[:, None]
    denom = jnp.maximum(jnp.abs(jnp.sum(q * n_tot, axis=1)), jnp.exp(-m_t))
    o_ref[0, 0] = ((h_intra + h_inter) / denom[:, None]).astype(o_ref.dtype)

    # ---- carry update ----
    M_L = M[chunk - 1]
    m_new = m_t[chunk - 1]
    w_s = jnp.exp(g - M_L)                       # (bt,)
    c_ref[...] = c_ref[...] * jnp.exp(m_prev - M_L) + \
        jax.lax.dot_general(k * w_s[:, None], v, (((0,), (0,)), ((), ())))
    n_ref[...] = n_ref[...] * jnp.exp(m_prev - M_L) + \
        jnp.sum(k * w_s[:, None], axis=0)
    m_ref[0] = m_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk_pallas(q, k, v, li, lf, *, chunk: int = DEFAULT_CHUNK,
                       interpret: bool = True):
    """q,k,v: (B,H,S,dh); li,lf: (B,H,S) log input/forget gates.

    Returns h: (B,H,S,dh).
    """
    b, h, s, dh = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_mlstm_kernel, chunk=chunk)
    blk4 = lambda b_, h_, ci: (b_, h_, ci, 0)
    blk3 = lambda b_, h_, ci: (b_, h_, ci)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dh), blk4),
            pl.BlockSpec((1, 1, chunk, dh), blk4),
            pl.BlockSpec((1, 1, chunk, dh), blk4),
            pl.BlockSpec((1, 1, chunk), blk3),
            pl.BlockSpec((1, 1, chunk), blk3),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, dh), blk4),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, li, lf)
