"""Jitted wrapper for the mLSTM chunk kernel with ref-based VJP."""

from __future__ import annotations

import jax

from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_pallas
from repro.kernels.mlstm_chunk.ref import mlstm_ref
from repro.telemetry.kernels import kernel_probe


@jax.custom_vjp
def _mlstm_chunk_core(q, k, v, li, lf):
    return mlstm_chunk_pallas(q, k, v, li, lf)


def _fwd(q, k, v, li, lf):
    return _mlstm_chunk_core(q, k, v, li, lf), (q, k, v, li, lf)


def _bwd(res, g):
    _, vjp = jax.vjp(mlstm_ref, *res)
    return vjp(g)


_mlstm_chunk_core.defvjp(_fwd, _bwd)


def mlstm_chunk(q, k, v, li, lf):
    probe = kernel_probe("mlstm_chunk")
    out = _mlstm_chunk_core(q, k, v, li, lf)
    if probe is not None:
        *lead, S, d = q.shape
        B = 1
        for n in lead:
            B *= n
        # intra-chunk QK^T + PV (causal halves) at 2 FLOPs/MAC
        probe.finish(out, flops=2.0 * B * S * S * d,
                     arrays=(q, k, v, li, lf))
    return out
