"""Jitted wrapper for the mLSTM chunk kernel with ref-based VJP."""

from __future__ import annotations

import jax

from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_pallas
from repro.kernels.mlstm_chunk.ref import mlstm_ref


@jax.custom_vjp
def mlstm_chunk(q, k, v, li, lf):
    return mlstm_chunk_pallas(q, k, v, li, lf)


def _fwd(q, k, v, li, lf):
    return mlstm_chunk(q, k, v, li, lf), (q, k, v, li, lf)


def _bwd(res, g):
    _, vjp = jax.vjp(mlstm_ref, *res)
    return vjp(g)


mlstm_chunk.defvjp(_fwd, _bwd)
