"""Jitted wrapper for the RG-LRU kernel with ref-based VJP."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_ref


@jax.custom_vjp
def rglru_scan(log_a, b, h0):
    return rglru_scan_pallas(log_a, b, h0)


def _fwd(log_a, b, h0):
    return rglru_scan(log_a, b, h0), (log_a, b, h0)


def _bwd(res, g):
    log_a, b, h0 = res
    _, vjp = jax.vjp(rglru_scan_ref, log_a, b, h0)
    return vjp(g)


rglru_scan.defvjp(_fwd, _bwd)
