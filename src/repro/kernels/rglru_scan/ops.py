"""Jitted wrapper for the RG-LRU kernel with ref-based VJP."""

from __future__ import annotations

import jax

from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.telemetry.kernels import kernel_probe


@jax.custom_vjp
def _rglru_scan_core(log_a, b, h0):
    return rglru_scan_pallas(log_a, b, h0)


def _fwd(log_a, b, h0):
    return _rglru_scan_core(log_a, b, h0), (log_a, b, h0)


def _bwd(res, g):
    log_a, b, h0 = res
    _, vjp = jax.vjp(rglru_scan_ref, log_a, b, h0)
    return vjp(g)


_rglru_scan_core.defvjp(_fwd, _bwd)


def rglru_scan(log_a, b, h0):
    probe = kernel_probe("rglru_scan")
    out = _rglru_scan_core(log_a, b, h0)
    if probe is not None:
        # exp + multiply-accumulate per element of the scanned sequence
        probe.finish(out, flops=3.0 * log_a.size, arrays=(log_a, b, h0))
    return out
