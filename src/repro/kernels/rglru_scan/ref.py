"""Pure-jnp oracle for the RG-LRU scan kernel (sequential-scan form)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(log_a, b, h0):
    """h_t = exp(log_a_t) * h_{t-1} + b_t.  log_a,b: (B,S,W); h0: (B,W)."""
    def step(h, inp):
        la, bb = inp
        h = jnp.exp(la) * h + bb
        return h, h

    la = jnp.moveaxis(log_a.astype(jnp.float32), 1, 0)
    bb = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), (la, bb))
    return jnp.moveaxis(hs, 0, 1).astype(log_a.dtype)
