"""RG-LRU recurrence Pallas TPU kernel.

Computes h_t = exp(log_a_t) * h_{t-1} + b_t over blocked (time, width) VMEM
tiles.  Grid: (B, nw, nt) with the time dim innermost and sequential; the
running state for each (batch, width-tile) lives in VMEM scratch across the
nt iterations, so HBM traffic is exactly one read of (log_a, b) and one
write of h — the recurrence is bandwidth-bound, and this tiling keeps it at
the streaming minimum (the roofline memory term).

The diagonal recurrence is elementwise over width, so the width tile (lanes)
can be large (512) while the time tile bounds the sequential inner loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 256
DEFAULT_BLOCK_W = 512


def _rglru_kernel(log_a_ref, b_ref, h0_ref, o_ref, carry_ref, *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)

    log_a = log_a_ref[0].astype(jnp.float32)     # (bt, bw)
    b = b_ref[0].astype(jnp.float32)

    def body(t, h):
        h = jnp.exp(log_a[t]) * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    carry_ref[...] = jax.lax.fori_loop(0, block_t, body, carry_ref[...])


@functools.partial(jax.jit, static_argnames=("block_t", "block_w", "interpret"))
def rglru_scan_pallas(log_a, b, h0, *, block_t: int = DEFAULT_BLOCK_T,
                      block_w: int = DEFAULT_BLOCK_W, interpret: bool = True):
    """log_a, b: (B,S,W); h0: (B,W).  Returns h: (B,S,W)."""
    bsz, s, w = log_a.shape
    block_t = min(block_t, s)
    block_w = min(block_w, w)
    assert s % block_t == 0 and w % block_w == 0, (s, w, block_t, block_w)
    nt, nw = s // block_t, w // block_w

    kernel = functools.partial(_rglru_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=(bsz, nw, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda b_, wi, ti: (b_, ti, wi)),
            pl.BlockSpec((1, block_t, block_w), lambda b_, wi, ti: (b_, ti, wi)),
            pl.BlockSpec((1, block_w), lambda b_, wi, ti: (b_, wi)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_w),
                               lambda b_, wi, ti: (b_, ti, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), log_a.dtype),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(log_a, b, h0)
