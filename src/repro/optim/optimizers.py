"""Optimizers from scratch (optax is not available in this container).

API mirrors the optax gradient-transformation style:

    opt = masked(sgd(lr), trainable_mask)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

``masked`` zeroes updates where the mask is False — this is how the PHSFL
frozen head (Eq. 12 of the paper: lr=0 for w_{1,hd}) is realized, and how the
personalization phase (Eq. 18: only the head trains) is realized with the
complementary mask.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def _lr_at(lr, count):
    return lr(count) if callable(lr) else lr


def sgd(lr) -> Optimizer:
    """Plain SGD (the paper's optimizer; no state beyond a step count)."""

    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step_lr = _lr_at(lr, state["count"])
        updates = jax.tree.map(lambda g: -step_lr * g, grads)
        return updates, {"count": state["count"] + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params):
        mu = jax.tree.map(lambda m, g: beta * m + g, state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: g + beta * m, mu, grads)
        else:
            upd = mu
        step_lr = _lr_at(lr, state["count"])
        updates = jax.tree.map(lambda u: -step_lr * u, upd)
        return updates, {"count": state["count"] + 1, "mu": mu}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def update(grads, state, params):
        count = state["count"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        step_lr = _lr_at(lr, count)

        def upd(m_, v_, p):
            mh = m_ / c1
            vh = v_ / c2
            u = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-step_lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"count": count, "m": m, "v": v}

    return Optimizer(init, update)


def masked(opt: Optimizer, mask: PyTree) -> Optimizer:
    """Apply ``opt`` only where mask is True; zero updates elsewhere.

    Inner state is kept for every leaf (simplicity over memory); the masked
    leaves simply never move.  ``mask`` is a pytree of Python bools matching
    the params tree structure.
    """

    def init(params):
        return opt.init(params)

    def update(grads, state, params):
        # zero out gradients of frozen leaves before the inner update so that
        # stateful optimizers do not accumulate moments for them either.
        gz = jax.tree.map(lambda m, g: g if m else jnp.zeros_like(g), mask, grads)
        updates, state = opt.update(gz, state, params)
        updates = jax.tree.map(lambda m, u: u if m else jnp.zeros_like(u),
                               mask, updates)
        return updates, state

    return Optimizer(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)


def global_norm(tree: PyTree):
    sq = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(sq))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: x * scale, grads)


def make_optimizer(name: str, lr, *, momentum_beta: float = 0.9,
                   weight_decay: float = 0.0) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr, momentum_beta)
    if name == "adamw":
        return adamw(lr, weight_decay=weight_decay)
    raise ValueError(f"unknown optimizer {name!r}")
