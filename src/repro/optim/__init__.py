from repro.optim.optimizers import (
    Optimizer,
    sgd,
    momentum,
    adamw,
    masked,
    make_optimizer,
    apply_updates,
    global_norm,
    clip_by_global_norm,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine

__all__ = [
    "Optimizer", "sgd", "momentum", "adamw", "masked", "make_optimizer",
    "apply_updates", "global_norm", "clip_by_global_norm",
    "constant", "cosine_decay", "warmup_cosine",
]
