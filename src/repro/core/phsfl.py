"""PHSFL training rounds on the TPU mesh.

Two distribution strategies (see DESIGN.md §2/§5):

1. ``make_phsfl_round`` — paper-faithful (SFL-V1 semantics).  Every client
   owns a full model replica: parameters carry a leading client dim C
   (= pods * clients_per_pod) sharded over the manual ('pod','data') axes;
   the 'model' axis stays *automatic* so GSPMD tensor-parallelizes each
   client's replica.  One call = one edge round:

       kappa0 local SGD steps (lax.scan, NO cross-client collectives)
       -> weighted psum over 'data'   (edge aggregation, Eqs. 14-15)
       -> [every kappa1 calls] weighted psum over 'pod' (global agg, Eq. 16)

   The frozen head (Eq. 12) is an optimizer mask, so the head leaves never
   move and the psum leaves them bit-identical across clients.

2. ``make_shared_server_step`` — beyond-paper (SFL-V2-like).  The server-side
   body is ONE shared copy (FSDP-sharded over ('pod','data') x 'model');
   only the small client block + head carry the per-client dim (vmapped).
   Body gradients sync every step; client blocks still aggregate on the
   kappa0/kappa1 schedule.  This removes the dominant per-client memory and
   the full-model edge all-reduce — the datacenter analogue of the paper's
   Remark-1 communication saving (ship activations, not the model).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import HierarchyConfig, ModelConfig, TrainConfig
from repro.core.hierarchy import (edge_aggregate_mesh, global_aggregate_mesh,
                                  masked_psum_weighted)
from repro.core.split import (GLOBAL_TRAIN, HSFL_TRAIN, split_spec_for,
                              trainable_mask, part_masks)
from repro.models.registry import Model
from repro.optim import apply_updates, make_optimizer, masked
from repro.sharding.rules import data_axes, params_specs


# --------------------------------------------------------------- common ----
def _shard_map(f, mesh: Mesh, in_specs, out_specs, manual):
    """shard_map across jax versions: >= 0.5 exposes ``jax.shard_map`` with
    ``axis_names``/``check_vma``; 0.4.x has the experimental API with the
    complementary ``auto`` set and ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(manual),
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    auto = frozenset(mesh.axis_names) - frozenset(manual)
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False, auto=auto)


def _client_axes(mesh: Mesh):
    ca = data_axes(mesh)
    return ca if len(ca) > 1 else ca[0]


def _squeeze0(tree):
    return jax.tree.map(lambda x: x[0], tree)


def _unsqueeze0(tree):
    return jax.tree.map(lambda x: x[None], tree)


def abstract_params(model: Model, *, stacked_clients: int | None = None):
    """ShapeDtypeStruct params tree (no allocation)."""
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    if stacked_clients is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((stacked_clients,) + s.shape,
                                           s.dtype), shapes)
    return shapes


def _local_scan(model: Model, tcfg: TrainConfig, opt):
    """One client's kappa0 local SGD steps — the SINGLE definition shared by
    the mesh and host rounds, so their numerics cannot drift apart."""
    def scan(p, s, batch_c):
        def local_step(carry, mb):
            pp, ss = carry
            pol = None if tcfg.remat_policy == "full" else tcfg.remat_policy
            loss, g = jax.value_and_grad(
                lambda q: model.loss(q, mb, remat=tcfg.remat,
                                     remat_policy=pol))(pp)
            upd, ss = opt.update(g, ss, pp)
            return (apply_updates(pp, upd), ss), loss

        (p, s), losses = jax.lax.scan(local_step, (p, s), batch_c)
        return p, s, losses

    return scan


def build_optimizer(model: Model, tcfg: TrainConfig, cut=None):
    """Masked optimizer implementing the PHSFL frozen head (Eq. 12).

    ``cut`` re-partitions the client/body boundary (see ``split_spec_for``);
    the head — the only part the optimizer mask distinguishes — is the same
    at every cut, which is exactly the paper's Remark 2: the round numerics
    cannot depend on the cut, only the comm accounting does."""
    spec = split_spec_for(model.cfg, cut)
    phase = GLOBAL_TRAIN if tcfg.freeze_head else HSFL_TRAIN
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    mask = trainable_mask(shapes, spec, phase)
    opt = make_optimizer(tcfg.optimizer, tcfg.learning_rate,
                         weight_decay=tcfg.weight_decay)
    return masked(opt, mask), mask


# ------------------------------------------------ paper-faithful round -----
@dataclass
class PHSFLRound:
    """One compiled edge round (optionally with global sync)."""
    fn: Callable            # (params, opt_state, batch, alpha_u, alpha_b
                            #  [, mask]) -> (params, opt_state, metrics)
    params_spec: Any        # PartitionSpec tree for the stacked params
    num_clients: int


def make_phsfl_round(model: Model, hcfg: HierarchyConfig, tcfg: TrainConfig,
                     mesh: Mesh, *, global_sync: bool,
                     participation: bool = False, cut=None) -> PHSFLRound:
    """One compiled edge round.

    With ``participation=True`` the returned fn takes a sixth argument: a
    (num_clients,) 0/1 mask from the wireless scheduler.  Aggregation
    weights renormalize over the participating clients (Eqs. 14-16 over the
    survivors); an ES with zero participants keeps its pre-round edge model.
    An all-ones mask is bit-identical to the unmasked round.

    ``cut`` declares the client/body split boundary (for LMs, the client
    depth).  By Remark 2 it cannot change the round's numerics — the
    compiled fn is identical for every cut — but it keeps the declared
    split in sync with the wireless cut controller's byte accounting.
    """
    cfg = model.cfg
    opt, _ = build_optimizer(model, tcfg, cut)
    ca = _client_axes(mesh)
    manual = set(data_axes(mesh))
    num_clients = 1
    for a in data_axes(mesh):
        num_clients *= mesh.shape[a]

    local_scan = _local_scan(model, tcfg, opt)

    def per_client(params, opt_state, batch_c, au, ab, mask):
        p = _squeeze0(params)
        s = _squeeze0(opt_state)
        batch_c = _squeeze0(batch_c)
        p_prev = p                  # edge model before this round's steps

        p, s, losses = local_scan(p, s, batch_c)

        # ---- edge aggregation: weighted psum over clients of this ES ----
        agg_dtype = jnp.dtype(tcfg.agg_dtype)
        if mask is None:
            p = edge_aggregate_mesh(p, au[0], agg_dtype)
            if global_sync and "pod" in mesh.axis_names:
                # ---- global aggregation: weighted psum over edge servers --
                p = global_aggregate_mesh(p, ab[0], agg_dtype)
        else:
            m = mask[0].astype(agg_dtype)
            p = masked_psum_weighted(p, au[0], m, p_prev, "data", agg_dtype)
            if global_sync and "pod" in mesh.axis_names:
                # an ES joins the global round iff it had >= 1 participant
                es_m = (jax.lax.psum(m, "data") > 0).astype(agg_dtype)
                p = masked_psum_weighted(p, ab[0], es_m, p, "pod", agg_dtype)
        # true mean over ALL clients (the P() out-spec otherwise surfaces
        # shard 0's local loss with the replication check disabled)
        mean_loss = losses.mean()
        for a in data_axes(mesh):
            mean_loss = jax.lax.pmean(mean_loss, a)
        return _unsqueeze0(p), _unsqueeze0(s), mean_loss

    lead = P(ca)
    nargs = 6 if participation else 5
    body = per_client if participation else (
        lambda pr, st, b, au, ab: per_client(pr, st, b, au, ab, None))
    shd = _shard_map(
        body, mesh,
        in_specs=(lead,) * nargs,
        out_specs=(lead, lead, P()),
        manual=manual)

    if participation:
        def round_fn(params, opt_state, batch, alpha_u, alpha_b, mask):
            new_p, new_s, loss = shd(params, opt_state, batch,
                                     alpha_u, alpha_b, mask)
            return new_p, new_s, {"loss": loss}
    else:
        def round_fn(params, opt_state, batch, alpha_u, alpha_b):
            new_p, new_s, loss = shd(params, opt_state, batch,
                                     alpha_u, alpha_b)
            return new_p, new_s, {"loss": loss}

    pspec = params_specs(abstract_params(model), model.axes(), mesh, mode="tp")
    pspec = jax.tree.map(lambda s: P(ca, *tuple(s)), pspec,
                        is_leaf=lambda x: isinstance(x, P))
    return PHSFLRound(fn=round_fn, params_spec=pspec, num_clients=num_clients)


# --------------------------------------------- host mirror (single device) --
def make_host_round(model: Model, hcfg: HierarchyConfig, tcfg: TrainConfig,
                    *, num_clients: int, global_sync: bool,
                    participation: bool = False, cut=None) -> PHSFLRound:
    """Mesh-free mirror of :func:`make_phsfl_round` for single-device runs.

    Same semantics, same numerics: vmapped clients run the identical local
    scan, then edge aggregation is a weighted mean over each ES's client
    group in ``agg_dtype`` (and, when ``global_sync``, a weighted mean over
    ES groups by alpha_b) — exactly what the psum path computes, so a parity
    test can compare the two bit-for-bit at f32.  Optimizer states stay
    per-client, matching the mesh path.  ``hcfg.num_edge_servers`` groups
    the leading client dim; alpha_u must be normalized within each group.
    ``cut`` declares the split boundary exactly as in make_phsfl_round
    (a Remark-2 no-op on numerics).
    """
    opt, _ = build_optimizer(model, tcfg, cut)
    B = hcfg.num_edge_servers
    assert num_clients % B == 0, (num_clients, B)
    Ub = num_clients // B
    agg_dtype = jnp.dtype(tcfg.agg_dtype)

    local_scan = _local_scan(model, tcfg, opt)

    def one_client(p, s, bc):
        p, s, losses = local_scan(p, s, bc)
        return p, s, losses.mean()

    def _edge(p, p_prev, au, mask):
        w = au.astype(agg_dtype).reshape(B, Ub)
        if mask is not None:
            m = mask.astype(agg_dtype).reshape(B, Ub)
            w = w * m
            tot = w.sum(axis=1, keepdims=True)
            n = m.sum(axis=1, keepdims=True)
            one = jnp.asarray(1.0, agg_dtype)
            denom = jnp.where(n >= Ub, one, jnp.where(tot > 0, tot, one))

        def agg(x, fb):
            xr = x.astype(agg_dtype).reshape((B, Ub) + x.shape[1:])
            wexp = w.reshape((B, Ub) + (1,) * (x.ndim - 1))
            acc = (xr * wexp).sum(axis=1, keepdims=True)
            if mask is not None:
                acc = acc / denom.reshape((B, 1) + (1,) * (x.ndim - 1))
            out = jnp.broadcast_to(acc, xr.shape).astype(x.dtype)
            if mask is not None:
                sel = (n > 0).reshape((B, 1) + (1,) * (x.ndim - 1))
                out = jnp.where(sel, out, fb.reshape(xr.shape))
            return out.reshape(x.shape)

        return jax.tree.map(agg, p, p_prev)

    def _global(p, ab, mask):
        wb = ab.astype(agg_dtype).reshape(B, Ub)[:, :1]      # (B, 1)
        if mask is not None:
            m = (mask.astype(agg_dtype).reshape(B, Ub).sum(
                axis=1, keepdims=True) > 0).astype(agg_dtype)  # ES mask (B,1)
            wb = wb * m
            tot = wb.sum()
            n = m.sum()
            one = jnp.asarray(1.0, agg_dtype)
            denom = jnp.where(n >= B, one, jnp.where(tot > 0, tot, one))

        def agg(x):
            xr = x.astype(agg_dtype).reshape((B, Ub) + x.shape[1:])
            wexp = wb.reshape((B, 1) + (1,) * (x.ndim - 1))
            acc = (xr * wexp).sum(axis=0, keepdims=True)
            if mask is not None:
                acc = acc / denom
                acc = jnp.where(n > 0, acc, xr)   # nobody synced: keep edges
            out = jnp.broadcast_to(acc, xr.shape).astype(x.dtype)
            return out.reshape(x.shape)

        return jax.tree.map(agg, p)

    def round_body(params, opt_state, batch, au, ab, mask):
        p_prev = params
        p, s, losses = jax.vmap(one_client)(params, opt_state, batch)
        p = _edge(p, p_prev, au, mask)
        if global_sync:
            p = _global(p, ab, mask)
        return p, s, {"loss": losses.mean()}

    if participation:
        round_fn = round_body
    else:
        round_fn = lambda pr, st, b, au, ab: round_body(pr, st, b, au, ab,
                                                        None)
    return PHSFLRound(fn=round_fn, params_spec=None, num_clients=num_clients)


def init_stacked_params(model: Model, key, num_clients: int):
    """Materialize identical per-client replicas (host-side, small scale)."""
    p = model.init(key)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_clients,) + x.shape), p)


# ---------------------------------------------- shared-server (SFL-V2) -----
@dataclass
class SharedServerStep:
    fn: Callable            # (params, opt_state, batch) -> (params, opt, metrics)
    sync_clients: Callable  # (params, do_global: bool static) -> params
    client_mask: Any


def make_shared_server_step(model: Model, hcfg: HierarchyConfig,
                            tcfg: TrainConfig, mesh: Mesh,
                            num_clients: int) -> SharedServerStep:
    """Beyond-paper mode: shared body, per-client client-block + head.

    params: client-part leaves carry a leading (num_clients,) dim; body/head
    leaves are shared.  Plain pjit (no manual axes) — GSPMD shards the
    client dim over ('pod','data') and the body FSDP-style.
    """
    cfg = model.cfg
    spec = split_spec_for(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    masks = part_masks(shapes, spec)
    client_mask = masks["client"]
    opt, _ = build_optimizer(model, tcfg)

    in_axes_tree = jax.tree.map(lambda c: 0 if c else None, client_mask)

    def _merged_loss(params, cp, b):
        return model.loss(
            jax.tree.map(lambda m, c, s: c if m else s, client_mask, cp,
                         params), b, remat=tcfg.remat)

    def loss_fn(params, batch):
        if cfg.moe is not None:
            # jax.lax.ragged_dot (MoE grouped matmul) does not support vmap
            # over non-leading dims yet; map clients sequentially by index
            # instead — identical math, and the scan body costs once in HLO.
            def one(i):
                cp = jax.tree.map(lambda m, x: x[i] if m else x,
                                  client_mask, params)
                b = jax.tree.map(lambda x: x[i], batch)
                return model.loss(cp, b, remat=tcfg.remat)

            losses = jax.lax.map(one, jnp.arange(num_clients))
        else:
            losses = jax.vmap(
                lambda cp, b: _merged_loss(params, cp, b),
                in_axes=(in_axes_tree, 0))(params, batch)
        return losses.mean()

    def step(params, opt_state, batch):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        upd, opt_state = opt.update(g, opt_state, params)
        params = apply_updates(params, upd)
        return params, opt_state, {"loss": loss}

    def sync_clients(params, do_global: bool):
        """kappa0-boundary aggregation of the per-client client blocks."""
        pods = mesh.shape.get("pod", 1)
        per_pod = num_clients // pods

        def agg(m, x):
            if not m:
                return x
            if do_global:
                mean = x.mean(axis=0, keepdims=True)
                return jnp.broadcast_to(mean, x.shape)
            xr = x.reshape((pods, per_pod) + x.shape[1:])
            mean = xr.mean(axis=1, keepdims=True)
            return jnp.broadcast_to(mean, xr.shape).reshape(x.shape)

        return jax.tree.map(agg, client_mask, params)

    return SharedServerStep(fn=step, sync_clients=sync_clients,
                            client_mask=client_mask)


def init_shared_server_params(model: Model, key, num_clients: int):
    p = model.init(key)
    spec = split_spec_for(model.cfg)
    masks = part_masks(p, spec)
    return jax.tree.map(
        lambda m, x: jnp.broadcast_to(x[None], (num_clients,) + x.shape)
        if m else x, masks["client"], p)
