"""Personalization by head fine-tuning (paper Sec. III-B, Eq. 18).

After global training produces w*, each client fine-tunes ONLY the
classifier/head for K SGD steps on its local data; the client block and body
stay exactly w*.  The personalized model is
w_u^K = [w*_{b,0}; [w*_{b,1,bd}; w_{u,1,hd}^K]].

Two implementations:
  - ``personalize_head_bank``: framework-scale.  Since the body is frozen,
    the final hidden states are computed ONCE per client and the K SGD steps
    run on the cached hiddens (beyond-paper speedup; identical math when the
    fine-tuning minibatch set is fixed).
  - fedsim's faithful per-step recompute lives in core/fedsim.py.

Serving: ``merge_head`` grafts a personalized head onto the shared trunk —
this is what launch/serve.py uses to serve per-client personalized models.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.split import split_spec_for, part_masks
from repro.models import transformer as tf_mod
from repro.models.registry import Model


def extract_head(params, cfg) -> dict:
    """The head subtree (paths preserved), e.g. {"lm_head": {"w": ...}}."""
    from repro.utils.tree import map_with_path, path_str
    spec = split_spec_for(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out: dict = {}
    for path, leaf in flat:
        p = path_str(path)
        if spec.part_of(p) == "head":
            cur = out
            keys = p.split("/")
            for k in keys[:-1]:
                cur = cur.setdefault(k, {})
            cur[keys[-1]] = leaf
    return out


def merge_head(params, head_params, cfg):
    """Graft a (per-client) head onto shared trunk params.

    ``head_params`` may be a partial tree containing only the head paths
    (as produced by extract_head) or a full params-shaped tree.
    """
    from repro.utils.tree import map_with_path, path_str
    spec = split_spec_for(cfg)

    def lookup(tree, path: str):
        cur = tree
        for k in path.split("/"):
            if not isinstance(cur, dict) or k not in cur:
                return None
            cur = cur[k]
        return cur

    def pick(path, leaf):
        if spec.part_of(path) != "head":
            return leaf
        h = lookup(head_params, path)
        assert h is not None, f"head leaf {path} missing from head_params"
        return h

    return map_with_path(pick, params)


def head_loss(head_w, cfg: ModelConfig, hidden, labels):
    """Cross-entropy using an explicit head weight (B,S,D)x(D,V)."""
    fake_params = {"lm_head": {"w": head_w}}
    return tf_mod.lm_loss(fake_params, cfg, hidden, labels)


def personalize_head_bank(model: Model, params, batches, tcfg: TrainConfig):
    """Fine-tune one head per client from cached hidden states.

    batches: dict of arrays with leading client dim C — {"tokens": (C,B,S),
    "labels": (C,B,S), ...}.  Returns head bank (C, D, V) and per-client
    losses (C, K).
    """
    cfg = model.cfg

    def per_client(batch_c):
        hidden, _ = model.apply(params, batch_c)           # body forward ONCE
        w0 = params["lm_head"]["w"]

        def step(w, _):
            loss, g = jax.value_and_grad(head_loss)(w, cfg, hidden,
                                                    batch_c["labels"])
            return w - tcfg.finetune_lr * g.astype(w.dtype), loss

        w, losses = jax.lax.scan(step, w0, None, length=tcfg.finetune_steps)
        return w, losses

    if cfg.moe is not None:
        # ragged_dot (MoE grouped matmul) cannot be vmapped yet — map
        # clients sequentially (identical math).
        return jax.lax.map(per_client, batches)
    return jax.vmap(per_client)(batches)


def personalized_eval(model: Model, params, head_bank, batches):
    """Per-client loss of the personalized models on held-out batches."""
    cfg = model.cfg

    def per_client(w_head, batch_c):
        hidden, _ = model.apply(params, batch_c)
        return head_loss(w_head, cfg, hidden, batch_c["labels"])

    if cfg.moe is not None:
        return jax.lax.map(lambda args: per_client(*args),
                           (head_bank, batches))
    return jax.vmap(per_client)(head_bank, batches)
