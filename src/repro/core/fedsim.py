"""Faithful PHSFL simulation (paper Secs. III–V) on the paper's CNN.

This module reproduces the paper's algorithm *exactly* as specified:

- B edge servers, U_b clients each, Dirichlet(alpha) non-IID data;
- split learning dataflow: the client computes the cut-layer activations
  o_fp (Step 3.2) and offloads them + minibatch indices (Step 3.4); the ES
  completes the forward with its labels (Step 3.5), backprops the server
  part (3.6), returns the cut-layer gradient o_bp (3.7), and the client
  finishes backprop by VJP (3.8).  ``split_grad`` implements this literal
  dataflow (and a test asserts it equals monolithic backprop — Remark 2);
- PHSFL: the head (fc2) is frozen during global training (Eq. 12);
  HSFL baseline: identical but the head trains;
- hierarchical aggregation: weighted edge aggregation every kappa0 local
  epochs (Eqs. 14-15), weighted global aggregation every kappa1 edge rounds
  (Eq. 16);
- personalization: K head-only SGD steps per client (Eq. 18).

Clients are vmapped (stacked parameter replicas) for speed; the math is the
per-client loop of the paper.
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import HierarchyConfig, TrainConfig, WirelessConfig
from repro.configs.phsfl_cnn import CNNConfig
from repro.core.hierarchy import es_assignment
from repro.data.synthetic import FederatedImageData
from repro.models import cnn


# ---------------------------------------------------------------------------
def split_grad(params, x, y, cut: str = cnn.DEFAULT_CUT, *,
               codecs=None, key=None):
    """Literal split-learning gradient exchange (Steps 3.2–3.8) at ``cut``.

    Remark 2 in code: the VJP composition through ANY cut point replays the
    same chain rule, so the returned gradients are bit-identical across all
    candidate cuts (and to monolithic backprop up to float re-association —
    see test_split.py / test_cutter.py).

    ``codecs`` (a :class:`repro.compress.LinkCodecs`, static under jit)
    pushes the two cut-layer payloads through their lossy channel exactly
    where the wire sits: the ES computes its forward AND its gradient at
    the DECODED activations o_fp_hat (what it actually received), and the
    client backprops from the decoded gradient o_bp_hat.  ``key`` drives
    stochastic codecs; identity/None codecs reproduce the uncompressed
    dataflow bit-for-bit."""
    client_keys = cnn.client_keys_for(cut)
    client_p = {k: params[k] for k in client_keys}
    server_p = {k: params[k] for k in params if k not in client_keys}
    k_act = k_grad = None
    if codecs is not None:
        if key is None:
            if not codecs.is_lossless():
                # a silent fixed key would reuse the SAME rounding noise
                # every minibatch, correlating the quantization error the
                # stochastic rounding exists to keep unbiased
                raise ValueError("stochastic codecs need an explicit "
                                 "key= per call")
            key = jax.random.PRNGKey(0)      # identity: never consumed
        k_act, k_grad = jax.random.split(key)

    # Step 3.2: client forward to the cut layer
    o_fp, client_vjp = jax.vjp(
        lambda cp: cnn.client_forward(cp, x, cut), client_p)

    # Step 3.4 wire: o_fp crosses the uplink through the activation codec
    if codecs is not None and codecs.activations is not None:
        o_fp = codecs.activations.apply(k_act, o_fp)

    # Steps 3.5–3.6: server forward + server-side backprop
    def server_loss(sp, o):
        logits = cnn.server_forward(sp, o, cut)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    loss, (g_server, o_bp) = jax.value_and_grad(
        server_loss, argnums=(0, 1))(server_p, o_fp)

    # Step 3.7 wire: o_bp crosses the downlink through the gradient codec
    if codecs is not None and codecs.gradients is not None:
        o_bp = codecs.gradients.apply(k_grad, o_bp)

    # Step 3.8: cut-layer gradient back to the client; client VJP
    (g_client,) = client_vjp(o_bp)
    return loss, {**g_client, **g_server}


def monolithic_grad(params, x, y):
    """Reference: ordinary end-to-end backprop (for the Remark-2 test)."""
    return jax.value_and_grad(cnn.loss_fn)(params, x, y)


# ---------------------------------------------------------------------------
@dataclass
class FedSimResult:
    history: list = field(default_factory=list)          # per-round metrics
    global_params: dict | None = None
    personalized_heads: dict | None = None               # stacked (U, ...)
    per_client_global: dict | None = None                # eval of w*
    per_client_personalized: dict | None = None          # eval of w_u^K
    network: list = field(default_factory=list)          # per-edge-round
    total_sim_time_s: float = 0.0                        # simulated clock


class FedSim:
    """Runs PHSFL (freeze_head=True) or HSFL (False) on federated data."""

    def __init__(self, cfg: CNNConfig, data: FederatedImageData,
                 hcfg: HierarchyConfig, tcfg: TrainConfig, *,
                 batches_per_epoch: int = 5, seed: int = 0,
                 wireless: WirelessConfig | None = None,
                 cut: str | None = None, codecs=None, telemetry=None,
                 population=None, sampling: str = "uniform"):
        # population mode (repro.wireless.population): hcfg.num_clients
        # becomes the COHORT size (training slots); each edge round the
        # scheduler samples that many registered clients, ES-balanced so
        # slot i's home ES stays i // Ub, and slot i trains on data shard
        # cohort[i] % data.num_clients.  Without a population the classic
        # invariant holds: one shard per permanent client.
        self.population = population
        self.sampling = sampling
        self._slot_shard = None          # (U,) per-round slot -> data shard
        self._cohort = None              # (U,) per-round slot -> client id
        if population is None:
            assert data.num_clients == hcfg.num_clients
        else:
            if wireless is None or wireless.model == "ideal":
                raise ValueError("population mode needs a wireless config "
                                 "(the cohort sampler lives on the "
                                 "scheduler)")
            if population.num_es != hcfg.num_edge_servers:
                raise ValueError(
                    f"population has {population.num_es} edge servers but "
                    f"the hierarchy has {hcfg.num_edge_servers}")
            if wireless.staleness_lambda > 0.0:
                raise ValueError(
                    "staleness_lambda > 0 is incompatible with population "
                    "mode: the bank keys snapshots by client identity, but "
                    "training slots remap to different clients every round")
        self.cfg, self.data, self.h, self.t = cfg, data, hcfg, tcfg
        self.batches_per_epoch = batches_per_epoch
        # the TRAINING cut: which boundary split_grad exchanges activations
        # at.  Remark 2 guarantees the trajectory is invariant to it (the
        # invariance test pins this down bit-for-bit); the wireless side
        # prices it per round via the cut controller.
        self.cut = cut if cut is not None else cnn.DEFAULT_CUT
        if self.cut not in cnn.CUT_CANDIDATES:
            raise ValueError(f"unknown cut {self.cut!r}")
        # the TRAINING codecs (repro.compress.LinkCodecs): applied in the
        # literal dataflow (activations/gradients at the cut each minibatch,
        # client-block offload before every edge aggregation) AND handed to
        # the wireless side so the scheduler prices the same bits the
        # numerics pay.  Unlike the cut, a lossy codec DOES change learning
        # dynamics, so the simulation trains with exactly one codec set; the
        # joint (cut, codec) grid search is the controller's accounting-side
        # tool (see benchmarks/compress_sweep.py).
        self.codecs = codecs
        # observability (repro.telemetry): FedSim registers its own
        # fedsim.* instruments (round wall time, eval accuracy, live vs
        # stale aggregation mass) next to the scheduler's sched.* ones.
        # None (the default) skips every hook — bit-inert
        self.telemetry = telemetry
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)

        # wireless scenario: channel + participation (None => ideal network)
        self.scheduler = None
        if wireless is not None and wireless.model != "ideal":
            from repro.core.comm import comm_for_cnn, comm_table_for_cnn
            from repro.wireless import make_scheduler
            # Eq. 17 is an UPPER bound, so the shared byte accounting must
            # price the index payload ceil(log2 |D_u|) at the LARGEST client
            # dataset — the mean silently undercounts for every bigger-than-
            # average client under a skewed Dirichlet split (alpha << 1)
            max_size = int(max(len(i) for i in data.train_indices))
            if population is not None:
                from repro.wireless.population import CohortScheduler
                sched_u = population.N
                es_assign = population.es_assign
                extra = dict(cls=CohortScheduler, population=population,
                             cohort_size=hcfg.num_clients, sampling=sampling,
                             es_balanced=True)
            else:
                sched_u = hcfg.num_clients
                es_assign = es_assignment(hcfg.num_clients,
                                          hcfg.clients_per_es)
                extra = {}
            kw = dict(dataset_size=max(max_size, 2),
                      batch_size=tcfg.batch_size,
                      batches_per_epoch=batches_per_epoch,
                      codecs=self.codecs)
            if wireless.cut_policy != "fixed" or wireless.cut_candidates:
                table = comm_table_for_cnn(
                    cfg, cuts=tuple(wireless.cut_candidates) or None, **kw)
                if wireless.cut_policy == "fixed" and self.cut not in table:
                    raise ValueError(
                        f"cut_policy='fixed' would price one of "
                        f"{tuple(table)} but the training cut is "
                        f"{self.cut!r}; add it to cut_candidates")
                self.scheduler = make_scheduler(
                    wireless, sched_u, kappa0=hcfg.kappa0,
                    comm_table=table, es_assign=es_assign,
                    fixed_cut=self.cut if self.cut in table else 0,
                    telemetry=telemetry, **extra)
            else:
                comm = comm_for_cnn(cfg, cut=self.cut, **kw)
                self.scheduler = make_scheduler(wireless, sched_u,
                                                comm, hcfg.kappa0,
                                                es_assign=es_assign,
                                                telemetry=telemetry, **extra)
        self._edge_round = 0
        # staleness-weighted async edge aggregation (scheduler banks a
        # straggler's remainder; we snapshot its stacked params at the
        # banking round and fold them in at delivery with alpha_u * lambda^s)
        self.staleness_lambda = (wireless.staleness_lambda
                                 if self.scheduler is not None else 0.0)
        self._stale_params = None        # stacked (U, ...) banked snapshots
        # resumable run state (state_dict/load_state_dict): the stacked
        # client replicas, the global-round cursor, the simulated clock, and
        # the codec PRNG chain live on the instance, so run() continues
        # where it left off and a checkpoint captures everything the
        # trajectory depends on
        self._stacked = None
        self._round = 0
        self._sim_time = 0.0
        # codec PRNG chain: one subkey per stochastic-codec application,
        # disjoint from the data-sampling RNG and the init key
        self._ckey = (jax.random.fold_in(self.key, 0xC0DEC)
                      if codecs is not None else None)

        U, B = hcfg.num_clients, hcfg.num_edge_servers
        self.U, self.B, self.Ub = U, B, hcfg.clients_per_es
        # aggregation weights (paper Eq. 4/6): proportional to |D_u|.  In
        # population mode slot identity changes every edge round, so these
        # are uniform placeholders — _set_cohort_weights overwrites them
        # from population.data_size before each aggregation.
        if population is not None:
            sizes = np.ones(U, np.float64)
        else:
            sizes = np.array([len(i) for i in data.train_indices],
                             np.float64)
        if hcfg.weighting == "uniform":
            sizes = np.ones_like(sizes)
        es_sizes = sizes.reshape(B, self.Ub).sum(axis=1)
        self.alpha_u = (sizes.reshape(B, self.Ub)
                        / es_sizes[:, None]).reshape(U)      # within-ES
        self.alpha_b = es_sizes / es_sizes.sum()

        self._build_steps()

    # ------------------------------------------------------------- setup --
    def _build_steps(self):
        tcfg = self.t
        freeze = tcfg.freeze_head
        cut = self.cut
        codecs = self.codecs

        def apply_sgd(params, g, loss):
            lr = tcfg.learning_rate

            def upd(path_is_head, p, gg):
                if path_is_head and freeze:
                    return p                                  # Eq. (12)
                return p - lr * gg

            new = {k: jax.tree.map(partial(upd, k in cnn.HEAD_KEYS),
                                   params[k], g[k]) for k in params}
            return new, loss

        def sgd_update(params, x, y):
            loss, g = split_grad(params, x, y, cut)
            return apply_sgd(params, g, loss)

        def sgd_update_codec(params, x, y, key):
            loss, g = split_grad(params, x, y, cut, codecs=codecs, key=key)
            return apply_sgd(params, g, loss)

        if codecs is None:
            self._client_step = jax.jit(jax.vmap(sgd_update))
        else:
            self._client_step = jax.jit(jax.vmap(sgd_update_codec))

        # client-block offload codec: each client's w_{u,0} crosses the
        # uplink through the offload codec right before edge aggregation
        # (the downlink broadcast of the refreshed block is charged in the
        # byte accounting but left lossless in the numerics — the ES is the
        # fidelity bottleneck the paper's Eq. 17 prices twice)
        self._offload_step = None
        if codecs is not None and codecs.offload is not None:
            from repro.utils.prng import fold_in_str
            off = codecs.offload
            ckeys = cnn.client_keys_for(cut)

            def offload_q(params, key):
                def q(path, leaf):
                    return off.apply(
                        fold_in_str(key, jax.tree_util.keystr(path)), leaf)

                block = {k: params[k] for k in ckeys}
                return {**params,
                        **jax.tree_util.tree_map_with_path(q, block)}

            self._offload_step = jax.jit(jax.vmap(offload_q))

        def head_ft_step(params, x, y):
            """Eq. (18): head-only fine-tuning step."""
            def loss_head(head):
                p = {**params, "fc2": head}
                return cnn.loss_fn(p, x, y)

            loss, g = jax.value_and_grad(loss_head)(params["fc2"])
            head = jax.tree.map(lambda p, gg: p - tcfg.finetune_lr * gg,
                                params["fc2"], g)
            return {**params, "fc2": head}, loss

        self._head_ft_step = jax.jit(jax.vmap(head_ft_step))

        self._eval = jax.jit(jax.vmap(cnn.loss_and_acc))

    # -------------------------------------------------------------- data --
    def _sample_minibatches(self, batch_size: int, rng=None):
        """One (U, N, ...) stacked minibatch (client-local sampling).

        ``rng`` defaults to the training stream ``self.rng``; personalize
        passes its own stream so fine-tuning is invariant to how much
        training preceded it."""
        rng = self.rng if rng is None else rng
        shards = self._slot_shard
        xs, ys = [], []
        for u in range(self.U):
            x, y = self.data.client_train(
                u if shards is None else int(shards[u]))
            idx = rng.choice(len(x), size=batch_size,
                             replace=len(x) < batch_size)
            xs.append(x[idx])
            ys.append(y[idx])
        return jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys))

    def _stacked_test(self, cap: int = 256):
        xs, ys, ws = [], [], []
        for u in range(self.U):
            x, y = self.data.client_test(u % self.data.num_clients)
            n = min(len(x), cap)
            pad = cap - n
            xs.append(np.pad(x[:n], ((0, pad),) + ((0, 0),) * 3))
            yy = np.zeros(cap, np.int32)
            yy[:n] = y[:n]
            ys.append(yy)
            w = np.zeros(cap, np.float32)
            w[:n] = 1.0
            ws.append(w)
        return (jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys)),
                jnp.asarray(np.stack(ws)))

    # ---------------------------------------------------------- cohorts ---
    def _begin_cohort_round(self):
        """Population mode, top of each edge round: draw the cohort BEFORE
        the local epochs (the slots must know whose shard to train on),
        remap slot -> data shard, and recompute the Eq. 4/6 weights from
        the sampled clients' registered dataset sizes."""
        sched = self.scheduler
        cohort = sched.sample_cohort()
        self._cohort = cohort
        self._slot_shard = cohort % self.data.num_clients
        if self.h.weighting == "uniform":
            sizes = np.ones(self.U, np.float64)
        else:
            sizes = np.asarray(self.population.data_size,
                               np.float64)[cohort]
        es_sizes = sizes.reshape(self.B, self.Ub).sum(axis=1)
        self.alpha_u = (sizes.reshape(self.B, self.Ub)
                        / es_sizes[:, None]).reshape(self.U)
        self.alpha_b = es_sizes / es_sizes.sum()
        return cohort

    # ------------------------------------------------------- aggregation --
    def _masked_edge_weights(self, mask, stale_w=None):
        """(B, Ub) weights: alpha_u renormalized over participants, plus the
        (B,) empty-ES indicator.  A fully-participating ES keeps its alpha_u
        weights EXACTLY (no renormalization round-off), so an all-ones mask
        reproduces the ideal-network path bit-for-bit.

        ``stale_w`` (a (U,) array, lambda**staleness per client whose banked
        update was DELIVERED this round, 0 elsewhere) adds the async fold:
        each delivery joins its ES's average with raw weight
        ``alpha_u * stale_w``, and live + stale weights renormalize to sum
        to 1 together.  Returns ``(w, sw, empty)`` — ``sw`` is None on the
        exact synchronous path (``stale_w`` None), and an ES counts as empty
        only if it has neither a live participant nor a delivery."""
        B, Ub = self.B, self.Ub
        aw = self.alpha_u.reshape(B, Ub)                     # float64
        m = np.asarray(mask, np.float64).reshape(B, Ub) > 0
        raw = np.where(m, aw, 0.0)
        if stale_w is None:
            tot = raw.sum(axis=1, keepdims=True)
            full = m.all(axis=1, keepdims=True)
            w = np.where(full, aw, raw / np.where(tot > 0, tot, 1.0))
            return w, None, ~m.any(axis=1)
        sw = np.asarray(stale_w, np.float64).reshape(B, Ub)
        raw_stale = aw * sw
        tot = (raw + raw_stale).sum(axis=1, keepdims=True)
        denom = np.where(tot > 0, tot, 1.0)
        return (raw / denom, raw_stale / denom,
                ~(m | (sw > 0)).any(axis=1))

    def _edge_aggregate(self, stacked, mask=None, fallback=None, stale=None,
                        stale_w=None):
        """Eqs. (14)-(15): per-ES weighted average, broadcast back.

        With a participation ``mask`` the weights renormalize over the
        participating clients of each ES; an ES with zero participants keeps
        ``fallback`` (its model from before this edge round's local steps).
        ``stale``/``stale_w`` fold banked straggler snapshots into the same
        average with weight ``alpha_u * lambda**staleness`` (the staleness-
        weighted async path — see ``_masked_edge_weights``).
        """
        B, Ub = self.B, self.Ub
        if mask is None:
            w64, sw64 = self.alpha_u.reshape(B, Ub), None
            empty = np.zeros(B, bool)
        else:
            w64, sw64, empty = self._masked_edge_weights(mask, stale_w)
            assert fallback is not None or not empty.any()
        w = jnp.asarray(w64, jnp.float32)
        ws = None if sw64 is None else jnp.asarray(sw64, jnp.float32)

        def agg(x, fb=None, st=None):
            xr = x.reshape((B, Ub) + x.shape[1:])
            wexp = w.reshape((B, Ub) + (1,) * (x.ndim - 1))
            m = (xr * wexp).sum(axis=1, keepdims=True)
            if st is not None:
                swexp = ws.reshape((B, Ub) + (1,) * (x.ndim - 1))
                m = m + (st.reshape(xr.shape) * swexp).sum(axis=1,
                                                           keepdims=True)
            out = jnp.broadcast_to(m, xr.shape)
            if fb is not None and empty.any():
                sel = jnp.asarray(empty).reshape((B, 1) + (1,) * (x.ndim - 1))
                out = jnp.where(sel, fb.reshape(xr.shape), out)
            return out.reshape(x.shape)

        if mask is None or fallback is None:
            return jax.tree.map(agg, stacked)
        if stale is not None and ws is not None:
            return jax.tree.map(agg, stacked, fallback, stale)
        return jax.tree.map(agg, stacked, fallback)

    def _mapped_edge_weights(self, mask, es_map, stale_w=None):
        """(B, U) weight matrix for an ES-outage failover round.

        ``es_map`` (``RoundReport.es_map``) sends each client's update to
        its EFFECTIVE ES, so a re-associated client joins the live ES's
        average with its own alpha_u weight, renormalized together with
        that ES's home participants (and any stale deliveries).  Returns
        ``(w, sw, empty)`` like :meth:`_masked_edge_weights`; ``empty``
        marks ESs that aggregated nothing (dead, or no participants) —
        their clients keep their fallback params.
        """
        B, U = self.B, self.U
        m = np.asarray(mask, np.float64) > 0
        onehot = np.zeros((B, U))
        onehot[np.asarray(es_map, int), np.arange(U)] = 1.0
        raw = onehot * np.where(m, self.alpha_u, 0.0)[None, :]
        sw = np.zeros(U) if stale_w is None else np.asarray(stale_w,
                                                            np.float64)
        raw_stale = onehot * (self.alpha_u * sw)[None, :]
        tot = (raw + raw_stale).sum(axis=1, keepdims=True)
        denom = np.where(tot > 0, tot, 1.0)
        return raw / denom, raw_stale / denom, tot[:, 0] <= 0

    def _edge_aggregate_mapped(self, stacked, mask, fallback, es_map,
                               stale=None, stale_w=None):
        """Eqs. (14)-(15) under ES failover: aggregate by EFFECTIVE ES.

        Each client receives the refreshed model of the ES it actually
        worked with this round (``es_map``); a client whose effective ES
        aggregated nothing keeps ``fallback`` — which is exactly how a dead
        ES's edge model is carried forward (its skipped clients still hold
        it).  Only reassoc-outage rounds route here; every other round uses
        the home-(B, Ub) path bit-unchanged.
        """
        w64, sw64, empty = self._mapped_edge_weights(mask, es_map, stale_w)
        w = jnp.asarray(w64, jnp.float32)                      # (B, U)
        ws = jnp.asarray(sw64, jnp.float32)
        recv = jnp.asarray(np.asarray(es_map, int))            # (U,)
        keep_fb = jnp.asarray(empty)[recv]                     # (U,) bool

        def agg(x, fb, st=None):
            flat = x.reshape((self.U, -1))
            es = w @ flat                                      # (B, prod)
            if st is not None:
                es = es + ws @ st.reshape((self.U, -1))
            out = jnp.where(keep_fb[:, None], fb.reshape((self.U, -1)),
                            es[recv])
            return out.reshape(x.shape)

        if stale is not None and stale_w is not None:
            return jax.tree.map(agg, stacked, fallback, stale)
        return jax.tree.map(agg, stacked, fallback)

    def _global_aggregate(self, stacked, es_mask=None):
        """Eq. (16): CS-level weighted average over ESs, broadcast back.

        ``es_mask`` marks ESs that had at least one participating client
        this global round; alpha_b renormalizes over them (all ESs still
        RECEIVE the broadcast).  With no participating ES at all the models
        are left untouched (no global sync happened).
        """
        B, Ub = self.B, self.Ub
        wu = jnp.asarray(self.alpha_u.reshape(B, Ub), jnp.float32)
        if es_mask is None:
            wb64 = self.alpha_b
        else:
            m = np.asarray(es_mask, np.float64) > 0
            if not m.any():
                return stacked
            if m.all():
                wb64 = self.alpha_b                          # exact path
            else:
                raw = np.where(m, self.alpha_b, 0.0)
                wb64 = raw / raw.sum()
        wb = jnp.asarray(wb64, jnp.float32)

        def agg(x):
            xr = x.reshape((B, Ub) + x.shape[1:])
            wexp = wu.reshape((B, Ub) + (1,) * (x.ndim - 1))
            es = (xr * wexp).sum(axis=1)                     # (B, ...)
            g = (es * wb.reshape((B,) + (1,) * (es.ndim - 1))).sum(axis=0)
            return jnp.broadcast_to(g[None], x.shape)

        return jax.tree.map(agg, stacked)

    # --------------------------------------------------------------- run --
    def _ensure_initialized(self):
        """Materialize the stacked client replicas on first use (init is
        deterministic in ``self.key``, so a restored checkpoint simply
        overwrites this)."""
        if self._stacked is None:
            params0 = cnn.init(self.key, self.cfg)
            self._stacked = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (self.U,) + x.shape),
                params0)

    def _client_keys(self):
        self._ckey, sub = jax.random.split(self._ckey)
        return jax.random.split(sub, self.U)

    def run(self, rounds: int | None = None, log_every: int = 5) -> FedSimResult:
        """Train up to ``rounds`` TOTAL global rounds.

        The round count is ABSOLUTE, not incremental: a fresh simulator
        runs them all, while one resumed from a checkpoint
        (``load_state_dict``/``restore``) — or simply run() a second time —
        continues from its round cursor.  Kill at round k, restore, and
        ``run(rounds)`` replays the uninterrupted trajectory bit-for-bit
        (every RNG stream, the staleness bank, and the simulated clock are
        checkpoint state).
        """
        h, t = self.h, self.t
        rounds = rounds if rounds is not None else h.global_rounds
        self._ensure_initialized()
        stacked = self._stacked
        res = FedSimResult()
        res.total_sim_time_s = self._sim_time
        xt, yt, wt = self._stacked_test()

        sched = self.scheduler
        client_keys = self._client_keys
        tel = self.telemetry
        tel_on = tel is not None and getattr(tel, "enabled", False)

        for t2 in range(self._round, rounds):
            t_wall = _time.perf_counter() if tel_on else 0.0
            round_losses = []
            es_any = np.zeros(self.B, bool)
            parts = []
            for t1 in range(h.kappa1):                       # edge rounds
                prev = stacked if sched is not None else None
                cohort = (self._begin_cohort_round()
                          if self.population is not None else None)
                for _ in range(h.kappa0):                    # local epochs
                    for _ in range(self.batches_per_epoch):  # minibatches
                        x, y = self._sample_minibatches(t.batch_size)
                        if self.codecs is None:
                            stacked, loss = self._client_step(stacked, x, y)
                        else:
                            stacked, loss = self._client_step(
                                stacked, x, y, client_keys())
                        round_losses.append(float(loss.mean()))
                if self._offload_step is not None:
                    # the client block crosses the uplink lossily before
                    # every edge aggregation (Phi_off's numerics side)
                    stacked = self._offload_step(stacked, client_keys())
                if sched is None:
                    stacked = self._edge_aggregate(stacked)  # Eq. 14-15
                else:                                        # masked Eq. 14-15
                    rep = sched.step(self._edge_round)
                    self._edge_round += 1
                    if cohort is not None:
                        # population-wide (N,) report -> this round's slots
                        from repro.wireless.population import cohort_report
                        rep = cohort_report(rep, cohort)
                    live = rep.mask > 0
                    if rep.es_map is not None:
                        # failover round: participation counts for the ES
                        # the client actually worked with
                        es_any |= np.bincount(rep.es_map[live],
                                              minlength=self.B) > 0
                    else:
                        es_any |= live.reshape(self.B, self.Ub).any(1)
                    parts.append(rep.num_participants)
                    self._sim_time += rep.round_time_s
                    res.total_sim_time_s = self._sim_time
                    row = {"edge_round": rep.round_idx,
                           "participants": rep.num_participants,
                           "scheduled": int(rep.scheduled.sum()),
                           "round_time_s": rep.round_time_s,
                           "bits": rep.bits_tx}
                    if rep.mean_cut is not None:
                        row["mean_cut"] = rep.mean_cut
                    if rep.compute_s is not None and rep.compute_s.any():
                        row["compute_s_max"] = float(rep.compute_s.max())
                        row["compute_j"] = float(rep.compute_j.sum())
                    if rep.crashed is not None:
                        row["crashed"] = int(rep.crashed.sum())
                        row["failed"] = int(rep.failed.sum())
                        row["retx_bits"] = rep.retx_bits
                        row["retx_j"] = rep.retx_j
                    if rep.es_down is not None:
                        row["es_down"] = int(rep.es_down.sum())
                    # staleness-weighted async fold (lambda > 0 only):
                    # deliveries read the snapshots banked in EARLIER rounds
                    # (delivered requires idle, banked requires scheduled,
                    # so the two sets never overlap within a round), then
                    # this round's new stragglers are snapshotted BEFORE the
                    # aggregation overwrites their local models
                    stale_tree = stale_w = None
                    if rep.stale_delivered is not None:
                        deliv = rep.stale_delivered > 0
                        if deliv.any() and self._stale_params is not None:
                            lam = self.staleness_lambda
                            stale_w = np.where(
                                deliv, lam ** rep.stale_delivered, 0.0)
                            stale_tree = self._stale_params
                            if tel_on:
                                # pre-normalization aggregation mass the
                                # banked (discounted) updates contribute
                                # next to the live participants'
                                tel.metrics.counter(
                                    "fedsim.agg_mass_stale").inc(
                                    float(stale_w.sum()))
                        if tel_on:
                            tel.metrics.counter("fedsim.agg_mass_live").inc(
                                float(np.asarray(rep.mask).sum()))
                            if rep.es_map is not None:
                                es_any |= np.bincount(rep.es_map[deliv],
                                                      minlength=self.B) > 0
                            else:
                                es_any |= deliv.reshape(self.B,
                                                        self.Ub).any(1)
                        row["stale_banked"] = int(rep.stale_banked.sum())
                        row["stale_delivered"] = int(deliv.sum())
                        row["stale_dropped"] = int(rep.stale_dropped.sum())
                    res.network.append(row)
                    if (rep.stale_banked is not None
                            and rep.stale_banked.any()):
                        sel = jnp.asarray(rep.stale_banked)
                        if self._stale_params is None:
                            self._stale_params = jax.tree.map(
                                lambda x: x + 0, stacked)      # materialize
                        else:
                            self._stale_params = jax.tree.map(
                                lambda b, x: jnp.where(
                                    sel.reshape((self.U,)
                                                + (1,) * (x.ndim - 1)),
                                    x, b),
                                self._stale_params, stacked)
                    if rep.es_map is not None:
                        # reassoc failover: aggregate by the EFFECTIVE ES
                        agged = self._edge_aggregate_mapped(
                            stacked, rep.mask, prev, rep.es_map,
                            stale=stale_tree, stale_w=stale_w)
                    else:
                        agged = self._edge_aggregate(stacked, mask=rep.mask,
                                                     fallback=prev,
                                                     stale=stale_tree,
                                                     stale_w=stale_w)
                    if (rep.down_failed is not None
                            and rep.down_failed.any()):
                        # lost downlink: the ES has this client's update
                        # (it aggregated) but the client never received the
                        # refreshed edge model — it keeps its own
                        keep = jnp.asarray(rep.down_failed)
                        agged = jax.tree.map(
                            lambda new, old: jnp.where(
                                keep.reshape((self.U,)
                                             + (1,) * (new.ndim - 1)),
                                old, new),
                            agged, stacked)
                    stacked = agged
                    if cohort is not None:
                        # registry bookkeeping: participants now hold the
                        # edge model refreshed at this round
                        self.population.head_slot[cohort[live]] = \
                            rep.round_idx
            if sched is None:
                stacked = self._global_aggregate(stacked)    # Eq. 16
            else:                                            # masked Eq. 16
                stacked = self._global_aggregate(stacked, es_mask=es_any)
            self._stacked = stacked
            self._round = t2 + 1

            if tel_on:
                tel.metrics.histogram("fedsim.round_wall_s").observe(
                    _time.perf_counter() - t_wall)
                tel.metrics.counter("fedsim.rounds").inc()
            if (t2 + 1) % log_every == 0 or t2 == rounds - 1:
                gl, ga = self._weighted_eval(stacked, xt, yt, wt)
                row = {"round": t2 + 1,
                       "train_loss": float(np.mean(round_losses)),
                       "test_loss": gl, "test_acc": ga}
                if sched is not None:
                    row["mean_participants"] = float(np.mean(parts))
                    row["sim_time_s"] = res.total_sim_time_s
                res.history.append(row)
                if tel_on:
                    tel.metrics.gauge("fedsim.train_loss").set(
                        row["train_loss"])
                    tel.metrics.gauge("fedsim.test_loss").set(gl)
                    tel.metrics.gauge("fedsim.test_acc").set(ga)
                    tel.flush(step=t2 + 1, force=True)
        res.global_params = jax.tree.map(lambda x: x[0], stacked)
        res.per_client_global = self._per_client_eval(stacked, xt, yt, wt)
        return res

    # ----------------------------------------------------- checkpointing --
    def state_dict(self) -> dict:
        """Everything the trajectory depends on, as one npz-able pytree:
        the stacked client replicas, the round cursors, the simulated
        clock, the data-sampling RNG, the codec PRNG chain, the scheduler's
        state (budgets, stale bank, channel/thinning/fault streams), and
        the banked stale snapshots.  ``load_state_dict`` of this dict into
        a freshly constructed simulator of the same config resumes the run
        bit-identically (the acceptance test kills a run at round k and
        diffs final params)."""
        from repro.checkpoint.ckpt import rng_state_array
        self._ensure_initialized()
        out = {"round": np.int64(self._round),
               "edge_round": np.int64(self._edge_round),
               "sim_time_s": np.float64(self._sim_time),
               "rng": rng_state_array(self.rng),
               "params": self._stacked}
        if self._ckey is not None:
            out["codec_key"] = np.asarray(self._ckey)
        if self.scheduler is not None:
            out["scheduler"] = self.scheduler.state_dict()
        if self.staleness_lambda > 0.0:
            # fixed structure whether or not a bank exists yet, so the
            # checkpoint tree shape is round-independent (npz restore
            # rebuilds into the target structure)
            has = self._stale_params is not None
            out["stale_has"] = np.int64(has)
            out["stale_params"] = (self._stale_params if has else
                                   jax.tree.map(jnp.zeros_like,
                                                self._stacked))
        return out

    def load_state_dict(self, state: dict) -> None:
        from repro.checkpoint.ckpt import restore_rng_state
        self._round = int(state["round"])
        self._edge_round = int(state["edge_round"])
        self._sim_time = float(state["sim_time_s"])
        restore_rng_state(self.rng, state["rng"])
        self._stacked = jax.tree.map(jnp.asarray, state["params"])
        if self._ckey is not None:
            self._ckey = jnp.asarray(state["codec_key"])
        if self.scheduler is not None:
            self.scheduler.load_state_dict(state["scheduler"])
        if self.staleness_lambda > 0.0:
            self._stale_params = (
                jax.tree.map(jnp.asarray, state["stale_params"])
                if int(state["stale_has"]) else None)

    def save(self, directory: str, step: int | None = None) -> str:
        """Atomic checkpoint of :meth:`state_dict` (step defaults to the
        global-round cursor)."""
        from repro.checkpoint.ckpt import save_checkpoint
        return save_checkpoint(directory,
                               self._round if step is None else step,
                               self.state_dict())

    def restore(self, directory: str, step: int | None = None) -> int | None:
        """Load the latest (or ``step``'s) checkpoint from ``directory``
        into this simulator; returns the restored step, or None when the
        directory holds no checkpoint (fresh start)."""
        from repro.checkpoint.ckpt import latest_step, load_checkpoint
        if step is None:
            step = latest_step(directory)
        if step is None:
            return None
        state = load_checkpoint(directory, step, self.state_dict())
        self.load_state_dict(state)
        return step

    def _weighted_eval(self, stacked, xt, yt, wt):
        per = self._per_client_eval(stacked, xt, yt, wt)
        return float(np.mean(per["loss"])), float(np.mean(per["acc"]))

    def _per_client_eval(self, stacked, xt, yt, wt):
        """Per-client masked accuracy/loss of the stacked models."""
        def one(params, x, y, w):
            logits = cnn.apply(params, x)
            logp = jax.nn.log_softmax(logits)
            nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
            acc = (logits.argmax(-1) == y).astype(jnp.float32)
            denom = jnp.maximum(w.sum(), 1.0)
            return (nll * w).sum() / denom, (acc * w).sum() / denom

        loss, acc = jax.jit(jax.vmap(one))(stacked, xt, yt, wt)
        return {"loss": np.asarray(loss), "acc": np.asarray(acc)}

    # ----------------------------------------------------- personalize ----
    def personalize(self, global_params, steps: int | None = None):
        """Eq. (18): per-client head-only fine-tuning of w*.

        Fine-tuning minibatches come from a DEDICATED rng stream seeded at
        ``seed + 3`` (disjoint from the training stream ``self.rng`` and
        from the wireless side's ``seed``/``+1``/``+2`` streams), so the
        personalized heads depend only on (seed, global_params) — NOT on
        how many training rounds advanced ``self.rng`` before the call.
        Sampling from ``self.rng`` here made ``personalize(w)`` return
        different heads for the same ``w`` depending on the preceding
        ``run()`` length — an irreproducibility bug, regression-pinned in
        tests/test_pipeline.py."""
        steps = steps or self.t.finetune_steps
        rng = np.random.default_rng(self.seed + 3)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (self.U,) + x.shape),
            global_params)
        for _ in range(steps):
            x, y = self._sample_minibatches(self.t.batch_size, rng=rng)
            stacked, _ = self._head_ft_step(stacked, x, y)
        xt, yt, wt = self._stacked_test()
        per = self._per_client_eval(stacked, xt, yt, wt)
        heads = jax.tree.map(lambda x: x, stacked["fc2"])
        return heads, per


# ---------------------------------------------------------------------------
def centralized_sgd(cfg: CNNConfig, data: FederatedImageData,
                    tcfg: TrainConfig, epochs: int, seed: int = 0):
    """The paper's Genie baseline: SGD over the pooled dataset."""
    from repro.data.loader import batch_iterator

    ds = data.dataset
    params = cnn.init(jax.random.PRNGKey(seed), cfg)

    @jax.jit
    def step(params, x, y):
        loss, g = jax.value_and_grad(cnn.loss_fn)(params, x, y)
        return jax.tree.map(lambda p, gg: p - tcfg.learning_rate * gg,
                            params, g), loss

    it = batch_iterator(ds.x_train, ds.y_train, tcfg.batch_size,
                        seed=seed, epochs=epochs)
    for x, y in it:
        params, _ = step(params, jnp.asarray(x), jnp.asarray(y))

    logits = cnn.apply(params, jnp.asarray(ds.x_test))
    acc = float((np.asarray(logits.argmax(-1)) == ds.y_test).mean())
    logp = jax.nn.log_softmax(logits)
    loss = float(-np.take_along_axis(np.asarray(logp), ds.y_test[:, None],
                                     axis=1).mean())
    return params, {"acc": acc, "loss": loss}
