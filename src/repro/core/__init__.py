"""The paper's primary contribution: PHSFL — model splitting, hierarchical
multi-timescale aggregation, frozen-head training, and head personalization —
plus the faithful small-scale simulator, comm accounting, and Theorem-1
bound calculator."""

from repro.core.split import (SplitSpec, split_spec_for, part_masks,
                              trainable_mask, count_parts,
                              GLOBAL_TRAIN, HSFL_TRAIN, PERSONALIZE)
from repro.core.hierarchy import (edge_aggregate, global_aggregate,
                                  masked_edge_aggregate,
                                  masked_global_aggregate,
                                  edge_aggregate_mesh, global_aggregate_mesh,
                                  masked_psum_weighted,
                                  sgd_step_index, normalized_weights)
from repro.core.phsfl import (make_phsfl_round, make_host_round,
                              make_shared_server_step,
                              build_optimizer, abstract_params,
                              init_stacked_params, init_shared_server_params,
                              PHSFLRound, SharedServerStep)
from repro.core.personalize import (personalize_head_bank, personalized_eval,
                                    merge_head, extract_head, head_loss)
from repro.core.fedsim import FedSim, centralized_sgd, split_grad, monolithic_grad
from repro.core.comm import (CommModel, comm_for_cnn, comm_for_lm,
                             comm_table_for_cnn, comm_table_for_lm)
from repro.core.theory import BoundInputs, bound_terms, lr_limit, uniform_weights

__all__ = [
    "SplitSpec", "split_spec_for", "part_masks", "trainable_mask",
    "count_parts", "GLOBAL_TRAIN", "HSFL_TRAIN", "PERSONALIZE",
    "edge_aggregate", "global_aggregate", "masked_edge_aggregate",
    "masked_global_aggregate", "edge_aggregate_mesh",
    "global_aggregate_mesh", "masked_psum_weighted",
    "sgd_step_index", "normalized_weights",
    "make_phsfl_round", "make_host_round",
    "make_shared_server_step", "build_optimizer",
    "abstract_params", "init_stacked_params", "init_shared_server_params",
    "PHSFLRound", "SharedServerStep",
    "personalize_head_bank", "personalized_eval", "merge_head",
    "extract_head", "head_loss",
    "FedSim", "centralized_sgd", "split_grad", "monolithic_grad",
    "CommModel", "comm_for_cnn", "comm_for_lm",
    "comm_table_for_cnn", "comm_table_for_lm",
    "BoundInputs", "bound_terms", "lr_limit", "uniform_weights",
]
