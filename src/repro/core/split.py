"""Model splitting (the SL part of PHSFL, paper Sec. III-A Steps 2.1–2.2).

The model parameter pytree is partitioned into three parts:

    client  w_{b,0}    — embedding + first n_client_layers blocks (trained on
                         the client device)
    body    w_{b,1,bd} — remaining blocks + final norm (trained on the ES)
    head    w_{b,1,hd} — the output classifier (randomly initialized and
                         FROZEN during global training, Eq. 12; fine-tuned
                         per client for personalization, Eq. 18)

On TPU the split is a parameter partition + masking (the lowered graph is
identical — this is exactly the paper's Remark 2: the cut-layer choice does
not change learning dynamics).  The faithful activation-exchange dataflow is
exercised by core/fedsim.py on the paper's CNN.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

import jax

from repro.configs.base import ModelConfig
from repro.configs.phsfl_cnn import CNNConfig
from repro.utils.tree import map_with_path

# training phases
GLOBAL_TRAIN = "global_train"      # PHSFL: everything but the head trains
HSFL_TRAIN = "hsfl_train"          # baseline: everything trains
PERSONALIZE = "personalize"        # only the head trains (Eq. 18)


@dataclass(frozen=True)
class SplitSpec:
    client_patterns: tuple[str, ...]
    head_patterns: tuple[str, ...]

    def part_of(self, path: str) -> str:
        if any(re.search(p, path) for p in self.head_patterns):
            return "head"
        if any(re.search(p, path) for p in self.client_patterns):
            return "client"
        return "body"


def split_spec_for(cfg, cut=None) -> SplitSpec:
    """Build the SplitSpec for a model config.

    ``cut`` selects which candidate boundary the client/body split falls on:
    a cut NAME from ``cnn.CUT_CANDIDATES`` for the CNN, or an int overriding
    ``cfg.n_client_layers`` for LMs.  ``None`` keeps the config's default.
    By the paper's Remark 2 the choice never changes learning dynamics —
    only the Remark-1 byte accounting (core/comm.py) and the wireless cut
    controller (repro.wireless.cutter) care.
    """
    if isinstance(cfg, CNNConfig):
        from repro.models import cnn
        keys = cnn.client_keys_for(cut if cut is not None else cnn.DEFAULT_CUT)
        return SplitSpec(
            client_patterns=tuple(f"^{k}(/|$)" for k in keys),
            head_patterns=tuple(f"^{k}(/|$)" for k in cnn.HEAD_KEYS),
        )
    assert isinstance(cfg, ModelConfig)
    n_client = cfg.n_client_layers if cut is None else int(cut)
    if cfg.encdec is not None:
        # client side = the modality frontend projection + token embedding;
        # the split is NOT depth-parameterized, so a depth cut that expects
        # to move the boundary must fail loudly rather than no-op
        if cut is not None and int(cut) != cfg.n_client_layers:
            raise ValueError(
                "encoder-decoder archs have a frontend-based split; "
                "cut-depth candidates are not supported")
        return SplitSpec(
            client_patterns=(r"^src_proj(/|$)", r"^embed(/|$)"),
            head_patterns=(rf"^{cfg.head_name}(/|$)",),
        )
    # decoder LMs: compute_stages guarantees the first n_client_layers are
    # unscanned blocks of stage0 ("lead"); they plus the embedding form w_0.
    from repro.models.transformer import compute_stages
    stages = compute_stages(cfg)
    client: list[str] = [r"^embed(/|$)"]
    if n_client and stages and stages[0].which == "lead":
        for j, lid in enumerate(stages[0].layer_ids):
            if lid < n_client:
                client.append(rf"^stage0/b{j}(/|$)")
    return SplitSpec(client_patterns=tuple(client),
                     head_patterns=(rf"^{cfg.head_name}(/|$)",))


def part_masks(params, spec: SplitSpec):
    """Boolean mask trees for each part; exactly one True per leaf."""
    def mk(part):
        return map_with_path(lambda path, _: spec.part_of(path) == part, params)

    return {"client": mk("client"), "body": mk("body"), "head": mk("head")}


def trainable_mask(params, spec: SplitSpec, phase: str):
    """What trains in each phase (True = trainable)."""
    if phase == GLOBAL_TRAIN:
        return map_with_path(lambda p, _: spec.part_of(p) != "head", params)
    if phase == HSFL_TRAIN:
        return jax.tree.map(lambda _: True, params)
    if phase == PERSONALIZE:
        return map_with_path(lambda p, _: spec.part_of(p) == "head", params)
    raise ValueError(phase)


def count_parts(params, spec: SplitSpec):
    """Parameter counts per part (Z_0, Z_bd, Z_hd of the paper)."""
    import numpy as np
    counts = {"client": 0, "body": 0, "head": 0}

    def visit(path, leaf):
        counts[spec.part_of(path)] += int(np.prod(leaf.shape))
        return leaf

    map_with_path(visit, params)
    return counts
