"""Communication-overhead accounting (paper Remark 1).

All quantities in BITS.  omega = floating-point mantissa-ish precision
parameter as in [4]; payload per float = (omega + 1) bits.

  Phi_local  = N_b * { 2[(N * Z_c)(omega+1)] + N * (ceil(log2 |D_u|) + 1) }
      per local round: N_b minibatches, each shipping o_fp up, o_bp down
      (the 2x), plus the sampled indices.
  Phi_off    = Z_0 * (omega + 1)
      client-side model offload (one direction).
  Phi_PHSFL <= kappa0 * Phi_local + 2 * Phi_off       (Eq. 17)
  Phi_HFL    = 2 * Z * (omega + 1)
      classic HFL ships the whole model down + up.

PHSFL wins iff Phi_HFL > Phi_PHSFL, typically because Z >> Z_0 + Z_c.

Compression (repro.compress): each of the three wire payloads — cut-layer
activations up (act_codec), cut-layer gradients down (grad_codec), and the
client-block offload (off_codec) — may carry a Codec whose
``payload_bits(n_elements)`` replaces the hardcoded ``(omega+1)`` bits per
element.  ``None`` keeps the paper's full-precision accounting exactly.

The datacenter analogue (measured, not modeled) is the collective-bytes
delta between the paper-faithful round (full-model all-reduce over 'data')
and the shared-server round (client-block-only all-reduce): see
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:
    from repro.compress import Codec, LinkCodecs


@dataclass(frozen=True)
class CommModel:
    omega: int = 32              # bits per float payload (omega+1 with sign)
    batch_size: int = 32         # N
    batches_per_epoch: int = 5   # minibatches per local epoch
    cut_size: int = 0            # Z_c: cut-layer activation elements per sample
    client_params: int = 0       # Z_0
    total_params: int = 0        # Z
    dataset_size: int = 1        # |D_u,ft|
    client_flops_per_sample: float = 0.0  # training (fwd+bwd) FLOPs the
    #                              client block burns per sample at this cut
    #                              (the device model's compute twin of Z_c)
    # per-payload codecs (None = the paper's (omega+1)-bit accounting)
    act_codec: Optional["Codec"] = None    # o_fp, client -> ES
    grad_codec: Optional["Codec"] = None   # o_bp, ES -> client
    off_codec: Optional["Codec"] = None    # client-block offload

    def _payload(self, codec, n_elements: int) -> int:
        # None and a width-deferring IdentityCodec both mean: this model's
        # own (omega+1) bits per element — exact for any omega
        if codec is None or getattr(codec, "bits_per_element", 0) is None:
            return n_elements * (self.omega + 1)
        return codec.payload_bits(n_elements)

    def phi_activation_bits(self) -> int:
        """One direction of one minibatch's cut-layer tensor at FULL
        precision (the codec-free Remark-1 reference)."""
        return self.batch_size * self.cut_size * (self.omega + 1)

    def phi_activation_up_bits(self) -> int:
        """One minibatch's o_fp on the wire, through act_codec."""
        return self._payload(self.act_codec, self.batch_size * self.cut_size)

    def phi_grad_down_bits(self) -> int:
        """One minibatch's o_bp on the wire, through grad_codec."""
        return self._payload(self.grad_codec, self.batch_size * self.cut_size)

    def phi_indices_bits(self) -> int:
        return self.batch_size * (math.ceil(math.log2(max(self.dataset_size, 2))) + 1)

    def phi_local_bits(self) -> int:
        per_batch = (self.phi_activation_up_bits()
                     + self.phi_grad_down_bits() + self.phi_indices_bits())
        return self.batches_per_epoch * per_batch

    def phi_off_bits(self) -> int:
        return self._payload(self.off_codec, self.client_params)

    def phi_phsfl_bits(self, kappa0: int) -> int:
        """Eq. (17) upper bound for one edge aggregation round."""
        return kappa0 * self.phi_local_bits() + 2 * self.phi_off_bits()

    def phi_hfl_bits(self) -> int:
        return 2 * self.total_params * (self.omega + 1)

    def phsfl_wins(self, kappa0: int) -> bool:
        return self.phi_hfl_bits() > self.phi_phsfl_bits(kappa0)


def _codec_fields(codecs) -> dict:
    if codecs is None:
        return {}
    return dict(act_codec=codecs.activations, grad_codec=codecs.gradients,
                off_codec=codecs.offload)


def comm_for_cnn(cfg, dataset_size: int, *, omega: int = 32,
                 batch_size: int = 32, batches_per_epoch: int = 5,
                 cut: str | None = None,
                 codecs: Optional["LinkCodecs"] = None) -> CommModel:
    """Instantiate the comm model from the paper's CNN split at ``cut``."""
    import jax

    from repro.core.split import count_parts, split_spec_for
    from repro.models import cnn as cnn_mod

    cut = cut if cut is not None else cnn_mod.DEFAULT_CUT
    params = jax.eval_shape(
        lambda k: cnn_mod.init(k, cfg), jax.random.PRNGKey(0))
    counts = count_parts(params, split_spec_for(cfg, cut))
    z_c = cnn_mod.cut_activation_size(cfg, 1, cut)
    from repro.utils.flops import training_flops
    flops = training_flops(cnn_mod.client_block_flops(cfg, 1, cut))
    return CommModel(omega=omega, batch_size=batch_size,
                     batches_per_epoch=batches_per_epoch, cut_size=z_c,
                     client_params=counts["client"],
                     total_params=sum(counts.values()),
                     dataset_size=dataset_size,
                     client_flops_per_sample=flops, **_codec_fields(codecs))


def comm_for_lm(cfg, seq_len: int, dataset_size: int, *, omega: int = 16,
                batch_size: int = 8, batches_per_epoch: int = 1,
                cut: int | None = None,
                codecs: Optional["LinkCodecs"] = None) -> CommModel:
    """Comm model for an LM architecture (cut after ``cut`` blocks, default
    ``cfg.n_client_layers``).  The config is rebuilt at the requested cut so
    the lead (unscanned) stage always covers the client block and the
    Z_0 count is exact for any candidate depth."""
    import dataclasses

    import jax

    from repro.core.split import count_parts, split_spec_for
    from repro.models import build_model

    if cut is not None and cut != cfg.n_client_layers:
        if cfg.encdec is not None:
            # the encoder-decoder client block is the modality frontend
            # (src_proj + embed), not a depth prefix — every depth candidate
            # would price the SAME (Z_0, Z_c) cell and the cut controller
            # would "adapt" over indistinguishable candidates
            raise ValueError(
                "encoder-decoder archs have a frontend-based split; "
                "cut-depth candidates are not supported")
        cfg = dataclasses.replace(cfg, n_client_layers=int(cut))
    model = build_model(cfg)
    params = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    counts = count_parts(params, split_spec_for(cfg))
    z_c = seq_len * cfg.d_model            # cut activations per sample
    # the standard 6ND training estimate over the client block's params,
    # per sample = seq_len tokens (utils.flops.dense_model_flops)
    from repro.utils.flops import dense_model_flops
    flops = dense_model_flops(counts["client"], seq_len)
    return CommModel(omega=omega, batch_size=batch_size,
                     batches_per_epoch=batches_per_epoch, cut_size=z_c,
                     client_params=counts["client"],
                     total_params=sum(counts.values()),
                     dataset_size=dataset_size,
                     client_flops_per_sample=flops, **_codec_fields(codecs))


def _cross_codecs(cuts, codecs, one_cell):
    """Build a per-cut table, or a (cut, codec_name)-keyed cut x codec table
    when ``codecs`` is a dict of named LinkCodecs (cut-major order, so the
    CutController's deepest-feasible search walks cuts first)."""
    if isinstance(codecs, dict):
        return {(c, name): one_cell(c, lc)
                for c in cuts for name, lc in codecs.items()}
    return {c: one_cell(c, codecs) for c in cuts}


def comm_table_for_cnn(cfg, dataset_size: int, *,
                       cuts: tuple[str, ...] | None = None,
                       codecs=None, **kw) -> dict:
    """Per-cut ``(Z_0, Z_c)`` table over the CNN's candidate cuts, shallow to
    deep — the byte side of the ASFL-style cut-selection knob.  ``codecs``
    is a single :class:`repro.compress.LinkCodecs` applied to every cut, or
    a dict of named LinkCodecs producing the cut x codec bit table keyed by
    ``(cut, codec_name)``.  An empty ``cuts`` tuple means all candidates."""
    from repro.models import cnn as cnn_mod

    cuts = cuts if cuts else cnn_mod.CUT_CANDIDATES
    return _cross_codecs(cuts, codecs,
                         lambda c, lc: comm_for_cnn(cfg, dataset_size, cut=c,
                                                    codecs=lc, **kw))


def comm_table_for_lm(cfg, seq_len: int, dataset_size: int, *,
                      cuts: tuple[int, ...], codecs=None, **kw) -> dict:
    """Per-cut table over candidate ``n_client_layers`` depths for an LM
    (same ``codecs`` semantics as :func:`comm_table_for_cnn`).  The LM has
    no default candidate list, so an empty ``cuts`` tuple is an error."""
    if not cuts:
        raise ValueError("comm_table_for_lm needs at least one candidate "
                         "client depth in cuts=")
    return _cross_codecs(tuple(int(c) for c in cuts), codecs,
                         lambda c, lc: comm_for_lm(cfg, seq_len, dataset_size,
                                                   cut=c, codecs=lc, **kw))
