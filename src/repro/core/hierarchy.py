"""Hierarchical aggregation (paper Sec. II-B, Eqs. 4–7 and 14–16).

Two renderings of the same math:
  - host-side (fedsim): explicit weighted sums over lists of client trees;
  - mesh-side (phsfl):  weighted ``lax.psum`` over the manual 'data' (=ES's
    clients) and 'pod' (=CS's edge servers) mesh axes.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import HierarchyConfig
from repro.utils.tree import tree_weighted_sum


# --------------------------------------------------------- bookkeeping -----
def sgd_step_index(t2: int, t1: int, t0: int, h: HierarchyConfig) -> int:
    """Eq. (1): t = t2*k1*k0 + t1*k0 + t0."""
    return t2 * h.kappa1 * h.kappa0 + t1 * h.kappa0 + t0


def normalized_weights(sizes) -> np.ndarray:
    s = np.asarray(sizes, dtype=np.float64)
    assert (s >= 0).all() and s.sum() > 0
    return s / s.sum()


# ------------------------------------------------------------ host side ----
def edge_aggregate(client_trees: list, alpha_u) -> object:
    """Eq. (4)/(14-15): w_b = sum_u alpha_u w_u  (alpha_u on the simplex)."""
    w = np.asarray(alpha_u, dtype=np.float64)
    assert abs(w.sum() - 1.0) < 1e-6, "alpha_u must sum to 1 within an ES"
    return tree_weighted_sum(client_trees, list(w))


def global_aggregate(edge_trees: list, alpha_b) -> object:
    """Eq. (6)/(16): w = sum_b alpha_b w_b."""
    w = np.asarray(alpha_b, dtype=np.float64)
    assert abs(w.sum() - 1.0) < 1e-6, "alpha_b must sum to 1"
    return tree_weighted_sum(edge_trees, list(w))


# ------------------------------------------------------------ mesh side ----
def psum_weighted(tree, weight, axis_name: str, agg_dtype=jnp.float32):
    """sum_i weight_i * tree_i over a manual mesh axis.

    ``weight`` is this shard's scalar aggregation weight (alpha_u or alpha_b,
    already normalized over the axis).  Inside shard_map.  The reduction
    defaults to f32 — standard practice for parameter averaging (and bf16
    all-reduce also hits an XLA-CPU compiler bug); agg_dtype=bf16 is the
    §Perf wire-compression knob (halves collective bytes, adds one rounding
    step per aggregation).
    """
    def agg(t):
        acc = jax.lax.psum(t.astype(agg_dtype) * weight.astype(agg_dtype),
                           axis_name)
        return acc.astype(t.dtype)

    return jax.tree.map(agg, tree)


def edge_aggregate_mesh(tree, alpha_u_shard, agg_dtype=jnp.float32):
    """Weighted aggregation over the 'data' axis (clients within an ES)."""
    return psum_weighted(tree, alpha_u_shard, "data", agg_dtype)


def global_aggregate_mesh(tree, alpha_b_shard, agg_dtype=jnp.float32):
    """Weighted aggregation over the 'pod' axis (edge servers at the CS)."""
    return psum_weighted(tree, alpha_b_shard, "pod", agg_dtype)
