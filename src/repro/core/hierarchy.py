"""Hierarchical aggregation (paper Sec. II-B, Eqs. 4–7 and 14–16).

Two renderings of the same math:
  - host-side (fedsim): explicit weighted sums over lists of client trees;
  - mesh-side (phsfl):  weighted ``lax.psum`` over the manual 'data' (=ES's
    clients) and 'pod' (=CS's edge servers) mesh axes.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import HierarchyConfig
from repro.utils.tree import tree_weighted_sum


# --------------------------------------------------------- bookkeeping -----
def sgd_step_index(t2: int, t1: int, t0: int, h: HierarchyConfig) -> int:
    """Eq. (1): t = t2*k1*k0 + t1*k0 + t0."""
    return t2 * h.kappa1 * h.kappa0 + t1 * h.kappa0 + t0


def normalized_weights(sizes) -> np.ndarray:
    s = np.asarray(sizes, dtype=np.float64)
    assert (s >= 0).all() and s.sum() > 0
    return s / s.sum()


def es_assignment(num_clients: int, clients_per_es: int) -> np.ndarray:
    """The default client -> edge-server map: contiguous round-robin blocks
    (client u belongs to ES ``u // clients_per_es``).

    The SINGLE source of truth for the static layout — FedSim, the train
    launcher, and ``repro.wireless.population.Population`` all derive it
    here (they used to each hand-roll the same ``arange // Ub``, which is
    how a refactor desynchronizes the scheduler's contention groups from
    the aggregation hierarchy).  Location-clustered alternatives live on
    ``Population`` (``assignment="kmeans"``)."""
    return np.arange(int(num_clients)) // int(clients_per_es)


# ------------------------------------------------------------ host side ----
def edge_aggregate(client_trees: list, alpha_u) -> object:
    """Eq. (4)/(14-15): w_b = sum_u alpha_u w_u  (alpha_u on the simplex)."""
    w = np.asarray(alpha_u, dtype=np.float64)
    assert abs(w.sum() - 1.0) < 1e-6, "alpha_u must sum to 1 within an ES"
    return tree_weighted_sum(client_trees, list(w))


def global_aggregate(edge_trees: list, alpha_b) -> object:
    """Eq. (6)/(16): w = sum_b alpha_b w_b."""
    w = np.asarray(alpha_b, dtype=np.float64)
    assert abs(w.sum() - 1.0) < 1e-6, "alpha_b must sum to 1"
    return tree_weighted_sum(edge_trees, list(w))


# ------------------------------------------- participation-masked (host) ----
def _masked_weighted_sum(trees: list, weights, mask, fallback):
    """Weighted sum over the sub-list where mask > 0, weights renormalized to
    the simplex over participants.  A full mask takes the exact unmasked code
    path (bit-for-bit identical to ``tree_weighted_sum(trees, weights)``);
    an empty mask returns ``fallback`` (the previous model) or raises."""
    m = np.asarray(mask, dtype=np.float64)
    w = np.asarray(weights, dtype=np.float64)
    assert m.shape == w.shape == (len(trees),)
    if (m > 0).all():
        return tree_weighted_sum(trees, list(w))
    keep = np.flatnonzero(m > 0)
    if len(keep) == 0:
        if fallback is None:
            raise ValueError("no participants and no fallback model given")
        return fallback
    sub_w = w[keep]
    return tree_weighted_sum([trees[i] for i in keep],
                             list(sub_w / sub_w.sum()))


def masked_edge_aggregate(client_trees: list, alpha_u, mask,
                          fallback=None) -> object:
    """Eqs. (14-15) over the participating clients of one ES: the straggler
    mask zeroes dropped clients and the alpha_u weights renormalize over the
    survivors; with no survivors the ES keeps ``fallback`` (its previous
    edge model)."""
    return _masked_weighted_sum(client_trees, alpha_u, mask, fallback)


def masked_global_aggregate(edge_trees: list, alpha_b, mask,
                            fallback=None) -> object:
    """Eq. (16) over the ESs that had at least one participant this global
    round; alpha_b renormalizes over them."""
    return _masked_weighted_sum(edge_trees, alpha_b, mask, fallback)


# ------------------------------------------------------------ mesh side ----
def psum_weighted(tree, weight, axis_name: str, agg_dtype=jnp.float32):
    """sum_i weight_i * tree_i over a manual mesh axis.

    ``weight`` is this shard's scalar aggregation weight (alpha_u or alpha_b,
    already normalized over the axis).  Inside shard_map.  The reduction
    defaults to f32 — standard practice for parameter averaging (and bf16
    all-reduce also hits an XLA-CPU compiler bug); agg_dtype=bf16 is the
    §Perf wire-compression knob (halves collective bytes, adds one rounding
    step per aggregation).
    """
    def agg(t):
        acc = jax.lax.psum(t.astype(agg_dtype) * weight.astype(agg_dtype),
                           axis_name)
        return acc.astype(t.dtype)

    return jax.tree.map(agg, tree)


def masked_psum_weighted(tree, weight, mask, fallback, axis_name: str,
                         agg_dtype=jnp.float32):
    """Participation-masked variant of :func:`psum_weighted` (inside
    shard_map).

    ``mask`` is this shard's 0/1 participation scalar.  Weights renormalize
    over the participating shards; with zero participants every shard keeps
    its ``fallback`` tree (the model from before this round's local steps).
    When ALL shards participate the divisor is exactly 1.0 — multiplying by a
    1.0 mask and dividing by 1.0 are exact, so the result is bit-identical
    to the unmasked ``psum_weighted`` path.
    """
    m = mask.astype(agg_dtype)
    w = weight.astype(agg_dtype) * m
    n_part = jax.lax.psum(m, axis_name)
    n_all = jax.lax.psum(jnp.ones((), agg_dtype), axis_name)
    total = jax.lax.psum(w, axis_name)
    denom = jnp.where(n_part >= n_all, jnp.asarray(1.0, agg_dtype),
                      jnp.where(total > 0, total, jnp.asarray(1.0, agg_dtype)))

    def agg(t, fb):
        acc = jax.lax.psum(t.astype(agg_dtype) * w, axis_name) / denom
        return jnp.where(n_part > 0, acc.astype(t.dtype), fb)

    return jax.tree.map(agg, tree, fallback)


def edge_aggregate_mesh(tree, alpha_u_shard, agg_dtype=jnp.float32):
    """Weighted aggregation over the 'data' axis (clients within an ES)."""
    return psum_weighted(tree, alpha_u_shard, "data", agg_dtype)


def global_aggregate_mesh(tree, alpha_b_shard, agg_dtype=jnp.float32):
    """Weighted aggregation over the 'pod' axis (edge servers at the CS)."""
    return psum_weighted(tree, alpha_b_shard, "pod", agg_dtype)
