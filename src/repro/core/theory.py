"""Theorem 1 convergence-bound calculator (paper Sec. IV / Appendix A).

Computes the right-hand side of Eq. (21) term by term so benchmarks can
report how each system knob (kappa0, kappa1, eta, weights) moves the bound,
and tests can check the claimed monotonicities.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class BoundInputs:
    eta: float               # learning rate
    beta: float              # smoothness
    sigma2: float            # gradient-noise variance bound sigma^2
    eps0_2: float            # client<->ES divergence bound epsilon_0^2
    eps1_2: float            # ES<->CS divergence bound epsilon_1^2
    kappa0: int
    kappa1: int
    T: int                   # total SGD steps
    f0_minus_fT: float       # E[f(w^0)] - E[f(w^T)]
    alpha_u: np.ndarray      # (B, U_b) within-ES weights (rows sum to 1)
    alpha_b: np.ndarray      # (B,) CS weights (sum to 1)


def lr_limit(beta: float, kappa0: int, kappa1: int) -> float:
    """Theorem 1 requires eta < 1 / (2*sqrt(5)*beta*kappa1*kappa0)."""
    return 1.0 / (2.0 * math.sqrt(5.0) * beta * kappa1 * kappa0)


def _weight_sums(alpha_u: np.ndarray, alpha_b: np.ndarray):
    """sum_b a_b sum_u a_u^2  and  sum_b a_b^2 sum_u a_u^2."""
    au2 = (alpha_u ** 2).sum(axis=1)                      # (B,)
    s_ab_au2 = float((alpha_b * au2).sum())
    s_ab2_au2 = float(((alpha_b ** 2) * au2).sum())
    return s_ab_au2, s_ab2_au2


def bound_terms(bi: BoundInputs) -> dict:
    """Each additive term of Eq. (21); 'total' is the bound."""
    eta, beta, k0, k1 = bi.eta, bi.beta, bi.kappa0, bi.kappa1
    s_ab_au2, s_ab2_au2 = _weight_sums(bi.alpha_u, bi.alpha_b)
    b2e2 = beta ** 2 * eta ** 2

    gamma0 = 4 * b2e2 * k0 ** 2 * (1 - s_ab_au2) \
        + 80 * (k1 ** 2) * (beta ** 4) * (eta ** 4) * (k0 ** 4)
    gamma1 = 4 * k1 * k0 * b2e2 * (s_ab_au2 - s_ab2_au2) \
        - 80 * (k1 ** 2) * (beta ** 4) * (eta ** 4) * (k0 ** 4) * s_ab_au2

    terms = {
        "optimality": 2 * bi.f0_minus_fT / (eta * bi.T),
        "sgd_variance": beta * eta * bi.sigma2 * s_ab2_au2,
        "gamma0_variance": gamma0 * bi.sigma2,
        "gamma1_variance": gamma1 * bi.sigma2,
        "eps0_divergence": 12 * b2e2 * (k0 ** 2) * bi.eps0_2
        + 240 * bi.eps0_2 * (k1 ** 2) * (beta ** 4) * (eta ** 4) * (k0 ** 4),
        "eps1_divergence": 20 * b2e2 * (k1 ** 2) * (k0 ** 2) * bi.eps1_2,
    }
    terms["total"] = float(sum(terms.values()))
    terms["eta_ok"] = bi.eta < lr_limit(beta, k0, k1)
    return terms


def uniform_weights(B: int, Ub: int):
    return (np.full((B, Ub), 1.0 / Ub), np.full((B,), 1.0 / B))
