"""Typed metrics registry: counters, gauges, histograms (stdlib-only).

Three instrument kinds, each a tiny mutable cell registered by name:

- :class:`Counter` — monotone accumulator (``inc``); bits moved, joules
  burned, participants, kernel calls.
- :class:`Gauge` — last-write-wins level (``set``); stale-bank depth,
  eval accuracy, aggregation weight mass.
- :class:`Histogram` — streaming summary of observations (``observe``):
  count/sum/min/max plus fixed-bound bucket counts; round wall times,
  per-kernel wall times.

The :class:`MetricsRegistry` is the single owner: ``counter(name)`` /
``gauge(name)`` / ``histogram(name)`` get-or-create, and re-registering a
name as a DIFFERENT kind raises (a silent kind change would corrupt every
downstream reader).  ``flush_jsonl`` appends one self-describing JSON line
per call (the schema tier-1 CI checks), and ``summary_table`` renders the
run-end plain-text table.

Everything here is host-side Python on plain floats — nothing touches jax,
and an unused registry costs one dict.
"""

from __future__ import annotations

import json
import math


class Counter:
    """Monotone accumulator.  ``inc`` by any non-negative amount."""

    __slots__ = ("name", "help", "value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(inc by {amount})")
        self.value += float(amount)

    def as_dict(self) -> dict:
        return {"value": self.value}


class Gauge:
    """Last-write-wins level.  ``set`` to any float."""

    __slots__ = ("name", "help", "value")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def as_dict(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Streaming summary: count/sum/min/max + fixed-bound bucket counts.

    ``buckets`` are the upper bounds of the counting buckets (an implicit
    +inf bucket closes the tail, Prometheus-style cumulative-free counts:
    ``bucket_counts[i]`` is the number of observations in
    ``(bounds[i-1], bounds[i]]``).
    """

    __slots__ = ("name", "help", "bounds", "bucket_counts", "count", "sum",
                 "min", "max")
    kind = "histogram"
    DEFAULT_BOUNDS = (0.001, 0.01, 0.1, 1.0, 10.0, 100.0)

    def __init__(self, name: str, help: str = "", buckets=None):
        self.name, self.help = name, help
        bounds = tuple(float(b) for b in (buckets or self.DEFAULT_BOUNDS))
        if list(bounds) != sorted(bounds):
            raise ValueError(f"histogram {name!r} bucket bounds must be "
                             f"sorted, got {bounds}")
        self.bounds = bounds
        self.bucket_counts = [0] * (len(bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def as_dict(self) -> dict:
        return {"count": self.count, "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "mean": self.mean if self.count else None,
                "bounds": list(self.bounds),
                "bucket_counts": list(self.bucket_counts)}


_KINDS = {c.kind: c for c in (Counter, Gauge, Histogram)}


class MetricsRegistry:
    """Get-or-create instrument store with JSONL flush + summary table."""

    def __init__(self):
        self._instruments: dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, help, **kw)
            self._instruments[name] = inst
            return inst
        if not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as {inst.kind}, "
                f"cannot re-register as {cls.kind}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=None) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def __contains__(self, name: str) -> bool:
        return name in self._instruments

    def __getitem__(self, name: str):
        return self._instruments[name]

    def names(self) -> list[str]:
        return sorted(self._instruments)

    def snapshot(self) -> dict:
        """All instruments as one JSON-safe {name: {kind, ...state}} dict
        (the JSONL record body; sorted for byte-stable output)."""
        return {name: {"kind": self._instruments[name].kind,
                       **self._instruments[name].as_dict()}
                for name in self.names()}

    def flush_jsonl(self, fh, *, step: int | None = None) -> dict:
        """Append one JSON line: ``{"step": ..., "metrics": snapshot}``.
        Returns the record (tests assert the schema on it)."""
        rec = {"step": step, "metrics": self.snapshot()}
        fh.write(json.dumps(rec, sort_keys=True) + "\n")
        return rec

    def summary_table(self) -> str:
        """Run-end plain-text table, one instrument per row."""
        rows = [("metric", "kind", "value")]
        for name in self.names():
            inst = self._instruments[name]
            if inst.kind == "histogram":
                val = (f"n={inst.count} mean={inst.mean:.6g} "
                       f"min={inst.min:.6g} max={inst.max:.6g}"
                       if inst.count else "n=0")
            else:
                val = f"{inst.value:.6g}"
            rows.append((name, inst.kind, val))
        widths = [max(len(r[i]) for r in rows) for i in range(3)]
        lines = []
        for i, r in enumerate(rows):
            lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths))
                         .rstrip())
            if i == 0:
                lines.append("  ".join("-" * w for w in widths))
        return "\n".join(lines)
