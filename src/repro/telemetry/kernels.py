"""Pallas-kernel call instrumentation: count, wall time, bytes, FLOP/s.

The four ``kernels/<name>/ops.py`` wrappers call :func:`kernel_probe` at
entry.  With no sink installed (the default) the probe is ``None`` and the
wrapper pays one module-global read — zero overhead, zero behavior change.
With a sink (a :class:`repro.telemetry.metrics.MetricsRegistry`, installed
by ``Telemetry(kernels=True)`` or :func:`set_kernel_sink`), each call
records under ``kernel.<name>.*``:

- ``calls`` / ``traced_calls`` — concrete executions vs jit-trace visits.
  A wrapper invoked under ``jax.jit`` runs at TRACE time with abstract
  values; there is no meaningful wall clock there, so traced visits are
  only counted (the compiled executable's kernel launches are invisible to
  Python — profile those with the roofline tools in ``launch.roofline``).
- ``flops`` / ``bytes`` — nominal work per concrete call, from the
  wrapper's own analytic estimate (the same arithmetic the roofline tables
  use), accumulated as counters.
- ``wall_s`` — a histogram of per-call wall time.  Timing a concrete call
  blocks on the result (``block_until_ready``), which is exactly what an
  eager benchmark wants and why the probe is opt-in.
- ``gflops_per_s`` — a gauge of the LAST call's achieved rate
  (``flops / wall``), the measured companion of the analytic roofline.
"""

from __future__ import annotations

import time

_SINK = None      # MetricsRegistry | None; None = instrumentation off


def set_kernel_sink(registry) -> None:
    """Install (or clear, with None) the global kernel metrics sink."""
    global _SINK
    _SINK = registry


def get_kernel_sink():
    return _SINK


def _is_traced(arrays) -> bool:
    from jax.core import Tracer
    return any(isinstance(a, Tracer) for a in arrays)


class _Probe:
    __slots__ = ("name", "t0")

    def __init__(self, name: str):
        self.name = name
        self.t0 = time.perf_counter()

    def finish(self, out, *, flops: float = 0.0, arrays=()) -> None:
        """Record the call.  ``arrays`` are the operands + results whose
        concreteness decides traced-vs-executed and whose ``nbytes`` sum
        is the bytes-moved estimate."""
        reg = _SINK
        if reg is None:
            return
        leaves = [a for a in (*arrays, out) if a is not None]
        base = f"kernel.{self.name}"
        if _is_traced(leaves):
            reg.counter(f"{base}.traced_calls").inc()
            return
        try:
            import jax
            jax.block_until_ready(out)
        except Exception:
            pass
        wall = time.perf_counter() - self.t0
        nbytes = float(sum(getattr(a, "nbytes", 0) for a in leaves))
        reg.counter(f"{base}.calls").inc()
        reg.counter(f"{base}.flops").inc(max(float(flops), 0.0))
        reg.counter(f"{base}.bytes").inc(nbytes)
        reg.histogram(f"{base}.wall_s").observe(wall)
        if wall > 0.0 and flops > 0.0:
            reg.gauge(f"{base}.gflops_per_s").set(flops / wall / 1e9)


def kernel_probe(name: str):
    """Start a probe for one wrapper call; None when instrumentation is
    off (callers guard their single ``finish`` on that)."""
    if _SINK is None:
        return None
    return _Probe(name)
