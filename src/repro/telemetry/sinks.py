"""Line-oriented metric sinks (the home of the old ``utils.logging``).

:class:`MetricLogger` is the repo's one-line-per-step stdout logger,
folded into the telemetry subsystem: it still prints ``[name] {json}``
lines, but values now keep their JSON-native types (ints stay ints, bools
stay bools, lists stay lists — the old implementation coerced everything
non-float through ``str``, silently stringifying structured values in the
JSONL output), and an optional ``telemetry=`` mirror forwards numeric
values into the run's :class:`~repro.telemetry.metrics.MetricsRegistry`
as ``log.<name>.<key>`` gauges, so ad-hoc driver logs land in the same
``metrics.jsonl`` as the structured instruments.

``repro.utils.logging`` remains as a thin import shim for old call sites.
"""

from __future__ import annotations

import json
import sys
import time


def json_safe(v):
    """Coerce ``v`` to a JSON-native value, preserving its type.

    bool/int/float/str/None pass through; numpy scalars unwrap via
    ``item()``; arrays and sequences become lists (element-wise coerced);
    dicts coerce their values; anything else falls back to ``str``.
    """
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if hasattr(v, "item") and not hasattr(v, "__len__"):
        try:
            return json_safe(v.item())            # numpy / 0-d array scalar
        except (TypeError, ValueError):
            pass
    if hasattr(v, "tolist"):
        return json_safe(v.tolist())              # ndarray -> nested lists
    if isinstance(v, dict):
        return {str(k): json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [json_safe(x) for x in v]
    return str(v)


class MetricLogger:
    """Tiny structured logger (stdout, no deps)."""

    def __init__(self, name: str = "repro", stream=None, telemetry=None):
        self.name = name
        self.stream = stream or sys.stdout
        self.telemetry = telemetry
        self._t0 = time.time()

    def log(self, step: int | None = None, **metrics):
        rec = {"t": round(time.time() - self._t0, 3)}
        if step is not None:
            rec["step"] = step
        for k, v in metrics.items():
            rec[k] = json_safe(v)
        print(f"[{self.name}] " + json.dumps(rec), file=self.stream,
              flush=True)
        tel = self.telemetry
        if tel is not None and getattr(tel, "enabled", False):
            for k, v in rec.items():
                if k != "t" and isinstance(v, (bool, int, float)):
                    tel.metrics.gauge(f"log.{self.name}.{k}").set(float(v))
        return rec
