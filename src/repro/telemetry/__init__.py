"""Observability for the PHSFL stack: traces, metrics, manifests.

The paper's claims are about time, bits, and energy; this package makes
every one of them inspectable without perturbing a single number:

- **Trace export** (``telemetry.trace``): each wireless round's
  :class:`~repro.wireless.timeline.RoundTimeline` — compute chunks, uplink
  payloads with their HARQ retransmission attempts, downlink, crashes —
  becomes Chrome/Perfetto trace events, one track per client and per edge
  server, streamed to disk by :class:`TraceWriter`.  Open the file at
  https://ui.perfetto.dev or chrome://tracing.
- **Metrics** (``telemetry.metrics``): a stdlib-only typed registry of
  counters/gauges/histograms.  The scheduler registers participation,
  withdrawals/backfills, goodput-vs-retransmit bits, stale-bank
  depth/age, and per-phase energy; FedSim registers round wall time, eval
  accuracy, and live-vs-stale aggregation mass; the Pallas ops wrappers
  (via ``telemetry.kernels``) register call counts, wall time, bytes, and
  achieved FLOP/s.  Flushed as JSONL plus a run-end summary table.
- **Manifest** (``telemetry.manifest``): config hash, seeds, jax/device
  info, git SHA — who made this artifact.

:class:`Telemetry` bundles the three behind one handle.  The OFF state is
the default everywhere (``telemetry=None`` parameters, enforced by the
``telemetry-off-default`` reprolint rule) and is bit-inert: no file I/O,
no RNG, no arithmetic — the golden-report regressions run against it.
See the package README for file formats and knobs.
"""

from __future__ import annotations

import os

from repro.telemetry.kernels import (get_kernel_sink, kernel_probe,
                                     set_kernel_sink)
from repro.telemetry.manifest import (collect_manifest, config_hash,
                                      write_manifest)
from repro.telemetry.metrics import (Counter, Gauge, Histogram,
                                     MetricsRegistry)
from repro.telemetry.sinks import MetricLogger, json_safe
from repro.telemetry.trace import (TraceWriter, round_span_s,
                                   timeline_to_trace_events)

__all__ = [
    "Telemetry",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "TraceWriter", "timeline_to_trace_events", "round_span_s",
    "collect_manifest", "config_hash", "write_manifest",
    "MetricLogger", "json_safe",
    "kernel_probe", "set_kernel_sink", "get_kernel_sink",
]


class Telemetry:
    """One handle over the run's trace writer, metrics registry, manifest.

    ``Telemetry(out_dir)`` is the ON state: ``<out_dir>/trace.json``
    (streamed Chrome trace), ``<out_dir>/metrics.jsonl`` (one registry
    snapshot every ``metrics_every`` flushes), ``<out_dir>/manifest.json``
    (via :meth:`write_manifest`), ``<out_dir>/summary.txt`` (at
    :meth:`close`).  ``kernels=True`` additionally installs the metrics
    registry as the global Pallas-wrapper sink for the lifetime of the
    handle.

    ``Telemetry.disabled()`` is the OFF state every entry point defaults
    to: ``enabled`` is False and :meth:`record_round` / :meth:`flush` /
    :meth:`close` return immediately — instrumented code stays bit-inert.
    """

    def __init__(self, out_dir: str | None = None, *, trace: bool = True,
                 metrics_every: int = 1, kernels: bool = False,
                 _enabled: bool = True):
        self.enabled = bool(_enabled)
        self.out_dir = out_dir
        self.metrics = MetricsRegistry()
        self.metrics_every = max(int(metrics_every), 1)
        self.trace = None
        self._metrics_fh = None
        self._flushes = 0
        self._owns_kernel_sink = False
        self._closed = False
        if not self.enabled:
            return
        if out_dir is not None:
            os.makedirs(out_dir, exist_ok=True)
            if trace:
                self.trace = TraceWriter(os.path.join(out_dir,
                                                      "trace.json"))
            self._metrics_fh = open(os.path.join(out_dir, "metrics.jsonl"),
                                    "w")
        if kernels:
            set_kernel_sink(self.metrics)
            self._owns_kernel_sink = True

    _DISABLED = None

    @classmethod
    def disabled(cls) -> "Telemetry":
        """The shared OFF instance (the default of every entry point)."""
        if cls._DISABLED is None:
            cls._DISABLED = cls(_enabled=False)
        return cls._DISABLED

    # ------------------------------------------------------------ rounds --
    def record_round(self, report, timeline, *, es_assign=None,
                     deadline_s: float = float("inf"),
                     withdrawn: int = 0, backfilled: int = 0,
                     tx_j: float = 0.0, bank_depth: int = 0,
                     bank_age_max: int = 0) -> None:
        """One scheduler round: trace events + the scheduler's instruments
        (called by ``ParticipationScheduler.step`` when telemetry is on)."""
        if not self.enabled:
            return
        m = self.metrics
        rep = report
        m.counter("sched.rounds").inc()
        m.counter("sched.participants").inc(rep.num_participants)
        if rep.scheduled is not None:
            m.counter("sched.scheduled").inc(int(rep.scheduled.sum()))
        m.counter("sched.withdrawn").inc(int(withdrawn))
        m.counter("sched.backfilled").inc(int(backfilled))
        m.counter("sched.bits_moved").inc(float(rep.bits_tx))
        m.counter("sched.goodput_bits").inc(
            max(float(rep.bits_tx) - float(rep.retx_bits), 0.0))
        m.counter("sched.retx_bits").inc(float(rep.retx_bits))
        m.counter("energy.retx_j").inc(float(rep.retx_j))
        m.counter("energy.tx_j").inc(float(tx_j))
        if rep.compute_j is not None:
            m.counter("energy.compute_j").inc(float(rep.compute_j.sum()))
        m.gauge("sched.participation").set(
            rep.num_participants / max(len(rep.mask), 1))
        m.histogram("sched.round_time_s").observe(float(rep.round_time_s))
        if rep.stale_banked is not None:
            m.counter("stale.banked").inc(int(rep.stale_banked.sum()))
            m.counter("stale.delivered").inc(
                int((rep.stale_delivered > 0).sum()))
            m.counter("stale.dropped").inc(int(rep.stale_dropped.sum()))
            m.gauge("stale.bank_depth").set(int(bank_depth))
            m.gauge("stale.bank_age_max").set(int(bank_age_max))
        if rep.crashed is not None:
            m.counter("faults.crashed").inc(int(rep.crashed.sum()))
            m.counter("faults.failed").inc(int(rep.failed.sum()))
        if rep.es_down is not None:
            m.counter("faults.es_down_rounds").inc(int(rep.es_down.sum()))
        if self.trace is not None:
            self.trace.add_round(report, timeline, es_assign=es_assign,
                                 deadline_s=deadline_s)
        self.flush(step=int(rep.round_idx))

    # ------------------------------------------------------------- sinks --
    def flush(self, step: int | None = None, force: bool = False) -> None:
        """Append one metrics.jsonl snapshot every ``metrics_every`` calls
        (every call with ``force``)."""
        if not self.enabled or self._metrics_fh is None:
            return
        self._flushes += 1
        if force or (self._flushes - 1) % self.metrics_every == 0:
            self.metrics.flush_jsonl(self._metrics_fh, step=step)
            self._metrics_fh.flush()

    def write_manifest(self, *, config=None, seeds=None,
                       extra=None) -> dict | None:
        """Collect and (when an out_dir exists) write manifest.json."""
        if not self.enabled:
            return None
        man = collect_manifest(config=config, seeds=seeds, extra=extra)
        if self.out_dir is not None:
            write_manifest(os.path.join(self.out_dir, "manifest.json"), man)
        return man

    def summary(self) -> str:
        return self.metrics.summary_table()

    def close(self) -> str | None:
        """Final flush, summary.txt, trace finalization.  Idempotent;
        returns the summary table (None when disabled)."""
        if not self.enabled:
            return None
        if self._closed:
            return self.summary()
        self._closed = True
        if self._owns_kernel_sink and get_kernel_sink() is self.metrics:
            set_kernel_sink(None)
        table = self.summary()
        if self._metrics_fh is not None:
            self.metrics.flush_jsonl(self._metrics_fh, step=None)
            self._metrics_fh.close()
            self._metrics_fh = None
        if self.out_dir is not None:
            with open(os.path.join(self.out_dir, "summary.txt"), "w") as fh:
                fh.write(table + "\n")
        if self.trace is not None:
            self.trace.close()
        return table
