"""Run manifest: everything needed to attribute a metrics/trace artifact.

A manifest answers "what produced this file?": a stable hash of the run's
config, the seeds, the jax/device environment, the git SHA of the working
tree, and the exact command line.  ``collect_manifest`` gathers it (every
probe is best-effort — a missing git binary or an import-less environment
degrades to ``None``, never an exception), ``write_manifest`` puts it next
to the other telemetry outputs.
"""

from __future__ import annotations

import hashlib
import json
import os
import platform
import subprocess
import sys
import time


def config_hash(config) -> str | None:
    """sha256 over a canonical rendering of ``config``.

    Frozen dataclasses (every repo config) have deterministic ``repr``s, so
    two runs share a hash iff they share a config.  Dicts are rendered as
    sorted-keys JSON-ish reprs for the same stability.
    """
    if config is None:
        return None
    if isinstance(config, dict):
        text = json.dumps({k: repr(v) for k, v in config.items()},
                          sort_keys=True)
    else:
        text = repr(config)
    return hashlib.sha256(text.encode()).hexdigest()


def git_sha(cwd: str | None = None) -> str | None:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             cwd=cwd or os.getcwd(), capture_output=True,
                             text=True, timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def jax_info() -> dict | None:
    try:
        import jax
        devs = jax.devices()
        return {"version": jax.__version__,
                "backend": devs[0].platform if devs else None,
                "device_kind": devs[0].device_kind if devs else None,
                "device_count": len(devs)}
    except Exception:                     # no jax / no backend: still a run
        return None


def collect_manifest(*, config=None, seeds=None, extra=None) -> dict:
    """One JSON-safe dict describing this run's provenance."""
    man = {
        "config_hash": config_hash(config),
        "config_repr": None if config is None else repr(config),
        "seeds": seeds,
        "git_sha": git_sha(),
        "jax": jax_info(),
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "argv": list(sys.argv),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }
    if extra:
        man.update(extra)
    return man


def write_manifest(path: str, manifest: dict) -> str:
    with open(path, "w") as fh:
        json.dump(manifest, fh, indent=1, sort_keys=True, default=repr)
        fh.write("\n")
    return path
