"""Chrome/Perfetto trace export for wireless round timelines.

``timeline_to_trace_events`` is a pure function from one round's
:class:`repro.wireless.timeline.RoundTimeline` to Trace Event Format
records (the JSON chrome://tracing and https://ui.perfetto.dev both open):
every compute chunk, uplink payload (HARQ attempts individually, labelled
``uplink[p<payload>.a<attempt>]`` on fault rounds), and the downlink
becomes a complete ("ph": "X") event on its client's track, a crashed
client gets an instant crash marker at its cap, and timestamps are the
timeline's latency-free activity seconds times 1e6 (trace ``ts``/``dur``
are microseconds) offset by the round's start on the run clock.  The
conversion never rounds: ``ts == (t0 + start_s) * 1e6`` and
``dur == (end_s - start_s) * 1e6`` hold with EXACT float equality against
the scheduler's RoundTimeline (asserted in tests/test_telemetry.py —
compare in microsecond space; dividing back by 1e6 reintroduces binary
rounding).

:class:`TraceWriter` streams rounds to disk as they happen: it lays rounds
back-to-back on one run clock (each round advances the clock by
``max(round_time_s, last emitted segment end)``), adds one track per client
and per edge server, round-start instant markers, per-ES round/outage
spans, stale-delivery markers, and a deadline marker per finite-deadline
round.  The file is the Trace Event "JSON Array Format" written
incrementally — valid the moment the first event lands (the closing ``]``
is optional in both viewers), so a crashed run still leaves an openable
trace.

Track layout:

- pid 0 ``round markers``  — instant events ``round <r>`` / ``deadline``;
- pid 1 ``clients``        — tid u: client u's compute/uplink/downlink;
- pid 2 ``edge servers``   — tid b: one ``round <r>`` span per round
  (args: that ES's participant count), ``outage`` spans on down rounds.
"""

from __future__ import annotations

import json

import numpy as np

PID_MARKERS = 0
PID_CLIENTS = 1
PID_ES = 2


def _finite(*vals) -> bool:
    return all(np.isfinite(v) for v in vals)


def _us(t_s: float) -> float:
    return float(t_s) * 1e6


def timeline_to_trace_events(tl, round_idx: int, *, t0_s: float = 0.0,
                             clients=None, pid: int = PID_CLIENTS) -> list:
    """One round's per-client segments as Trace Event dicts.

    ``clients`` is an optional (U,) bool mask of tracks to emit (default:
    every client); pass ``RoundReport.scheduled`` to hide the clients that
    never transmitted.  Events are emitted in (client, kind, segment)
    order, so the output is deterministic for a given timeline.  Segments
    with non-finite endpoints (ideal-channel infinities) are skipped —
    they have no screen representation.
    """
    U = tl.comp_start.shape[0]
    sel = (np.ones(U, bool) if clients is None
           else np.asarray(clients, bool))
    n_comp = tl.comp_start.shape[1]
    n_tx = tl.tx_start.shape[1]
    events = []
    for u in range(U):
        if not sel[u]:
            continue
        common = {"pid": pid, "tid": int(u), "cat": "wireless"}
        for i in range(n_comp):
            s, e = float(tl.comp_start[u, i]), float(tl.comp_end[u, i])
            if not _finite(s, e):
                continue
            name = "compute" if n_comp == 1 else f"compute[{i}]"
            events.append({"name": name, "ph": "X", "ts": _us(t0_s + s),
                           "dur": _us(e - s),
                           "args": {"round": int(round_idx)}, **common})
        for i in range(n_tx):
            s, e = float(tl.tx_start[u, i]), float(tl.tx_end[u, i])
            bits = float(tl.tx_bits[u, i])
            # fault builders emit zero-width placeholder columns for
            # attempts a client never made — nothing to draw
            if (bits <= 0.0 and n_tx > 1) or not _finite(s, e):
                continue
            if tl.tx_payload is not None:
                p, a = int(tl.tx_payload[i]), int(tl.tx_attempt[i])
                name = (f"uplink[p{p}.a{a}]" if a > 0
                        else (f"uplink[p{p}]" if tl.tx_payload.max() > 0
                              else "uplink"))
                args = {"round": int(round_idx), "bits": bits,
                        "payload": p, "attempt": a, "retx": a > 0}
            else:
                name = "uplink" if n_tx == 1 else f"uplink[{i}]"
                args = {"round": int(round_idx), "bits": bits}
            events.append({"name": name, "ph": "X", "ts": _us(t0_s + s),
                           "dur": _us(e - s), "args": args, **common})
        s, e = float(tl.down_start[u]), float(tl.down_end[u])
        if _finite(s, e):
            events.append({"name": "downlink", "ph": "X",
                           "ts": _us(t0_s + s), "dur": _us(e - s),
                           "args": {"round": int(round_idx)}, **common})
        if tl.crashed is not None and bool(tl.crashed[u]):
            events.append({"name": "crash", "ph": "i", "s": "t",
                           "ts": _us(t0_s + float(tl.cap_s[u])),
                           "args": {"round": int(round_idx)}, **common})
    return events


def round_span_s(report, tl=None) -> float:
    """How far this round advances the run clock: the simulated round wall
    clock, stretched to cover any emitted segment that outlives it (a
    straggler's uplink keeps transmitting past the deadline on the
    timeline's activity clock), so back-to-back rounds never overlap."""
    span = float(report.round_time_s)
    if tl is not None and report.scheduled is not None:
        sel = np.asarray(report.scheduled, bool)
        if sel.any():
            ends = np.concatenate([tl.tx_end[sel].ravel(),
                                   tl.down_end[sel].ravel(),
                                   tl.comp_end[sel].ravel()])
            ends = ends[np.isfinite(ends)]
            if ends.size:
                span = max(span, float(ends.max()))
    return span if np.isfinite(span) else 0.0


class TraceWriter:
    """Streams trace events to one JSON-array file, round by round."""

    def __init__(self, path):
        self.path = str(path)
        self._fh = open(self.path, "w")
        self._fh.write("[\n")
        self._first = True
        self._named: set = set()
        self.clock_s = 0.0
        self.rounds = 0
        self._meta(PID_MARKERS, None, "round markers")
        self._closed = False

    # -------------------------------------------------------- low level --
    def add_events(self, events) -> None:
        for ev in events:
            self._fh.write(("" if self._first else ",\n") +
                           json.dumps(ev, sort_keys=True))
            self._first = False

    def _meta(self, pid: int, tid: int | None, name: str) -> None:
        """process_name / thread_name metadata, emitted once per track."""
        key = (pid, tid)
        if key in self._named:
            return
        self._named.add(key)
        if tid is None:
            self.add_events([{"name": "process_name", "ph": "M", "pid": pid,
                              "args": {"name": name}}])
        else:
            self._meta(pid, None, {PID_CLIENTS: "clients",
                                   PID_ES: "edge servers"}.get(pid, name))
            self.add_events([{"name": "thread_name", "ph": "M", "pid": pid,
                              "tid": tid, "args": {"name": name}}])

    # ------------------------------------------------------- round level --
    def add_round(self, report, tl, *, es_assign=None,
                  deadline_s: float = float("inf")) -> float:
        """Append one round (report + its timeline) at the current clock;
        advances and returns the new clock."""
        t0 = self.clock_s
        r = int(report.round_idx)
        self.add_events([{"name": f"round {r}", "ph": "i", "s": "g",
                          "ts": _us(t0), "pid": PID_MARKERS, "tid": 0,
                          "cat": "round",
                          "args": {"participants": report.num_participants,
                                   "round_time_s": float(
                                       report.round_time_s)}}])
        if np.isfinite(deadline_s):
            self.add_events([{"name": "deadline", "ph": "i", "s": "g",
                              "ts": _us(t0 + float(deadline_s)),
                              "pid": PID_MARKERS, "tid": 0, "cat": "round",
                              "args": {"round": r}}])
        sel = report.scheduled
        U = len(report.mask)
        for u in range(U):
            if sel is None or sel[u]:
                self._meta(PID_CLIENTS, u, f"client {u}")
        self.add_events(timeline_to_trace_events(
            tl, r, t0_s=t0, clients=sel))
        # stale-bank deliveries: not timeline segments (background pushes),
        # marked as instants on the delivering client's track
        if report.stale_delivered is not None:
            for u in np.flatnonzero(report.stale_delivered > 0):
                self._meta(PID_CLIENTS, int(u), f"client {int(u)}")
                self.add_events([{
                    "name": f"stale delivery (s={int(report.stale_delivered[u])})",
                    "ph": "i", "s": "t", "ts": _us(t0),
                    "pid": PID_CLIENTS, "tid": int(u), "cat": "wireless",
                    "args": {"round": r}}])
        span = round_span_s(report, tl)
        if es_assign is not None:
            ea = np.asarray(es_assign, int)
            live = np.asarray(report.mask) > 0
            for b in range(int(ea.max()) + 1):
                self._meta(PID_ES, b, f"ES {b}")
                down = (report.es_down is not None
                        and b < len(report.es_down)
                        and bool(report.es_down[b]))
                self.add_events([{
                    "name": "outage" if down else f"round {r}",
                    "ph": "X", "ts": _us(t0), "dur": _us(span),
                    "pid": PID_ES, "tid": b, "cat": "es",
                    "args": {"round": r,
                             "participants": int(live[ea == b].sum())}}])
        self.clock_s = t0 + span
        self.rounds += 1
        return self.clock_s

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._fh.write("\n]\n")
        self._fh.close()
