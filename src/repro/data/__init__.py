from repro.data.dirichlet import dirichlet_partition
from repro.data.synthetic import (
    SyntheticImageDataset,
    make_federated_image_data,
    synthetic_token_batch,
)
from repro.data.loader import ClientLoader, batch_iterator

__all__ = [
    "dirichlet_partition",
    "SyntheticImageDataset",
    "make_federated_image_data",
    "synthetic_token_batch",
    "ClientLoader",
    "batch_iterator",
]
