"""Dirichlet non-IID partitioner (the paper's data-distribution strategy [4]).

Samples of each class are split across clients with proportions drawn from a
symmetric Dirichlet(alpha): small alpha => highly skewed (each client sees few
classes), large alpha => near-IID.
"""

from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, num_clients: int, alpha: float,
                        seed: int = 0, min_per_client: int = 2) -> list[np.ndarray]:
    """Return per-client index arrays; every sample assigned exactly once.

    Retries the draw until every client has >= min_per_client samples so the
    downstream per-client fine-tuning/eval is well-defined (standard practice).
    """
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    n = len(labels)
    for _attempt in range(25):
        client_indices: list[list[int]] = [[] for _ in range(num_clients)]
        for c in classes:
            idx = np.flatnonzero(labels == c)
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(num_clients, alpha))
            # split points proportional to the draw
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for client, part in enumerate(np.split(idx, cuts)):
                client_indices[client].extend(part.tolist())
        sizes = np.array([len(ci) for ci in client_indices])
        if sizes.min() >= min_per_client:
            break
    else:
        # top-up fallback (standard practice at extreme skew): move random
        # samples from the largest clients to the starved ones.  The donor
        # must never be the starved client itself (argmax can land on it
        # when every client is tiny, which used to move samples nowhere and
        # loop forever), and sizes are recomputed after every single move so
        # a drained donor stops being picked.
        for u in range(num_clients):
            while len(client_indices[u]) < min_per_client:
                sizes = np.array([len(ci) if i != u else -1
                                  for i, ci in enumerate(client_indices)])
                donor = int(np.argmax(sizes))
                if sizes[donor] <= min_per_client:
                    raise ValueError(
                        f"cannot satisfy min_per_client={min_per_client}: "
                        f"{len(labels)} samples over {num_clients} clients")
                take = client_indices[donor].pop(
                    rng.integers(len(client_indices[donor])))
                client_indices[u].append(take)
    out = [np.array(sorted(ci), dtype=np.int64) for ci in client_indices]
    assert sum(len(o) for o in out) == n
    return out


def class_proportions(labels: np.ndarray, parts: list[np.ndarray],
                      num_classes: int) -> np.ndarray:
    """(num_clients, num_classes) per-client class shares of a partition."""
    labels = np.asarray(labels)
    prop = np.zeros((len(parts), num_classes))
    for u, idx in enumerate(parts):
        cnt = np.bincount(labels[idx], minlength=num_classes)
        prop[u] = cnt
    col = prop.sum(axis=0, keepdims=True)
    col[col == 0] = 1.0
    return prop / col          # share of each CLASS owned by each client


def partition_like(labels: np.ndarray, proportions: np.ndarray,
                   seed: int = 0) -> list[np.ndarray]:
    """Partition ``labels`` so client u receives ``proportions[u, c]`` of
    class c — used to give each client a TEST set matching its train
    distribution (the paper's setup: personalization targets the client's
    own distribution)."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    num_clients, num_classes = proportions.shape
    client_indices: list[list[int]] = [[] for _ in range(num_clients)]
    for c in range(num_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        cuts = (np.cumsum(proportions[:, c]) * len(idx)).astype(int)[:-1]
        for u, part in enumerate(np.split(idx, cuts)):
            client_indices[u].extend(part.tolist())
    return [np.array(sorted(ci), dtype=np.int64) for ci in client_indices]
