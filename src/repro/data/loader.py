"""Mini-batch iterators for the federated simulation and LM training."""

from __future__ import annotations

import numpy as np


class ClientLoader:
    """Cyclic mini-batch sampler over one client's local dataset.

    The paper's Step 3.2: each client uniformly samples N-sized mini-batches;
    sampled indices are offloaded to the ES along with the activations (the
    ES holds the labels).
    """

    def __init__(self, x: np.ndarray, y: np.ndarray, batch_size: int, seed: int):
        assert len(x) == len(y) and len(x) > 0
        self.x, self.y = x, y
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)

    def next_batch(self):
        n = len(self.x)
        idx = self.rng.choice(n, size=min(self.batch_size, n),
                              replace=n < self.batch_size)
        return self.x[idx], self.y[idx], idx


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0,
                   epochs: int | None = None):
    """Epoch-shuffled full passes (for the centralized Genie baseline)."""
    rng = np.random.default_rng(seed)
    epoch = 0
    while epochs is None or epoch < epochs:
        perm = rng.permutation(len(x))
        for i in range(0, len(x) - batch_size + 1, batch_size):
            sl = perm[i:i + batch_size]
            yield x[sl], y[sl]
        epoch += 1
