"""Synthetic datasets.

This container has no CIFAR-10; the paper-validation experiments use a
class-conditional synthetic image dataset that preserves the *structure* that
PHSFL exploits: all classes share low-level feature statistics (the paper's
"many of the features have similar attributes"), while class identity lives
in a lower-dimensional signal subspace.  Accuracy numbers are therefore not
directly comparable to CIFAR-10, but every distributional claim
(generalized vs personalized, Dir(0.1) vs Dir(0.5), PHSFL vs HSFL) is
evaluated on identical footing across algorithms.

Also provides synthetic token streams for the LM-scale smoke tests.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.dirichlet import dirichlet_partition


@dataclass
class SyntheticImageDataset:
    x_train: np.ndarray          # (N, H, W, C) float32
    y_train: np.ndarray          # (N,) int32
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int


def make_image_dataset(num_classes: int = 10, image_size: int = 32,
                       channels: int = 3, train_per_class: int = 500,
                       test_per_class: int = 100, signal_rank: int = 24,
                       noise: float = 0.35, seed: int = 0) -> SyntheticImageDataset:
    """Class-conditional Gaussian images with a shared feature basis.

    x = B @ z_c + eps, where B is a (D, signal_rank) basis shared across all
    classes (the "similar attributes"), z_c ~ N(mu_c, I) a class-specific
    latent, eps pixel noise.  A linear probe on the shared features separates
    classes well; pixels alone do not — mirroring body-learns-features /
    head-learns-classes.
    """
    rng = np.random.default_rng(seed)
    d = image_size * image_size * channels
    basis = rng.normal(0, 1.0 / np.sqrt(signal_rank), size=(d, signal_rank))
    mus = rng.normal(0, 1.6, size=(num_classes, signal_rank))

    def sample(per_class: int, salt: int):
        r = np.random.default_rng(seed + salt)
        xs, ys = [], []
        for c in range(num_classes):
            z = r.normal(0, 1, size=(per_class, signal_rank)) + mus[c]
            x = z @ basis.T + r.normal(0, noise, size=(per_class, d))
            xs.append(x)
            ys.append(np.full(per_class, c))
        x = np.concatenate(xs).astype(np.float32)
        y = np.concatenate(ys).astype(np.int32)
        perm = r.permutation(len(y))
        x = x[perm].reshape(-1, image_size, image_size, channels)
        return x, y[perm]

    x_train, y_train = sample(train_per_class, salt=1)
    x_test, y_test = sample(test_per_class, salt=2)
    return SyntheticImageDataset(x_train, y_train, x_test, y_test, num_classes)


@dataclass
class FederatedImageData:
    dataset: SyntheticImageDataset
    train_indices: list[np.ndarray]   # per client
    test_indices: list[np.ndarray]    # per client
    alpha: float

    @property
    def num_clients(self) -> int:
        return len(self.train_indices)

    def client_train(self, u: int):
        idx = self.train_indices[u]
        return self.dataset.x_train[idx], self.dataset.y_train[idx]

    def client_test(self, u: int):
        idx = self.test_indices[u]
        return self.dataset.x_test[idx], self.dataset.y_test[idx]

    def client_weights(self) -> np.ndarray:
        """alpha_u proportional to |D_u| (paper Eq. 4)."""
        sizes = np.array([len(i) for i in self.train_indices], dtype=np.float64)
        return sizes / sizes.sum()


def make_federated_image_data(num_clients: int, alpha: float, *,
                              num_classes: int = 10, image_size: int = 32,
                              train_per_class: int = 500,
                              test_per_class: int = 100,
                              seed: int = 0) -> FederatedImageData:
    """Paper Sec. V-A setup: both train and test are Dirichlet-partitioned with
    the *same* per-client class profile (so personalization has a target)."""
    from repro.data.dirichlet import class_proportions, partition_like

    ds = make_image_dataset(num_classes=num_classes, image_size=image_size,
                            train_per_class=train_per_class,
                            test_per_class=test_per_class, seed=seed)
    tr = dirichlet_partition(ds.y_train, num_clients, alpha, seed=seed + 10)
    # each client's TEST set matches its TRAIN class profile (the paper's
    # personalization setup: w_u^K is evaluated on the client's own
    # distribution)
    prop = class_proportions(ds.y_train, tr, num_classes)
    te = partition_like(ds.y_test, prop, seed=seed + 11)
    return FederatedImageData(ds, tr, te, alpha)


def synthetic_token_batch(rng: np.ndarray | int, batch: int, seq_len: int,
                          vocab: int) -> dict[str, np.ndarray]:
    """Markov-ish synthetic token stream for LM smoke tests."""
    r = np.random.default_rng(rng)
    base = r.integers(0, vocab, size=(batch, seq_len), dtype=np.int32)
    # induce local correlation: every other token repeats previous +1 mod vocab
    base[:, 1::2] = (base[:, 0:-1:2] + 1) % vocab
    return {"tokens": base, "labels": np.roll(base, -1, axis=1)}
