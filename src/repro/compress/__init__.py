"""Compression subsystem: codecs for the split-learning wire payloads.

The paper's Remark-1 accounting (``repro.core.comm``) charges every
cut-layer activation (o_fp, uplink), backprop gradient (o_bp, downlink),
and client-block offload at full ``(omega+1)``-bit precision.  This package
makes those bits configurable: a :class:`~repro.compress.codecs.Codec` has
a **numerics path** (``encode``/``decode``/``apply`` — jit-able JAX
transforms applied in the literal dataflow by ``repro.core.fedsim``) and a
**byte path** (``payload_bits(n_elements)`` — what ``CommModel`` charges,
and therefore what the :class:`~repro.wireless.cutter.CutController` and
:class:`~repro.wireless.scheduler.ParticipationScheduler` optimize over).

Codec -> literature map
=======================

- ``IdentityCodec`` (``"fp32"``): the paper's own accounting; bit-identical
  to the pre-compression simulator in both paths (the regression anchor).
- ``UniformQuantCodec`` (``"int8"``/``"int4"``): per-tensor absmax-scaled
  symmetric uniform quantization with stochastic rounding — the scalar
  limit of FedLite's (product/vector) quantization of smashed data
  [arXiv:2204.01632], which reports ~490x cut-layer payload compression at
  <1% accuracy loss; the hot per-minibatch path runs the fused Pallas
  kernel in ``repro.kernels.quantize``.
- ``TopKCodec`` (``"topk"``): magnitude sparsification with explicit
  ceil(log2 n) index-bit accounting — the classic gradient-sparsification
  baseline FedLite compares against, applied to the smashed payloads.
- ``Fp8Codec`` (``"fp8"``): per-tensor-scaled float8 (e4m3) cast — the
  low-precision-float analogue HierSFL's client-edge quantized offloading
  approximates [arXiv:2403.16050, perturbed/compressed smashed data at the
  client-edge hop].

``LinkCodecs`` picks one codec per payload direction (activations up,
gradients down, offloads at the aggregation boundary), so asymmetric
schemes (e.g. int8 up, fp32 down) are one constructor call.
"""

from repro.compress.codecs import (CODEC_NAMES, Codec, Fp8Codec,
                                   IdentityCodec, LinkCodecs, TopKCodec,
                                   UniformQuantCodec, get_codec, link_codecs)

__all__ = [
    "CODEC_NAMES", "Codec", "IdentityCodec", "UniformQuantCodec",
    "TopKCodec", "Fp8Codec", "LinkCodecs", "get_codec", "link_codecs",
]
