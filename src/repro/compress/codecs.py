"""The codec family: identity, uniform int quantizer, top-k, fp8 cast.

Every codec is a frozen (hashable) dataclass so it can be a field of the
frozen ``CommModel`` and be closed over by jitted step functions as static
data.  Each exposes two faces:

- the **numerics path** — ``encode``/``decode`` (and their fused
  composition ``apply``) are jit-able JAX transforms that simulate the
  lossy channel in the literal split-learning dataflow.  Stochastic
  rounding is driven by explicit PRNG keys (``repro.utils.prng``-style),
  so runs are reproducible and deterministic codecs simply ignore the key;
- the **byte path** — ``payload_bits(n_elements)`` is what one encoded
  tensor costs on the wire, which is what ``repro.core.comm`` charges
  instead of the hardcoded ``(omega + 1)`` bits per element.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Codec:
    """Common API: a lossy tensor channel with exact byte accounting."""

    name = "codec"

    def __post_init__(self):
        # codecs ride inside the frozen CommModel and are closed over by
        # jitted step functions as static data — every field must hash NOW,
        # not fail later inside jax's static-arg machinery with a message
        # that points nowhere near the offending codec
        for f in dataclasses.fields(self):
            value = getattr(self, f.name)
            try:
                hash(value)
            except TypeError:
                raise TypeError(
                    f"{type(self).__name__}.{f.name} must be hashable "
                    f"(codecs are static data under jit); got "
                    f"{type(value).__name__}: {value!r}") from None

    def payload_bits(self, n_elements: int) -> int:
        raise NotImplementedError

    def encode(self, key, x):
        raise NotImplementedError

    def decode(self, enc):
        raise NotImplementedError

    def apply(self, key, x):
        """The round trip the receiver sees: decode(encode(x))."""
        return self.decode(self.encode(key, x))


@dataclass(frozen=True)
class IdentityCodec(Codec):
    """Full-precision passthrough: today's (omega+1)-bit accounting, and a
    numerics path that is bit-identical to no codec at all (the regression
    anchor for the whole subsystem).

    ``bits_per_element=None`` (the default) DEFERS the byte accounting to
    the consuming ``CommModel``'s own ``omega+1`` — so one identity codec
    is exact for the CNN (omega=32) and the LM (omega=16) alike; pin a
    width explicitly only for standalone payload math."""

    bits_per_element: int | None = None

    name = "fp32"

    def payload_bits(self, n_elements: int) -> int:
        if self.bits_per_element is None:
            raise ValueError(
                "this IdentityCodec defers its width to the comm model's "
                "omega; construct it with an explicit bits_per_element (or "
                "get_codec('fp32', omega=...)) for standalone payload math")
        return n_elements * self.bits_per_element

    def encode(self, key, x):
        return (x,)

    def decode(self, enc):
        return enc[0]

    def apply(self, key, x):
        return x


@dataclass(frozen=True)
class UniformQuantCodec(Codec):
    """Symmetric uniform quantizer to ``bits``-bit integers with per-tensor
    absmax scaling and stochastic rounding (the FedLite-style smashed-data
    quantizer).  The hot ``apply`` path is the fused Pallas kernel in
    ``repro.kernels.quantize``; ``encode``/``decode`` expose the integer
    payload itself.  int4 values travel packed (4 bits each on the wire)
    but are stored in int8 lanes on chip."""

    bits: int = 8
    stochastic: bool = True
    scale_bits: int = 32             # one fp32 scale per tensor
    interpret: bool = True           # Pallas interpret-mode fallback

    def __post_init__(self):
        super().__post_init__()
        # the integer payload lives in int8 lanes (encode) and the kernel
        # clips to [-qmax, qmax]; wider widths would silently wrap
        if not 2 <= self.bits <= 8:
            raise ValueError(f"uniform quantizer supports 2..8 bits, got "
                             f"{self.bits}")

    @property
    def name(self) -> str:           # type: ignore[override]
        return f"int{self.bits}"

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    def payload_bits(self, n_elements: int) -> int:
        return n_elements * self.bits + self.scale_bits

    def _uniforms(self, key, shape):
        if self.stochastic:
            return jax.random.uniform(key, shape, jnp.float32)
        return jnp.full(shape, 0.5, jnp.float32)

    def encode(self, key, x):
        from repro.kernels.quantize.ops import tensor_scale
        scale = tensor_scale(x, self.qmax)[0, 0]
        inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
        q = jnp.floor(x.astype(jnp.float32) * inv + self._uniforms(key, x.shape))
        q = jnp.clip(q, -self.qmax, self.qmax).astype(jnp.int8)
        return (q, scale)

    def decode(self, enc):
        q, scale = enc
        return q.astype(jnp.float32) * scale

    def apply(self, key, x):
        from repro.kernels.quantize.ops import quantize_dequantize
        return quantize_dequantize(x, key, bits=self.bits,
                                   stochastic=self.stochastic,
                                   interpret=self.interpret)


@dataclass(frozen=True)
class TopKCodec(Codec):
    """Magnitude top-k sparsification over the flattened tensor: ship the
    k = max(1, frac * n) largest-|x| values plus their indices; the receiver
    scatters into zeros.  Index bits are charged at ceil(log2 n) each —
    sparsity is only a win once value+index bits undercut dense payloads."""

    frac: float = 0.05
    value_bits: int = 32

    @property
    def name(self) -> str:           # type: ignore[override]
        return f"topk{self.frac:g}"

    def k_for(self, n_elements: int) -> int:
        return max(1, int(n_elements * self.frac))

    def payload_bits(self, n_elements: int) -> int:
        k = self.k_for(n_elements)
        idx_bits = math.ceil(math.log2(max(n_elements, 2)))
        return k * (self.value_bits + idx_bits)

    def encode(self, key, x):
        flat = x.reshape(-1)
        k = self.k_for(flat.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(flat.astype(jnp.float32)), k)
        return (flat[idx], idx, x.shape)

    def decode(self, enc):
        vals, idx, shape = enc
        n = math.prod(shape)
        return jnp.zeros(n, vals.dtype).at[idx].set(vals).reshape(shape)


@dataclass(frozen=True)
class Fp8Codec(Codec):
    """Per-tensor-scaled cast to float8 (e4m3): x -> (x / s) as fp8, with
    s = absmax / 448 so the tensor spans the fp8 dynamic range.  8 bits per
    element plus one fp32 scale; rounding is the dtype cast's
    (deterministic), so the key is ignored."""

    scale_bits: int = 32

    name = "fp8"

    def payload_bits(self, n_elements: int) -> int:
        return n_elements * 8 + self.scale_bits

    @staticmethod
    def _dtype():
        dt = getattr(jnp, "float8_e4m3fn", None)
        if dt is None:                       # gate: very old jax builds
            raise NotImplementedError(
                "this jax build has no float8_e4m3fn dtype; use the int8 "
                "codec instead")
        return dt

    def encode(self, key, x):
        dt = self._dtype()
        absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
        scale = jnp.where(absmax > 0, absmax / 448.0, 1.0)
        return ((x.astype(jnp.float32) / scale).astype(dt), scale)

    def decode(self, enc):
        y, scale = enc
        return y.astype(jnp.float32) * scale


# --------------------------------------------------------------------------
@dataclass(frozen=True)
class LinkCodecs:
    """Which codec each of the three Remark-1 payloads travels through.
    ``None`` means the legacy full-precision ``(omega+1)``-bit path."""

    activations: Codec | None = None   # cut-layer o_fp, client -> ES
    gradients: Codec | None = None     # cut-layer o_bp, ES -> client
    offload: Codec | None = None       # client-block params at round edges

    def __post_init__(self):
        # same static-data contract as Codec.__post_init__: the triple is a
        # CommModel field and a jit static arg, so reject non-codec (and
        # thus possibly unhashable) payloads at construction
        for f in dataclasses.fields(self):
            value = getattr(self, f.name)
            if value is not None and not isinstance(value, Codec):
                raise TypeError(
                    f"LinkCodecs.{f.name} must be a Codec or None (static "
                    f"data under jit); got {type(value).__name__}: "
                    f"{value!r}")

    def is_lossless(self) -> bool:
        return all(c is None or isinstance(c, IdentityCodec)
                   for c in (self.activations, self.gradients, self.offload))


CODEC_NAMES = ("fp32", "int8", "int4", "topk", "fp8")


def get_codec(name: str, *, bits: int | None = None, topk_frac: float = 0.05,
              omega: int | None = None, stochastic: bool = True,
              interpret: bool = True) -> Codec:
    """Codec presets by name (``bits`` overrides the int quantizer width).

    ``omega`` only pins the identity codec's width; left None, the identity
    codec defers to whatever ``omega`` the consuming CommModel carries."""
    if name in ("fp32", "identity"):
        return IdentityCodec(
            bits_per_element=None if omega is None else omega + 1)
    if name in ("int8", "int4"):
        return UniformQuantCodec(bits=bits or int(name[3:]),
                                 stochastic=stochastic, interpret=interpret)
    if name == "topk":
        return TopKCodec(frac=topk_frac)
    if name == "fp8":
        return Fp8Codec()
    raise ValueError(f"unknown codec {name!r}; one of {CODEC_NAMES}")


def link_codecs(name: str, **kw) -> LinkCodecs:
    """The same preset codec on all three links (the common scenario)."""
    c = get_codec(name, **kw)
    return LinkCodecs(activations=c, gradients=c, offload=c)
