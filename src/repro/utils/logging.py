"""Tiny structured logger (stdout, no deps)."""

from __future__ import annotations

import json
import sys
import time


class MetricLogger:
    def __init__(self, name: str = "repro", stream=None):
        self.name = name
        self.stream = stream or sys.stdout
        self._t0 = time.time()

    def log(self, step: int | None = None, **metrics):
        rec = {"t": round(time.time() - self._t0, 3)}
        if step is not None:
            rec["step"] = step
        for k, v in metrics.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                rec[k] = str(v)
        print(f"[{self.name}] " + json.dumps(rec), file=self.stream, flush=True)
        return rec
