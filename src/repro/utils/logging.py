"""Back-compat shim: MetricLogger moved to ``repro.telemetry.sinks``.

The logger now preserves JSON-native value types (the old version coerced
every non-float through ``str``) and can mirror numeric values into a
:class:`repro.telemetry.Telemetry` metrics registry.  Import from
``repro.telemetry`` in new code.
"""

from __future__ import annotations

from repro.telemetry.sinks import MetricLogger, json_safe

__all__ = ["MetricLogger", "json_safe"]
