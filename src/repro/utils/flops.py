"""Analytical FLOP accounting (used for the MODEL_FLOPS roofline term)."""

from __future__ import annotations


def matmul_flops(m: int, k: int, n: int) -> int:
    """FLOPs of an (m,k) @ (k,n) matmul (multiply-adds counted as 2)."""
    return 2 * m * k * n


def dense_model_flops(num_params: int, num_tokens: int) -> int:
    """The standard 6*N*D training-FLOPs estimate (fwd 2ND + bwd 4ND)."""
    return 6 * num_params * num_tokens


def forward_model_flops(num_params: int, num_tokens: int) -> int:
    """2*N*D forward-only estimate (prefill / decode)."""
    return 2 * num_params * num_tokens


def attention_flops(batch: int, q_len: int, kv_len: int, num_heads: int,
                    head_dim: int, *, backward: bool = False) -> int:
    """QK^T + AV flops for (possibly rectangular) attention."""
    f = 2 * batch * num_heads * q_len * kv_len * head_dim * 2  # qk and av
    return f * 3 if backward else f
