"""Analytical FLOP accounting (used for the MODEL_FLOPS roofline term)."""

from __future__ import annotations


def matmul_flops(m: int, k: int, n: int) -> int:
    """FLOPs of an (m,k) @ (k,n) matmul (multiply-adds counted as 2)."""
    return 2 * m * k * n


def dense_model_flops(num_params: int, num_tokens: int) -> int:
    """The standard 6*N*D training-FLOPs estimate (fwd 2ND + bwd 4ND)."""
    return 6 * num_params * num_tokens


def forward_model_flops(num_params: int, num_tokens: int) -> int:
    """2*N*D forward-only estimate (prefill / decode)."""
    return 2 * num_params * num_tokens


def attention_flops(batch: int, q_len: int, kv_len: int, num_heads: int,
                    head_dim: int, *, backward: bool = False) -> int:
    """QK^T + AV flops for (possibly rectangular) attention."""
    f = 2 * batch * num_heads * q_len * kv_len * head_dim * 2  # qk and av
    return f * 3 if backward else f


def conv2d_flops(batch: int, out_h: int, out_w: int, kernel: int,
                 cin: int, cout: int) -> int:
    """FLOPs of one 2-D convolution producing a (batch, out_h, out_w, cout)
    map from a kernel x kernel window over cin channels (multiply-adds as 2).
    Unlike a dense layer, the weights are reused at every output position,
    so this is NOT 2 * params * batch — which is why the wireless device
    model cannot price the CNN's client block from Z_0 alone."""
    return 2 * batch * out_h * out_w * kernel * kernel * cin * cout


def dense_layer_flops(batch: int, din: int, dout: int) -> int:
    """Forward FLOPs of a (batch, din) @ (din, dout) dense layer."""
    return matmul_flops(batch, din, dout)


def training_flops(forward_flops: int) -> int:
    """fwd + bwd at the standard 1:2 ratio (same rule as the 6ND estimate:
    2ND forward, 4ND backward)."""
    return 3 * forward_flops
