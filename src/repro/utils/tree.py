"""Pytree utilities used across the framework.

The framework represents model parameters as nested dicts of ``jnp.ndarray``
leaves.  Logical-axis metadata lives in a *parallel* tree whose leaves are
tuples of axis names (``("embed", "mlp")``); helpers here treat tuples as
leaves where needed.
"""

from __future__ import annotations

import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _is_axes_leaf(x: Any) -> bool:
    """Leaves of an axes tree are tuples of (str | None)."""
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def axes_leaf(x: Any) -> bool:  # public alias
    return _is_axes_leaf(x)


def path_str(path) -> str:
    """Render a jax key path as 'a/b/0/c'."""
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_paths(tree: PyTree, is_leaf: Callable[[Any], bool] | None = None) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    return [path_str(p) for p, _ in flat]


def map_with_path(fn: Callable[[str, Any], Any], tree: PyTree,
                  is_leaf: Callable[[Any], bool] | None = None) -> PyTree:
    """tree_map where fn receives (path_string, leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: fn(path_str(p), x), tree, is_leaf=is_leaf)


def mask_by_path(tree: PyTree, patterns: list[str],
                 is_leaf: Callable[[Any], bool] | None = None) -> PyTree:
    """Boolean mask tree: True where the leaf path matches any regex pattern."""
    regs = [re.compile(p) for p in patterns]
    return map_with_path(
        lambda path, _: any(r.search(path) for r in regs), tree, is_leaf=is_leaf)


def tree_size(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def merge_trees(mask: PyTree, a: PyTree, b: PyTree) -> PyTree:
    """Select leaf from ``a`` where mask is True else from ``b``."""
    return jax.tree.map(lambda m, x, y: x if m else y, mask, a, b)


def select_tree(mask: PyTree, tree: PyTree) -> PyTree:
    """Keep only leaves where mask is True (others replaced by None subtree).

    Returns a tree of the same structure with non-selected leaves set to None;
    useful for reporting.
    """
    return jax.tree.map(lambda m, x: x if m else None, mask, tree)


def tree_allfinite(tree: PyTree):
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)
              if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)]
    if not leaves:
        return jnp.asarray(True)
    return jnp.stack(leaves).all()


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: x + y, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_weighted_sum(trees: list[PyTree], weights) -> PyTree:
    """sum_i weights[i] * trees[i]  (the FedAvg aggregation primitive)."""
    assert len(trees) == len(weights) and trees, "need >=1 tree"
    out = tree_scale(trees[0], weights[0])
    for t, w in zip(trees[1:], weights[1:]):
        out = tree_add(out, tree_scale(t, w))
    return out


def tree_l2_distance(a: PyTree, b: PyTree):
    sq = jax.tree.map(lambda x, y: jnp.sum((x.astype(jnp.float32) - y.astype(jnp.float32)) ** 2), a, b)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))
