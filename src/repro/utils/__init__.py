from repro.utils.tree import (
    tree_paths,
    path_str,
    map_with_path,
    mask_by_path,
    tree_size,
    tree_bytes,
    merge_trees,
    select_tree,
    tree_allfinite,
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_weighted_sum,
    tree_l2_distance,
)
from repro.utils.prng import key_iter, fold_in_str
from repro.utils.flops import matmul_flops, dense_model_flops

__all__ = [
    "tree_paths",
    "path_str",
    "map_with_path",
    "mask_by_path",
    "tree_size",
    "tree_bytes",
    "merge_trees",
    "select_tree",
    "tree_allfinite",
    "tree_zeros_like",
    "tree_add",
    "tree_scale",
    "tree_weighted_sum",
    "tree_l2_distance",
    "key_iter",
    "fold_in_str",
    "matmul_flops",
    "dense_model_flops",
]
