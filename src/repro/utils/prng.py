"""PRNG helpers."""

from __future__ import annotations

import jax


def key_iter(seed: int):
    """Infinite iterator of fresh PRNG keys."""
    key = jax.random.PRNGKey(seed)
    while True:
        key, sub = jax.random.split(key)
        yield sub


def fold_in_str(key, name: str):
    """Deterministically derive a key from a string (stable across runs)."""
    h = 0
    for ch in name:
        h = (h * 131 + ord(ch)) % (2**31 - 1)
    return jax.random.fold_in(key, h)
